//! END-TO-END VALIDATION DRIVER (the deliverable the system prompt calls
//! out): exercises the full three-layer stack on the real trained
//! artifacts and reports the paper's headline metrics.
//!
//! Pipeline (python only at build time — `make train && make artifacts`):
//!   train (jax)  →  AOT HLO + weight blobs  →  THIS BINARY:
//!     1. INT8-calibrate + StruM-transform every layer (L3 quantizer);
//!     2. encode/decode round-trip through the §IV-D codec;
//!     3. evaluate top-1 through PJRT: float, INT8 baseline, sparsity /
//!        DLIQ / MIP2Q at p = 0.5 — the Pallas kernel head included;
//!     4. cycle-simulate the network on the FlexNN model (2× check);
//!     5. price the DPU variants from the simulated activity (Fig. 13);
//!     6. print the headline verdict (accuracy loss < 1 %, PE power −31…34 %).
//!
//! Run: `cargo run --release --example e2e_pipeline -- [net] [limit]`
//! Training loss curves for the same run live in artifacts/train_log.json
//! and are summarized in EXPERIMENTS.md.

use std::path::Path;
use strum_dpu::encode::{decode_layer, encode_layer};
use strum_dpu::hw::dpu::DpuConfig;
use strum_dpu::hw::power::power;
use strum_dpu::hw::PeVariant;
use strum_dpu::model::eval::{evaluate, transform_network, EvalConfig};
use strum_dpu::model::import::{DataSet, NetWeights};
use strum_dpu::quant::Method;
use strum_dpu::runtime::Runtime;
use strum_dpu::sim::config::SimConfig;
use strum_dpu::sim::driver::simulate_network;
use strum_dpu::sim::SimMode;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let net = args.first().cloned().unwrap_or_else(|| "mini_resnet_a".into());
    let limit: Option<usize> = args.get(1).and_then(|s| s.parse().ok());
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        dir.join("hlo").exists(),
        "artifacts missing — run `make train artifacts` first"
    );

    println!("=== StruM end-to-end pipeline [{}] ===\n", net);
    let weights = NetWeights::load(dir, &net)?;
    println!(
        "loaded {}: {} quantizable layers, {} params, float top-1 {:.2}%",
        net,
        weights.manifest.layers.len(),
        weights.blob.len(),
        weights.manifest.eval_top1_float * 100.0
    );

    // --- 1+2: quantize + codec round-trip ---------------------------------
    let cfg_m = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
    let transformed = transform_network(&weights, &cfg_m)?;
    let mut bits = 0usize;
    let mut elems = 0usize;
    for s in &transformed {
        s.check_structure().map_err(anyhow::Error::msg)?;
        let enc = encode_layer(s);
        let dec = decode_layer(&enc)?;
        anyhow::ensure!(dec.values == s.values, "codec mismatch in {}", s.name);
        bits += enc.bits;
        elems += enc.padded_elems();
    }
    println!(
        "quantized + encoded {} weights: r = {:.4} (Eq.1 predicts 0.8750 at p=0.5,q=4)\n",
        elems,
        bits as f64 / (8.0 * elems as f64)
    );

    // --- 3: accuracy through PJRT (Pallas-kernel head inside the HLO),
    // falling back to the native integer engine when the build has no
    // PJRT runtime (note: the native path quantizes activations with a
    // dynamic scale even at act=false, so "float" becomes near-float).
    let rt = match Runtime::cpu() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            Some(rt)
        }
        Err(e) => {
            println!("PJRT unavailable ({}); evaluating on the native backend", e);
            None
        }
    };
    let data = DataSet::load(dir, "eval")?;
    let point = |name: &str, method: Method, p: f64, act: bool| -> anyhow::Result<f64> {
        let cfg = EvalConfig {
            act_quant: act,
            limit,
            ..EvalConfig::paper(method, p)
        };
        let r = match &rt {
            Some(rt) => evaluate(rt, dir, &net, &data, &cfg)?,
            None => strum_dpu::model::eval::evaluate_native(dir, &net, &data, &cfg)?,
        };
        println!("  {:<26} top-1 {:>6.2}%  (n={})", name, r.top1 * 100.0, r.n);
        Ok(r.top1)
    };
    let float_acc = point("float (no quant)", Method::Baseline, 0.0, false)?;
    let base = point("INT8 baseline", Method::Baseline, 0.0, true)?;
    let sp = point("structured sparsity p=.5", Method::StructuredSparsity, 0.5, true)?;
    let dl = point("DLIQ q=4 p=.5", Method::Dliq { q: 4 }, 0.5, true)?;
    let mp = point("MIP2Q L=7 p=.5", Method::Mip2q { l_max: 7 }, 0.5, true)?;
    let mp5 = point("MIP2Q L=5 p=.5", Method::Mip2q { l_max: 5 }, 0.5, true)?;

    // --- 4: cycle simulation ----------------------------------------------
    let layers: Vec<_> = weights
        .manifest
        .layers
        .iter()
        .zip(transform_network(&weights, &cfg_m)?)
        .map(|(lm, s)| (lm.shape_for_sim(), s))
        .collect();
    let base_layers: Vec<_> = weights
        .manifest
        .layers
        .iter()
        .zip(transform_network(&weights, &EvalConfig::paper(Method::Baseline, 0.0))?)
        .map(|(lm, s)| (lm.shape_for_sim(), s))
        .collect();
    let (_, dense_act) = simulate_network(
        &base_layers,
        &SimConfig::flexnn(SimMode::Int8Dense, None),
        0.7,
        0,
    );
    let (_, strum_act) = simulate_network(
        &layers,
        &SimConfig::flexnn(SimMode::StrumPerf, Some(Method::Mip2q { l_max: 7 })),
        0.7,
        0,
    );
    println!(
        "\nsim: dense {} cycles vs StruM-perf {} cycles  ({:.2}x, paper guarantees 2x)",
        dense_act.cycles,
        strum_act.cycles,
        dense_act.cycles as f64 / strum_act.cycles.max(1) as f64
    );

    // --- 5: power from simulated activity ----------------------------------
    let dpu = DpuConfig::flexnn_16x16();
    let (_, static_act) = simulate_network(
        &layers,
        &SimConfig::flexnn(SimMode::StrumStatic, Some(Method::Mip2q { l_max: 7 })),
        0.7,
        0,
    );
    let p_base = power(PeVariant::BaselineInt8, &dense_act, &dpu);
    let p_strum = power(PeVariant::StaticMip2q { l_max: 7 }, &static_act, &dpu);
    let pe_save = (1.0 - p_strum.pe_level() / p_base.pe_level()) * 100.0;
    let dpu_save = (1.0 - p_strum.dpu_level() / p_base.dpu_level()) * 100.0;
    println!(
        "power (sim activity): PE-level saving {:+.1}% (paper 31-34), DPU-level {:+.1}% (paper 10-12)",
        pe_save, dpu_save
    );

    // --- 6: verdict ---------------------------------------------------------
    println!("\n=== headline checks ===");
    let ok1 = (base - dl) < 0.01 && (base - mp) < 0.01;
    println!(
        "[{}] DLIQ/MIP2Q p=0.5 within 1% of INT8 baseline (Δ dliq {:+.2}%, Δ mip2q {:+.2}%, Δ mip2q-L5 {:+.2}%)",
        if ok1 { "PASS" } else { "WARN" },
        (dl - base) * 100.0,
        (mp - base) * 100.0,
        (mp5 - base) * 100.0
    );
    let ok2 = sp < dl && sp < mp;
    println!(
        "[{}] structured sparsity trails both StruM methods at p=0.5 (sp {:.2}%)",
        if ok2 { "PASS" } else { "WARN" },
        sp * 100.0
    );
    let ok3 = (25.0..45.0).contains(&pe_save);
    println!("[{}] PE power saving in band (got {:+.1}%)", if ok3 { "PASS" } else { "WARN" }, pe_save);
    println!(
        "float reference {:.2}% | INT8 {:.2}% (calibration cost {:+.2}%)",
        float_acc * 100.0,
        base * 100.0,
        (base - float_acc) * 100.0
    );
    Ok(())
}
