//! Serving example: the batching coordinator under open-loop load, with
//! two model variants (INT8 baseline vs MIP2Q) served side by side —
//! the "vendor serves the customer's model quantized" scenario from §I.
//!
//! Run: `cargo run --release --example serve_infer -- [net] [requests] [rate]`

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;
use strum_dpu::backend::BackendKind;
use strum_dpu::coordinator::{Coordinator, CoordinatorOptions, Router};
use strum_dpu::model::eval::EvalConfig;
use strum_dpu::model::import::DataSet;
use strum_dpu::quant::Method;
use strum_dpu::runtime::Runtime;
use strum_dpu::util::prng::Rng;

fn drive(
    coord: &Coordinator,
    data: &DataSet,
    n: usize,
    rate: f64,
    seed: u64,
) -> anyhow::Result<(usize, f64)> {
    let px = data.img * data.img * 3;
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut at = 0.0;
    let mut pend = Vec::new();
    for i in 0..n {
        at += rng.exponential(rate);
        if let Some(d) = Duration::from_secs_f64(at).checked_sub(t0.elapsed()) {
            std::thread::sleep(d);
        }
        let idx = i % data.n;
        pend.push((idx, coord.submit(data.images[idx * px..(idx + 1) * px].to_vec())));
    }
    let mut correct = 0;
    for (idx, rx) in pend {
        let r = rx.recv_timeout(Duration::from_secs(30))??;
        if r.class as i32 == data.labels[idx] {
            correct += 1;
        }
    }
    Ok((correct, t0.elapsed().as_secs_f64()))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let net = args.first().cloned().unwrap_or_else(|| "mini_resnet_a".into());
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(600);
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(300.0);
    let dir = Path::new("artifacts");

    // PJRT when the runtime + HLO artifacts are available, else the
    // native integer engine — same coordinator, same request path.
    let (mut router, kind) = match Runtime::cpu() {
        Ok(rt) => {
            let rt = Arc::new(rt);
            println!("PJRT platform: {}", rt.platform());
            (Router::new(rt), BackendKind::Pjrt)
        }
        Err(e) => {
            println!("PJRT unavailable ({}); serving on the native backend", e);
            (Router::native(), BackendKind::Native)
        }
    };
    let data = DataSet::load(dir, "eval")?;

    for (label, method) in [
        ("int8-baseline", Method::Baseline),
        ("mip2q-L7-p0.5", Method::Mip2q { l_max: 7 }),
    ] {
        let p = if method == Method::Baseline { 0.0 } else { 0.5 };
        let v = router.register_kind(label, dir, &net, &EvalConfig::paper(method, p), kind)?;
        println!(
            "\n--- serving {} ({} [{}] batch sizes {:?}) at {} req/s ---",
            label,
            net,
            kind.name(),
            v.batches(),
            rate
        );
        let coord = Coordinator::start(
            v,
            CoordinatorOptions {
                // 25 ms batching deadline: at a few hundred req/s this fills the
                // 16-wide executables instead of burning them on 2-image batches.
                max_wait: Duration::from_millis(25),
                workers: 2,
                max_batch: None,
            },
        );
        let (correct, wall) = drive(&coord, &data, n, rate, 11)?;
        println!("{}", coord.metrics_report());
        println!(
            "served {} requests in {:.2}s — accuracy {:.2}%",
            n,
            wall,
            correct as f64 / n as f64 * 100.0
        );
        coord.shutdown();
    }
    println!("\nNOTE: identical serving path, only the weight arguments differ —");
    println!("StruM needs no model surgery, no retraining, no special executables.");
    Ok(())
}
