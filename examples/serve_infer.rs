//! Serving example: the multi-variant engine under open-loop load, with
//! two model variants (INT8 baseline vs MIP2Q) served CONCURRENTLY on
//! one shared worker pool — the "vendor serves the customer's model
//! quantized" scenario from §I, multi-tenant edition: both precision
//! points live behind the same pool and the deficit-round-robin
//! scheduler keeps either from starving the other.
//!
//! Run: `cargo run --release --example serve_infer -- [net] [requests] [rate]`
//!
//! Pass `--wire` to drive the same fleet over TCP instead of in-process
//! handles: the example binds a loopback `WireServer` in front of the
//! engine and submits every request through `WireClient` with a 250 ms
//! deadline budget — identical engine, identical variants, one extra
//! network hop (and typed deadline sheds when the budget is missed).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;
use strum_dpu::backend::BackendKind;
use strum_dpu::coordinator::{Engine, EngineOptions, Router, SubmitError, Ticket, VariantHandle};
use strum_dpu::model::eval::EvalConfig;
use strum_dpu::model::import::DataSet;
use strum_dpu::quant::Method;
use strum_dpu::runtime::Runtime;
use strum_dpu::server::{ErrorCode, WireClient, WireResponse, WireServer, WireServerOptions};
use strum_dpu::util::prng::Rng;

/// Open-loop Poisson load round-robined across the variant handles.
/// Returns per-variant (served, correct) counts.
fn drive(
    handles: &[VariantHandle],
    data: &DataSet,
    n: usize,
    rate: f64,
    seed: u64,
) -> anyhow::Result<Vec<(usize, usize)>> {
    let px = data.img * data.img * 3;
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut at = 0.0;
    let mut pend: Vec<(usize, usize, Ticket)> = Vec::new();
    for i in 0..n {
        at += rng.exponential(rate);
        if let Some(d) = Duration::from_secs_f64(at).checked_sub(t0.elapsed()) {
            std::thread::sleep(d);
        }
        let idx = i % data.n;
        let vi = i % handles.len();
        match handles[vi].submit(data.images[idx * px..(idx + 1) * px].to_vec()) {
            Ok(t) => pend.push((vi, idx, t)),
            Err(SubmitError::QueueFull { .. }) => {} // shed under backpressure
            Err(e) => return Err(e.into()),
        }
    }
    let mut counts = vec![(0usize, 0usize); handles.len()];
    for (vi, idx, ticket) in pend {
        let r = ticket.wait_deadline(Duration::from_secs(30))?;
        counts[vi].0 += 1;
        if r.class as i32 == data.labels[idx] {
            counts[vi].1 += 1;
        }
    }
    Ok(counts)
}

/// Wire mode: the same open-loop load, but every request crosses TCP —
/// loopback server in front of the engine, `WireClient` on the other
/// side, a 250 ms deadline budget on each request.
fn drive_wire(
    engine: &Arc<Engine>,
    keys: &[String],
    data: &DataSet,
    n: usize,
    rate: f64,
) -> anyhow::Result<(Vec<(usize, usize)>, usize)> {
    let server = WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default())?;
    println!("wire mode: listening on {}", server.local_addr());
    let mut client = WireClient::connect(server.local_addr().to_string())?;
    let px = data.img * data.img * 3;
    let mut rng = Rng::new(11);
    let t0 = std::time::Instant::now();
    let mut at = 0.0;
    let mut counts = vec![(0usize, 0usize); keys.len()];
    let mut shed = 0usize;
    for i in 0..n {
        at += rng.exponential(rate);
        if let Some(d) = Duration::from_secs_f64(at).checked_sub(t0.elapsed()) {
            std::thread::sleep(d);
        }
        let idx = i % data.n;
        let vi = i % keys.len();
        let image = &data.images[idx * px..(idx + 1) * px];
        match client.infer_deadline(&keys[vi], image, Duration::from_millis(250))? {
            WireResponse::Infer(r) => {
                counts[vi].0 += 1;
                if r.class as i32 == data.labels[idx] {
                    counts[vi].1 += 1;
                }
            }
            // Deadline sheds AND QueueFull backpressure are expected
            // under overload — same tolerance as the in-process drive().
            WireResponse::Error { code, .. }
                if code.is_shed() || code == ErrorCode::QueueFull =>
            {
                shed += 1
            }
            WireResponse::Error { code, detail } => {
                anyhow::bail!("wire error {}: {}", code, detail)
            }
        }
    }
    let stats = server.stats();
    println!(
        "server: connections={} requests={} presubmit_sheds={} protocol_errors={}",
        stats.connections, stats.requests, stats.shed_presubmit, stats.protocol_errors
    );
    server.shutdown();
    Ok((counts, shed))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let wire = args.iter().any(|a| a == "--wire");
    let pos: Vec<&String> = args.iter().filter(|a| !a.starts_with("--")).collect();
    let net = pos
        .first()
        .map(|s| s.to_string())
        .unwrap_or_else(|| "mini_resnet_a".into());
    let n: usize = pos.get(1).and_then(|s| s.parse().ok()).unwrap_or(600);
    let rate: f64 = pos.get(2).and_then(|s| s.parse().ok()).unwrap_or(300.0);
    let dir = Path::new("artifacts");

    // PJRT when the runtime + HLO artifacts are available, else the
    // native integer engine — same serving path either way.
    let (mut router, kind) = match Runtime::cpu() {
        Ok(rt) => {
            let rt = Arc::new(rt);
            println!("PJRT platform: {}", rt.platform());
            (Router::new(rt), BackendKind::Pjrt)
        }
        Err(e) => {
            println!("PJRT unavailable ({}); serving on the native backend", e);
            (Router::native(), BackendKind::Native)
        }
    };
    let data = DataSet::load(dir, "eval")?;

    // ONE engine, one shared pool; both variants registered on it. The
    // old layout burned (workers+1) threads per variant — this serves
    // the whole fleet with `workers` threads.
    let engine = Arc::new(Engine::start(EngineOptions {
        // 25 ms batching deadline: at a few hundred req/s this fills the
        // 16-wide executables instead of burning them on 2-image batches.
        max_wait: Duration::from_millis(25),
        workers: 2,
        ..EngineOptions::default()
    }));
    let mut handles = Vec::new();
    for (label, method) in [
        ("int8-baseline", Method::Baseline),
        ("mip2q-L7-p0.5", Method::Mip2q { l_max: 7 }),
    ] {
        let p = if method == Method::Baseline { 0.0 } else { 0.5 };
        let v = router.register_kind(label, dir, &net, &EvalConfig::paper(method, p), kind)?;
        println!(
            "registered {} ({} [{}] batch sizes {:?})",
            label,
            net,
            kind.name(),
            v.batches()
        );
        handles.push(engine.register(v)?);
    }
    println!(
        "\n--- serving {} variants on {} shared workers at {} req/s ---",
        handles.len(),
        engine.worker_count(),
        rate
    );
    let t0 = std::time::Instant::now();
    let (counts, wire_shed) = if wire {
        let keys: Vec<String> = handles.iter().map(|h| h.key().to_string()).collect();
        drive_wire(&engine, &keys, &data, n, rate)?
    } else {
        (drive(&handles, &data, n, rate, 11)?, 0)
    };
    let wall = t0.elapsed().as_secs_f64();

    // Typed metrics: per-variant rows + the fleet rollup.
    let snapshot = engine.metrics();
    println!("{}", snapshot.render());
    for (h, (served, correct)) in handles.iter().zip(&counts) {
        if *served > 0 {
            println!(
                "{}: {} served, accuracy {:.2}%",
                h.key(),
                served,
                *correct as f64 / *served as f64 * 100.0
            );
        }
    }
    let served_total: usize = counts.iter().map(|(s, _)| s).sum();
    println!(
        "served {} of {} submitted requests in {:.2}s{}",
        served_total,
        n,
        wall,
        if served_total < n {
            if wire {
                " (rest shed by deadline budgets or backpressure)"
            } else {
                " (rest shed by QueueFull backpressure)"
            }
        } else {
            ""
        }
    );
    if wire_shed > 0 {
        println!("{} wire requests shed with typed deadline codes", wire_shed);
    }
    // The engine drains and joins its pool when the Arc drops.
    drop(handles);
    drop(engine);
    println!("\nNOTE: identical serving path, only the weight arguments differ —");
    println!("StruM needs no model surgery, no retraining, no special executables.");
    Ok(())
}
