//! Quickstart: the five-minute tour of the StruM public API.
//!
//! 1. Build a toy "layer" of INT8 weights.
//! 2. Apply the three set-quantization strategies (§IV-C).
//! 3. Encode to the §IV-D compressed format and check Eq. 1 / Eq. 2.
//! 4. Price the hardware variants (Fig. 13's cost model).
//! 5. Cycle-simulate the layer on the FlexNN DPU model.
//!
//! Run: `cargo run --release --example quickstart`
//! (no artifacts needed — everything here is synthetic.)

use strum_dpu::encode::compression::ratio_for;
use strum_dpu::encode::{decode_layer, encode_layer};
use strum_dpu::hw::pe::{pe_cost, pe_dense_cycle_energy, PeVariant};
use strum_dpu::quant::tensor::qlayer;
use strum_dpu::quant::{apply_strum, Method, StrumParams};
use strum_dpu::sim::config::SimConfig;
use strum_dpu::sim::dataflow::LayerShape;
use strum_dpu::sim::{simulate_layer, SimMode};
use strum_dpu::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. A 64-output-channel 1x1 conv layer with Gaussian INT8 weights.
    let (oc, ic) = (64usize, 128usize);
    let mut rng = Rng::new(2025);
    let data: Vec<i8> = (0..oc * ic)
        .map(|_| (rng.gaussian() * 45.0).clamp(-127.0, 127.0) as i8)
        .collect();
    let layer = qlayer("toy", oc, 1, ic, data, vec![0.01; oc]);
    println!("layer: {} weights ({} oc x {} ic)\n", layer.len(), oc, ic);

    // 2. StruM transforms at the paper's hardware point [1,16], p = 0.5.
    println!("{:<22} {:>10} {:>12} {:>10}", "method", "rmse(grid)", "measured p", "Eq.1/2 r");
    for method in [
        Method::StructuredSparsity,
        Method::Dliq { q: 4 },
        Method::Mip2q { l_max: 7 },
        Method::Mip2q { l_max: 5 },
    ] {
        let s = apply_strum(&layer, &StrumParams::paper(method, 0.5));
        s.check_structure().map_err(anyhow::Error::msg)?;
        println!(
            "{:<22} {:>10.3} {:>12.3} {:>10.4}",
            method.name(),
            s.grid_rmse,
            s.measured_p(),
            ratio_for(method, 0.5)
        );
    }

    // 3. Codec round-trip (§IV-D mask header + payload).
    let s = apply_strum(&layer, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));
    let enc = encode_layer(&s);
    let dec = decode_layer(&enc)?;
    assert_eq!(dec.values, s.values);
    println!(
        "\ncodec: {} weights -> {} bytes (measured r = {:.4}, Eq.1 r = {:.4})",
        s.len(),
        enc.bytes.len(),
        enc.measured_ratio(),
        ratio_for(s.params.method, 0.5)
    );

    // 4. Hardware cost of the PE variants (Fig. 13).
    println!("\n{:<20} {:>12} {:>16}", "PE variant", "area (NAND2)", "power/cycle");
    let base = pe_cost(PeVariant::BaselineInt8).area();
    let base_e = pe_dense_cycle_energy(PeVariant::BaselineInt8);
    for v in [
        PeVariant::BaselineInt8,
        PeVariant::StaticMip2q { l_max: 7 },
        PeVariant::StaticMip2q { l_max: 5 },
        PeVariant::DynamicMip2q { l_max: 7 },
    ] {
        let c = pe_cost(v);
        let e = pe_dense_cycle_energy(v);
        println!(
            "{:<20} {:>8.0} ({:+5.1}%) {:>10.0} ({:+5.1}%)",
            v.name(),
            c.area(),
            (c.area() / base - 1.0) * 100.0,
            e,
            (e / base_e - 1.0) * 100.0
        );
    }

    // 5. Cycle-simulate dense vs StruM-perf execution (the 2x guarantee).
    let shape = LayerShape::conv("toy", oc, ic, 1, 16, 16);
    let baseline = apply_strum(&layer, &StrumParams::paper(Method::Baseline, 0.0));
    let dense = simulate_layer(
        &shape,
        &baseline,
        &SimConfig::flexnn(SimMode::Int8Dense, None),
        1.0,
        0,
    );
    let perf = simulate_layer(
        &shape,
        &s,
        &SimConfig::flexnn(SimMode::StrumPerf, Some(s.params.method)),
        1.0,
        0,
    );
    println!(
        "\nsim: dense {} cycles, StruM-perf {} cycles -> {:.2}x speedup (paper: exactly 2x)",
        dense.cycles,
        perf.cycles,
        perf.speedup_vs(&dense)
    );
    Ok(())
}
