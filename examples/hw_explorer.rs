//! Hardware design-space explorer: sweeps the codesign knobs the paper
//! fixes (N = replaced lanes, L = shift range, p = precision ratio) and
//! prints the area/power/accuracy-proxy Pareto surface — the tool a
//! hardware architect would actually use to pick the Fig. 13 design point.
//!
//! Run: `cargo run --release --example hw_explorer`

use strum_dpu::encode::compression::ratio_for;
use strum_dpu::hw::adder::{accumulator, adder_tree};
use strum_dpu::hw::dpu::{dpu_cost, tops_per_area, DpuConfig};
use strum_dpu::hw::gates::Cost;
use strum_dpu::hw::multiplier::int8x8;
use strum_dpu::hw::power::{power, tops_per_watt, Activity};
use strum_dpu::hw::shifter::barrel_shifter;
use strum_dpu::hw::PeVariant;
use strum_dpu::quant::tensor::qlayer;
use strum_dpu::quant::{apply_strum, Method, StrumParams};
use strum_dpu::util::prng::Rng;

/// Accuracy proxy: int-grid RMSE of the transform on Gaussian weights
/// (cheap stand-in for a full eval; the real accuracy sweeps are
/// `strum report fig10|fig11`).
fn rmse_proxy(method: Method, p: f64) -> f64 {
    let mut rng = Rng::new(9);
    let data: Vec<i8> = (0..64 * 256)
        .map(|_| (rng.gaussian() * 45.0).clamp(-127.0, 127.0) as i8)
        .collect();
    let layer = qlayer("probe", 64, 1, 256, data, vec![1.0; 64]);
    apply_strum(&layer, &StrumParams::paper(method, p)).grid_rmse
}

fn main() {
    let cfg = DpuConfig::flexnn_16x16();

    println!("=== lane building blocks (NAND2-equivalents) ===");
    let mul = int8x8();
    println!("{:<24} area {:>7.1}  energy/op {:>7.1}", "INT8x8 multiplier", mul.area, mul.energy);
    for l in [1u32, 3, 5, 7] {
        let s = barrel_shifter(8, l);
        println!(
            "{:<24} area {:>7.1}  energy/op {:>7.1}  ({:.0}% / {:.0}% of mult)",
            format!("barrel shifter L={}", l),
            s.area,
            s.energy,
            s.area / mul.area * 100.0,
            s.energy / mul.energy * 100.0
        );
    }
    let tree: Cost = adder_tree(8, 16);
    let acc = accumulator(32);
    println!("{:<24} area {:>7.1}", "adder tree (8x16b)", tree.area);
    println!("{:<24} area {:>7.1}", "accumulator (32b)", acc.area);

    println!("\n=== L sweep at p=0.5: area/power vs representational range ===");
    println!(
        "{:<6} {:>9} {:>10} {:>10} {:>12} {:>12}",
        "L", "payload q", "DPU area", "DPU power", "TOPS/mm2 Δ", "rmse proxy"
    );
    let act = Activity::dense(cfg.num_pes() as u64, 10_000, 0.5);
    let base_area = dpu_cost(PeVariant::BaselineInt8, &cfg).total.area;
    let base_tpa = tops_per_area(PeVariant::BaselineInt8, &cfg);
    let base_pwr = power(PeVariant::BaselineInt8, &act, &cfg).dpu_level();
    for l in [1u8, 3, 5, 7] {
        let v = PeVariant::StaticMip2q { l_max: l };
        let area = dpu_cost(v, &cfg).total.area;
        let pwr = power(v, &act, &cfg).dpu_level();
        println!(
            "{:<6} {:>9} {:>9.2}% {:>9.2}% {:>11.2}% {:>12.3}",
            l,
            strum_dpu::quant::Method::Mip2q { l_max: l }.payload_bits(),
            (area / base_area - 1.0) * 100.0,
            (pwr / base_pwr - 1.0) * 100.0,
            (tops_per_area(v, &cfg) / base_tpa - 1.0) * 100.0,
            rmse_proxy(Method::Mip2q { l_max: l }, 0.5),
        );
    }

    println!("\n=== p sweep (MIP2Q L=7): compression vs energy vs error ===");
    println!(
        "{:<6} {:>10} {:>12} {:>12} {:>12}",
        "p", "Eq.1 r", "DPU power Δ", "TOPS/W Δ", "rmse proxy"
    );
    for p in [0.25, 0.5, 0.75] {
        let v = PeVariant::StaticMip2q { l_max: 7 };
        let act_p = Activity::dense(cfg.num_pes() as u64, 10_000, p);
        let pwr = power(v, &act_p, &cfg).dpu_level();
        let base_p = power(PeVariant::BaselineInt8, &Activity::dense(cfg.num_pes() as u64, 10_000, 0.0), &cfg)
            .dpu_level();
        println!(
            "{:<6} {:>10.4} {:>11.2}% {:>11.2}% {:>12.3}",
            p,
            ratio_for(Method::Mip2q { l_max: 7 }, p),
            (pwr / base_p - 1.0) * 100.0,
            (tops_per_watt(v, &act_p, &cfg) / tops_per_watt(PeVariant::BaselineInt8, &act_p, &cfg) - 1.0)
                * 100.0,
            rmse_proxy(Method::Mip2q { l_max: 7 }, p),
        );
    }

    println!("\n=== static vs dynamic provisioning (the Fig. 13a/b choice) ===");
    for v in [
        PeVariant::BaselineInt8,
        PeVariant::StaticMip2q { l_max: 7 },
        PeVariant::DynamicMip2q { l_max: 7 },
        PeVariant::StaticDliq { q: 4 },
    ] {
        let d = dpu_cost(v, &cfg);
        let pwr = power(v, &act, &cfg);
        println!(
            "{:<20} DPU area {:>10.0} ({:+5.2}%)  DPU power {:>8.0} ({:+5.2}%)",
            v.name(),
            d.total.area,
            (d.total.area / base_area - 1.0) * 100.0,
            pwr.dpu_level(),
            (pwr.dpu_level() / base_pwr - 1.0) * 100.0
        );
    }
    println!("\n(The paper picks static L=5 for max savings, dynamic L=7 when");
    println!(" runtime quality fallback is worth ~3% DPU area.)");
}
