"""Layer-2 model tests: zoo forwards, export signatures, split-head
equivalence (Pallas head == plain GEMM head), activation fake-quant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile import nets as nets_mod


@pytest.mark.parametrize("net", list(nets_mod.NETS))
def test_forward_shapes_all_nets(net):
    params = nets_mod.init_params(net, 0)
    x = jnp.zeros((3, 32, 32, 3), jnp.float32)
    scales = jnp.zeros((nets_mod.num_quant_layers(net),), jnp.float32)
    y = nets_mod.apply(net, [jnp.asarray(p) for p in params], x, scales, split_head=False)
    assert y.shape == (3, nets_mod.NUM_CLASSES)
    assert np.isfinite(np.asarray(y)).all()


@pytest.mark.parametrize("net", ["mini_cnn_s", "mini_resnet_a", "mini_incept_a"])
def test_split_head_equals_plain_head(net):
    """hi-bank = fc_w, lo-bank = 0 must reproduce the training forward —
    ties the Pallas kernel head to the plain GEMM."""
    params = [jnp.asarray(p) for p in nets_mod.init_params(net, 1)]
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, 32, 3)).astype(np.float32))
    scales = jnp.zeros((nets_mod.num_quant_layers(net),), jnp.float32)
    y_plain = nets_mod.apply(net, list(params), x, scales, split_head=False)
    split = model_mod.split_head_params([np.asarray(p) for p in params])
    y_split = nets_mod.apply(
        net, [jnp.asarray(p) for p in split], x, scales, split_head=True
    )
    np.testing.assert_allclose(np.asarray(y_plain), np.asarray(y_split), rtol=1e-5, atol=1e-5)


def test_export_arg_specs_order(net="mini_cnn_s"):
    specs = model_mod.export_arg_specs(net, 4)
    # images + act_scales + params (fc_w doubled).
    n_params = len(nets_mod.param_shapes(net))
    assert len(specs) == 2 + n_params + 1
    assert specs[0].shape == (4, 32, 32, 3)
    assert specs[1].shape == (nets_mod.num_quant_layers(net),)


def test_export_forward_lowers(net="mini_cnn_s"):
    f = model_mod.export_forward(net)
    specs = model_mod.export_arg_specs(net, 2)
    lowered = jax.jit(f).lower(*specs)
    text = str(lowered.compiler_ir("stablehlo"))
    assert "stablehlo" in text or "module" in text


def test_act_fake_quant_changes_logits(net="mini_cnn_s"):
    params = [jnp.asarray(p) for p in nets_mod.init_params(net, 2)]
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 32, 32, 3)).astype(np.float32))
    zeros = jnp.zeros((nets_mod.num_quant_layers(net),), jnp.float32)
    coarse = jnp.full((nets_mod.num_quant_layers(net),), 0.5, jnp.float32)
    y0 = nets_mod.apply(net, list(params), x, zeros, split_head=False)
    y1 = nets_mod.apply(net, list(params), x, coarse, split_head=False)
    assert not np.allclose(np.asarray(y0), np.asarray(y1))


def test_collect_act_scales_positive(net="mini_cnn_s"):
    params = nets_mod.init_params(net, 3)
    x = np.random.default_rng(2).normal(size=(8, 32, 32, 3)).astype(np.float32)
    scales = model_mod.collect_act_scales(net, params, x)
    assert scales.shape == (nets_mod.num_quant_layers(net),)
    assert (scales > 0).all()


def test_layer_meta_consistent_with_params():
    for net in nets_mod.NETS:
        meta = nets_mod.layer_meta(net)
        shapes = dict(nets_mod.param_shapes(net))
        for m in meta:
            w = shapes[m["name"] + "_w"]
            if m["kind"] == "conv":
                assert w == (m["kh"], m["kw"], m["ic"], m["oc"])
            else:
                assert w == (m["ic"], m["oc"])
        # Spatial dims shrink monotonically.
        hws = [m["oh"] for m in meta]
        assert all(a >= b for a, b in zip(hws, hws[1:]))
