"""Python-side quantizer semantics + the rust-parity golden case.

The golden block below is hardcoded identically in
rust/tests/properties.rs (`python_parity_golden`): both implementations
must produce these exact effective values for the same input — pinning
rounding, tie-breaks, and padding behaviour across the language boundary.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quantize import (
    apply_strum,
    calibrate,
    dliq_requantize,
    from_canonical,
    mip2q_payload_bits,
    mip2q_requantize,
    round_half_away,
    to_canonical,
)

# --- The shared golden case (see rust/tests/properties.rs) -----------------
GOLDEN_INPUT = np.array(
    [17, -3, 64, 0, -128, 5, 99, -2, 33, -77, 1, 8, -16, 120, -9, 4],
    dtype=np.int16,
).reshape(1, 1, 16)

GOLDEN = {
    # method -> (p, expected effective values)
    "sparsity": (0.5, [17, 0, 64, 0, -128, 0, 99, 0, 33, -77, 0, 0, -16, 120, 0, 0]),
    "dliq": (0.5, [17, 0, 64, 0, -128, 0, 99, 0, 33, -77, 0, 16, -16, 120, -16, 0]),
    "mip2q": (0.5, [16, -3, 64, 0, -128, 5, 99, -2, 33, -77, 1, 8, -16, 120, -9, 4]),
}


def test_golden_parity_case():
    scales = np.ones(1, np.float32)
    for method, (p, expected) in GOLDEN.items():
        res = apply_strum(GOLDEN_INPUT.copy(), scales, method, p, q=4, l_max=7)
        got = res.values.ravel().tolist()
        assert got == expected, f"{method}: {got}"


# --- semantics --------------------------------------------------------------


def test_round_half_away():
    assert round_half_away(np.array([2.5, -2.5, 0.5, -0.5])).tolist() == [3, -3, 1, -1]


@settings(max_examples=50, deadline=None)
@given(q=st.integers(2, 8), v=st.integers(-127, 127))
def test_dliq_error_bound(q, v):
    eff, code = dliq_requantize(np.array([v], np.int16), q)
    step = 1 << (8 - q)
    max_code = (1 << (q - 1)) - 1
    assert abs(int(code[0])) <= max_code
    if abs(v) <= max_code * step:
        assert abs(int(eff[0]) - v) <= step // 2


@settings(max_examples=50, deadline=None)
@given(l_max=st.sampled_from([1, 3, 5, 7]), v=st.integers(-127, 127))
def test_mip2q_codebook(l_max, v):
    eff, code = mip2q_requantize(np.array([v], np.int16), l_max)
    mag = abs(int(eff[0]))
    assert mag in {1 << k for k in range(l_max + 1)}
    k = abs(int(code[0])) - 1
    assert 0 <= k <= l_max
    assert k < (1 << (mip2q_payload_bits(l_max) - 1))


def test_mip2q_exact_powers_zero_error():
    for k in range(8):
        v = np.array([1 << k], np.int16)
        eff, _ = mip2q_requantize(v, 7)
        assert int(eff[0]) == 1 << k


@settings(max_examples=20, deadline=None)
@given(
    oc=st.integers(1, 4),
    rows=st.integers(1, 3),
    cols=st.integers(1, 40),
    p=st.sampled_from([0.25, 0.5, 0.75]),
    method=st.sampled_from(["sparsity", "dliq", "mip2q"]),
    seed=st.integers(0, 10_000),
)
def test_strum_low_count_invariant(oc, rows, cols, p, method, seed):
    """Every [1,16] block (with pads counted low) has exactly round(p*16)
    low lanes — the hardware balance guarantee."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-127, 128, size=(oc, rows, cols)).astype(np.int16)
    res = apply_strum(q, np.ones(oc, np.float32), method, p)
    low_target = round(p * 16)
    bc = -(-cols // 16)
    for c in range(oc):
        for r in range(rows):
            for bj in range(bc):
                lo, hi_col = bj * 16, min((bj + 1) * 16, cols)
                real_low = (~res.mask[c, r, lo:hi_col]).sum()
                pads = 16 - (hi_col - lo)
                assert real_low + pads == low_target or pads >= low_target and real_low == 0


def test_calibrate_per_oc():
    w = np.zeros((2, 1, 4), np.float32)
    w[0] = [[1.0, -2.0, 0.5, 0.25]]
    w[1] = [[0.1, 0.05, -0.1, 0.02]]
    q, scales = calibrate(w)
    assert np.isclose(scales[0], 2.0 / 127)
    assert np.isclose(scales[1], 0.1 / 127)
    assert q[0, 0, 1] == -127 and q[1, 0, 0] == 127


@settings(max_examples=20, deadline=None)
@given(
    kh=st.integers(1, 3),
    kw=st.integers(1, 3),
    ic=st.integers(1, 8),
    oc=st.integers(1, 8),
    seed=st.integers(0, 1000),
)
def test_canonical_layout_roundtrip(kh, kw, ic, oc, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(kh, kw, ic, oc)).astype(np.float32)
    back = from_canonical(to_canonical(w), w.shape)
    assert (back == w).all()
    w2 = rng.normal(size=(ic * kh * kw, oc)).astype(np.float32)
    assert (from_canonical(to_canonical(w2), w2.shape) == w2).all()


def test_error_ordering_matches_paper():
    """mip2q ≤ dliq ≤ sparsity in weight-grid RMSE on Gaussian weights —
    the reason Table I orders the methods the way it does."""
    rng = np.random.default_rng(3)
    q = np.clip(rng.normal(0, 45, size=(8, 1, 64)), -127, 127).astype(np.int16)
    scales = np.ones(8, np.float32)

    def rmse(method):
        res = apply_strum(q, scales, method, 0.5)
        return float(np.sqrt(((res.values - q) ** 2).mean()))

    assert rmse("mip2q") < rmse("sparsity")
    assert rmse("dliq") < rmse("sparsity")
