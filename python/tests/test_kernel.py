"""Layer-1 correctness: Pallas StruM GEMM vs the pure-jnp oracle.

Hypothesis sweeps shapes/densities/dtypes; every case must match ref.py to
float tolerance (f32) or bit-exactly (int32). This is the CORE correctness
signal of the kernel layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import strum_matmul_int_ref, strum_matmul_ref
from compile.kernels.strum_matmul import (
    strum_matmul_f32,
    strum_matmul_int,
    vmem_bytes,
)


def banks_from(w: np.ndarray, mask: np.ndarray):
    hi = np.where(mask, w, 0).astype(w.dtype)
    lo = np.where(~mask, w, 0).astype(w.dtype)
    return hi, lo


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 33),
    k=st.integers(1, 97),
    n=st.integers(1, 24),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_f32_matches_ref_random_shapes(m, k, n, density, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    mask = rng.random((k, n)) < density
    hi, lo = banks_from(w, mask)
    out = strum_matmul_f32(jnp.array(x), jnp.array(hi), jnp.array(lo))
    ref = strum_matmul_ref(jnp.array(x), jnp.array(hi), jnp.array(lo))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 17),
    k=st.integers(1, 64),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_int_bit_exact(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int32)
    # hi bank: int8 values; lo bank: MIP2Q-style ±2^k effective values.
    mask = rng.random((k, n)) < 0.5
    hi = np.where(mask, rng.integers(-127, 128, size=(k, n)), 0).astype(np.int32)
    ks = rng.integers(0, 8, size=(k, n))
    sign = np.where(rng.random((k, n)) < 0.5, -1, 1)
    lo = np.where(~mask, sign * (1 << ks), 0).astype(np.int32)
    out = strum_matmul_int(jnp.array(x), jnp.array(hi), jnp.array(lo))
    ref = strum_matmul_int_ref(jnp.array(x), jnp.array(hi), jnp.array(lo))
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_block_shapes_that_tile_exactly():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(256, 1024)).astype(np.float32)
    w = rng.normal(size=(1024, 128)).astype(np.float32)
    mask = rng.random((1024, 128)) < 0.5
    hi, lo = banks_from(w, mask)
    out = strum_matmul_f32(jnp.array(x), jnp.array(hi), jnp.array(lo))
    np.testing.assert_allclose(np.asarray(out), x @ w, rtol=3e-4, atol=3e-4)


def test_zero_low_bank_equals_plain_gemm():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 48)).astype(np.float32)
    w = rng.normal(size=(48, 12)).astype(np.float32)
    out = strum_matmul_f32(jnp.array(x), jnp.array(w), jnp.array(np.zeros_like(w)))
    np.testing.assert_allclose(np.asarray(out), x @ w, rtol=1e-5, atol=1e-5)


def test_complementary_banks_reconstruct_dense():
    # The StruM decomposition invariant: hi + lo == w exactly when masks
    # are complementary (zero where the other bank is nonzero).
    rng = np.random.default_rng(2)
    w = rng.normal(size=(64, 16)).astype(np.float32)
    mask = rng.random((64, 16)) < 0.25
    hi, lo = banks_from(w, mask)
    assert (hi + lo == w).all()
    assert ((hi == 0) | (lo == 0)).all()
    x = rng.normal(size=(4, 64)).astype(np.float32)
    out = strum_matmul_f32(jnp.array(x), jnp.array(hi), jnp.array(lo))
    np.testing.assert_allclose(np.asarray(out), x @ w, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_degenerate_dims(dtype):
    x = np.ones((1, 1), dtype)
    w = np.full((1, 1), 3, dtype)
    z = np.zeros((1, 1), dtype)
    out = (strum_matmul_f32 if dtype == np.float32 else strum_matmul_int)(
        jnp.array(x), jnp.array(w), jnp.array(z)
    )
    assert np.asarray(out)[0, 0] == 3


def test_int_accumulator_headroom():
    # Worst-case magnitudes at k=4096 must not overflow int32.
    k = 4096
    x = np.full((1, k), 127, np.int32)
    hi = np.full((k, 1), 127, np.int32)
    lo = np.zeros((k, 1), np.int32)
    out = strum_matmul_int(jnp.array(x), jnp.array(hi), jnp.array(lo))
    assert int(np.asarray(out)[0, 0]) == 127 * 127 * k  # 66_064_384 < 2^31


def test_vmem_budget():
    # Default blocks stay within a 4 MiB VMEM envelope (DESIGN.md §2).
    assert vmem_bytes(128, 128, 512) <= 4 * 1024 * 1024
