"""Layer-2 export surface: the jitted forward functions AOT-lowered to HLO.

Model HLO signature (per network):

    f(images[B,32,32,3], act_scales[L], w0, b0, w1, b1, ..., fc_w_hi,
      fc_w_lo, fc_b) -> (logits[B,12],)

Weights are ARGUMENTS so one executable evaluates any quantize-dequantized
weight set the rust coordinator produces; the classifier head takes the
StruM two-bank decomposition and runs through the Pallas kernel (nets.py).
act_scales[i] fake-quants the input of quantizable layer i (0 = float).
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import nets


def export_forward(net: str):
    """Returns f(x, act_scales, *params_split_head) -> (logits,)."""

    def f(x, act_scales, *params):
        return (nets.apply(net, list(params), x, act_scales, split_head=True),)

    return f


def export_arg_specs(net: str, batch: int):
    """ShapeDtypeStructs for the export signature, in order."""
    specs = [
        jax.ShapeDtypeStruct((batch, nets.INPUT_HW, nets.INPUT_HW, 3), jnp.float32),
        jax.ShapeDtypeStruct((nets.num_quant_layers(net),), jnp.float32),
    ]
    shapes = nets.param_shapes(net)
    for name, shape in shapes:
        if name == "fc_w":
            # Split head: two banks.
            specs.append(jax.ShapeDtypeStruct(shape, jnp.float32))
            specs.append(jax.ShapeDtypeStruct(shape, jnp.float32))
        else:
            specs.append(jax.ShapeDtypeStruct(shape, jnp.float32))
    return specs


def split_head_params(params: list[np.ndarray]) -> list[np.ndarray]:
    """Train-order params → export-order (fc_w duplicated as hi-bank with
    a zero lo-bank; the rust side overwrites both from its decomposition)."""
    out = list(params[:-2])
    fc_w, fc_b = params[-2], params[-1]
    out += [fc_w, np.zeros_like(fc_w), fc_b]
    return out


def forward_train(net: str):
    """Training-path forward (single fc weight, no act quant)."""

    def f(params, x):
        scales = jnp.zeros((nets.num_quant_layers(net),), jnp.float32)
        return nets.apply(net, list(params), x, scales, split_head=False)

    return f


def collect_act_scales(net: str, params: list[np.ndarray], x_calib: np.ndarray,
                       pct: float = 99.9) -> np.ndarray:
    """Static activation calibration (§VI): runs the float forward on a
    calibration batch capturing each quantizable layer's input |act|
    percentile → symmetric INT8 scale."""
    meta = nets.layer_meta(net)
    records: list[np.ndarray] = []

    # Re-implement the walk with a capture hook: easiest is to call apply
    # with act_scales=0 but instrument via jax's pure callbacks — instead,
    # exploit that apply fake-quants layer inputs: we capture by running
    # layer-by-layer below using the same spec walk.
    import jax.numpy as jnp

    from .nets import NETS, Conv, Inception, Residual, _conv, _pool

    x = jnp.asarray(x_calib)
    p = list(params)

    def take2():
        return jnp.asarray(p.pop(0)), jnp.asarray(p.pop(0))

    def record(t):
        records.append(np.asarray(jnp.abs(t)).ravel())

    for s in NETS[net]:
        if isinstance(s, Conv):
            w, b = take2()
            record(x)
            x = jax.nn.relu(_conv(x, w, b))
            if s.pool:
                x = _pool(x)
        elif isinstance(s, Residual):
            ic = x.shape[-1]
            w, b = take2()
            record(x)
            y = jax.nn.relu(_conv(x, w, b))
            w, b = take2()
            record(y)
            y = _conv(y, w, b)
            if ic != s.oc:
                w, b = take2()
                record(x)
                sc = _conv(x, w, b)
            else:
                sc = x
            x = jax.nn.relu(y + sc)
        elif isinstance(s, Inception):
            branches = []
            for _ in range(3):
                w, b = take2()
                record(x)
                branches.append(jax.nn.relu(_conv(x, w, b)))
            x = jnp.concatenate(branches, axis=-1)
    x = jnp.mean(x, axis=(1, 2))
    record(x)
    fc_w, fc_b = take2()
    _ = x @ fc_w + fc_b
    assert len(records) == len(meta), (len(records), len(meta))
    scales = np.array(
        [np.percentile(r, pct) / 127.0 if r.size else 1.0 for r in records],
        dtype=np.float32,
    )
    return np.maximum(scales, 1e-8)
