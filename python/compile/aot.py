"""AOT export: lower every zoo forward (and the standalone StruM kernels)
to HLO TEXT for the rust PJRT runtime.

HLO *text* (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out, default ../artifacts):
    hlo/<net>_b<batch>.hlo.txt     model forward, weights-as-arguments
    hlo/strum_matmul_f32.hlo.txt   standalone float two-bank kernel
    hlo/strum_matmul_int.hlo.txt   standalone bit-exact integer kernel
    hlo/manifest.json              arg orders, shapes, batch sizes

Usage: python -m compile.aot [--out DIR] [--nets a,b] [--batches 1,256]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from . import nets as nets_mod
from .kernels.strum_matmul import strum_matmul_f32, strum_matmul_int, vmem_bytes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_net(net: str, batch: int, out: str) -> dict:
    f = model_mod.export_forward(net)
    specs = model_mod.export_arg_specs(net, batch)
    lowered = jax.jit(f).lower(*specs)
    text = to_hlo_text(lowered)
    path = f"{out}/hlo/{net}_b{batch}.hlo.txt"
    with open(path, "w") as fh:
        fh.write(text)
    args = ["images", "act_scales"]
    for name, _ in nets_mod.param_shapes(net):
        if name == "fc_w":
            args += ["fc_w_hi", "fc_w_lo"]
        else:
            args.append(name)
    return {
        "net": net,
        "batch": batch,
        "path": f"hlo/{net}_b{batch}.hlo.txt",
        "args": args,
        "bytes": len(text),
    }


def export_kernels(out: str, m: int, k: int, n: int) -> list[dict]:
    entries = []
    fspec = [
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    ]
    lowered = jax.jit(lambda x, h, l: (strum_matmul_f32(x, h, l),)).lower(*fspec)
    with open(f"{out}/hlo/strum_matmul_f32.hlo.txt", "w") as fh:
        fh.write(to_hlo_text(lowered))
    entries.append(
        {"kernel": "strum_matmul_f32", "m": m, "k": k, "n": n, "dtype": "f32",
         "path": "hlo/strum_matmul_f32.hlo.txt",
         "vmem_bytes": vmem_bytes(min(m, 128), min(n, 128), min(k, 512))}
    )
    ispec = [
        jax.ShapeDtypeStruct((m, k), jnp.int32),
        jax.ShapeDtypeStruct((k, n), jnp.int32),
        jax.ShapeDtypeStruct((k, n), jnp.int32),
    ]
    lowered = jax.jit(lambda x, h, l: (strum_matmul_int(x, h, l),)).lower(*ispec)
    with open(f"{out}/hlo/strum_matmul_int.hlo.txt", "w") as fh:
        fh.write(to_hlo_text(lowered))
    entries.append(
        {"kernel": "strum_matmul_int", "m": m, "k": k, "n": n, "dtype": "i32",
         "path": "hlo/strum_matmul_int.hlo.txt",
         "vmem_bytes": vmem_bytes(min(m, 128), min(n, 128), min(k, 512))}
    )
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--nets", default=",".join(nets_mod.NETS))
    ap.add_argument("--batches", default="256")
    ap.add_argument("--kernel-mkn", default="64,256,64")
    args = ap.parse_args()

    os.makedirs(f"{args.out}/hlo", exist_ok=True)
    manifest = {"models": [], "kernels": []}
    for net in args.nets.split(","):
        net = net.strip()
        for b in (int(x) for x in args.batches.split(",")):
            entry = export_net(net, b, args.out)
            manifest["models"].append(entry)
            print(f"lowered {net} b={b}: {entry['bytes']} chars")
    m, k, n = (int(x) for x in args.kernel_mkn.split(","))
    manifest["kernels"] = export_kernels(args.out, m, k, n)
    print("lowered standalone kernels")
    with open(f"{args.out}/hlo/manifest.json", "w") as fh:
        json.dump(manifest, fh, indent=1)
    print("aot manifest written")


if __name__ == "__main__":
    main()
