"""Build-time training of the mini-CNN zoo on the synthetic dataset.

Trains each network with Adam + cosine decay, logs the loss curve, and
writes artifacts the rust coordinator consumes:

    artifacts/data/train.bin / eval.bin (+ labels)   raw little-endian f32/i32
    artifacts/weights/<net>.bin                      concatenated f32 params
    artifacts/weights/<net>.json                     manifest (layers, shapes,
                                                     act scales, eval top-1)
    artifacts/train_log.json                         loss curves (E2E record)

Usage: python -m compile.train [--nets a,b] [--steps N] [--out DIR]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import nets as nets_mod

TRAIN_N = 9_600
EVAL_N = 1_920
BATCH = 128
SEED = 0


def adam_init(params):
    return (
        [np.zeros_like(p) for p in params],
        [np.zeros_like(p) for p in params],
    )


def train_net(net: str, steps: int, xs, ys, xe, ye, log):
    fwd = model_mod.forward_train(net)

    def loss_fn(params, x, y):
        logits = fwd(params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def eval_acc(params, x, y):
        logits = fwd(params, x)
        return jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))

    params = [jnp.asarray(p) for p in nets_mod.init_params(net, SEED)]
    m, v = adam_init(params)
    m = [jnp.asarray(t) for t in m]
    v = [jnp.asarray(t) for t in v]
    b1, b2, eps, lr0 = 0.9, 0.999, 1e-8, 3e-3

    rng = np.random.default_rng(SEED + hash(net) % 1000)
    t0 = time.time()
    curve = []
    for step in range(1, steps + 1):
        idx = rng.integers(0, xs.shape[0], size=BATCH)
        x, y = jnp.asarray(xs[idx]), jnp.asarray(ys[idx])
        lr = lr0 * 0.5 * (1 + np.cos(np.pi * step / steps))
        loss, grads = grad_fn(params, x, y)
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mh = mi / (1 - b1**step)
            vh = vi / (1 - b2**step)
            new_p.append(p - lr * mh / (jnp.sqrt(vh) + eps))
            new_m.append(mi)
            new_v.append(vi)
        params, m, v = new_p, new_m, new_v
        if step % 25 == 0 or step == 1:
            curve.append({"step": step, "loss": float(loss)})
    acc = float(eval_acc(params, jnp.asarray(xe), jnp.asarray(ye)))
    dt = time.time() - t0
    log[net] = {"curve": curve, "eval_top1": acc, "seconds": round(dt, 1), "steps": steps}
    print(f"{net:16s} top-1 {acc*100:5.2f}%  ({dt:.0f}s, final loss {curve[-1]['loss']:.4f})")
    return [np.asarray(p) for p in params], acc


def save_artifacts(out: str, net: str, params, acc, act_scales):
    os.makedirs(f"{out}/weights", exist_ok=True)
    shapes = nets_mod.param_shapes(net)
    blob = np.concatenate([p.astype("<f4").ravel() for p in params])
    blob.tofile(f"{out}/weights/{net}.bin")
    manifest = {
        "net": net,
        "num_classes": nets_mod.NUM_CLASSES,
        "input": [nets_mod.INPUT_HW, nets_mod.INPUT_HW, 3],
        "eval_top1_float": acc,
        "act_scales": [float(s) for s in act_scales],
        "layers": nets_mod.layer_meta(net),
        "params": [
            {"name": n, "shape": list(s), "offset": int(off), "len": int(np.prod(s))}
            for (n, s), off in zip(
                shapes,
                np.cumsum([0] + [int(np.prod(s)) for _, s in shapes])[:-1],
            )
        ],
    }
    with open(f"{out}/weights/{net}.json", "w") as f:
        json.dump(manifest, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nets", default=",".join(nets_mod.NETS))
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    os.makedirs(f"{args.out}/data", exist_ok=True)
    print("generating dataset ...")
    xs, ys = data_mod.make_dataset(TRAIN_N, seed=1)
    xe, ye = data_mod.make_dataset(EVAL_N, seed=2)
    xs.astype("<f4").tofile(f"{args.out}/data/train_x.bin")
    ys.astype("<i4").tofile(f"{args.out}/data/train_y.bin")
    xe.astype("<f4").tofile(f"{args.out}/data/eval_x.bin")
    ye.astype("<i4").tofile(f"{args.out}/data/eval_y.bin")
    with open(f"{args.out}/data/manifest.json", "w") as f:
        json.dump(
            {
                "train_n": TRAIN_N,
                "eval_n": EVAL_N,
                "img": nets_mod.INPUT_HW,
                "channels": 3,
                "classes": nets_mod.NUM_CLASSES,
            },
            f,
        )

    log: dict = {}
    for net in args.nets.split(","):
        net = net.strip()
        params, acc = train_net(net, args.steps, xs, ys, xe, ye, log)
        act_scales = model_mod.collect_act_scales(net, params, xe[:256])
        save_artifacts(args.out, net, params, acc, act_scales)
    with open(f"{args.out}/train_log.json", "w") as f:
        json.dump(log, f, indent=1)
    print("train artifacts written to", args.out)


if __name__ == "__main__":
    main()
