"""Synthetic image-classification dataset (the ImageNet substitute).

StruM is a post-training weight transform; its accuracy behaviour depends on
the weight-magnitude statistics of trained conv nets, not on dataset scale
(DESIGN.md §1). This module generates a 12-class, 32x32x3 procedural dataset
whose classes span easy (color/orientation) to subtle (texture-frequency)
distinctions, so quantization damage produces a graded accuracy loss rather
than a cliff or a plateau.

Classes (4 hues x 3 patterns):
  hue h in {0,1,2,3} sets the dominant color mix;
  pattern p in {0,1,2}:
    0 - oriented stripes (angle jittered around a class-specific base);
    1 - checkerboard with class-specific cell size;
    2 - concentric rings with class-specific frequency.

Every image gets random phase, scale jitter, brightness jitter, and iid
Gaussian pixel noise.
"""

import numpy as np

NUM_CLASSES = 12
IMG = 32
CHANNELS = 3

# Hues are deliberately close to gray: color alone is a weak cue, so the
# classifier must use the (noisy) texture patterns — this keeps trained
# accuracy off the 100% ceiling and makes quantization damage measurable.
_HUES = np.array(
    [
        [0.62, 0.48, 0.45],
        [0.45, 0.62, 0.48],
        [0.46, 0.49, 0.62],
        [0.58, 0.57, 0.44],
    ],
    dtype=np.float32,
)


def _pattern(cls: int, rng: np.random.Generator) -> np.ndarray:
    """One 32x32 grayscale pattern for class `cls`."""
    hue, pat = cls % 4, cls // 4
    yy, xx = np.meshgrid(np.arange(IMG), np.arange(IMG), indexing="ij")
    yy = yy.astype(np.float32)
    xx = xx.astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi)
    jitter = rng.uniform(0.85, 1.15)
    if pat == 0:
        # Oriented stripes: base angle differs per hue to decouple cues.
        ang = (np.pi / 8) * (1 + hue) + rng.normal(0, 0.08)
        freq = 0.55 * jitter
        g = np.sin(freq * (np.cos(ang) * xx + np.sin(ang) * yy) + phase)
    elif pat == 1:
        # Checkerboard, cell size 3 + hue (subtle frequency distinction).
        cell = 3 + hue
        g = np.sign(np.sin(np.pi * xx / cell + phase) * np.sin(np.pi * yy / cell + phase))
        g = g.astype(np.float32) * jitter
    else:
        # Concentric rings around a jittered center.
        cy = IMG / 2 + rng.normal(0, 2.0)
        cx = IMG / 2 + rng.normal(0, 2.0)
        r = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
        freq = (0.45 + 0.1 * hue) * jitter
        g = np.sin(freq * r + phase)
    return g.astype(np.float32)


def make_image(cls: int, rng: np.random.Generator) -> np.ndarray:
    g = _pattern(cls, rng)
    g = (g - g.min()) / (g.max() - g.min() + 1e-6)
    # Weak pattern amplitude over a textured background.
    amp = rng.uniform(0.35, 0.7)
    g = 0.5 + amp * (g - 0.5)
    hue = _HUES[cls % 4] * rng.uniform(0.85, 1.15)
    img = g[:, :, None] * hue[None, None, :]
    # Distractor texture (class-independent low-frequency blob).
    yy, xx = np.meshgrid(np.arange(IMG), np.arange(IMG), indexing="ij")
    ph1, ph2 = rng.uniform(0, 2 * np.pi, 2)
    distract = 0.10 * np.sin(0.19 * xx + ph1) * np.cos(0.23 * yy + ph2)
    img += distract[:, :, None]
    img += rng.normal(0, 0.22, size=img.shape)  # heavy pixel noise
    img *= rng.uniform(0.8, 1.2)  # brightness jitter
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def make_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images [n,32,32,3] f32, labels [n] i32), class-balanced."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % NUM_CLASSES
    rng.shuffle(labels)
    imgs = np.stack([make_image(int(c), rng) for c in labels])
    return imgs.astype(np.float32), labels.astype(np.int32)


def save_bin(path: str, arr: np.ndarray) -> None:
    """Raw little-endian dump (rust reads with a manifest)."""
    arr.astype("<f4" if arr.dtype == np.float32 else "<i4").tofile(path)


if __name__ == "__main__":
    x, y = make_dataset(240, 0)
    assert x.shape == (240, 32, 32, 3) and x.dtype == np.float32
    assert y.min() >= 0 and y.max() == NUM_CLASSES - 1
    print("data ok", x.mean())
