"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest + hypothesis compare every kernel output against these)."""

import jax.numpy as jnp


def strum_matmul_ref(x, w_hi, w_lo):
    """Reference two-bank GEMM: x @ (w_hi + w_lo), computed as the fused
    single-bank product (the mathematically equal form)."""
    return x @ (w_hi + w_lo)


def strum_matmul_int_ref(x_i32, whi_i32, wlo_i32):
    """Integer reference with int32 accumulation."""
    x = x_i32.astype(jnp.int32)
    return x @ whi_i32.astype(jnp.int32) + x @ wlo_i32.astype(jnp.int32)
