"""Layer-1 Pallas kernels: the StruM mixed-precision GEMM.

Hardware adaptation (DESIGN.md §2): the FlexNN PE's two multiplier banks
(INT8 multipliers for mask=1 lanes, barrel shifters for mask=0 lanes)
become two *dense* partial GEMMs on the MXU — `x @ w_hi + x @ w_lo` — with
the mask realized as the complementary zero patterns of the two weight
banks. Dense two-bank evaluation keeps MXU-shaped operands (no
gather/scatter), exactly as the adder tree wants dense lanes; the mask
header's routing role is played by the precomputed decomposition.

Two variants:

* `strum_matmul_f32`  — float banks; used inside every zoo network's
  classifier head (the accuracy-evaluation path: banks carry fake-quant
  dequantized values).
* `strum_matmul_int`  — int32 banks; bit-exact emulation of the PE
  datapath (products and accumulation in int32). Exported standalone and
  cross-checked against the rust simulator's dot products.

Kernels are written with `interpret=True`: the CPU PJRT client cannot run
Mosaic custom-calls; interpret mode lowers to plain HLO while preserving
the block structure. Block sizes are chosen for the paper's [1,16] StruM
block never to straddle a K-tile (bk % 16 == 0) and to fit VMEM:
(bm*bk + 2*bk*bn + bm*bn) * 4B ≤ ~4 MiB for the defaults below.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is ≤ pref (keeps the grid exact)."""
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


def _matmul2_kernel(x_ref, hi_ref, lo_ref, o_ref, *, k_steps, dtype):
    """One (bm, bn) output tile: accumulate over K in bk chunks.

    Grid = (M/bm, N/bn, k_steps); K is the innermost (sequential) axis so
    the accumulator tile stays resident in VMEM across K steps — the same
    HBM↔VMEM schedule the FlexNN column achieves with its weight-resident
    RFs.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    # Two dense banks = the PE's multiplier bank + shifter bank.
    acc = jnp.dot(x, hi_ref[...], preferred_element_type=dtype)
    acc += jnp.dot(x, lo_ref[...], preferred_element_type=dtype)
    o_ref[...] += acc


def _strum_matmul(x, w_hi, w_lo, *, bm, bn, bk, dtype):
    m, k = x.shape
    k2, n = w_hi.shape
    assert k == k2 and w_lo.shape == (k, n), (x.shape, w_hi.shape, w_lo.shape)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    k_steps = k // bk
    kernel = functools.partial(_matmul2_kernel, k_steps=k_steps, dtype=dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, w_hi, w_lo)


def strum_matmul_f32(x, w_hi, w_lo, *, bm: int = 128, bn: int = 128, bk: int = 512):
    """Float two-bank StruM GEMM: `x @ w_hi + x @ w_lo`."""
    return _strum_matmul(x, w_hi, w_lo, bm=bm, bn=bn, bk=bk, dtype=jnp.float32)


def strum_matmul_int(x_i32, whi_i32, wlo_i32, *, bm: int = 128, bn: int = 128, bk: int = 512):
    """Bit-exact integer StruM GEMM (int32 accumulate), emulating the PE
    datapath: `whi` carries INT8 values on mask=1 lanes (0 elsewhere),
    `wlo` the low-set effective values (DLIQ `code << (8-q)` or MIP2Q
    ±2^k) on mask=0 lanes."""
    return _strum_matmul(x_i32, whi_i32, wlo_i32, bm=bm, bn=bn, bk=bk, dtype=jnp.int32)


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """VMEM footprint estimate of one grid step (x + 2 banks + acc)."""
    return itemsize * (bm * bk + 2 * bk * bn + bm * bn)


if __name__ == "__main__":
    import numpy as np

    x = np.random.default_rng(0).normal(size=(8, 48)).astype(np.float32)
    w = np.random.default_rng(1).normal(size=(48, 12)).astype(np.float32)
    mask = np.random.default_rng(2).random((48, 12)) < 0.5
    hi = np.where(mask, w, 0).astype(np.float32)
    lo = np.where(~mask, w, 0).astype(np.float32)
    out = strum_matmul_f32(jnp.array(x), jnp.array(hi), jnp.array(lo))
    np.testing.assert_allclose(np.asarray(out), x @ w, rtol=1e-5, atol=1e-5)
    print("strum_matmul_f32 ok; vmem(128,128,512) =", vmem_bytes(128, 128, 512), "bytes")
