"""Python mirror of the rust StruM quantizer (rust/src/quant/).

Build-time only: used for (a) activation-scale calibration during AOT
export, (b) golden-file parity tests pinning the rust and python
implementations to identical semantics (rounding, tie-breaks, padding).

Layout convention matches rust: a layer is per-OC matrices of
[rows = kh*kw, cols = ic]; JAX's HWIO conv kernels are transposed into
this canonical order by `to_canonical` (and back by `from_canonical`).
"""

from dataclasses import dataclass

import numpy as np


# --------------------------------------------------------------------------
# Layout


def to_canonical(w: np.ndarray) -> np.ndarray:
    """HWIO (kh,kw,ic,oc) or (in,out) FC → canonical [oc, rows, cols]."""
    if w.ndim == 4:
        kh, kw, ic, oc = w.shape
        return np.transpose(w, (3, 0, 1, 2)).reshape(oc, kh * kw, ic)
    if w.ndim == 2:
        cin, cout = w.shape
        return np.transpose(w, (1, 0)).reshape(cout, 1, cin)
    raise ValueError(w.shape)


def from_canonical(c: np.ndarray, orig_shape: tuple) -> np.ndarray:
    """Canonical [oc, rows, cols] → original HWIO / (in,out)."""
    if len(orig_shape) == 4:
        kh, kw, ic, oc = orig_shape
        return np.transpose(c.reshape(oc, kh, kw, ic), (1, 2, 3, 0))
    if len(orig_shape) == 2:
        cin, cout = orig_shape
        return np.transpose(c.reshape(cout, cin), (1, 0))
    raise ValueError(orig_shape)


# --------------------------------------------------------------------------
# INT8 calibration (symmetric, per output channel) — rust calibrate.rs


def round_half_away(x: np.ndarray) -> np.ndarray:
    return np.where(x >= 0, np.floor(x + 0.5), np.ceil(x - 0.5))


def calibrate(canon: np.ndarray):
    """canon [oc, rows, cols] f32 → (int8 grid values i16, scales [oc])."""
    oc = canon.shape[0]
    flat = canon.reshape(oc, -1)
    amax = np.abs(flat).max(axis=1)
    scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = round_half_away(flat / scales[:, None]).clip(-127, 127).astype(np.int16)
    return q.reshape(canon.shape), scales


# --------------------------------------------------------------------------
# Set quantizers — rust dliq.rs / mip2q.rs / sparsity.rs


def dliq_requantize(v: np.ndarray, q: int):
    """(effective grid value, code) with the rust semantics."""
    if q <= 1:
        return np.zeros_like(v), np.zeros_like(v)
    shift = 8 - q
    step = 1 << shift
    max_code = (1 << (q - 1)) - 1
    code = round_half_away(v.astype(np.float64) / step).clip(-max_code, max_code)
    code = code.astype(np.int16)
    return (code << shift).astype(np.int16), code


def mip2q_requantize(v: np.ndarray, l_max: int):
    """(effective ±2^k value, sign-magnitude code ±(k+1))."""
    mag = np.abs(v).astype(np.int32)
    fl = np.where(mag >= 2, np.floor(np.log2(np.maximum(mag, 1))).astype(np.int32), 0)
    lo = np.minimum(fl, l_max)
    hi = np.minimum(fl + 1, l_max)
    e_lo = np.abs(mag - (1 << lo))
    e_hi = np.abs(mag - (1 << hi))
    k = np.where(e_hi < e_lo, hi, lo)
    k = np.where(mag <= 1, 0, k)
    eff = (1 << k).astype(np.int16)
    neg = v < 0
    eff = np.where(neg, -eff, eff).astype(np.int16)
    code = np.where(neg, -(k + 1), k + 1).astype(np.int16)
    return eff, code


def mip2q_payload_bits(l_max: int) -> int:
    if l_max == 0:
        return 1
    return int(np.ceil(np.log2(l_max + 1))) + 1


def pow2_error(v: np.ndarray, l_max: int) -> np.ndarray:
    eff, _ = mip2q_requantize(v, l_max)
    d = v.astype(np.int64) - eff
    return (d * d).astype(np.int64)


# --------------------------------------------------------------------------
# Block transform — rust quant::apply_strum


@dataclass
class StrumResult:
    values: np.ndarray  # effective grid values [oc, rows, cols] i16
    mask: np.ndarray  # bool, True = high precision
    codes: np.ndarray  # payload codes i16
    scales: np.ndarray


def apply_strum(
    qvals: np.ndarray,
    scales: np.ndarray,
    method: str,
    p: float,
    l: int = 1,
    w: int = 16,
    q: int = 4,
    l_max: int = 7,
) -> StrumResult:
    """Mirror of rust `apply_strum` on canonical [oc, rows, cols] i16.

    Padding lanes (block grid beyond the matrix) prefer the low set at
    cost 0, exactly as in rust (stable order: pads first, then by key,
    then by block-slot index).
    """
    oc, rows, cols = qvals.shape
    out_vals = qvals.astype(np.int16).copy()
    out_codes = qvals.astype(np.int16).copy()
    out_mask = np.ones(qvals.shape, dtype=bool)
    if method == "baseline":
        return StrumResult(out_vals, out_mask, out_codes, scales)
    low_n = int(round(p * l * w))
    if low_n == 0:
        return StrumResult(out_vals, out_mask, out_codes, scales)

    br = -(-rows // l)
    bc = -(-cols // w)
    for c in range(oc):
        for bi in range(br):
            for bj in range(bc):
                # Gather block (pad id = -1).
                vals, idxs = [], []
                for dr in range(l):
                    for dc in range(w):
                        r, col = bi * l + dr, bj * w + dc
                        if r < rows and col < cols:
                            vals.append(int(qvals[c, r, col]))
                            idxs.append((r, col))
                        else:
                            vals.append(0)
                            idxs.append(None)
                n = len(vals)
                # Selection keys matching rust quantize_block.
                keys = []
                for slot in range(n):
                    if idxs[slot] is None:
                        keys.append((-1, slot))  # pads first
                    elif method in ("sparsity", "dliq"):
                        keys.append((abs(vals[slot]) * 256 + (slot & 0xFF), slot))
                    elif method == "mip2q":
                        err = int(pow2_error(np.array([vals[slot]], np.int16), l_max)[0])
                        keys.append((err * 65536 + (slot & 0xFFFF), slot))
                    else:
                        raise ValueError(method)
                order = sorted(range(n), key=lambda s: keys[s])
                low_slots = set(order[:low_n])
                for slot in low_slots:
                    if idxs[slot] is None:
                        continue
                    r, col = idxs[slot]
                    v = np.array([vals[slot]], np.int16)
                    if method == "sparsity":
                        eff, code = np.zeros(1, np.int16), np.zeros(1, np.int16)
                    elif method == "dliq":
                        eff, code = dliq_requantize(v, q)
                    else:
                        eff, code = mip2q_requantize(v, l_max)
                    out_vals[c, r, col] = eff[0]
                    out_codes[c, r, col] = code[0]
                    out_mask[c, r, col] = False
    return StrumResult(out_vals, out_mask, out_codes, scales)


def dequantize(res: StrumResult) -> np.ndarray:
    return res.values.astype(np.float32) * res.scales[:, None, None]


def strum_transform_weight(w_f32: np.ndarray, method: str, p: float, **kw) -> np.ndarray:
    """float weight → calibrate → strum → dequantize, in original layout."""
    canon = to_canonical(w_f32)
    qv, scales = calibrate(canon)
    res = apply_strum(qv, scales, method, p, **kw)
    return from_canonical(dequantize(res), w_f32.shape)


if __name__ == "__main__":
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.1, size=(3, 3, 16, 32)).astype(np.float32)
    for method in ("baseline", "sparsity", "dliq", "mip2q"):
        out = strum_transform_weight(w, method, 0.5)
        err = np.abs(out - w).mean()
        print(f"{method:9s} mean |Δw| = {err:.5f}")
