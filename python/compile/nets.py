"""Mini-CNN zoo (Layer 2).

Ten small conv nets mirroring the architectural families of the paper's
Table I (VGG / ResNet / Inception / Darknet), trained from scratch on the
synthetic dataset. Each net is described by a spec tree; `init` builds the
parameter list, `apply` runs the forward pass, and `layer_meta` emits the
quantizable-tensor manifest that the rust side consumes (shapes + output
spatial dims for the FlexNN simulator).

Weights are always *arguments* of the jitted forward so one AOT-lowered HLO
evaluates any quantize-dequantized weight set. The classifier head runs
through the Pallas StruM GEMM kernel (two dense banks: high-precision and
low-precision), so the lowered HLO contains the Layer-1 kernel.

Activation fake-quant: `apply` takes a per-layer scale vector `act_scales`
(0 = float passthrough); scales are calibrated at build time (aot.py),
mirroring the paper's Graffitist INT8 static calibration.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.strum_matmul import strum_matmul_f32

# --------------------------------------------------------------------------
# Spec types


@dataclass
class Conv:
    name: str
    k: int
    oc: int
    pool: bool = False  # 2x2 avg pool after activation


@dataclass
class Residual:
    name: str
    oc: int  # both convs at this width; 1x1 projection if ic != oc


@dataclass
class Inception:
    name: str
    oc: int  # total output channels, split across 1x1 / 3x3 / 5x5 branches


NETS: dict[str, list] = {
    "mini_vgg_a": [
        Conv("c0", 3, 16),
        Conv("c1", 3, 32, pool=True),
        Conv("c2", 3, 32),
        Conv("c3", 3, 64, pool=True),
    ],
    "mini_vgg_b": [
        Conv("c0", 3, 16),
        Conv("c1", 3, 16),
        Conv("c2", 3, 32, pool=True),
        Conv("c3", 3, 32),
        Conv("c4", 3, 64, pool=True),
        Conv("c5", 3, 64),
    ],
    "mini_vgg_c": [
        Conv("c0", 3, 24),
        Conv("c1", 3, 48, pool=True),
        Conv("c2", 3, 48),
        Conv("c3", 3, 96, pool=True),
        Conv("c4", 3, 96),
    ],
    "mini_resnet_a": [
        Conv("stem", 3, 16),
        Residual("r0", 16),
        Conv("d0", 3, 32, pool=True),
        Residual("r1", 32),
    ],
    "mini_resnet_b": [
        Conv("stem", 3, 16),
        Residual("r0", 16),
        Conv("d0", 3, 32, pool=True),
        Residual("r1", 32),
        Conv("d1", 3, 64, pool=True),
        Residual("r2", 64),
    ],
    "mini_resnet_c": [
        Conv("stem", 3, 24),
        Residual("r0", 24),
        Conv("d0", 3, 48, pool=True),
        Residual("r1", 48),
        Residual("r2", 48),
    ],
    "mini_incept_a": [
        Conv("stem", 3, 16, pool=True),
        Inception("i0", 32),
        Conv("d0", 3, 48, pool=True),
    ],
    "mini_incept_b": [
        Conv("stem", 3, 16, pool=True),
        Inception("i0", 32),
        Inception("i1", 48),
        Conv("d0", 3, 64, pool=True),
    ],
    "mini_darknet": [
        Conv("c0", 3, 24, pool=True),
        Conv("c1", 1, 16),
        Conv("c2", 3, 32, pool=True),
        Conv("c3", 1, 16),
        Conv("c4", 3, 48),
    ],
    "mini_cnn_s": [
        Conv("c0", 3, 16, pool=True),
        Conv("c1", 3, 32, pool=True),
        Conv("c2", 3, 32),
    ],
}

NUM_CLASSES = 12
INPUT_HW = 32


# --------------------------------------------------------------------------
# Spec walking: enumerate weight tensors


def _inception_branches(ic: int, oc: int):
    """(name suffix, k, ic, oc) for each branch; oc split 1/4, 1/2, 1/4."""
    o1 = oc // 4
    o3 = oc // 2
    o5 = oc - o1 - o3
    return [("b1", 1, ic, o1), ("b3", 3, ic, o3), ("b5", 5, ic, o5)]


def layer_meta(net: str) -> list[dict]:
    """Quantizable-tensor manifest: one entry per conv/fc weight, in
    parameter order, with the output spatial dims the simulator needs."""
    spec = NETS[net]
    meta = []
    ic, hw = 3, INPUT_HW
    for s in spec:
        if isinstance(s, Conv):
            meta.append(
                dict(name=s.name, kind="conv", kh=s.k, kw=s.k, ic=ic, oc=s.oc, oh=hw, ow=hw)
            )
            ic = s.oc
            if s.pool:
                hw //= 2
        elif isinstance(s, Residual):
            for sub in ("a", "b"):
                meta.append(
                    dict(
                        name=f"{s.name}{sub}", kind="conv", kh=3, kw=3, ic=ic if sub == "a" else s.oc,
                        oc=s.oc, oh=hw, ow=hw,
                    )
                )
            if ic != s.oc:
                meta.append(
                    dict(name=f"{s.name}p", kind="conv", kh=1, kw=1, ic=ic, oc=s.oc, oh=hw, ow=hw)
                )
            ic = s.oc
        elif isinstance(s, Inception):
            for suffix, k, bic, boc in _inception_branches(ic, s.oc):
                meta.append(
                    dict(name=f"{s.name}{suffix}", kind="conv", kh=k, kw=k, ic=bic, oc=boc, oh=hw, ow=hw)
                )
            ic = s.oc
        else:
            raise TypeError(s)
    meta.append(dict(name="fc", kind="fc", kh=1, kw=1, ic=ic, oc=NUM_CLASSES, oh=1, ow=1))
    return meta


def param_shapes(net: str) -> list[tuple[str, tuple]]:
    """(name, shape) for every parameter (weights HWIO + biases), in order."""
    out = []
    for m in layer_meta(net):
        if m["kind"] == "conv":
            out.append((m["name"] + "_w", (m["kh"], m["kw"], m["ic"], m["oc"])))
            out.append((m["name"] + "_b", (m["oc"],)))
        else:
            out.append((m["name"] + "_w", (m["ic"], m["oc"])))
            out.append((m["name"] + "_b", (m["oc"],)))
    return out


def init_params(net: str, seed: int) -> list[np.ndarray]:
    """He-initialized parameters as a flat list matching param_shapes."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_shapes(net):
        if name.endswith("_b"):
            params.append(np.zeros(shape, dtype=np.float32))
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = float(np.sqrt(2.0 / fan_in))
            params.append(rng.normal(0, std, size=shape).astype(np.float32))
    return params


# --------------------------------------------------------------------------
# Forward pass


def _fq(x, s):
    """Symmetric INT8 fake-quant with scale s; s == 0 → float passthrough."""
    ss = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(x / ss), -127, 127) * ss
    return jnp.where(s > 0, q, x)


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b[None, None, None, :]


def _pool(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) * 0.25


def apply(net: str, params: list, x, act_scales, *, split_head: bool):
    """Forward pass.

    params: flat list per `param_shapes`, EXCEPT when split_head=True the
    final fc weight is replaced by two banks (w_hi, w_lo) — the StruM
    decomposition fed by the rust coordinator — and the head GEMM runs
    through the Pallas kernel. act_scales[i] fake-quants the input of
    quantizable layer i (0 disables).
    """
    spec = NETS[net]
    meta = layer_meta(net)
    p = list(params)
    li = 0  # index into meta / act_scales

    def take():
        nonlocal p
        v = p.pop(0)
        return v

    def conv_here(x, stride=1):
        nonlocal li
        w, b = take(), take()
        x = _fq(x, act_scales[li])
        li += 1
        return _conv(x, w, b, stride)

    for s in spec:
        if isinstance(s, Conv):
            x = jax.nn.relu(conv_here(x))
            if s.pool:
                x = _pool(x)
        elif isinstance(s, Residual):
            ic = x.shape[-1]
            y = jax.nn.relu(conv_here(x))
            y = conv_here(y)
            if ic != s.oc:
                sc = conv_here(x)
            else:
                sc = x
            x = jax.nn.relu(y + sc)
        elif isinstance(s, Inception):
            branches = []
            for _ in range(3):
                branches.append(jax.nn.relu(conv_here(x)))
            x = jnp.concatenate(branches, axis=-1)
        else:
            raise TypeError(s)

    # Global average pool → classifier head.
    x = jnp.mean(x, axis=(1, 2))
    x = _fq(x, act_scales[li])
    if split_head:
        w_hi, w_lo, b = p.pop(0), p.pop(0), p.pop(0)
        logits = strum_matmul_f32(x, w_hi, w_lo) + b[None, :]
    else:
        w, b = p.pop(0), p.pop(0)
        logits = x @ w + b[None, :]
    assert not p, f"unconsumed params: {len(p)}"
    assert li == len(meta) - 1, (li, len(meta))
    return logits


def num_quant_layers(net: str) -> int:
    return len(layer_meta(net))


if __name__ == "__main__":
    for net in NETS:
        meta = layer_meta(net)
        params = init_params(net, 0)
        x = jnp.zeros((2, 32, 32, 3), jnp.float32)
        scales = jnp.zeros((len(meta),), jnp.float32)
        y = apply(net, params, x, scales, split_head=False)
        n_params = sum(int(np.prod(p.shape)) for p in params)
        print(f"{net:16s} layers={len(meta):2d} params={n_params:7d} logits={y.shape}")
