//! Gateway integration tests — the replica-fleet tier end to end.
//!
//! Attach-mode tests mount in-process `WireServer` replicas (fast, no
//! child processes) under a `Gateway` and drive the full stack: fleet
//! health probing → shed-aware routing → retry/hedging → typed errors.
//! Supervised-mode tests spawn the real `strum` binary
//! (`CARGO_BIN_EXE_strum`) as child replicas: kill-mid-load chaos with
//! zero client-visible failures, and a corrupt-artifact rolling deploy
//! that must auto-roll-back.

use std::sync::Arc;
use std::time::{Duration, Instant};

use strum_dpu::backend::graph::{calibrate_act_scales, synth_net_weights};
use strum_dpu::backend::{Backend, BackendKind};
use strum_dpu::coordinator::{BatchPolicy, Engine, EngineOptions, Router, Variant};
use strum_dpu::gateway::{DeployPolicy, Gateway, GatewayOptions, HedgePolicy, ReplicaSpec};
use strum_dpu::model::eval::EvalConfig;
use strum_dpu::model::import::NetWeights;
use strum_dpu::quant::Method;
use strum_dpu::server::{
    AioServer, ErrorCode, WireClient, WireResponse, WireServer, WireServerOptions,
};
use strum_dpu::telemetry::{scan_dir, TailFilter, TelemetryConfig, TelemetrySink, TraceCtx};
use strum_dpu::util::json::Json;
use strum_dpu::util::prng::Rng;

const IMG: usize = 16;
const CLASSES: usize = 7;

fn calibrated_weights(seed: u64) -> NetWeights {
    let mut w = synth_net_weights("mini_cnn_s", IMG, CLASSES, seed).unwrap();
    let calib: Vec<f32> = {
        let mut rng = Rng::new(seed ^ 0xA5A5);
        (0..4 * IMG * IMG * 3).map(|_| rng.f32()).collect()
    };
    w.manifest.act_scales = calibrate_act_scales(&w, &calib, 4).unwrap();
    w
}

/// One in-process replica serving variant "base" from shared weights.
fn replica() -> (Arc<Engine>, WireServer, String) {
    let weights = calibrated_weights(33);
    let mut router = Router::native();
    let engine = Arc::new(Engine::start(EngineOptions {
        workers: 1,
        max_wait: Duration::from_millis(1),
        ..EngineOptions::default()
    }));
    let cfg = EvalConfig::paper(Method::Baseline, 0.0);
    let v = router.register_native_weights("base", &weights, &cfg).unwrap();
    engine.register(v).unwrap();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let addr = server.local_addr().to_string();
    (engine, server, addr)
}

fn random_image(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..IMG * IMG * 3).map(|_| rng.f32()).collect()
}

fn attach_gateway(addrs: Vec<String>, opts: GatewayOptions) -> (Gateway, WireServer, String) {
    let gw = Gateway::start(GatewayOptions {
        attach: addrs,
        probe_interval: Duration::from_millis(50),
        fail_threshold: 1,
        ..opts
    })
    .unwrap();
    let front = WireServer::bind_handler(
        "127.0.0.1:0",
        gw.handler(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = front.local_addr().to_string();
    (gw, front, addr)
}

/// Routing + failover: requests flow through the gateway to healthy
/// replicas; killing the replica taking the traffic reroutes (one
/// bounded retry) with zero client-visible errors.
#[test]
fn gateway_routes_and_fails_over_on_replica_death() {
    let (_e0, s0, a0) = replica();
    let (_e1, s1, a1) = replica();
    let (gw, front, addr) = attach_gateway(vec![a0, a1], GatewayOptions::default());
    assert!(gw.wait_healthy(2, Duration::from_secs(10)), "both replicas healthy");

    let mut client = WireClient::connect(&addr).unwrap();
    let image = random_image(5);
    for _ in 0..6 {
        let r = client.infer("base", &image).unwrap().into_infer().unwrap();
        assert_eq!(r.logits.len(), CLASSES);
    }
    // Sequential load always finds zero outstanding, so the lowest-id
    // replica (id 0) takes every request. Kill exactly that one.
    s0.shutdown();
    for _ in 0..6 {
        let r = client.infer("base", &image).unwrap().into_infer().unwrap();
        assert_eq!(r.logits.len(), CLASSES);
    }
    let view = gw.snapshot();
    // Either the router hit the dead replica and retried, or the prober
    // caught it first and routed around — both are correct failover.
    let r0_unhealthy = view.replicas.iter().any(|r| r.id == 0 && !r.healthy);
    assert!(
        view.retries >= 1 || r0_unhealthy,
        "failover left no trace (retries={}, fleet={:?})",
        view.retries,
        view.replicas
    );
    assert_eq!(view.upstream_errors, 0, "no request may surface an upstream error");
    assert_eq!(view.completed(), 12);
    front.shutdown();
    s1.shutdown();
    gw.shutdown();
}

/// Application errors are deterministic: forwarded verbatim, never
/// retried on another replica.
#[test]
fn gateway_does_not_retry_application_errors() {
    let (_e0, s0, a0) = replica();
    let (_e1, s1, a1) = replica();
    let (gw, front, addr) = attach_gateway(vec![a0, a1], GatewayOptions::default());
    assert!(gw.wait_healthy(2, Duration::from_secs(10)));
    let mut client = WireClient::connect(&addr).unwrap();
    let resp = client.infer("no-such-variant", &random_image(1)).unwrap();
    assert_eq!(resp.error_code(), Some(ErrorCode::UnknownVariant));
    let resp = client.infer("base", &[0.0f32; 3]).unwrap();
    assert_eq!(resp.error_code(), Some(ErrorCode::BadImage));
    assert_eq!(gw.snapshot().retries, 0, "app errors must not be retried");
    front.shutdown();
    s0.shutdown();
    s1.shutdown();
    gw.shutdown();
}

/// With no healthy replica the client gets a typed Upstream refusal —
/// not a hang, not a dropped connection.
#[test]
fn gateway_with_no_healthy_replica_returns_typed_upstream() {
    // An address nothing listens on: the replica never becomes healthy
    // (attached replicas start unroutable until a probe succeeds).
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        format!("127.0.0.1:{}", l.local_addr().unwrap().port())
    };
    let (gw, front, addr) = attach_gateway(vec![dead], GatewayOptions::default());
    let mut client = WireClient::connect(&addr).unwrap();
    let resp = client.infer("base", &random_image(1)).unwrap();
    assert_eq!(resp.error_code(), Some(ErrorCode::Upstream));
    assert!(gw.snapshot().upstream_errors >= 1);
    front.shutdown();
    gw.shutdown();
}

/// The gateway's metrics op reports fleet rows plus a variants
/// passthrough, so `strum loadgen` discovers keys exactly as it would
/// from a single replica.
#[test]
fn gateway_metrics_report_fleet_and_variant_passthrough() {
    let (_e0, s0, a0) = replica();
    let (gw, front, addr) = attach_gateway(vec![a0], GatewayOptions::default());
    assert!(gw.wait_healthy(1, Duration::from_secs(10)));
    let mut client = WireClient::connect(&addr).unwrap();
    client.infer("base", &random_image(3)).unwrap().into_infer().unwrap();
    let metrics = Json::parse(&client.metrics().unwrap()).unwrap();
    assert_eq!(metrics.get("gateway").and_then(|g| g.as_bool()), Some(true));
    let variants = metrics.get("variants").unwrap().as_arr().unwrap();
    assert_eq!(variants[0].get("key").unwrap().as_str().unwrap(), "base");
    assert_eq!(variants[0].get("img").unwrap().as_usize().unwrap(), IMG);
    let replicas = metrics.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(replicas.len(), 1);
    assert_eq!(replicas[0].get("state").unwrap().as_str().unwrap(), "up");
    assert_eq!(replicas[0].get("served").unwrap().as_usize().unwrap(), 1);
    front.shutdown();
    s0.shutdown();
    gw.shutdown();
}

// ---------------------------------------------------------------- hedging

/// Backend with a configurable service time (for hedge determinism).
struct SlowBackend {
    delay: Duration,
    sizes: Vec<usize>,
}

impl Backend for SlowBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }
    fn net(&self) -> &str {
        "slow"
    }
    fn classes(&self) -> usize {
        CLASSES
    }
    fn img(&self) -> usize {
        IMG
    }
    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }
    fn pick_batch(&self, n: usize) -> usize {
        n.max(1)
    }
    fn infer_batch(&self, _images: Vec<f32>, batch: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        Ok(vec![0.0; batch * CLASSES])
    }
}

fn slow_replica(delay: Duration) -> (Arc<Engine>, WireServer, String) {
    let engine = Arc::new(Engine::start(EngineOptions {
        workers: 1,
        max_wait: Duration::ZERO,
        ..EngineOptions::default()
    }));
    let variant = Arc::new(Variant {
        key: "slow".to_string(),
        net: "slow".to_string(),
        classes: CLASSES,
        img: IMG,
        backend: Arc::new(SlowBackend {
            delay,
            sizes: vec![1, 2, 4, 8, 16],
        }),
    });
    engine
        .register_with(
            variant,
            BatchPolicy {
                max_batch: 16,
                max_wait: Duration::ZERO,
            },
            64,
        )
        .unwrap();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let addr = server.local_addr().to_string();
    (engine, server, addr)
}

/// Tail hedging: when the primary dawdles past the hedge delay, the
/// backup answers first and wins.
#[test]
fn hedge_fires_and_backup_wins_against_a_slow_primary() {
    // Replica 0 (always picked first on idle ranks) is slow; replica 1
    // is fast. A 5 ms fixed hedge fires well inside the 150 ms primary.
    let (_e0, s0, a0) = slow_replica(Duration::from_millis(150));
    let (_e1, s1, a1) = slow_replica(Duration::from_millis(1));
    let (gw, front, addr) = attach_gateway(
        vec![a0, a1],
        GatewayOptions {
            hedge: Some(HedgePolicy::FixedMs(5)),
            ..GatewayOptions::default()
        },
    );
    assert!(gw.wait_healthy(2, Duration::from_secs(10)));
    let mut client = WireClient::connect(&addr).unwrap();
    let image = random_image(8);
    for _ in 0..3 {
        let r = client.infer("slow", &image).unwrap().into_infer().unwrap();
        assert_eq!(r.logits.len(), CLASSES);
        // Let the abandoned slow primary drain its outstanding slot, so
        // the next request picks the slow replica again (lowest id on an
        // idle tie) and must hedge again.
        std::thread::sleep(Duration::from_millis(200));
    }
    let view = gw.snapshot();
    assert!(view.hedges >= 3, "every request should have hedged (got {})", view.hedges);
    assert!(view.hedge_wins >= 1, "the fast backup should win at least once");
    front.shutdown();
    s0.shutdown();
    s1.shutdown();
    gw.shutdown();
}

/// [`slow_replica`] on the async tier: traced requests ride v2 frames
/// with the 9-byte trace tail, which the legacy blocking tier refuses
/// by design — both the front and the forward targets must speak v2.
fn aio_slow_replica(delay: Duration) -> (Arc<Engine>, AioServer, String) {
    let engine = Arc::new(Engine::start(EngineOptions {
        workers: 1,
        max_wait: Duration::ZERO,
        ..EngineOptions::default()
    }));
    let variant = Arc::new(Variant {
        key: "slow".to_string(),
        net: "slow".to_string(),
        classes: CLASSES,
        img: IMG,
        backend: Arc::new(SlowBackend {
            delay,
            sizes: vec![1, 2, 4, 8, 16],
        }),
    });
    engine
        .register_with(
            variant,
            BatchPolicy {
                max_batch: 16,
                max_wait: Duration::ZERO,
            },
            64,
        )
        .unwrap();
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        None,
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    (engine, server, addr)
}

/// Collects `(attempt, abandoned)` for every `gateway_attempt` span of
/// `trace` in `dir`, asserting each span carries the id bit-exact.
fn attempt_spans(dir: &std::path::Path, trace: u64) -> Vec<(u32, bool)> {
    let filter = TailFilter {
        trace: Some(trace),
        ..TailFilter::default()
    };
    let scan = scan_dir(dir, &filter).unwrap();
    scan.lines
        .iter()
        .filter(|l| l.tag == "span" && l.stage.as_deref() == Some("gateway_attempt"))
        .map(|l| {
            assert_eq!(l.trace, Some(trace), "trace id must survive bit-exact");
            (l.attempt, l.abandoned)
        })
        .collect()
}

/// A traced request keeps its 64-bit id bit-exact across a hedge: the
/// winner's and loser's `gateway_attempt` spans share the id under
/// distinct attempt ordinals, and exactly the loser is `abandoned`.
#[test]
fn traced_hedge_keeps_the_id_and_tags_the_loser_abandoned() {
    let dir = std::env::temp_dir().join(format!("strum-gw-trace-hedge-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sink = TelemetrySink::open(TelemetryConfig::under(&dir)).unwrap();

    let (_e0, s0, a0) = aio_slow_replica(Duration::from_millis(150));
    let (_e1, s1, a1) = aio_slow_replica(Duration::from_millis(1));
    let gw = Gateway::start(GatewayOptions {
        attach: vec![a0, a1],
        probe_interval: Duration::from_millis(50),
        fail_threshold: 1,
        hedge: Some(HedgePolicy::FixedMs(5)),
        telemetry: sink.clone(),
        ..GatewayOptions::default()
    })
    .unwrap();
    assert!(gw.wait_healthy(2, Duration::from_secs(10)));
    let front = AioServer::bind_handler(
        Some("127.0.0.1:0"),
        None,
        gw.handler(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = front.local_addr().unwrap().to_string();

    const TRACE: u64 = 0xC0FF_EED0_0D01;
    let mut client = WireClient::connect(&addr).unwrap();
    let r = client
        .infer_traced(
            "slow",
            &random_image(8),
            0,
            Some(TraceCtx {
                trace_id: TRACE,
                attempt: 0,
            }),
        )
        .unwrap()
        .into_infer()
        .unwrap();
    assert_eq!(r.logits.len(), CLASSES);
    assert!(gw.snapshot().hedges >= 1, "a 5 ms hedge must fire inside a 150 ms primary");
    // Let the abandoned slow forward drain before tearing its engine down.
    std::thread::sleep(Duration::from_millis(250));

    front.shutdown();
    s0.shutdown();
    s1.shutdown();
    gw.shutdown();
    sink.flush();

    let attempts = attempt_spans(&dir, TRACE);
    assert_eq!(attempts.len(), 2, "winner + loser spans (got {:?})", attempts);
    let mut ords: Vec<u32> = attempts.iter().map(|a| a.0).collect();
    ords.sort_unstable();
    assert_eq!(ords, vec![0, 1], "hedge attempts take distinct ordinals");
    assert_eq!(
        attempts.iter().filter(|a| a.1).count(),
        1,
        "exactly the loser is abandoned (got {:?})",
        attempts
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A transport-failure retry reuses the client's trace id under the
/// next attempt ordinal; neither span is abandoned — both outcomes
/// were read (one errored, one answered).
#[test]
fn traced_retry_reuses_the_id_with_distinct_attempts() {
    let dir = std::env::temp_dir().join(format!("strum-gw-trace-retry-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sink = TelemetrySink::open(TelemetryConfig::under(&dir)).unwrap();

    let (_e0, s0, a0) = aio_slow_replica(Duration::from_millis(1));
    let (_e1, s1, a1) = aio_slow_replica(Duration::from_millis(1));
    // A long probe interval keeps the prober out of the race: the dead
    // replica stays nominally routable, so the router itself must hit
    // the failure and retry under the same trace.
    let gw = Gateway::start(GatewayOptions {
        attach: vec![a0, a1],
        probe_interval: Duration::from_secs(30),
        fail_threshold: 1,
        telemetry: sink.clone(),
        ..GatewayOptions::default()
    })
    .unwrap();
    assert!(gw.wait_healthy(2, Duration::from_secs(10)));
    let front = AioServer::bind_handler(
        Some("127.0.0.1:0"),
        None,
        gw.handler(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = front.local_addr().unwrap().to_string();

    // Kill replica 0 — the idle-rank tie routes there first.
    s0.shutdown();
    const TRACE: u64 = 0x0DD_BA11;
    let mut client = WireClient::connect(&addr).unwrap();
    let r = client
        .infer_traced(
            "slow",
            &random_image(9),
            0,
            Some(TraceCtx {
                trace_id: TRACE,
                attempt: 0,
            }),
        )
        .unwrap()
        .into_infer()
        .unwrap();
    assert_eq!(r.logits.len(), CLASSES);
    assert!(gw.snapshot().retries >= 1, "dead replica must force a routed retry");

    front.shutdown();
    s1.shutdown();
    gw.shutdown();
    sink.flush();

    let mut attempts = attempt_spans(&dir, TRACE);
    attempts.sort_unstable();
    assert_eq!(
        attempts,
        vec![(0, false), (1, false)],
        "failed forward then retry share the trace, neither abandoned"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------- supervised replicas (chaos)

fn strum_binary() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_BIN_EXE_strum"))
}

fn serve_spec() -> ReplicaSpec {
    ReplicaSpec {
        binary: strum_binary(),
        args: [
            "serve",
            "--backend",
            "native",
            "--variants",
            "base",
            "--listen",
            "127.0.0.1:0",
            "--workers",
            "1",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        env: Vec::new(),
    }
}

/// Discovers the child fleet's variant key + image size via the
/// gateway's metrics passthrough.
fn discover_variant(addr: &str) -> (String, usize) {
    let mut client = WireClient::connect(addr).unwrap();
    let metrics = Json::parse(&client.metrics().unwrap()).unwrap();
    let v = &metrics.get("variants").unwrap().as_arr().unwrap()[0];
    (
        v.get("key").unwrap().as_str().unwrap().to_string(),
        v.get("img").unwrap().as_usize().unwrap(),
    )
}

/// THE chaos invariant: a replica armed to kill itself mid-run dies and
/// is restarted by its supervisor, and the client sees zero failed
/// requests throughout — sheds and retries are the gateway's problem.
#[test]
fn supervised_fleet_survives_replica_kill_with_zero_client_errors() {
    let gw = Gateway::start(GatewayOptions {
        replicas: 2,
        spec: Some(serve_spec()),
        // Replica slot 0 exits (code 113) after 5 inferences.
        fault_replica: Some((0, "kill-after=5".to_string())),
        probe_interval: Duration::from_millis(100),
        fail_threshold: 1,
        restart_backoff_base: Duration::from_millis(50),
        ..GatewayOptions::default()
    })
    .unwrap();
    assert!(
        gw.wait_healthy(2, Duration::from_secs(60)),
        "both children must come up"
    );
    let front = WireServer::bind_handler(
        "127.0.0.1:0",
        gw.handler(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = front.local_addr().to_string();
    let (key, img) = discover_variant(&addr);
    let image: Vec<f32> = {
        let mut rng = Rng::new(17);
        (0..img * img * 3).map(|_| rng.f32()).collect()
    };

    let mut client = WireClient::connect(&addr).unwrap();
    let mut completed = 0usize;
    for _ in 0..40 {
        match client.infer(&key, &image).unwrap() {
            WireResponse::Infer(_) => completed += 1,
            WireResponse::Error { code, detail } => {
                panic!("client-visible error {:?}: {}", code, detail)
            }
        }
    }
    assert_eq!(completed, 40, "zero client-visible failures through the kill");

    // The kill really happened and the supervisor restarted the slot.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let view = gw.snapshot();
        if view.replicas.iter().any(|r| r.restarts >= 1) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "expected a supervised restart; fleet: {:?}",
            view.replicas
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    let view = gw.snapshot();
    assert!(view.retries >= 1, "the kill must have forced at least one retry");
    assert_eq!(view.upstream_errors, 0);
    front.shutdown();
    gw.shutdown();
}

/// Rolling deploy of a corrupt artifact: the new cohort can never
/// become healthy (its replicas die loading the artifact), so the
/// deploy rolls back inside the health gate, latches the fatal flag
/// under fail_on_rollback, and the old cohort keeps serving.
#[test]
fn corrupt_artifact_deploy_rolls_back_and_old_cohort_keeps_serving() {
    let dir = std::env::temp_dir().join(format!("strum-gw-rollback-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let artifact_path = dir.join("push.strumc");

    let gw = Gateway::start(GatewayOptions {
        replicas: 1,
        spec: Some(serve_spec()),
        probe_interval: Duration::from_millis(100),
        fail_threshold: 2,
        restart_backoff_base: Duration::from_millis(50),
        watch: Some(DeployPolicy {
            artifact: artifact_path.clone(),
            replicas: 1,
            poll: Duration::from_millis(100),
            health_timeout: Duration::from_secs(5),
            probation: Duration::from_millis(300),
            regress_threshold: 0.2,
            fail_on_rollback: true,
        }),
        ..GatewayOptions::default()
    })
    .unwrap();
    assert!(gw.wait_healthy(1, Duration::from_secs(60)), "boot replica up");
    let front = WireServer::bind_handler(
        "127.0.0.1:0",
        gw.handler(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = front.local_addr().to_string();
    let (key, img) = discover_variant(&addr);
    let image: Vec<f32> = {
        let mut rng = Rng::new(23);
        (0..img * img * 3).map(|_| rng.f32()).collect()
    };
    let mut client = WireClient::connect(&addr).unwrap();
    client.infer(&key, &image).unwrap().into_infer().unwrap();

    // Push a new-version-but-corrupt artifact: a real compile from
    // DIFFERENT weights (new fingerprint → the watcher sees a new
    // version), truncated so `CompiledNet::load` fails in the children.
    let weights = calibrated_weights(99);
    let compiled =
        strum_dpu::artifact::compile_net(&weights, &EvalConfig::paper(Method::Baseline, 0.0))
            .unwrap();
    compiled.save(&artifact_path).unwrap();
    let bytes = std::fs::read(&artifact_path).unwrap();
    assert!(bytes.len() > 200, "artifact too small to truncate meaningfully");
    std::fs::write(&artifact_path, &bytes[..bytes.len() - 64]).unwrap();
    // The header still parses (new version visible)…
    strum_dpu::artifact::read_identity(&artifact_path).expect("truncated header must parse");
    // …but a full load fails, which is what the deploy children hit.
    assert!(strum_dpu::artifact::CompiledNet::load(&artifact_path).is_err());

    // The watcher must attempt the deploy, fail its health gate, and
    // roll back with the fatal latch.
    let deadline = Instant::now() + Duration::from_secs(60);
    while !gw.rollback_fired() {
        assert!(
            Instant::now() < deadline,
            "rollback never fired; fleet: {:?}",
            gw.snapshot().replicas
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    let view = gw.snapshot();
    assert_eq!(view.deploys, 1);
    assert_eq!(view.rollbacks, 1);
    assert_eq!(view.active_cohort, 0, "traffic must stay on the boot cohort");

    // The old cohort still serves.
    let r = client.infer(&key, &image).unwrap().into_infer().unwrap();
    assert_eq!(r.logits.len(), CLASSES);

    front.shutdown();
    gw.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
