//! Cross-module integration tests that need no artifacts: quantizer →
//! codec → simulator → hardware-model pipelines on synthetic layers, and
//! the serving engine's scheduling/backpressure/shutdown contracts
//! exercised against a gated mock backend (deterministic, no model in
//! the loop).

use strum_dpu::encode::compression::ratio_for;
use strum_dpu::encode::{decode_layer, encode_layer};
use strum_dpu::hw::dpu::DpuConfig;
use strum_dpu::hw::power::{power, Activity};
use strum_dpu::hw::PeVariant;
use strum_dpu::quant::tensor::qlayer;
use strum_dpu::quant::{apply_strum, apply_unstructured, Method, StrumParams};
use strum_dpu::sim::config::SimConfig;
use strum_dpu::sim::dataflow::LayerShape;
use strum_dpu::sim::driver::{simulate_layer, simulate_network};
use strum_dpu::sim::SimMode;
use strum_dpu::util::prng::Rng;

fn conv_layer(
    name: &str,
    oc: usize,
    ic: usize,
    k: usize,
    oh: usize,
    seed: u64,
) -> (LayerShape, strum_dpu::quant::QLayer) {
    let mut rng = Rng::new(seed);
    let data: Vec<i8> = (0..oc * k * k * ic)
        .map(|_| (rng.gaussian() * 45.0).clamp(-127.0, 127.0) as i8)
        .collect();
    (
        LayerShape::conv(name, oc, ic, k, oh, oh),
        qlayer(name, oc, k * k, ic, data, vec![0.01; oc]),
    )
}

/// quantize → encode → decode → simulate: the decoded layer must drive
/// the simulator to the identical cycle count and datapath behaviour as
/// the in-memory transform (what the real hardware does: it only ever
/// sees the compressed stream).
#[test]
fn decoded_stream_drives_identical_simulation() {
    let (shape, q) = conv_layer("c", 32, 64, 3, 8, 1);
    for method in [
        Method::StructuredSparsity,
        Method::Dliq { q: 4 },
        Method::Mip2q { l_max: 7 },
    ] {
        let s = apply_strum(&q, &StrumParams::paper(method, 0.5));
        let dec = decode_layer(&encode_layer(&s)).unwrap();
        let cfg = SimConfig::flexnn(SimMode::StrumStatic, Some(method));
        let a = simulate_layer(&shape, &s, &cfg, 0.7, 3);
        let b = simulate_layer(&shape, &dec, &cfg, 0.7, 3);
        assert_eq!(a.cycles, b.cycles, "{:?}", method);
        assert_eq!(a.mult_ops, b.mult_ops);
        assert_eq!(a.low_ops, b.low_ops);
    }
}

/// The full §V-B performance story on one synthetic network:
/// dense < sparse(0.5-dense acts) ; strum-perf = 2× dense ; static StruM
/// fallback = ½ dense on INT8 layers.
#[test]
fn performance_story_holds_end_to_end() {
    let (shape, q) = conv_layer("c", 32, 128, 1, 16, 2);
    let base = apply_strum(&q, &StrumParams::paper(Method::Baseline, 0.0));
    let strum = apply_strum(&q, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));

    let dense = simulate_layer(&shape, &base, &SimConfig::flexnn(SimMode::Int8Dense, None), 1.0, 0);
    let perf = simulate_layer(
        &shape,
        &strum,
        &SimConfig::flexnn(SimMode::StrumPerf, Some(Method::Mip2q { l_max: 7 })),
        1.0,
        0,
    );
    assert_eq!(perf.speedup_vs(&dense), 2.0, "guaranteed 2x");

    let fallback = simulate_layer(
        &shape,
        &base,
        &SimConfig::flexnn(SimMode::StrumStatic, None),
        1.0,
        0,
    );
    assert_eq!(fallback.cycles, 2 * dense.cycles, "INT8 fallback = half rate");

    let sparse = simulate_layer(
        &shape,
        &base,
        &SimConfig::flexnn(SimMode::SparseFindFirst, None),
        0.4,
        7,
    );
    assert!(sparse.cycles < dense.cycles, "find-first exploits zero acts");
}

/// Slowest-PE ablation at network scale: unstructured placement must cost
/// cycles vs structured at identical p, while having no-worse RMSE.
#[test]
fn unstructured_tradeoff_is_visible() {
    let layers: Vec<_> = (0..3)
        .map(|i| conv_layer(&format!("c{}", i), 32, 64 + 32 * i, 3, 8, 10 + i as u64))
        .collect();
    let method = Method::Mip2q { l_max: 7 };
    let cfg = SimConfig::flexnn(SimMode::StrumPerf, Some(method));
    let mut s_cycles = 0;
    let mut u_cycles = 0;
    for (shape, q) in &layers {
        let s = apply_strum(q, &StrumParams::paper(method, 0.5));
        let u = apply_unstructured(q, method, 0.5);
        assert!(u.grid_rmse <= s.grid_rmse + 1e-9);
        s_cycles += simulate_layer(shape, &s, &cfg, 1.0, 0).cycles;
        u_cycles += simulate_layer(shape, &u, &cfg, 1.0, 0).cycles;
    }
    assert!(
        u_cycles > s_cycles,
        "unstructured {} should exceed structured {}",
        u_cycles,
        s_cycles
    );
}

/// Sim-activity → power-model integration: a StruM run on the StruM PE
/// must save PE-level power vs the dense run on the baseline PE, within
/// the paper's band, and the compressed stream must shrink SRAM traffic.
#[test]
fn sim_activity_feeds_power_model() {
    let (shape, q) = conv_layer("c", 64, 128, 3, 8, 5);
    let base = apply_strum(&q, &StrumParams::paper(Method::Baseline, 0.0));
    let strum = apply_strum(&q, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));

    let (_, dense_act) = simulate_network(
        &[(shape.clone(), base)],
        &SimConfig::flexnn(SimMode::Int8Dense, None),
        0.7,
        0,
    );
    let (_, strum_act) = simulate_network(
        &[(shape, strum)],
        &SimConfig::flexnn(SimMode::StrumStatic, Some(Method::Mip2q { l_max: 7 })),
        0.7,
        0,
    );
    let cfg = DpuConfig::flexnn_16x16();
    let p_base = power(PeVariant::BaselineInt8, &dense_act, &cfg);
    let p_strum = power(PeVariant::StaticMip2q { l_max: 7 }, &strum_act, &cfg);
    let save = 1.0 - p_strum.pe_level() / p_base.pe_level();
    assert!(
        (0.15..0.50).contains(&save),
        "PE power saving from sim activity: {}",
        save
    );
    // Compressed weights shrink SRAM traffic (r = 7/8 at p=.5, q=4).
    assert!(strum_act.sram_bytes < dense_act.sram_bytes);
}

/// Weight-memory accounting across the whole pipeline matches Eq. 1.
#[test]
fn memory_accounting_matches_eq1() {
    let (_, q) = conv_layer("c", 16, 64, 1, 8, 9);
    let s = apply_strum(&q, &StrumParams::paper(Method::Dliq { q: 4 }, 0.5));
    let enc = encode_layer(&s);
    assert!((enc.measured_ratio() - ratio_for(Method::Dliq { q: 4 }, 0.5)).abs() < 1e-12);
    assert!((enc.measured_ratio() - 0.875).abs() < 1e-12);
}

// ---------------------------------------------------------------------------
// Serving engine contracts, driven through a gated mock backend: the gate
// holds `infer_batch` so queue states can be staged deterministically, and
// the execution log exposes the deficit-round-robin order.
// ---------------------------------------------------------------------------

mod engine_contracts {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};
    use strum_dpu::backend::{Backend, BackendKind};
    use strum_dpu::coordinator::{
        BatchPolicy, Engine, EngineOptions, ReplyError, SubmitError, Variant,
    };

    /// Backend whose `infer_batch` blocks until `gate` opens, logging the
    /// variant key of each executed batch. The reply class is the first
    /// pixel of each image, so correctness is checkable end to end.
    struct MockBackend {
        key: String,
        img: usize,
        classes: usize,
        sizes: Vec<usize>,
        gate: Arc<AtomicBool>,
        log: Arc<Mutex<Vec<String>>>,
    }

    impl Backend for MockBackend {
        fn kind(&self) -> BackendKind {
            BackendKind::Native
        }
        fn net(&self) -> &str {
            "mock"
        }
        fn classes(&self) -> usize {
            self.classes
        }
        fn img(&self) -> usize {
            self.img
        }
        fn batch_sizes(&self) -> &[usize] {
            &self.sizes
        }
        fn pick_batch(&self, n: usize) -> usize {
            n.max(1)
        }
        fn infer_batch(&self, images: Vec<f32>, batch: usize) -> anyhow::Result<Vec<f32>> {
            while !self.gate.load(Ordering::Acquire) {
                std::thread::sleep(Duration::from_micros(200));
            }
            self.log.lock().unwrap().push(self.key.clone());
            let px = self.img * self.img * 3;
            let mut out = vec![0f32; batch * self.classes];
            for b in 0..batch {
                let class = (images[b * px] as usize).min(self.classes - 1);
                out[b * self.classes + class] = 1.0;
            }
            Ok(out)
        }
    }

    const IMG: usize = 2;
    const CLASSES: usize = 4;

    fn mock_variant(
        key: &str,
        gate: Arc<AtomicBool>,
        log: Arc<Mutex<Vec<String>>>,
    ) -> Arc<Variant> {
        Arc::new(Variant {
            key: key.to_string(),
            net: "mock".to_string(),
            classes: CLASSES,
            img: IMG,
            backend: Arc::new(MockBackend {
                key: key.to_string(),
                img: IMG,
                classes: CLASSES,
                sizes: vec![1, 2, 4, 8, 16],
                gate,
                log,
            }),
        })
    }

    /// Image whose first pixel encodes the expected reply class.
    fn image_for(class: usize) -> Vec<f32> {
        let mut v = vec![0f32; IMG * IMG * 3];
        v[0] = class as f32;
        v
    }

    /// Waits until the engine has dispatched `n` batches for `key`
    /// (i.e. a worker is inside the gated `infer_batch`).
    fn wait_batches(engine: &Engine, key: &str, n: u64) {
        for _ in 0..5000 {
            let snap = engine.metrics();
            if snap
                .variants
                .iter()
                .find(|v| v.key == key)
                .map(|v| v.batches >= n)
                .unwrap_or(false)
            {
                return;
            }
            std::thread::sleep(Duration::from_micros(500));
        }
        panic!("variant {} never reached {} dispatched batches", key, n);
    }

    /// Per-request flush policy: every submit is its own batch, so the
    /// execution log shows exactly how the scheduler interleaves.
    fn one_by_one() -> BatchPolicy {
        BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
        }
    }

    /// A hot variant with a deep backlog cannot starve a cold one: after
    /// the round-robin pass the cold variant's requests execute among
    /// the first few batches, not after the hot queue drains.
    #[test]
    fn drr_scheduler_prevents_starvation() {
        let gate = Arc::new(AtomicBool::new(false));
        let log = Arc::new(Mutex::new(Vec::new()));
        let engine = Engine::start(EngineOptions {
            workers: 1,
            ..EngineOptions::default()
        });
        let hot = engine
            .register_with(mock_variant("hot", gate.clone(), log.clone()), one_by_one(), 64)
            .unwrap();
        let cold = engine
            .register_with(mock_variant("cold", gate.clone(), log.clone()), one_by_one(), 64)
            .unwrap();

        // First hot request is picked and blocks on the gate; 18 more
        // hot requests plus 2 cold ones pile up behind it.
        let mut tickets = vec![hot.submit(image_for(1)).unwrap()];
        wait_batches(&engine, "hot", 1);
        for _ in 0..18 {
            tickets.push(hot.submit(image_for(1)).unwrap());
        }
        let cold_tickets: Vec<_> = (0..2).map(|_| cold.submit(image_for(2)).unwrap()).collect();
        gate.store(true, Ordering::Release);

        for t in tickets {
            let r = t.wait_deadline(Duration::from_secs(10)).unwrap();
            assert_eq!(r.class, 1);
        }
        for t in cold_tickets {
            let r = t.wait_deadline(Duration::from_secs(10)).unwrap();
            assert_eq!(r.class, 2);
        }
        let order = log.lock().unwrap().clone();
        assert_eq!(order.len(), 21);
        let last_cold = order.iter().rposition(|k| k == "cold").unwrap();
        assert!(
            last_cold <= 6,
            "cold starved: served at positions {:?}",
            order
                .iter()
                .enumerate()
                .filter(|(_, k)| *k == "cold")
                .map(|(i, _)| i)
                .collect::<Vec<_>>()
        );
        engine.shutdown();
    }

    /// Bounded queues refuse with `QueueFull` at the configured depth
    /// instead of buffering unboundedly; queued work still completes.
    #[test]
    fn queue_full_backpressure() {
        let gate = Arc::new(AtomicBool::new(false));
        let log = Arc::new(Mutex::new(Vec::new()));
        let engine = Engine::start(EngineOptions {
            workers: 1,
            ..EngineOptions::default()
        });
        let h = engine
            .register_with(mock_variant("v", gate.clone(), log), one_by_one(), 2)
            .unwrap();
        // Worker takes the first request and blocks; two fit the queue.
        let t0 = h.submit(image_for(0)).unwrap();
        wait_batches(&engine, "v", 1);
        let t1 = h.submit(image_for(1)).unwrap();
        let t2 = h.submit(image_for(2)).unwrap();
        // Depth 2 reached: the next submit is refused, typed.
        let err = h.submit(image_for(3)).unwrap_err();
        assert!(
            matches!(err, SubmitError::QueueFull { depth: 2, .. }),
            "unexpected error {:?}",
            err
        );
        let snap = engine.metrics();
        assert_eq!(snap.variants[0].rejected, 1);
        assert_eq!(snap.variants[0].queued, 2);
        // Backpressure sheds load; accepted work is never dropped.
        gate.store(true, Ordering::Release);
        for (t, want) in [(t0, 0), (t1, 1), (t2, 2)] {
            let r = t.wait_deadline(Duration::from_secs(10)).unwrap();
            assert_eq!(r.class, want);
        }
        engine.shutdown();
    }

    /// Submitting after shutdown returns `ShuttingDown` — the old API
    /// enqueued into a dead pool and the caller hung forever.
    #[test]
    fn submit_after_shutdown_returns_shutting_down() {
        let gate = Arc::new(AtomicBool::new(true));
        let log = Arc::new(Mutex::new(Vec::new()));
        let engine = Engine::start(EngineOptions {
            workers: 1,
            ..EngineOptions::default()
        });
        let h = engine
            .register_with(mock_variant("v", gate, log), one_by_one(), 8)
            .unwrap();
        let t = h.submit(image_for(3)).unwrap();
        assert_eq!(t.wait_deadline(Duration::from_secs(10)).unwrap().class, 3);
        engine.shutdown();
        // The handle outlives the engine; it must fail fast, not hang.
        let err = h.submit(image_for(0)).unwrap_err();
        assert_eq!(err, SubmitError::ShuttingDown);
    }

    /// Routing misses and malformed images are typed errors too.
    #[test]
    fn submit_errors_are_typed() {
        let gate = Arc::new(AtomicBool::new(true));
        let log = Arc::new(Mutex::new(Vec::new()));
        let engine = Engine::start(EngineOptions {
            workers: 1,
            ..EngineOptions::default()
        });
        engine
            .register_with(mock_variant("v", gate, log), one_by_one(), 8)
            .unwrap();
        assert!(matches!(
            engine.submit("nope", image_for(0)).unwrap_err(),
            SubmitError::UnknownVariant { .. }
        ));
        assert!(matches!(
            engine.submit("v", vec![0.0; 5]).unwrap_err(),
            SubmitError::BadImage { expected, got: 5, .. } if expected == IMG * IMG * 3
        ));
        // Duplicate registration is refused at the engine API.
        let gate2 = Arc::new(AtomicBool::new(true));
        let log2 = Arc::new(Mutex::new(Vec::new()));
        assert!(engine
            .register_with(mock_variant("v", gate2, log2), one_by_one(), 8)
            .is_err());
        engine.shutdown();
    }

    /// Shutdown drains queued requests (deadlines waived) before the
    /// workers exit — nothing accepted is ever dropped.
    #[test]
    fn shutdown_drains_pending_queue() {
        let gate = Arc::new(AtomicBool::new(false));
        let log = Arc::new(Mutex::new(Vec::new()));
        let engine = Engine::start(EngineOptions {
            workers: 1,
            ..EngineOptions::default()
        });
        // A long deadline + big batch cap: nothing flushes on its own.
        let lazy = BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_secs(60),
        };
        let h = engine
            .register_with(mock_variant("v", gate.clone(), log), lazy, 16)
            .unwrap();
        let tickets: Vec<_> = (0..5).map(|i| h.submit(image_for(i % 4)).unwrap()).collect();
        gate.store(true, Ordering::Release);
        // Shutdown must flush the still-waiting batch promptly rather
        // than waiting out the 60 s deadline.
        engine.shutdown();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait_deadline(Duration::from_secs(10)).unwrap();
            assert_eq!(r.class, i % 4);
        }
    }

    /// `wait_deadline` expiry semantics: the timeout is a typed
    /// [`ReplyError::DeadlineExpired`] (never a hang), the ticket stays
    /// usable, and a result that arrives after the deadline is still
    /// takeable via `try_take`.
    #[test]
    fn wait_deadline_expiry_is_typed_and_late_reply_is_takeable() {
        let gate = Arc::new(AtomicBool::new(false));
        let log = Arc::new(Mutex::new(Vec::new()));
        let engine = Engine::start(EngineOptions {
            workers: 1,
            ..EngineOptions::default()
        });
        let h = engine
            .register_with(mock_variant("v", gate.clone(), log), one_by_one(), 8)
            .unwrap();
        let t = h.submit(image_for(3)).unwrap();
        // Gate closed: the bounded wait must come back typed, promptly.
        let err = t.wait_deadline(Duration::from_millis(20)).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ReplyError>(),
            Some(&ReplyError::DeadlineExpired)
        );
        // Still in flight — nothing to take yet.
        assert!(t.try_take().is_none());
        // The request itself was not cancelled: once the backend runs,
        // the late reply is collectable from the same ticket.
        gate.store(true, Ordering::Release);
        let mut reply = None;
        for _ in 0..5000 {
            if let Some(r) = t.try_take() {
                reply = Some(r);
                break;
            }
            std::thread::sleep(Duration::from_micros(500));
        }
        let r = reply.expect("late reply never arrived").unwrap();
        assert_eq!(r.class, 3);
        engine.shutdown();
    }

    /// Per-request deadlines shed at both stages: an already-expired
    /// deadline is refused at the door (typed `SubmitError::Expired`,
    /// nothing enqueued), and one that lapses while queued is shed by
    /// the worker before execution (typed `ReplyError::Shed` through the
    /// ticket). Both are counted in the variant's shed metric.
    #[test]
    fn deadlines_shed_at_door_and_in_queue() {
        let gate = Arc::new(AtomicBool::new(false));
        let log = Arc::new(Mutex::new(Vec::new()));
        let engine = Engine::start(EngineOptions {
            workers: 1,
            ..EngineOptions::default()
        });
        let h = engine
            .register_with(mock_variant("v", gate.clone(), log.clone()), one_by_one(), 8)
            .unwrap();
        // Door shed: the deadline has passed by the time the check runs.
        let err = h
            .submit_deadline(image_for(0), Some(Instant::now()))
            .unwrap_err();
        assert!(matches!(err, SubmitError::Expired { .. }), "{:?}", err);
        // Queue shed: pin the worker on a no-deadline request, enqueue a
        // short-deadline one behind it, and let the budget lapse.
        let t_pin = h.submit(image_for(1)).unwrap();
        wait_batches(&engine, "v", 1);
        let t_short = h
            .submit_deadline(
                image_for(2),
                Some(Instant::now() + Duration::from_millis(5)),
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(20));
        gate.store(true, Ordering::Release);
        assert_eq!(t_pin.wait_deadline(Duration::from_secs(10)).unwrap().class, 1);
        let err = t_short.wait_deadline(Duration::from_secs(10)).unwrap_err();
        assert_eq!(err.downcast_ref::<ReplyError>(), Some(&ReplyError::Shed));
        let snap = engine.metrics();
        assert_eq!(snap.variants[0].shed, 2);
        assert_eq!(snap.variants[0].completed, 1);
        // The shed request never reached the backend: only the pin ran.
        assert_eq!(log.lock().unwrap().len(), 1);
        engine.shutdown();
    }

    /// Per-variant priority weights: quantum 4 vs 1 drains the heavy
    /// variant in ~4-request batches while the light one goes one at a
    /// time — weighted credit, not starvation (both fleets complete).
    #[test]
    fn weighted_drr_drains_by_priority() {
        let gate = Arc::new(AtomicBool::new(false));
        let log = Arc::new(Mutex::new(Vec::new()));
        let engine = Engine::start(EngineOptions {
            workers: 1,
            ..EngineOptions::default()
        });
        let eager = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::ZERO,
        };
        let heavy = engine
            .register_weighted(
                mock_variant("heavy", gate.clone(), log.clone()),
                eager.clone(),
                64,
                4,
            )
            .unwrap();
        let light = engine
            .register_weighted(mock_variant("light", gate.clone(), log.clone()), eager, 64, 1)
            .unwrap();
        // Pin the worker on the first heavy request, then build both
        // backlogs while it blocks.
        let mut tickets = vec![heavy.submit(image_for(1)).unwrap()];
        wait_batches(&engine, "heavy", 1);
        for _ in 0..12 {
            tickets.push(heavy.submit(image_for(1)).unwrap());
        }
        let light_tickets: Vec<_> =
            (0..12).map(|_| light.submit(image_for(2)).unwrap()).collect();
        gate.store(true, Ordering::Release);
        for t in tickets {
            assert_eq!(t.wait_deadline(Duration::from_secs(10)).unwrap().class, 1);
        }
        for t in light_tickets {
            assert_eq!(t.wait_deadline(Duration::from_secs(10)).unwrap().class, 2);
        }
        let snap = engine.metrics();
        let heavy_snap = snap.variants.iter().find(|v| v.key == "heavy").unwrap();
        let light_snap = snap.variants.iter().find(|v| v.key == "light").unwrap();
        assert_eq!(heavy_snap.completed, 13);
        assert_eq!(light_snap.completed, 12);
        // Credit 4 cuts heavy's backlog into ~4-request batches (1 pin +
        // 3×4); credit 1 caps light at singles despite the same backlog.
        assert!(
            heavy_snap.batches <= 6,
            "heavy drained in {} batches (want few, large)",
            heavy_snap.batches
        );
        assert!(
            light_snap.batches >= 10,
            "light drained in {} batches (want ~12 singles)",
            light_snap.batches
        );
        assert!(heavy_snap.mean_batch > light_snap.mean_batch);
        engine.shutdown();
    }
}

/// Dense analytic activity and simulated dense activity agree on the
/// ordering of DPU power across variants (model consistency).
#[test]
fn analytic_and_simulated_activity_agree_on_ordering() {
    let cfg = DpuConfig::flexnn_16x16();
    let (shape, q) = conv_layer("c", 32, 64, 3, 8, 12);
    let strum = apply_strum(&q, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));
    let (_, sim_act) = simulate_network(
        &[(shape, strum)],
        &SimConfig::flexnn(SimMode::StrumStatic, Some(Method::Mip2q { l_max: 7 })),
        0.7,
        0,
    );
    let dense_act = Activity::dense(256, 10_000, 0.5);
    for act in [&sim_act, &dense_act] {
        let b = power(PeVariant::BaselineInt8, act, &cfg).dpu_level();
        let s7 = power(PeVariant::StaticMip2q { l_max: 7 }, act, &cfg).dpu_level();
        let s5 = power(PeVariant::StaticMip2q { l_max: 5 }, act, &cfg).dpu_level();
        assert!(s5 <= s7 && s7 < b);
    }
}
