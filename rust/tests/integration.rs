//! Cross-module integration tests that need no artifacts: quantizer →
//! codec → simulator → hardware-model pipelines on synthetic layers, and
//! the coordinator's batching logic under a mock-free load (policy level).

use strum_dpu::encode::compression::ratio_for;
use strum_dpu::encode::{decode_layer, encode_layer};
use strum_dpu::hw::dpu::DpuConfig;
use strum_dpu::hw::power::{power, Activity};
use strum_dpu::hw::PeVariant;
use strum_dpu::quant::tensor::qlayer;
use strum_dpu::quant::{apply_strum, apply_unstructured, Method, StrumParams};
use strum_dpu::sim::config::SimConfig;
use strum_dpu::sim::dataflow::LayerShape;
use strum_dpu::sim::driver::{simulate_layer, simulate_network};
use strum_dpu::sim::SimMode;
use strum_dpu::util::prng::Rng;

fn conv_layer(
    name: &str,
    oc: usize,
    ic: usize,
    k: usize,
    oh: usize,
    seed: u64,
) -> (LayerShape, strum_dpu::quant::QLayer) {
    let mut rng = Rng::new(seed);
    let data: Vec<i8> = (0..oc * k * k * ic)
        .map(|_| (rng.gaussian() * 45.0).clamp(-127.0, 127.0) as i8)
        .collect();
    (
        LayerShape::conv(name, oc, ic, k, oh, oh),
        qlayer(name, oc, k * k, ic, data, vec![0.01; oc]),
    )
}

/// quantize → encode → decode → simulate: the decoded layer must drive
/// the simulator to the identical cycle count and datapath behaviour as
/// the in-memory transform (what the real hardware does: it only ever
/// sees the compressed stream).
#[test]
fn decoded_stream_drives_identical_simulation() {
    let (shape, q) = conv_layer("c", 32, 64, 3, 8, 1);
    for method in [
        Method::StructuredSparsity,
        Method::Dliq { q: 4 },
        Method::Mip2q { l_max: 7 },
    ] {
        let s = apply_strum(&q, &StrumParams::paper(method, 0.5));
        let dec = decode_layer(&encode_layer(&s)).unwrap();
        let cfg = SimConfig::flexnn(SimMode::StrumStatic, Some(method));
        let a = simulate_layer(&shape, &s, &cfg, 0.7, 3);
        let b = simulate_layer(&shape, &dec, &cfg, 0.7, 3);
        assert_eq!(a.cycles, b.cycles, "{:?}", method);
        assert_eq!(a.mult_ops, b.mult_ops);
        assert_eq!(a.low_ops, b.low_ops);
    }
}

/// The full §V-B performance story on one synthetic network:
/// dense < sparse(0.5-dense acts) ; strum-perf = 2× dense ; static StruM
/// fallback = ½ dense on INT8 layers.
#[test]
fn performance_story_holds_end_to_end() {
    let (shape, q) = conv_layer("c", 32, 128, 1, 16, 2);
    let base = apply_strum(&q, &StrumParams::paper(Method::Baseline, 0.0));
    let strum = apply_strum(&q, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));

    let dense = simulate_layer(&shape, &base, &SimConfig::flexnn(SimMode::Int8Dense, None), 1.0, 0);
    let perf = simulate_layer(
        &shape,
        &strum,
        &SimConfig::flexnn(SimMode::StrumPerf, Some(Method::Mip2q { l_max: 7 })),
        1.0,
        0,
    );
    assert_eq!(perf.speedup_vs(&dense), 2.0, "guaranteed 2x");

    let fallback = simulate_layer(
        &shape,
        &base,
        &SimConfig::flexnn(SimMode::StrumStatic, None),
        1.0,
        0,
    );
    assert_eq!(fallback.cycles, 2 * dense.cycles, "INT8 fallback = half rate");

    let sparse = simulate_layer(
        &shape,
        &base,
        &SimConfig::flexnn(SimMode::SparseFindFirst, None),
        0.4,
        7,
    );
    assert!(sparse.cycles < dense.cycles, "find-first exploits zero acts");
}

/// Slowest-PE ablation at network scale: unstructured placement must cost
/// cycles vs structured at identical p, while having no-worse RMSE.
#[test]
fn unstructured_tradeoff_is_visible() {
    let layers: Vec<_> = (0..3)
        .map(|i| conv_layer(&format!("c{}", i), 32, 64 + 32 * i, 3, 8, 10 + i as u64))
        .collect();
    let method = Method::Mip2q { l_max: 7 };
    let cfg = SimConfig::flexnn(SimMode::StrumPerf, Some(method));
    let mut s_cycles = 0;
    let mut u_cycles = 0;
    for (shape, q) in &layers {
        let s = apply_strum(q, &StrumParams::paper(method, 0.5));
        let u = apply_unstructured(q, method, 0.5);
        assert!(u.grid_rmse <= s.grid_rmse + 1e-9);
        s_cycles += simulate_layer(shape, &s, &cfg, 1.0, 0).cycles;
        u_cycles += simulate_layer(shape, &u, &cfg, 1.0, 0).cycles;
    }
    assert!(
        u_cycles > s_cycles,
        "unstructured {} should exceed structured {}",
        u_cycles,
        s_cycles
    );
}

/// Sim-activity → power-model integration: a StruM run on the StruM PE
/// must save PE-level power vs the dense run on the baseline PE, within
/// the paper's band, and the compressed stream must shrink SRAM traffic.
#[test]
fn sim_activity_feeds_power_model() {
    let (shape, q) = conv_layer("c", 64, 128, 3, 8, 5);
    let base = apply_strum(&q, &StrumParams::paper(Method::Baseline, 0.0));
    let strum = apply_strum(&q, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));

    let (_, dense_act) = simulate_network(
        &[(shape.clone(), base)],
        &SimConfig::flexnn(SimMode::Int8Dense, None),
        0.7,
        0,
    );
    let (_, strum_act) = simulate_network(
        &[(shape, strum)],
        &SimConfig::flexnn(SimMode::StrumStatic, Some(Method::Mip2q { l_max: 7 })),
        0.7,
        0,
    );
    let cfg = DpuConfig::flexnn_16x16();
    let p_base = power(PeVariant::BaselineInt8, &dense_act, &cfg);
    let p_strum = power(PeVariant::StaticMip2q { l_max: 7 }, &strum_act, &cfg);
    let save = 1.0 - p_strum.pe_level() / p_base.pe_level();
    assert!(
        (0.15..0.50).contains(&save),
        "PE power saving from sim activity: {}",
        save
    );
    // Compressed weights shrink SRAM traffic (r = 7/8 at p=.5, q=4).
    assert!(strum_act.sram_bytes < dense_act.sram_bytes);
}

/// Weight-memory accounting across the whole pipeline matches Eq. 1.
#[test]
fn memory_accounting_matches_eq1() {
    let (_, q) = conv_layer("c", 16, 64, 1, 8, 9);
    let s = apply_strum(&q, &StrumParams::paper(Method::Dliq { q: 4 }, 0.5));
    let enc = encode_layer(&s);
    assert!((enc.measured_ratio() - ratio_for(Method::Dliq { q: 4 }, 0.5)).abs() < 1e-12);
    assert!((enc.measured_ratio() - 0.875).abs() < 1e-12);
}

/// Dense analytic activity and simulated dense activity agree on the
/// ordering of DPU power across variants (model consistency).
#[test]
fn analytic_and_simulated_activity_agree_on_ordering() {
    let cfg = DpuConfig::flexnn_16x16();
    let (shape, q) = conv_layer("c", 32, 64, 3, 8, 12);
    let strum = apply_strum(&q, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));
    let (_, sim_act) = simulate_network(
        &[(shape, strum)],
        &SimConfig::flexnn(SimMode::StrumStatic, Some(Method::Mip2q { l_max: 7 })),
        0.7,
        0,
    );
    let dense_act = Activity::dense(256, 10_000, 0.5);
    for act in [&sim_act, &dense_act] {
        let b = power(PeVariant::BaselineInt8, act, &cfg).dpu_level();
        let s7 = power(PeVariant::StaticMip2q { l_max: 7 }, act, &cfg).dpu_level();
        let s5 = power(PeVariant::StaticMip2q { l_max: 5 }, act, &cfg).dpu_level();
        assert!(s5 <= s7 && s7 < b);
    }
}
