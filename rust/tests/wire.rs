//! Wire front-end integration tests — loopback TCP, no artifacts:
//! a synthetic 3-variant native fleet served by `WireServer`, driven by
//! `WireClient`. The acceptance contract: logits over the wire are
//! bit-identical to in-process `VariantHandle::submit` for the same
//! images, deadline-shed requests come back as typed protocol codes
//! (never a hang), and the metrics op round-trips the fleet snapshot.

use std::sync::Arc;
use std::time::Duration;
use strum_dpu::backend::graph::{calibrate_act_scales, synth_net_weights};
use strum_dpu::backend::{Backend, BackendKind};
use strum_dpu::coordinator::{
    BatchPolicy, Engine, EngineOptions, Router, Variant, VariantHandle,
};
use strum_dpu::model::import::NetWeights;
use strum_dpu::model::eval::EvalConfig;
use strum_dpu::quant::Method;
use strum_dpu::server::{
    proto, AioServer, ErrorCode, HttpClient, PipelinedClient, WireClient, WireResponse,
    WireServer, WireServerOptions,
};
use strum_dpu::util::json::Json;
use strum_dpu::util::prng::Rng;

const IMG: usize = 16;
const CLASSES: usize = 7;

fn calibrated_weights(seed: u64) -> NetWeights {
    let mut w = synth_net_weights("mini_cnn_s", IMG, CLASSES, seed).unwrap();
    let calib: Vec<f32> = {
        let mut rng = Rng::new(seed ^ 0xA5A5);
        (0..4 * IMG * IMG * 3).map(|_| rng.f32()).collect()
    };
    w.manifest.act_scales = calibrate_act_scales(&w, &calib, 4).unwrap();
    w
}

/// A native 3-variant fleet (base / DLIQ / MIP2Q) on one engine.
fn native_fleet() -> (Arc<Engine>, Vec<VariantHandle>, Vec<&'static str>) {
    let weights = calibrated_weights(21);
    let mut router = Router::native();
    let engine = Arc::new(Engine::start(EngineOptions {
        workers: 2,
        max_wait: Duration::from_millis(1),
        ..EngineOptions::default()
    }));
    let keys = vec!["base", "dliq-q4", "mip2q-L7"];
    let specs = [
        (Method::Baseline, 0.0),
        (Method::Dliq { q: 4 }, 0.5),
        (Method::Mip2q { l_max: 7 }, 0.5),
    ];
    let mut handles = Vec::new();
    for (key, &(method, p)) in keys.iter().zip(&specs) {
        let cfg = EvalConfig::paper(method, p);
        let v = router.register_native_weights(key, &weights, &cfg).unwrap();
        handles.push(engine.register(v).unwrap());
    }
    (engine, handles, keys)
}

fn random_image(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..IMG * IMG * 3).map(|_| rng.f32()).collect()
}

/// The acceptance criterion: a round-trip through TCP framing, the
/// server, the engine, and back produces logits bit-identical to an
/// in-process submit of the same image to the same variant.
#[test]
fn wire_logits_match_in_process_bit_for_bit() {
    let (engine, handles, keys) = native_fleet();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = WireClient::connect(&addr).unwrap();
    for (vi, key) in keys.iter().enumerate() {
        for s in 0..3u64 {
            let image = random_image(1000 + s);
            let local = handles[vi].submit(image.clone()).unwrap().wait().unwrap();
            let wire = client
                .infer(key, &image)
                .unwrap()
                .into_infer()
                .unwrap_or_else(|e| panic!("{}: {}", key, e));
            assert_eq!(wire.logits.len(), CLASSES);
            // Bit-identical: the wire moves f32 bit patterns, and the
            // native backend is deterministic integer math.
            let a: Vec<u32> = local.logits.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = wire.logits.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "{} image {}", key, s);
            assert_eq!(wire.class, local.class);
        }
    }
    let stats = server.stats();
    assert_eq!(stats.protocol_errors, 0);
    assert_eq!(stats.requests, 9);
    server.shutdown();
}

/// Metrics op: the snapshot crosses the wire as JSON that parses, names
/// every variant with its geometry, and counts the completed requests.
#[test]
fn metrics_op_round_trips_the_fleet() {
    let (engine, _handles, keys) = native_fleet();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let mut client = WireClient::connect(server.local_addr().to_string()).unwrap();
    for key in &keys {
        client
            .infer(key, &random_image(7))
            .unwrap()
            .into_infer()
            .unwrap();
    }
    let snapshot = Json::parse(&client.metrics().unwrap()).unwrap();
    let variants = snapshot.get("variants").unwrap().as_arr().unwrap();
    assert_eq!(variants.len(), keys.len());
    for v in variants {
        let key = v.get("key").unwrap().as_str().unwrap();
        assert!(keys.iter().any(|k| *k == key), "unexpected variant {}", key);
        assert_eq!(v.get("img").unwrap().as_usize().unwrap(), IMG);
        assert_eq!(v.get("classes").unwrap().as_usize().unwrap(), CLASSES);
        assert_eq!(v.get("completed").unwrap().as_usize().unwrap(), 1);
    }
    assert_eq!(
        snapshot
            .get("fleet")
            .unwrap()
            .get("completed")
            .unwrap()
            .as_usize()
            .unwrap(),
        keys.len()
    );
    server.shutdown();
}

/// Typed wire errors for routing and validation failures.
#[test]
fn wire_refusals_are_typed() {
    let (engine, _handles, keys) = native_fleet();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let mut client = WireClient::connect(server.local_addr().to_string()).unwrap();
    let resp = client.infer("no-such-variant", &random_image(1)).unwrap();
    assert_eq!(resp.error_code(), Some(ErrorCode::UnknownVariant));
    let resp = client.infer(keys[0], &[0.0f32; 5]).unwrap();
    assert_eq!(resp.error_code(), Some(ErrorCode::BadImage));
    server.shutdown();
}

/// A malformed frame gets a typed BadFrame response (not a dropped
/// connection with no explanation, and never a panic).
#[test]
fn bad_frame_gets_typed_error_response() {
    use std::io::Write;
    let (engine, _handles, _keys) = native_fleet();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    // A framed payload with an op this protocol does not know.
    proto::write_frame(&mut stream, &[proto::PROTO_VERSION, 0x5f]).unwrap();
    stream.flush().unwrap();
    let payload = proto::read_frame(&mut stream).unwrap().unwrap();
    match proto::decode_response(&payload).unwrap() {
        proto::Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadFrame),
        other => panic!("expected BadFrame error, got {:?}", other),
    }
    assert_eq!(server.stats().protocol_errors, 1);
    server.shutdown();
}

/// Client reconnect: dropping the cached connection is transparent —
/// the next call dials again (and the retry path covers a stale socket).
#[test]
fn client_reconnects_after_disconnect() {
    let (engine, _handles, keys) = native_fleet();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let mut client = WireClient::connect(server.local_addr().to_string()).unwrap();
    client
        .infer(keys[0], &random_image(3))
        .unwrap()
        .into_infer()
        .unwrap();
    client.disconnect();
    client
        .infer(keys[1], &random_image(4))
        .unwrap()
        .into_infer()
        .unwrap();
    assert!(server.stats().connections >= 2);
    server.shutdown();
}

// ------------------------------------------------------- deadline shedding

/// Backend that takes a configurable wall-time per batch — slow enough
/// to make tiny deadline budgets expire deterministically.
struct SlowBackend {
    delay: Duration,
    sizes: Vec<usize>,
}

impl Backend for SlowBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }
    fn net(&self) -> &str {
        "slow"
    }
    fn classes(&self) -> usize {
        CLASSES
    }
    fn img(&self) -> usize {
        IMG
    }
    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }
    fn pick_batch(&self, n: usize) -> usize {
        n.max(1)
    }
    fn infer_batch(&self, _images: Vec<f32>, batch: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        Ok(vec![0.0; batch * CLASSES])
    }
}

fn slow_fleet(delay: Duration) -> (Arc<Engine>, VariantHandle) {
    let engine = Arc::new(Engine::start(EngineOptions {
        workers: 1,
        max_wait: Duration::ZERO,
        ..EngineOptions::default()
    }));
    let variant = Arc::new(Variant {
        key: "slow".to_string(),
        net: "slow".to_string(),
        classes: CLASSES,
        img: IMG,
        backend: Arc::new(SlowBackend {
            delay,
            sizes: vec![1, 2, 4, 8, 16],
        }),
    });
    let handle = engine
        .register_with(
            variant,
            BatchPolicy {
                max_batch: 16,
                max_wait: Duration::ZERO,
            },
            64,
        )
        .unwrap();
    (engine, handle)
}

/// A budget far below the service time must come back as a typed
/// deadline shed — and must never hang the connection.
#[test]
fn expired_deadline_is_shed_with_a_typed_code() {
    let (engine, _handle) = slow_fleet(Duration::from_millis(80));
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let mut client = WireClient::connect(server.local_addr().to_string()).unwrap();
    let image = random_image(9);
    let mut sheds = 0usize;
    for _ in 0..3 {
        let resp = client
            .infer_deadline("slow", &image, Duration::from_millis(2))
            .unwrap();
        match resp {
            WireResponse::Error { code, .. } if code.is_shed() => sheds += 1,
            other => panic!("expected a shed code, got {:?}", other),
        }
    }
    assert_eq!(sheds, 3);
    // The engine's own metrics saw the sheds (wait-stage sheds are
    // client-side abandons; door/queue sheds are engine-side) — either
    // way the wire reported typed codes, and nothing hung.
    server.shutdown();
}

/// Zero budget on the wire means "no deadline": the request completes
/// even on a slow backend.
#[test]
fn zero_budget_means_no_deadline() {
    let (engine, _handle) = slow_fleet(Duration::from_millis(30));
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let mut client = WireClient::connect(server.local_addr().to_string()).unwrap();
    let r = client
        .infer_budget_ms("slow", &random_image(2), 0)
        .unwrap()
        .into_infer()
        .unwrap();
    assert_eq!(r.logits.len(), CLASSES);
    server.shutdown();
}

// ------------------------------------------------- robustness satellites

/// Regression test for the acceptor/worker shutdown race: a connection
/// accepted in the same tick as shutdown must receive a typed
/// ShuttingDown response — never a silent close, never a hang.
#[test]
fn connection_racing_shutdown_gets_typed_refusal() {
    let (engine, _handles, _keys) = native_fleet();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let addr = server.local_addr();
    // Accepted (or queued) but no request sent yet: the worker is
    // blocked reading when the stop flag flips.
    let stream = std::net::TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    std::thread::sleep(Duration::from_millis(50));
    // shutdown() joins the workers; the refusal frame is written (and
    // sits in the socket buffer) before it returns.
    server.shutdown();
    let mut stream = stream;
    let payload = proto::read_frame(&mut stream)
        .expect("refusal frame must arrive")
        .expect("refusal must be a frame, not EOF");
    match proto::decode_response(&payload).unwrap() {
        proto::Response::Error { code, .. } => assert_eq!(code, ErrorCode::ShuttingDown),
        other => panic!("expected ShuttingDown, got {:?}", other),
    }
}

/// Queued-behind-busy-workers variant of the same race: with one
/// conn worker occupied, a second connection sits in the accept queue
/// when shutdown lands — it too must get the typed refusal.
#[test]
fn queued_connection_at_shutdown_is_refused_not_dropped() {
    let (engine, _handle) = slow_fleet(Duration::from_millis(120));
    let server = WireServer::bind(
        "127.0.0.1:0",
        engine.clone(),
        WireServerOptions {
            conn_workers: 1,
            ..WireServerOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    // Occupy the only worker with a slow inference.
    let busy_addr = addr.clone();
    let busy = std::thread::spawn(move || {
        let mut c = WireClient::connect(&busy_addr).unwrap();
        // Outcome may be logits or a typed refusal depending on where
        // the drain catches it; both are fine — hanging is not.
        let _ = c.infer("slow", &random_image(11));
    });
    std::thread::sleep(Duration::from_millis(30));
    // This one queues behind the busy worker.
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    std::thread::sleep(Duration::from_millis(20));
    server.shutdown();
    let mut stream = stream;
    let payload = proto::read_frame(&mut stream)
        .expect("queued connection must get a frame")
        .expect("typed refusal, not EOF");
    match proto::decode_response(&payload).unwrap() {
        proto::Response::Error { code, .. } => assert_eq!(code, ErrorCode::ShuttingDown),
        other => panic!("expected ShuttingDown, got {:?}", other),
    }
    busy.join().unwrap();
}

/// Drain under load: shutdown lands while clients are mid-flight. Every
/// request must resolve — logits, a typed refusal, or (only once the
/// teardown has closed the socket) a transport error. Nothing may hang:
/// the read timeouts plus this test's own completion are the assertion.
#[test]
fn drain_under_load_never_hangs_a_request() {
    let (engine, _handle) = slow_fleet(Duration::from_millis(3));
    let server = WireServer::bind(
        "127.0.0.1:0",
        engine.clone(),
        WireServerOptions {
            conn_workers: 2,
            ..WireServerOptions::default()
        },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let clients = 3usize;
    let per_client = 30usize;
    let joins: Vec<_> = (0..clients)
        .map(|ci| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = WireClient::new(addr)
                    .with_connect_attempts(1)
                    .with_read_timeout(Duration::from_secs(5));
                let image = random_image(90 + ci as u64);
                let (mut ok, mut typed, mut transport) = (0usize, 0usize, 0usize);
                for _ in 0..per_client {
                    match client.infer("slow", &image) {
                        Ok(WireResponse::Infer(_)) => ok += 1,
                        Ok(WireResponse::Error { .. }) => typed += 1,
                        Err(_) => transport += 1,
                    }
                }
                (ok, typed, transport)
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(60));
    server.shutdown();
    let mut total_ok = 0usize;
    let mut total = 0usize;
    for j in joins {
        let (ok, typed, transport) = j.join().expect("client thread must not panic");
        total_ok += ok;
        total += ok + typed + transport;
    }
    // Every scheduled request resolved one way or another, and the
    // pre-shutdown window really served traffic.
    assert_eq!(total, clients * per_client);
    assert!(total_ok > 0, "no request completed before the drain");
}

/// Client dial backoff: a dead address fails with a typed WireCallError
/// carrying the attempt count, and the attempts actually back off.
#[test]
fn client_backoff_reports_typed_attempts() {
    // Grab a port nothing listens on (bind, read the port, drop).
    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{}", port);
    let t0 = std::time::Instant::now();
    let err = WireClient::new(addr.clone())
        .with_connect_attempts(3)
        .infer("any", &random_image(1))
        .expect_err("dialing a dead port must fail");
    let call = err
        .downcast_ref::<strum_dpu::server::WireCallError>()
        .expect("error must be a typed WireCallError");
    assert_eq!(call.addr, addr);
    assert_eq!(call.connect_attempts, 3);
    assert!(!call.timed_out, "a refused dial is not a read timeout");
    // Two backoff pauses with jitter >= 0.5: >= 10ms + 20ms.
    assert!(
        t0.elapsed() >= Duration::from_millis(25),
        "three attempts must include backoff pauses (took {:?})",
        t0.elapsed()
    );

    // A single-attempt client fails fast with attempts == 1 (the
    // failover-beats-backoff configuration the gateway router uses).
    let err = WireClient::new(addr.clone())
        .with_connect_attempts(1)
        .infer("any", &random_image(1))
        .expect_err("still dead");
    let call = err.downcast_ref::<strum_dpu::server::WireCallError>().unwrap();
    assert_eq!(call.connect_attempts, 1);
}

// ------------------------------------------------- async tier (aio + http)

/// The async tier serves legacy v1 clients unchanged: `WireClient`
/// against an `AioServer` produces logits bit-identical to in-process
/// submits, exactly like the blocking tier's acceptance test.
#[test]
fn aio_serves_v1_clients_bit_identically() {
    let (engine, handles, keys) = native_fleet();
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        None,
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let mut client = WireClient::connect(&addr).unwrap();
    for (vi, key) in keys.iter().enumerate() {
        let image = random_image(8000 + vi as u64);
        let local = handles[vi].submit(image.clone()).unwrap().wait().unwrap();
        let wire = client.infer(key, &image).unwrap().into_infer().unwrap();
        let a: Vec<u32> = local.logits.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = wire.logits.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "{}", key);
    }
    let stats = server.stats();
    assert_eq!(stats.protocol_errors, 0);
    assert_eq!(stats.requests, keys.len() as u64);
    // One v1 client, strictly request/response: never pipelined.
    assert_eq!(stats.pipelined_conns, 0);
    server.shutdown();
}

/// The HTTP acceptance criterion: `POST /v1/infer` answers with logits
/// bit-identical to the binary protocol for the same image — f32 bit
/// patterns survive the JSON round trip.
#[test]
fn http_and_binary_logits_are_bit_identical() {
    let (engine, _handles, keys) = native_fleet();
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        Some("127.0.0.1:0"),
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let bin_addr = server.local_addr().unwrap().to_string();
    let http_addr = server.http_addr().unwrap().to_string();
    let mut bin = WireClient::connect(&bin_addr).unwrap();
    let mut http = HttpClient::new(http_addr);
    for key in &keys {
        for s in 0..2u64 {
            let image = random_image(4000 + s);
            let wire = bin.infer(key, &image).unwrap().into_infer().unwrap();
            let (status, body) = http.infer(key, &image, 0).unwrap();
            assert_eq!(status, 200, "{}: {}", key, body);
            let j = Json::parse(&body).unwrap();
            let logits: Vec<f32> = j
                .get("logits")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap() as f32)
                .collect();
            let a: Vec<u32> = wire.logits.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = logits.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "{} image {}", key, s);
            assert_eq!(j.get("class").unwrap().as_usize().unwrap(), wire.class);
        }
    }
    let stats = server.stats();
    assert_eq!(stats.protocol_errors, 0);
    assert_eq!(stats.http_requests, keys.len() as u64 * 2);
    server.shutdown();
}

/// Out-of-order pipelining: on one v2 connection, a fast metrics reply
/// overtakes a slow in-flight inference; correlation ids pair each
/// reply with its request.
#[test]
fn pipelined_replies_arrive_out_of_order_by_corr_id() {
    let (engine, _handle) = slow_fleet(Duration::from_millis(60));
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        None,
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let mut client = PipelinedClient::connect(&addr).unwrap();
    let slow_corr = client.submit("slow", &random_image(1), 0).unwrap();
    let fast_corr = client.submit_metrics().unwrap();
    match client.recv().unwrap() {
        proto::FramedResponse::V2 { corr_id, resp } => {
            assert_eq!(corr_id, fast_corr, "metrics must overtake the slow infer");
            assert!(matches!(resp, proto::Response::MetricsJson(_)));
        }
        other => panic!("expected a v2 metrics reply, got {:?}", other),
    }
    let (corr, second) = client.recv_infer().unwrap();
    assert_eq!(corr, slow_corr);
    assert!(matches!(second, WireResponse::Infer(_)));
    let stats = server.stats();
    assert_eq!(stats.pipelined_conns, 1);
    server.shutdown();
}

/// Streaming batch submission: one v2 frame carrying several images
/// comes back as one reply with a logits row per image, in submission
/// order, each row bit-identical to an in-process submit.
#[test]
fn streaming_batch_returns_one_row_per_image_in_order() {
    let (engine, handles, keys) = native_fleet();
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        None,
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let mut client = PipelinedClient::connect(&addr).unwrap();
    let px = IMG * IMG * 3;
    let images: Vec<f32> = (0..3u64).flat_map(random_image).collect();
    let corr = client.submit_batch(keys[0], 0, px, &images).unwrap();
    match client.recv().unwrap() {
        proto::FramedResponse::V2Batch { corr_id, rows } => {
            assert_eq!(corr_id, corr);
            assert_eq!(rows.len(), 3);
            for (i, row) in rows.iter().enumerate() {
                match row {
                    proto::Response::Logits { logits, .. } => {
                        let local = handles[0]
                            .submit(images[i * px..(i + 1) * px].to_vec())
                            .unwrap()
                            .wait()
                            .unwrap();
                        let a: Vec<u32> = local.logits.iter().map(|x| x.to_bits()).collect();
                        let b: Vec<u32> = logits.iter().map(|x| x.to_bits()).collect();
                        assert_eq!(a, b, "row {}", i);
                    }
                    other => panic!("row {}: expected logits, got {:?}", i, other),
                }
            }
        }
        other => panic!("expected a batch reply, got {:?}", other),
    }
    server.shutdown();
}

/// Backpressure must release: a burst deeper than `MAX_PIPELINE` parks
/// the excess in the server's input buffer (the poller stops reading at
/// the cap), and those buffered requests must still be answered once
/// completions free slots — no new socket bytes will arrive to
/// re-trigger parsing, so the poller has to resume it on its own.
#[test]
fn pipeline_backpressure_resumes_for_buffered_requests() {
    use std::collections::HashSet;
    use strum_dpu::server::aio::MAX_PIPELINE;
    let (engine, _handle) = slow_fleet(Duration::from_millis(5));
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        None,
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let mut client = PipelinedClient::connect(&addr)
        .unwrap()
        .with_read_timeout(Duration::from_secs(30));
    let total = MAX_PIPELINE + 12;
    let image = random_image(42);
    let mut want: HashSet<u32> = HashSet::new();
    for _ in 0..total {
        want.insert(client.submit("slow", &image, 0).unwrap());
    }
    // Every submit must be answered — logits or a typed shed, never a
    // hang on the requests that were buffered past the pipeline cap.
    for i in 0..total {
        match client.recv().expect("every burst request must be answered") {
            proto::FramedResponse::V2 { corr_id, .. } => {
                assert!(want.remove(&corr_id), "duplicate corr id {}", corr_id);
            }
            other => panic!("reply {}: expected a v2 reply, got {:?}", i, other),
        }
    }
    assert!(want.is_empty());
    server.shutdown();
}

/// A batch frame declaring zero-pixel images (`px == 0`, `count ≥ 1`)
/// carries no image bytes and would fan out into nothing — it must be
/// refused with a typed error and a closed connection, never parked as
/// a request that no completion will ever answer (which would leak the
/// connection forever).
#[test]
fn zero_pixel_batch_is_refused_not_leaked() {
    use std::io::Read;
    let (engine, _handles, keys) = native_fleet();
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        None,
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let mut s = std::net::TcpStream::connect(server.local_addr().unwrap()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let payload = proto::encode_infer_batch(9, keys[0], 0, 1, 0, &[]);
    proto::write_frame(&mut s, &payload).unwrap();
    let reply = proto::read_frame(&mut s)
        .expect("a typed refusal, not a hang")
        .unwrap();
    match proto::decode_response_framed(&reply).unwrap() {
        proto::FramedResponse::V1(proto::Response::Error { code, .. }) => {
            assert_eq!(code, ErrorCode::BadFrame);
        }
        other => panic!("expected a typed bad-frame error, got {:?}", other),
    }
    let mut rest = Vec::new();
    s.read_to_end(&mut rest).expect("EOF after the refusal");
    assert!(rest.is_empty(), "connection must close after the refusal");
    assert_eq!(server.stats().protocol_errors, 1);
    server.shutdown();
}

/// A connection that negotiates v2 (unordered replies) with its first
/// frame may not downgrade to v1 mid-stream: a v1 frame there has no
/// correlation id and its in-order contract can no longer be honored,
/// so the server refuses it with a typed `BadFrame` and closes.
#[test]
fn version_downgrade_mid_connection_is_refused() {
    use std::io::Read;
    let (engine, _handles, _keys) = native_fleet();
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        None,
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let mut s = std::net::TcpStream::connect(server.local_addr().unwrap()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // First frame v2: the connection negotiates unordered delivery.
    proto::write_frame(&mut s, &proto::encode_metrics_v2(1)).unwrap();
    let reply = proto::read_frame(&mut s).unwrap().unwrap();
    match proto::decode_response_framed(&reply).unwrap() {
        proto::FramedResponse::V2 { corr_id, resp } => {
            assert_eq!(corr_id, 1);
            assert!(matches!(resp, proto::Response::MetricsJson(_)));
        }
        other => panic!("expected a v2 metrics reply, got {:?}", other),
    }
    // Then a v1 frame on the same connection: refused, not served.
    proto::write_frame(&mut s, &proto::encode_request(&proto::Request::Metrics)).unwrap();
    let reply = proto::read_frame(&mut s)
        .expect("a typed refusal, not a hang")
        .unwrap();
    match proto::decode_response_framed(&reply).unwrap() {
        proto::FramedResponse::V1(proto::Response::Error { code, detail }) => {
            assert_eq!(code, ErrorCode::BadFrame);
            assert!(detail.contains("downgrade"), "detail: {}", detail);
        }
        other => panic!("expected a typed bad-frame error, got {:?}", other),
    }
    let mut rest = Vec::new();
    s.read_to_end(&mut rest).expect("EOF after the refusal");
    assert!(rest.is_empty(), "connection must close after the refusal");
    assert_eq!(server.stats().protocol_errors, 1);
    server.shutdown();
}

/// Malformed HTTP must be answered with a 400 and a closed connection —
/// never a hang, never a panic, and counted as a protocol error.
#[test]
fn malformed_http_gets_400_and_never_hangs() {
    use std::io::{Read, Write};
    let (engine, _handles, _keys) = native_fleet();
    let server = AioServer::bind(
        None,
        Some("127.0.0.1:0"),
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let addr = server.http_addr().unwrap();
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(b"NONSENSE\r\n\r\n").unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("a 400 then EOF, not a hang");
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 400"), "got: {}", text);
    assert_eq!(server.stats().protocol_errors, 1);
    server.shutdown();
}

/// HTTP/1.1 keep-alive: many requests (infer, metrics JSON, Prometheus
/// text, and a 404) ride one TCP connection, confirmed from both ends —
/// the client dialed once, the server accepted once.
#[test]
fn http_keep_alive_reuses_one_connection() {
    let (engine, _handles, keys) = native_fleet();
    let server = AioServer::bind(
        None,
        Some("127.0.0.1:0"),
        engine.clone(),
        WireServerOptions::default(),
    )
    .unwrap();
    let mut http = HttpClient::new(server.http_addr().unwrap().to_string());
    for s in 0..5u64 {
        let (status, body) = http.infer(keys[0], &random_image(300 + s), 0).unwrap();
        assert_eq!(status, 200, "{}", body);
    }
    let (status, body) = http.request("GET", "/v1/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(Json::parse(&body).is_ok(), "metrics body must be JSON");
    let (status, prom) = http.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(
        prom.contains("strum_requests_completed_total"),
        "Prometheus text must expose known families:\n{}",
        prom
    );
    let (status, _) = http.request("GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    assert_eq!(http.dials(), 1, "keep-alive must not redial");
    let stats = server.stats();
    assert_eq!(stats.connections, 1);
    assert_eq!(stats.http_requests, 8);
    server.shutdown();
}

/// Wire requests and in-process handles share one engine: the server is
/// just another submitter, and both see the same fleet metrics.
#[test]
fn wire_and_in_process_share_the_engine() {
    let (engine, handles, keys) = native_fleet();
    let server =
        WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default()).unwrap();
    let mut client = WireClient::connect(server.local_addr().to_string()).unwrap();
    client
        .infer(keys[0], &random_image(5))
        .unwrap()
        .into_infer()
        .unwrap();
    handles[0].submit(random_image(6)).unwrap().wait().unwrap();
    let snap = engine.metrics();
    let base = snap.variants.iter().find(|v| v.key == keys[0]).unwrap();
    assert_eq!(base.completed, 2);
    server.shutdown();
}
