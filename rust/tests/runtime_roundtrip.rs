//! Runtime integration tests over the real artifacts (skipped with a
//! notice when `make train artifacts` has not been run): HLO load +
//! execute, rust-vs-HLO kernel bit-exactness, accuracy sanity, and the
//! live serving-engine path.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;
use strum_dpu::coordinator::{Engine, EngineOptions, Router};
use strum_dpu::model::eval::{evaluate, EvalConfig};
use strum_dpu::model::import::{DataSet, NetWeights};
use strum_dpu::quant::{Method};
use strum_dpu::runtime::{Runtime, Tensor};
use strum_dpu::util::prng::Rng;

fn artifacts() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("hlo").exists() && dir.join("weights").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP runtime test: artifacts missing (run `make train artifacts`)");
        None
    }
}

/// PJRT runtime, or a skip notice on builds without the `pjrt` feature.
fn runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime test: {}", e);
            None
        }
    }
}

/// The integer StruM microkernel HLO must match a host reference
/// bit-for-bit — tying the Pallas kernel (L1) to the rust datapath (L3).
#[test]
fn strum_int_kernel_bit_exact_vs_host() {
    let Some(dir) = artifacts() else { return };
    let Some(rt) = runtime() else { return };
    let exe = rt.load_hlo(&dir.join("hlo/strum_matmul_int.hlo.txt")).unwrap();
    let (m, k, n) = (64usize, 256usize, 64usize);
    let mut rng = Rng::new(42);
    let x: Vec<i32> = (0..m * k).map(|_| rng.range(0, 255) as i32 - 127).collect();
    let hi: Vec<i32> = (0..k * n)
        .map(|_| if rng.chance(0.5) { rng.range(0, 255) as i32 - 127 } else { 0 })
        .collect();
    let lo: Vec<i32> = hi
        .iter()
        .map(|&h| {
            if h == 0 {
                let s = if rng.chance(0.5) { -1 } else { 1 };
                s * (1 << rng.range(0, 8))
            } else {
                0
            }
        })
        .collect();
    let out = exe
        .run_i32(&[
            Tensor::i32(x.clone(), &[m, k]),
            Tensor::i32(hi.clone(), &[k, n]),
            Tensor::i32(lo.clone(), &[k, n]),
        ])
        .unwrap();
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i64;
            for kk in 0..k {
                acc += x[i * k + kk] as i64 * (hi[kk * n + j] + lo[kk * n + j]) as i64;
            }
            assert_eq!(out[0][i * n + j] as i64, acc, "({}, {})", i, j);
        }
    }
}

/// The float StruM kernel: two complementary banks reconstruct the dense
/// GEMM to float tolerance.
#[test]
fn strum_f32_kernel_reconstructs_dense() {
    let Some(dir) = artifacts() else { return };
    let Some(rt) = runtime() else { return };
    let exe = rt.load_hlo(&dir.join("hlo/strum_matmul_f32.hlo.txt")).unwrap();
    let (m, k, n) = (64usize, 256usize, 64usize);
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..m * k).map(|_| rng.gaussian() as f32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32 * 0.1).collect();
    let mask: Vec<bool> = (0..k * n).map(|_| rng.chance(0.5)).collect();
    let hi: Vec<f32> = w.iter().zip(&mask).map(|(&v, &m)| if m { v } else { 0.0 }).collect();
    let lo: Vec<f32> = w.iter().zip(&mask).map(|(&v, &m)| if m { 0.0 } else { v }).collect();
    let out = exe
        .run_f32(&[
            Tensor::f32(x.clone(), &[m, k]),
            Tensor::f32(hi, &[k, n]),
            Tensor::f32(lo, &[k, n]),
        ])
        .unwrap();
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for kk in 0..k {
                acc += x[i * k + kk] as f64 * w[kk * n + j] as f64;
            }
            let got = out[0][i * n + j] as f64;
            assert!(
                (got - acc).abs() < 1e-3 * (1.0 + acc.abs()),
                "({},{}): {} vs {}",
                i,
                j,
                got,
                acc
            );
        }
    }
}

/// Float eval through PJRT reproduces the accuracy python recorded at
/// train time (same data, same graph ⇒ tight tolerance).
#[test]
fn float_eval_matches_training_record() {
    let Some(dir) = artifacts() else { return };
    let Some(rt) = runtime() else { return };
    let net = "mini_cnn_s";
    let weights = NetWeights::load(dir, net).unwrap();
    let data = DataSet::load(dir, "eval").unwrap();
    let cfg = EvalConfig {
        act_quant: false,
        ..EvalConfig::paper(Method::Baseline, 0.0)
    };
    let r = evaluate(&rt, dir, net, &data, &cfg).unwrap();
    let expect = weights.manifest.eval_top1_float;
    assert!(
        (r.top1 - expect).abs() < 0.005,
        "PJRT float top1 {} vs python {}",
        r.top1,
        expect
    );
}

/// INT8 baseline costs < 2% accuracy vs float (static calibration works).
#[test]
fn int8_baseline_close_to_float() {
    let Some(dir) = artifacts() else { return };
    let Some(rt) = runtime() else { return };
    let net = "mini_resnet_c";
    let data = DataSet::load(dir, "eval").unwrap();
    let float_cfg = EvalConfig {
        act_quant: false,
        limit: Some(512),
        ..EvalConfig::paper(Method::Baseline, 0.0)
    };
    let int8_cfg = EvalConfig {
        limit: Some(512),
        ..EvalConfig::paper(Method::Baseline, 0.0)
    };
    let f = evaluate(&rt, dir, net, &data, &float_cfg).unwrap();
    let q = evaluate(&rt, dir, net, &data, &int8_cfg).unwrap();
    assert!(
        f.top1 - q.top1 < 0.02,
        "float {} vs int8 {}",
        f.top1,
        q.top1
    );
}

/// MIP2Q p=0.5 stays within 2% of the INT8 baseline on a 512-sample
/// slice (the Table-I headline, loose-tolerance CI version).
#[test]
fn mip2q_headline_accuracy() {
    let Some(dir) = artifacts() else { return };
    let Some(rt) = runtime() else { return };
    let net = "mini_resnet_c";
    let data = DataSet::load(dir, "eval").unwrap();
    let base = evaluate(
        &rt,
        dir,
        net,
        &data,
        &EvalConfig { limit: Some(512), ..EvalConfig::paper(Method::Baseline, 0.0) },
    )
    .unwrap();
    let mip = evaluate(
        &rt,
        dir,
        net,
        &data,
        &EvalConfig { limit: Some(512), ..EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5) },
    )
    .unwrap();
    assert!(
        base.top1 - mip.top1 < 0.02,
        "baseline {} vs mip2q {}",
        base.top1,
        mip.top1
    );
}

/// Live serving engine: submit concurrent requests, all complete,
/// batching happens, accuracy is sane, no request is dropped or
/// reordered wrongly.
#[test]
fn engine_serves_pjrt_variant_correctly() {
    let Some(dir) = artifacts() else { return };
    let Some(rt) = runtime() else { return };
    let rt = Arc::new(rt);
    let mut router = Router::new(rt);
    let net = "mini_cnn_s";
    let v = router
        .register("test", dir, net, &EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5))
        .unwrap();
    let engine = Engine::start(EngineOptions {
        max_wait: Duration::from_millis(2),
        workers: 2,
        max_batch: Some(16),
        ..EngineOptions::default()
    });
    let handle = engine.register(v).unwrap();
    let data = DataSet::load(dir, "eval").unwrap();
    let px = data.img * data.img * 3;
    let n = 64;
    let pend: Vec<_> = (0..n)
        .map(|i| {
            let idx = i % data.n;
            (
                idx,
                handle
                    .submit(data.images[idx * px..(idx + 1) * px].to_vec())
                    .unwrap(),
            )
        })
        .collect();
    let mut correct = 0;
    for (idx, ticket) in pend {
        let reply = ticket.wait_deadline(Duration::from_secs(60)).unwrap();
        assert!(reply.batch.1 >= reply.batch.0, "padded >= occupancy");
        if reply.class as i32 == data.labels[idx] {
            correct += 1;
        }
    }
    // mini_cnn_s is a >85% model; 64 samples at ≥60% is a safe floor.
    assert!(correct * 10 >= n * 6, "accuracy too low: {}/{}", correct, n);
    engine.shutdown();
}
