//! Property-based invariant suite (in-tree mini-proptest driver —
//! `strum_dpu::util::proptest`). Covers the quantizer, the MIP2Q
//! optimality claim, the §IV-D codec, Eq. 1/2, the simulator datapath,
//! the native dual-bank GEMM vs the dequantize→f32 reference, the
//! batching policy, and the rust↔python golden parity case.

use strum_dpu::backend::strum_gemm::StrumGemm;
use strum_dpu::coordinator::batcher::BatchPolicy;
use strum_dpu::encode::compression::{ratio_for, ratio_payload, ratio_sparsity};
use strum_dpu::encode::{decode_layer, encode_layer};
use strum_dpu::quant::tensor::qlayer;
use strum_dpu::quant::{
    apply_strum, apply_unstructured, mip2q, quantize_block, Method, StrumParams,
};
use strum_dpu::sim::config::PeLanes;
use strum_dpu::sim::pe::{dot_int8_dense, dot_strum, reference_dot, WBlockRef};
use strum_dpu::util::proptest::{check, Gen};
use std::time::{Duration, Instant};

fn gen_method(g: &mut Gen) -> Method {
    match g.usize_in(0, 5) {
        0 => Method::StructuredSparsity,
        1 => Method::Dliq { q: 2 },
        2 => Method::Dliq { q: 4 },
        3 => Method::Mip2q { l_max: 3 },
        4 => Method::Mip2q { l_max: 5 },
        _ => Method::Mip2q { l_max: 7 },
    }
}

fn gen_layer(g: &mut Gen) -> strum_dpu::quant::QLayer {
    let oc = g.usize_in(1, 4);
    let rows = g.usize_in(1, 3);
    let cols = g.usize_in(1, 40);
    let data: Vec<i8> = (0..oc * rows * cols).map(|_| g.i8()).collect();
    qlayer("prop", oc, rows, cols, data, vec![0.01; oc])
}

#[test]
fn structure_invariant_always_holds() {
    check("every block has exactly round(p·l·w) low lanes", 150, |g| {
        let layer = gen_layer(g);
        let method = gen_method(g);
        let p = *g.choose(&[0.25, 0.5, 0.75]);
        let (l, w) = *g.choose(&[(1usize, 16usize), (1, 8), (2, 8), (4, 4), (1, 4)]);
        let s = apply_strum(&layer, &StrumParams::new(method, l, w, p));
        s.check_structure().is_ok()
    });
}

#[test]
fn codec_roundtrip_is_lossless() {
    check("encode→decode == identity on (values, codes, mask)", 120, |g| {
        let layer = gen_layer(g);
        let method = gen_method(g);
        let p = *g.choose(&[0.25, 0.5, 0.75]);
        let s = apply_strum(&layer, &StrumParams::paper(method, p));
        let enc = encode_layer(&s);
        match decode_layer(&enc) {
            Ok(d) => d.values == s.values && d.mask == s.mask && d.codes == s.codes,
            Err(_) => false,
        }
    });
}

#[test]
fn measured_ratio_matches_equations_when_aligned() {
    check("measured r == Eq.1/Eq.2 on pad-free layers", 100, |g| {
        let method = gen_method(g);
        let p = *g.choose(&[0.25, 0.5, 0.75]);
        let oc = g.usize_in(1, 3);
        let blocks = g.usize_in(1, 6);
        let cols = blocks * 16;
        let data: Vec<i8> = (0..oc * cols).map(|_| g.i8()).collect();
        let layer = qlayer("r", oc, 1, cols, data, vec![1.0; oc]);
        let s = apply_strum(&layer, &StrumParams::paper(method, p));
        let enc = encode_layer(&s);
        // Aligned layers: exact match with the analytic ratio, except that
        // round(p·16)/16 replaces p.
        let p_eff = (p * 16.0).round() / 16.0;
        (enc.measured_ratio() - ratio_for(method, p_eff)).abs() < 1e-9
    });
}

#[test]
fn mip2q_greedy_selection_is_l2_optimal() {
    check("greedy mask == brute-force optimum (≤16-elem blocks)", 80, |g| {
        let n = g.usize_in(2, 12);
        let vals: Vec<i16> = (0..n).map(|_| g.i8() as i16).collect();
        let idxs: Vec<usize> = (0..n).collect();
        let low_n = g.usize_in(0, n);
        let l_max = *g.choose(&[3u8, 5, 7]);
        let (new_vals, _, _) =
            quantize_block(&vals, &idxs, low_n, Method::Mip2q { l_max });
        let err: u64 = new_vals
            .iter()
            .zip(vals.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as i64;
                (d * d) as u64
            })
            .sum();
        let best = mip2q::brute_force_best_error(&vals, n - low_n, l_max);
        err == best
    });
}

#[test]
fn unstructured_error_never_worse_than_structured() {
    // Pad-free layers with p=0.5: both selections quantize exactly N/2
    // elements, so the globally-optimal (unstructured) choice can only
    // match or beat the block-constrained one — the accuracy-vs-hardware
    // tradeoff the paper navigates.
    check("layer-global selection has ≤ structured RMSE", 60, |g| {
        let oc = g.usize_in(1, 4);
        let blocks = g.usize_in(1, 8);
        let cols = blocks * 16;
        let data: Vec<i8> = (0..oc * cols).map(|_| g.i8()).collect();
        let layer = qlayer("u", oc, 1, cols, data, vec![0.01; oc]);
        let method = *g.choose(&[Method::StructuredSparsity, Method::Mip2q { l_max: 7 }]);
        let p = 0.5;
        let s = apply_strum(&layer, &StrumParams::paper(method, p));
        let u = apply_unstructured(&layer, method, p);
        u.grid_rmse <= s.grid_rmse + 1e-9
    });
}

#[test]
fn pe_datapath_matches_reference_dot() {
    check("sim PE accumulator == effective-value dot product", 100, |g| {
        let method = gen_method(g);
        let blocks_n = g.usize_in(1, 6);
        let cols = blocks_n * 16;
        let data: Vec<i8> = (0..cols).map(|_| g.i8()).collect();
        let acts: Vec<i8> = (0..cols).map(|_| g.i8()).collect();
        let layer = qlayer("pe", 1, 1, cols, data, vec![1.0]);
        let s = apply_strum(&layer, &StrumParams::paper(method, 0.5));
        let mut blocks = Vec::new();
        let mut chunks = Vec::new();
        for bi in 0..blocks_n {
            let r = bi * 16..(bi + 1) * 16;
            blocks.push((
                s.values[r.clone()].to_vec(),
                s.codes[r.clone()].to_vec(),
                s.mask[r.clone()].to_vec(),
            ));
            chunks.push(acts[r].to_vec());
        }
        let brefs: Vec<WBlockRef> = blocks
            .iter()
            .map(|(v, c, m)| WBlockRef { values: v, codes: c, mask: m })
            .collect();
        let arefs: Vec<&[i8]> = chunks.iter().map(|c| c.as_slice()).collect();
        let lanes = PeLanes { mult: 4, low: 4 };
        let got = dot_strum(&brefs, &arefs, lanes, method).acc;
        got == reference_dot(&brefs, &arefs)
    });
}

#[test]
fn dense_pe_cycles_are_exact() {
    check("dense dot cycles == Σ ceil(w/mult)", 60, |g| {
        let blocks_n = g.usize_in(1, 8);
        let w = *g.choose(&[8usize, 16]);
        let mult = *g.choose(&[4u32, 8]);
        let vals = vec![1i16; w];
        let codes = vec![1i8; w];
        let mask = vec![true; w];
        let acts = vec![1i8; w];
        let blk = WBlockRef { values: &vals, codes: &codes, mask: &mask };
        let blocks: Vec<WBlockRef> = (0..blocks_n).map(|_| blk).collect();
        let arefs: Vec<&[i8]> = (0..blocks_n).map(|_| acts.as_slice()).collect();
        let r = dot_int8_dense(&blocks, &arefs, PeLanes { mult, low: 0 });
        r.cycles == (blocks_n as u64) * (w as u64).div_ceil(mult as u64)
    });
}

#[test]
fn compression_equations_bounds() {
    check("0 < r ≤ 9/8 and payload ≥ sparsity", 200, |g| {
        let p = g.f64_in(0.0, 1.0);
        let q = g.usize_in(2, 7) as u32;
        let rp = ratio_payload(p, q);
        let rs = ratio_sparsity(p);
        rp > 0.0 && rp <= 1.125 + 1e-12 && rs <= rp && rs > 0.0
    });
}

#[test]
fn batch_policy_never_exceeds_max() {
    check("batch policy take ≤ max_batch, 0 on empty", 150, |g| {
        let max_batch = g.usize_in(1, 64);
        let wait_us = g.usize_in(1, 10_000) as u64;
        let policy = BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(wait_us),
        };
        let queued = g.usize_in(0, 200);
        let now = Instant::now();
        let age = Duration::from_micros(g.usize_in(0, 20_000) as u64);
        let oldest = if queued > 0 { Some(now - age) } else { None };
        let take = policy.decide(queued, oldest, now);
        take <= max_batch
            && take <= queued.max(take) // never more than queued
            && (queued != 0 || take == 0)
            && (take <= queued)
    });
}

/// The dual-bank native GEMM is a lossless decomposition: for any layer,
/// method, block shape, and odd matrix dims, the encoded→decoded
/// execution form must reproduce Σ x·values *exactly* in integer
/// arithmetic — the high bank's int8 products plus the low bank's 4-bit
/// multiplies (DLIQ) or shift-adds (MIP2Q).
#[test]
fn native_gemm_banks_are_exact_on_the_int_grid() {
    check("encoded dual-bank dot == Σ x·values", 80, |g| {
        let layer = gen_layer(g);
        let method = gen_method(g);
        let p = *g.choose(&[0.25, 0.5, 0.75]);
        let (l, w) = *g.choose(&[(1usize, 16usize), (1, 8), (2, 8), (4, 4), (1, 4)]);
        let s = apply_strum(&layer, &StrumParams::new(method, l, w, p));
        let gemm = StrumGemm::from_encoded(&encode_layer(&s)).expect("from_encoded");
        let k = gemm.k;
        let x: Vec<i8> = (0..k).map(|_| g.i8()).collect();
        (0..gemm.oc).all(|c| {
            let expect: i64 = (0..k).map(|j| x[j] as i64 * s.values[c * k + j] as i64).sum();
            gemm.dot(&x, c) as i64 == expect
        })
    });
}

/// Requantized native output tracks the dequantize→f32 reference within
/// a fraction of one per-channel grid step, across methods (DLIQ, MIP2Q,
/// sparsity), block shapes, and odd dims — the float error comes only
/// from final-scale rounding, never from the integer banks.
#[test]
fn native_gemm_matches_dequantized_f32_reference() {
    check("dual-bank · scales ≈ f32 reference dot", 80, |g| {
        let layer = gen_layer(g);
        let method = gen_method(g);
        let p = *g.choose(&[0.25, 0.5, 0.75]);
        let (l, w) = *g.choose(&[(1usize, 16usize), (1, 8), (2, 8), (1, 4)]);
        let s = apply_strum(&layer, &StrumParams::new(method, l, w, p));
        let gemm = StrumGemm::from_encoded(&encode_layer(&s)).expect("from_encoded");
        let k = gemm.k;
        let act_scale = g.f32_in(1e-4, 0.1).max(1e-5);
        let x: Vec<i8> = (0..k).map(|_| g.i8()).collect();
        let deq = s.dequantize();
        (0..gemm.oc).all(|c| {
            let native = gemm.dot(&x, c) as f32 * (act_scale * gemm.scales[c]);
            let reference: f64 = (0..k)
                .map(|j| (x[j] as f64 * act_scale as f64) * deq[c * k + j] as f64)
                .sum();
            // One per-channel grid step of headroom: |err| ≤ s_act·s_w·k^½-ish;
            // in practice only final f32 rounding, so half a step is ample.
            let tol = (act_scale * gemm.scales[c]) as f64 * 0.5 + 1e-6 * reference.abs();
            (native as f64 - reference).abs() <= tol.max(1e-9)
        })
    });
}

/// The rust half of the golden parity case pinned in
/// python/tests/test_quantize.py — byte-identical expectations.
#[test]
fn python_parity_golden() {
    let input: Vec<i8> = vec![17, -3, 64, 0, -128, 5, 99, -2, 33, -77, 1, 8, -16, 120, -9, 4];
    let layer = qlayer("golden", 1, 1, 16, input, vec![1.0]);
    let cases: Vec<(Method, Vec<i16>)> = vec![
        (
            Method::StructuredSparsity,
            vec![17, 0, 64, 0, -128, 0, 99, 0, 33, -77, 0, 0, -16, 120, 0, 0],
        ),
        (
            Method::Dliq { q: 4 },
            vec![17, 0, 64, 0, -128, 0, 99, 0, 33, -77, 0, 16, -16, 120, -16, 0],
        ),
        (
            Method::Mip2q { l_max: 7 },
            vec![16, -3, 64, 0, -128, 5, 99, -2, 33, -77, 1, 8, -16, 120, -9, 4],
        ),
    ];
    for (method, expect) in cases {
        let s = apply_strum(&layer, &StrumParams::paper(method, 0.5));
        assert_eq!(s.values, expect, "{:?}", method);
    }
}
