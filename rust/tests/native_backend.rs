//! Native-backend integration tests — artifact-free: synthetic networks
//! are built in memory from the zoo specs, calibrated, StruM-transformed,
//! encoded, and served end-to-end through the coordinator with NO PJRT,
//! XLA, HLO artifact, or Python anywhere. The float reference forward
//! plays the role the PJRT path plays on real artifacts: the integer
//! engine must agree with it.

use std::time::Duration;
use strum_dpu::backend::graph::{calibrate_act_scales, forward_f32_reference, synth_net_weights};
use strum_dpu::backend::{Backend, BackendKind, NativeBackend, NetworkPlan};
use strum_dpu::coordinator::{Engine, EngineOptions, Router, SubmitError};
use strum_dpu::model::eval::{evaluate_native_weights, transform_network, EvalConfig};
use strum_dpu::model::import::{DataSet, NetWeights};
use strum_dpu::model::zoo;
use strum_dpu::quant::Method;
use strum_dpu::util::prng::Rng;

fn random_images(n: usize, img: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * img * img * 3).map(|_| rng.f32()).collect()
}

/// Synthetic weights with act scales calibrated on a float pre-pass —
/// the same static-calibration story the real artifacts carry.
fn calibrated_weights(net: &str, img: usize, classes: usize, seed: u64) -> NetWeights {
    let mut w = synth_net_weights(net, img, classes, seed).unwrap();
    let calib = random_images(4, img, seed ^ 0xA5A5);
    w.manifest.act_scales = calibrate_act_scales(&w, &calib, 4).unwrap();
    w
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// Gap between the best and second-best logit (confidence margin).
fn margin(xs: &[f32]) -> f32 {
    let mut best = f32::NEG_INFINITY;
    let mut second = f32::NEG_INFINITY;
    for &x in xs {
        if x > best {
            second = best;
            best = x;
        } else if x > second {
            second = x;
        }
    }
    best - second
}

/// The acceptance check: native integer logits track the f32 reference
/// per method, and top-1 agrees wherever the reference is confident.
#[test]
fn native_engine_matches_f32_reference() {
    let img = 16usize;
    let classes = 7usize;
    let weights = calibrated_weights("mini_cnn_s", img, classes, 11);
    let px = img * img * 3;
    let batch = 8usize;
    let images = random_images(batch, img, 99);
    for (method, p) in [
        (Method::Baseline, 0.0),
        (Method::StructuredSparsity, 0.5),
        (Method::Dliq { q: 4 }, 0.5),
        (Method::Mip2q { l_max: 7 }, 0.5),
        (Method::Mip2q { l_max: 5 }, 0.25),
    ] {
        let cfg = EvalConfig {
            batch,
            ..EvalConfig::paper(method, p)
        };
        let transformed = transform_network(&weights, &cfg).unwrap();
        let plan = NetworkPlan::from_transformed(&weights, &transformed, true).unwrap();
        for i in 0..batch {
            let image = &images[i * px..(i + 1) * px];
            let native = plan.forward_one(image).unwrap();
            let reference = forward_f32_reference(&weights, &transformed, image, true).unwrap();
            assert_eq!(native.len(), classes);
            let denom = reference
                .iter()
                .fold(1f32, |a, &x| a.max(x.abs()));
            for (j, (&n, &r)) in native.iter().zip(reference.iter()).enumerate() {
                assert!(
                    (n - r).abs() <= 5e-3 * denom,
                    "{:?} image {} logit {}: native {} vs reference {}",
                    method,
                    i,
                    j,
                    n,
                    r
                );
            }
            if margin(&reference) > 1e-2 * denom {
                assert_eq!(
                    argmax(&native),
                    argmax(&reference),
                    "{:?} image {}: top-1 disagrees",
                    method,
                    i
                );
            }
        }
    }
}

/// Every zoo architecture builds a plan and produces finite logits.
#[test]
fn every_zoo_net_executes_natively() {
    let img = 16usize;
    for net in zoo::net_names() {
        let weights = calibrated_weights(net, img, 5, 3);
        let cfg = EvalConfig {
            batch: 2,
            ..EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5)
        };
        let backend = NativeBackend::new(&weights, &cfg).unwrap();
        assert_eq!(backend.kind(), BackendKind::Native);
        assert_eq!(backend.img(), img);
        assert_eq!(backend.classes(), 5);
        let images = random_images(2, img, 8);
        let logits = backend.infer_batch(images, 2).unwrap();
        assert_eq!(logits.len(), 2 * 5, "{}", net);
        assert!(logits.iter().all(|v| v.is_finite()), "{}", net);
        // No padding on the native engine.
        assert_eq!(backend.pick_batch(3), 3);
    }
}

/// Full native serving path for a single variant: router → engine →
/// workers, replies must equal direct plan execution. No artifacts
/// involved. (This is the old single-variant `Coordinator` contract,
/// now expressed as one registration on the shared-pool engine.)
#[test]
fn native_engine_serves_single_variant_end_to_end() {
    let img = 16usize;
    let classes = 7usize;
    let weights = calibrated_weights("mini_resnet_a", img, classes, 21);
    let cfg = EvalConfig {
        batch: 8,
        ..EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5)
    };
    // Direct (unbatched) execution for ground truth.
    let transformed = transform_network(&weights, &cfg).unwrap();
    let plan = NetworkPlan::from_transformed(&weights, &transformed, true).unwrap();

    let mut router = Router::native();
    let v = router
        .register_native_weights("native-test", &weights, &cfg)
        .unwrap();
    assert_eq!(v.classes, classes);
    assert_eq!(v.img, img);
    let engine = Engine::start(EngineOptions {
        max_wait: Duration::from_millis(2),
        workers: 2,
        max_batch: Some(8),
        ..EngineOptions::default()
    });
    let handle = engine.register(v).unwrap();
    let px = img * img * 3;
    let n = 24usize;
    let images = random_images(n, img, 5);
    let pend: Vec<_> = (0..n)
        .map(|i| handle.submit(images[i * px..(i + 1) * px].to_vec()).unwrap())
        .collect();
    for (i, ticket) in pend.into_iter().enumerate() {
        let reply = ticket.wait_deadline(Duration::from_secs(60)).unwrap();
        assert!(reply.batch.1 >= reply.batch.0, "padded >= occupancy");
        let direct = plan.forward_one(&images[i * px..(i + 1) * px]).unwrap();
        assert_eq!(reply.class, argmax(&direct), "request {}", i);
        assert_eq!(reply.logits.len(), classes);
    }
    let snap = engine.metrics();
    assert_eq!(snap.fleet.completed, n as u64);
    engine.shutdown();
}

/// Malformed requests get a typed `BadImage` error at submit time
/// instead of the old silent truncate/zero-pad behaviour.
#[test]
fn submit_rejects_wrong_image_size() {
    let img = 16usize;
    let weights = calibrated_weights("mini_cnn_s", img, 4, 2);
    let cfg = EvalConfig {
        batch: 4,
        ..EvalConfig::paper(Method::Baseline, 0.0)
    };
    let mut router = Router::native();
    let v = router.register_native_weights("v", &weights, &cfg).unwrap();
    let engine = Engine::start(EngineOptions::default());
    let handle = engine.register(v).unwrap();
    // Too short and too long both bounce with a typed error.
    for bad in [7usize, img * img * 3 + 1] {
        let err = handle.submit(vec![0.5; bad]).unwrap_err();
        assert!(
            matches!(err, SubmitError::BadImage { got, .. } if got == bad),
            "len {}: unexpected error {:?}",
            bad,
            err
        );
        let msg = format!("{}", err);
        assert!(msg.contains("expected"), "unhelpful error: {}", msg);
    }
    // A well-formed request still succeeds.
    let ticket = handle.submit(vec![0.5; img * img * 3]).unwrap();
    assert!(ticket.wait_deadline(Duration::from_secs(30)).is_ok());
    engine.shutdown();
}

/// The multi-variant acceptance test: ONE engine, one shared worker
/// pool, three precision points (baseline / DLIQ / MIP2Q) of the same
/// net served concurrently — every reply must equal the direct plan
/// execution of ITS variant, and the whole fleet runs on `workers`
/// threads (the old per-variant layout needed 3×(workers+1)).
#[test]
fn engine_serves_three_variants_on_one_pool() {
    let img = 16usize;
    let classes = 7usize;
    let weights = calibrated_weights("mini_cnn_s", img, classes, 17);
    let specs = [
        ("base", Method::Baseline, 0.0),
        ("dliq", Method::Dliq { q: 4 }, 0.5),
        ("mip2q", Method::Mip2q { l_max: 7 }, 0.5),
    ];
    let mut router = Router::native();
    let engine = Engine::start(EngineOptions {
        workers: 2,
        max_wait: Duration::from_millis(2),
        max_batch: Some(8),
        ..EngineOptions::default()
    });
    // One serving thread per worker, no per-variant batcher threads.
    assert_eq!(engine.worker_count(), 2);
    let mut handles = Vec::new();
    let mut plans = Vec::new();
    for (key, method, p) in specs {
        let cfg = EvalConfig {
            batch: 8,
            ..EvalConfig::paper(method, p)
        };
        let transformed = transform_network(&weights, &cfg).unwrap();
        plans.push(NetworkPlan::from_transformed(&weights, &transformed, true).unwrap());
        let v = router.register_native_weights(key, &weights, &cfg).unwrap();
        handles.push(engine.register(v).unwrap());
    }
    assert_eq!(engine.keys(), vec!["base", "dliq", "mip2q"]);

    let px = img * img * 3;
    let n = 30usize; // 10 per variant, interleaved
    let images = random_images(n, img, 23);
    let pend: Vec<_> = (0..n)
        .map(|i| {
            let vi = i % handles.len();
            let t = handles[vi]
                .submit(images[i * px..(i + 1) * px].to_vec())
                .unwrap();
            (vi, i, t)
        })
        .collect();
    for (vi, i, ticket) in pend {
        let reply = ticket.wait_deadline(Duration::from_secs(60)).unwrap();
        let direct = plans[vi].forward_one(&images[i * px..(i + 1) * px]).unwrap();
        assert_eq!(
            reply.class,
            argmax(&direct),
            "request {} on variant {}",
            i,
            vi
        );
        assert_eq!(reply.logits.len(), classes);
    }
    // Typed metrics: per-variant rows sum into the fleet rollup.
    let snap = engine.metrics();
    assert_eq!(snap.workers, 2);
    assert_eq!(snap.variants.len(), 3);
    for v in &snap.variants {
        assert_eq!(v.completed, 10, "variant {}", v.key);
        assert_eq!(v.rejected, 0);
        assert_eq!(v.queued, 0);
    }
    assert_eq!(snap.fleet.completed, 30);
    // The snapshot serializes through the in-tree JSON layer.
    let j = snap.to_json();
    assert_eq!(
        j.get("variants").unwrap().as_arr().unwrap().len(),
        3
    );
    engine.shutdown();
}

/// Hot-retire: a drained variant's queued work still completes, the
/// slot disappears, and the remaining variants keep serving.
#[test]
fn engine_retires_variant_while_serving() {
    let img = 16usize;
    let classes = 5usize;
    let weights = calibrated_weights("mini_cnn_s", img, classes, 29);
    let mut router = Router::native();
    let engine = Engine::start(EngineOptions {
        workers: 2,
        max_wait: Duration::from_millis(1),
        ..EngineOptions::default()
    });
    let cfg_a = EvalConfig::paper(Method::Baseline, 0.0);
    let cfg_b = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
    let a = router.register_native_weights("a", &weights, &cfg_a).unwrap();
    let b = router.register_native_weights("b", &weights, &cfg_b).unwrap();
    let ha = engine.register(a).unwrap();
    let hb = engine.register(b).unwrap();

    let px = img * img * 3;
    let images = random_images(8, img, 31);
    let ta: Vec<_> = (0..4)
        .map(|i| ha.submit(images[i * px..(i + 1) * px].to_vec()).unwrap())
        .collect();
    let tb: Vec<_> = (4..8)
        .map(|i| hb.submit(images[i * px..(i + 1) * px].to_vec()).unwrap())
        .collect();
    // retire() blocks until a's queue is drained — its tickets all
    // resolve successfully afterwards.
    engine.retire("a").unwrap();
    for t in ta {
        assert!(t.wait_deadline(Duration::from_secs(30)).is_ok());
    }
    // The retired key is gone; the handle reports it.
    assert_eq!(engine.keys(), vec!["b"]);
    let err = ha.submit(images[..px].to_vec()).unwrap_err();
    assert!(
        matches!(err, SubmitError::UnknownVariant { .. }),
        "unexpected error {:?}",
        err
    );
    assert!(engine.retire("a").is_err());
    // b keeps serving after the retire.
    for t in tb {
        assert!(t.wait_deadline(Duration::from_secs(30)).is_ok());
    }
    let t = hb.submit(images[..px].to_vec()).unwrap();
    assert!(t.wait_deadline(Duration::from_secs(30)).is_ok());
    engine.shutdown();
}

/// `evaluate_native` agrees with a hand-rolled reference evaluation on a
/// synthetic dataset (top-1 identical on confidently-classified images).
#[test]
fn native_eval_matches_reference_top1() {
    let img = 16usize;
    let classes = 6usize;
    let weights = calibrated_weights("mini_vgg_a", img, classes, 31);
    let n = 32usize;
    let px = img * img * 3;
    let images = random_images(n, img, 77);
    let mut rng = Rng::new(13);
    let labels: Vec<i32> = (0..n).map(|_| rng.range(0, classes) as i32).collect();
    let data = DataSet {
        images: images.clone(),
        labels: labels.clone(),
        n,
        img,
    };
    let cfg = EvalConfig {
        batch: 8,
        ..EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5)
    };
    let r = evaluate_native_weights(&weights, &data, &cfg).unwrap();
    assert_eq!(r.n, n);

    let transformed = transform_network(&weights, &cfg).unwrap();
    let mut ref_correct = 0usize;
    let mut confident_disagreements = 0usize;
    let plan = NetworkPlan::from_transformed(&weights, &transformed, true).unwrap();
    for i in 0..n {
        let image = &images[i * px..(i + 1) * px];
        let reference = forward_f32_reference(&weights, &transformed, image, true).unwrap();
        if argmax(&reference) as i32 == labels[i] {
            ref_correct += 1;
        }
        let denom = reference.iter().fold(1f32, |a, &x| a.max(x.abs()));
        let native = plan.forward_one(image).unwrap();
        if margin(&reference) > 1e-2 * denom && argmax(&native) != argmax(&reference) {
            confident_disagreements += 1;
        }
    }
    assert_eq!(confident_disagreements, 0, "native/reference top-1 split");
    // Top-1 rates can only differ through margin-thin images.
    let ref_top1 = ref_correct as f64 / n as f64;
    assert!(
        (r.top1 - ref_top1).abs() <= 2.0 / n as f64,
        "native top1 {} vs reference {}",
        r.top1,
        ref_top1
    );
}
