//! Kernel-layer invariant suite: every SIMD path must be *bit-identical*
//! to the scalar reference (int32 accumulators compared with `==`, never
//! a tolerance), across odd sizes, unaligned slice offsets, and
//! all-saturated ±127 inputs; the fused graph walk must reproduce the
//! unfused walk's logits bit-for-bit on every zoo net.
//!
//! Run with `STRUM_KERNEL=scalar` to pin the dispatcher to the reference
//! path (the CI forced-scalar job does exactly that).

use strum_dpu::backend::graph::{calibrate_act_scales, synth_net_weights};
use strum_dpu::backend::kernels::{
    available_isas, dot_i8_isa, dot_i8_x4_isa, dot_i8_x4_rows2_isa, gemm_i8_blocked_isa,
    mark_nonzero_rows, Isa,
};
use strum_dpu::backend::{parallel, NetworkPlan};
use strum_dpu::model::eval::{transform_network, EvalConfig};
use strum_dpu::model::import::NetWeights;
use strum_dpu::model::zoo;
use strum_dpu::quant::Method;
use strum_dpu::util::prng::Rng;
use strum_dpu::util::proptest::{check, Gen};

/// Naive triple-loop GEMM — the semantics every driver must match.
fn naive_gemm(x: &[i8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc += x[i * k + kk] as i32 * w[j * k + kk] as i32;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

#[test]
fn dot_kernels_bit_exact_random() {
    check("dot_i8 SIMD == scalar", 300, |g: &mut Gen| {
        // Odd sizes on purpose: tails of every SIMD width get hit.
        let n = g.usize_in(0, 333);
        let x: Vec<i8> = (0..n).map(|_| g.i8()).collect();
        let w: Vec<i8> = (0..n).map(|_| g.i8()).collect();
        let want = dot_i8_isa(Isa::Scalar, &x, &w);
        available_isas()
            .into_iter()
            .all(|isa| dot_i8_isa(isa, &x, &w) == want)
    });
}

#[test]
fn dot_kernels_bit_exact_unaligned_offsets() {
    let mut rng = Rng::new(77);
    let buf_x: Vec<i8> = (0..4103).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
    let buf_w: Vec<i8> = (0..4103).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect();
    for off_x in 0..5usize {
        for off_w in 0..5usize {
            for len in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 257, 4000] {
                let x = &buf_x[off_x..off_x + len];
                let w = &buf_w[off_w..off_w + len];
                let want = dot_i8_isa(Isa::Scalar, x, w);
                for isa in available_isas() {
                    assert_eq!(
                        dot_i8_isa(isa, x, w),
                        want,
                        "{:?} off=({}, {}) len={}",
                        isa,
                        off_x,
                        off_w,
                        len
                    );
                }
            }
        }
    }
}

#[test]
fn dot_kernels_bit_exact_saturated() {
    // Worst-case magnitude: every product is ±127² and every int16
    // madd pair sits at its extreme. 4096 lanes keeps the exact sum
    // far from i32 overflow, as the kernel contract requires.
    for (a, b) in [(127i8, 127i8), (127, -127), (-127, -127), (-127, 127)] {
        for n in [64usize, 333, 4096] {
            let x = vec![a; n];
            let w = vec![b; n];
            let want = dot_i8_isa(Isa::Scalar, &x, &w);
            assert_eq!(want, n as i32 * (a as i32 * b as i32));
            for isa in available_isas() {
                assert_eq!(dot_i8_isa(isa, &x, &w), want, "{:?} {}x({},{})", isa, n, a, b);
            }
        }
    }
}

#[test]
fn dot_x4_bit_exact_random() {
    check("dot_i8_x4 SIMD == scalar singles", 200, |g: &mut Gen| {
        let n = g.usize_in(0, 200);
        let x: Vec<i8> = (0..n).map(|_| g.i8()).collect();
        let ws: Vec<Vec<i8>> = (0..4).map(|_| (0..n).map(|_| g.i8()).collect()).collect();
        let want = [
            dot_i8_isa(Isa::Scalar, &x, &ws[0]),
            dot_i8_isa(Isa::Scalar, &x, &ws[1]),
            dot_i8_isa(Isa::Scalar, &x, &ws[2]),
            dot_i8_isa(Isa::Scalar, &x, &ws[3]),
        ];
        available_isas()
            .into_iter()
            .all(|isa| dot_i8_x4_isa(isa, &x, &ws[0], &ws[1], &ws[2], &ws[3]) == want)
    });
}

/// Scalar oracle for the 2×4 block: eight independent scalar dots.
fn rows2_oracle(x0: &[i8], x1: &[i8], ws: &[Vec<i8>]) -> [[i32; 4]; 2] {
    [
        [
            dot_i8_isa(Isa::Scalar, x0, &ws[0]),
            dot_i8_isa(Isa::Scalar, x0, &ws[1]),
            dot_i8_isa(Isa::Scalar, x0, &ws[2]),
            dot_i8_isa(Isa::Scalar, x0, &ws[3]),
        ],
        [
            dot_i8_isa(Isa::Scalar, x1, &ws[0]),
            dot_i8_isa(Isa::Scalar, x1, &ws[1]),
            dot_i8_isa(Isa::Scalar, x1, &ws[2]),
            dot_i8_isa(Isa::Scalar, x1, &ws[3]),
        ],
    ]
}

#[test]
fn dot_x4_rows2_bit_exact_random() {
    check("dot_i8_x4_rows2 SIMD == scalar singles", 200, |g: &mut Gen| {
        // Odd lengths on purpose: every fused 2×4 kernel's tail gets hit.
        let n = g.usize_in(0, 333);
        let x0: Vec<i8> = (0..n).map(|_| g.i8()).collect();
        let x1: Vec<i8> = (0..n).map(|_| g.i8()).collect();
        let ws: Vec<Vec<i8>> = (0..4).map(|_| (0..n).map(|_| g.i8()).collect()).collect();
        let want = rows2_oracle(&x0, &x1, &ws);
        available_isas()
            .into_iter()
            .all(|isa| dot_i8_x4_rows2_isa(isa, &x0, &x1, &ws[0], &ws[1], &ws[2], &ws[3]) == want)
    });
}

#[test]
fn dot_x4_rows2_bit_exact_unaligned_offsets() {
    let mut rng = Rng::new(177);
    let mut buf = || -> Vec<i8> { (0..4103).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect() };
    let buf_x0 = buf();
    let buf_x1 = buf();
    let buf_ws: Vec<Vec<i8>> = (0..4).map(|_| buf()).collect();
    for off in 0..5usize {
        for len in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 257, 4000] {
            let x0 = &buf_x0[off..off + len];
            let x1 = &buf_x1[1..1 + len];
            let ws: Vec<Vec<i8>> = buf_ws.iter().map(|b| b[off..off + len].to_vec()).collect();
            let want = rows2_oracle(x0, x1, &ws);
            for isa in available_isas() {
                assert_eq!(
                    dot_i8_x4_rows2_isa(isa, x0, x1, &ws[0], &ws[1], &ws[2], &ws[3]),
                    want,
                    "{:?} off={} len={}",
                    isa,
                    off,
                    len
                );
            }
        }
    }
}

#[test]
fn dot_x4_rows2_bit_exact_saturated() {
    // Every product at ±127² keeps all eight accumulators at the int16
    // madd-pair extreme; 4096 lanes stays far from i32 overflow.
    for (a, b) in [(127i8, 127i8), (127, -127), (-127, -127), (-127, 127)] {
        for n in [64usize, 333, 4096] {
            let x0 = vec![a; n];
            let x1 = vec![b; n];
            let ws: Vec<Vec<i8>> = (0..4).map(|_| vec![b; n]).collect();
            let want = rows2_oracle(&x0, &x1, &ws);
            for isa in available_isas() {
                assert_eq!(
                    dot_i8_x4_rows2_isa(isa, &x0, &x1, &ws[0], &ws[1], &ws[2], &ws[3]),
                    want,
                    "{:?} {}x({},{})",
                    isa,
                    n,
                    a,
                    b
                );
            }
        }
    }
}

#[test]
fn blocked_gemm_bit_exact_with_and_without_skip() {
    check("blocked GEMM == naive", 60, |g: &mut Gen| {
        let m = g.usize_in(1, 9);
        let k = g.usize_in(1, 150);
        let n = g.usize_in(1, 20);
        let mut x: Vec<i8> = (0..m * k).map(|_| g.i8()).collect();
        let w: Vec<i8> = (0..n * k).map(|_| g.i8()).collect();
        // Randomly blank some rows so the skip path gets real coverage.
        for i in 0..m {
            if g.bool() && g.bool() {
                x[i * k..(i + 1) * k].fill(0);
            }
        }
        let want = naive_gemm(&x, &w, m, k, n);
        let mut flags = Vec::new();
        mark_nonzero_rows(&x, m, k, &mut flags);
        available_isas().into_iter().all(|isa| {
            let mut plain = vec![-1i32; m * n];
            gemm_i8_blocked_isa(isa, &x, &w, m, k, n, &mut plain, None);
            let mut skipped = vec![-1i32; m * n];
            gemm_i8_blocked_isa(isa, &x, &w, m, k, n, &mut skipped, Some(&flags));
            plain == want && skipped == want
        })
    });
}

fn random_images(n: usize, img: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * img * img * 3).map(|_| rng.f32()).collect()
}

fn calibrated_weights(net: &str, img: usize, classes: usize, seed: u64) -> NetWeights {
    let mut w = synth_net_weights(net, img, classes, seed).unwrap();
    let calib = random_images(4, img, seed ^ 0xA5A5);
    w.manifest.act_scales = calibrate_act_scales(&w, &calib, 4).unwrap();
    w
}

fn assert_logits_identical(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{}: logit count", ctx);
    for (j, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{} logit {}: {} vs {}", ctx, j, x, y);
    }
}

/// The fused epilogue walk (quantized plane handoff, fused pool, zero-row
/// skip) must reproduce the unfused separate-pass walk bit-for-bit on
/// every zoo net — with static activation scales and with dynamic ones.
#[test]
fn fused_forward_matches_unfused_on_every_zoo_net() {
    let img = 16usize;
    let classes = 5usize;
    for net in zoo::net_names() {
        let weights = calibrated_weights(net, img, classes, 7);
        for (method, p) in [
            (Method::Baseline, 0.0),
            (Method::Dliq { q: 4 }, 0.5),
            (Method::Mip2q { l_max: 7 }, 0.5),
        ] {
            let cfg = EvalConfig::paper(method, p);
            let transformed = transform_network(&weights, &cfg).unwrap();
            for act_quant in [true, false] {
                let plan =
                    NetworkPlan::from_transformed(&weights, &transformed, act_quant).unwrap();
                let images = random_images(2, img, 31);
                let px = img * img * 3;
                for i in 0..2 {
                    let image = &images[i * px..(i + 1) * px];
                    let fused = plan.forward_one(image).unwrap();
                    let unfused = plan.forward_one_unfused(image).unwrap();
                    assert_logits_identical(
                        &fused,
                        &unfused,
                        &format!("{} {:?} act_quant={} image {}", net, method, act_quant, i),
                    );
                }
            }
        }
    }
}

/// The per-output-channel parallel split must not change a single bit.
#[test]
fn oc_parallel_width_matches_serial() {
    let img = 16usize;
    let weights = calibrated_weights("mini_vgg_a", img, 6, 13);
    let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
    let transformed = transform_network(&weights, &cfg).unwrap();
    let plan = NetworkPlan::from_transformed(&weights, &transformed, true).unwrap();
    let image = random_images(1, img, 3);
    let serial = plan.forward_one(&image).unwrap();
    for width in [2usize, 3, 8] {
        let par = plan.forward_one_width(&image, width).unwrap();
        assert_logits_identical(&serial, &par, &format!("width {}", width));
    }
}

/// Narrow batches (fewer images than workers) go down the per-OC split
/// path inside `infer_batch_width`; wide batches fan out per image.
/// Both must equal the serial single-image results.
#[test]
fn infer_batch_width_shapes_agree() {
    let img = 16usize;
    let classes = 4usize;
    let weights = calibrated_weights("mini_cnn_s", img, classes, 19);
    let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
    let transformed = transform_network(&weights, &cfg).unwrap();
    let plan = NetworkPlan::from_transformed(&weights, &transformed, true).unwrap();
    let px = img * img * 3;
    for (batch, width) in [(1usize, 4usize), (2, 8), (6, 2), (5, 5)] {
        let images = random_images(batch, img, batch as u64 * 91);
        let got = parallel::infer_batch_width(&plan, &images, batch, width).unwrap();
        assert_eq!(got.len(), batch * classes);
        for i in 0..batch {
            let one = plan.forward_one(&images[i * px..(i + 1) * px]).unwrap();
            assert_logits_identical(
                &one,
                &got[i * classes..(i + 1) * classes],
                &format!("batch {} width {} image {}", batch, width, i),
            );
        }
    }
}
