//! End-to-end telemetry test: serve real wire requests over loopback
//! TCP with a live JSONL sink, then reconcile the event log against the
//! engine's typed metrics snapshot.
//!
//! The contract under test is 1:1 emission — every
//! `record_done`/`record_shed`/`record_rejected` call site also emits
//! exactly one event — so per-variant counts derived from the log must
//! equal the snapshot's counters exactly (given zero channel drops,
//! which the test also asserts).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use strum_dpu::backend::graph::{calibrate_act_scales, synth_net_weights};
use strum_dpu::coordinator::{Engine, EngineOptions, Router, SubmitError};
use strum_dpu::model::eval::EvalConfig;
use strum_dpu::quant::Method;
use strum_dpu::server::{
    AioServer, HttpClient, PipelinedClient, WireClient, WireResponse, WireServer,
    WireServerOptions,
};
use strum_dpu::telemetry::{
    segment_files, validate_line, TelemetryConfig, TelemetrySink, TraceCtx,
};
use strum_dpu::util::prng::Rng;

const IMG: usize = 16;
const CLASSES: usize = 8;

fn fleet_engine(
    sink: TelemetrySink,
    seed: u64,
    trace_sample: u32,
) -> anyhow::Result<(Arc<Engine>, Vec<f32>)> {
    let mut weights = synth_net_weights("mini_cnn_s", IMG, CLASSES, seed)?;
    let px = IMG * IMG * 3;
    let mut rng = Rng::new(seed ^ 1);
    let calib: Vec<f32> = (0..4 * px).map(|_| rng.f32()).collect();
    weights.manifest.act_scales = calibrate_act_scales(&weights, &calib, 4)?;
    let mut router = Router::native();
    let engine = Arc::new(Engine::start(EngineOptions {
        workers: 2,
        max_wait: Duration::from_millis(1),
        telemetry: sink,
        telemetry_interval: Some(Duration::from_millis(50)),
        trace_sample,
        ..EngineOptions::default()
    }));
    for (label, method, p) in [
        ("base", Method::Baseline, 0.0),
        ("mip2q-L7", Method::Mip2q { l_max: 7 }, 0.5),
    ] {
        let cfg = EvalConfig::paper(method, p);
        let v = router.register_native_weights(label, &weights, &cfg)?;
        engine.register(v)?;
    }
    let image: Vec<f32> = (0..px).map(|_| rng.f32()).collect();
    Ok((engine, image))
}

#[test]
fn wire_serving_events_reconcile_with_metrics() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("strum-telemetry-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sink = TelemetrySink::open(TelemetryConfig::under(&dir))?;
    let run_id = sink.run_id().to_string();
    assert!(!run_id.is_empty());

    let (engine, image) = fleet_engine(sink.clone(), 91, 0)?;
    let server = WireServer::bind(
        "127.0.0.1:0",
        engine.clone(),
        WireServerOptions {
            conn_workers: 2,
            telemetry: sink.clone(),
            ..WireServerOptions::default()
        },
    )?;
    let addr = server.local_addr().to_string();

    // Real framed requests over loopback, round-robined across the
    // fleet; every one should complete (no deadline pressure).
    let keys = ["base", "mip2q-L7"];
    let mut client = WireClient::connect(&addr)?;
    let mut wire_ok = 0usize;
    for i in 0..40 {
        match client.infer(keys[i % keys.len()], &image)? {
            WireResponse::Infer(_) => wire_ok += 1,
            WireResponse::Error { code, detail } => {
                panic!("unexpected wire error {:?}: {}", code, detail)
            }
        }
    }
    assert_eq!(wire_ok, 40);

    // Deterministic door sheds: an already-expired deadline is refused
    // at submit, recording one shed metric + one request_shed event.
    let past = Instant::now()
        .checked_sub(Duration::from_millis(50))
        .expect("monotonic clock far enough from boot");
    let mut door_sheds = 0u64;
    for _ in 0..7 {
        match engine.submit_deadline("base", image.clone(), Some(past)) {
            Err(SubmitError::Expired { .. }) => door_sheds += 1,
            other => panic!("expected Expired, got {:?}", other.map(|_| "ticket")),
        }
    }
    assert_eq!(door_sheds, 7);

    // Snapshot after all request activity is finished (every wire call
    // above was synchronous), then tear down and drain the sink.
    let snap = engine.metrics();
    drop(client);
    server.shutdown();
    if let Ok(engine) = Arc::try_unwrap(engine) {
        engine.shutdown();
    }
    sink.flush();
    assert_eq!(sink.dropped(), 0, "bounded channel must not have overflowed");

    // Read every rotated segment back and validate line by line.
    let files = segment_files(&dir, &run_id);
    assert!(!files.is_empty(), "no telemetry segments under {:?}", dir);
    let mut lines = 0u64;
    let mut tags: BTreeMap<String, u64> = BTreeMap::new();
    // (tag, variant key) -> count, for per-variant reconciliation.
    let mut per_key: BTreeMap<(String, String), u64> = BTreeMap::new();
    for f in &files {
        for line in std::fs::read_to_string(f)?.lines() {
            let parsed = validate_line(line)
                .unwrap_or_else(|e| panic!("invalid telemetry line {:?}: {:#}", line, e));
            assert_eq!(parsed.run_id, run_id, "all lines share the sink's run_id");
            lines += 1;
            *tags.entry(parsed.tag.clone()).or_insert(0) += 1;
            if let Some(key) = parsed.key {
                *per_key.entry((parsed.tag, key)).or_insert(0) += 1;
            }
        }
    }
    assert_eq!(
        lines,
        sink.emitted(),
        "every accepted event reaches disk exactly once"
    );

    // Fleet-level reconciliation: done + shed + rejected totals match.
    assert_eq!(tags.get("request_done").copied().unwrap_or(0), snap.fleet.completed);
    assert_eq!(tags.get("request_shed").copied().unwrap_or(0), snap.fleet.shed);
    assert_eq!(tags.get("request_rejected").copied().unwrap_or(0), snap.fleet.rejected);
    assert_eq!(snap.fleet.completed, 40);
    assert_eq!(snap.fleet.shed, 7);

    // Per-variant reconciliation against each snapshot row.
    for v in &snap.variants {
        let count = |tag: &str| {
            per_key
                .get(&(tag.to_string(), v.key.clone()))
                .copied()
                .unwrap_or(0)
        };
        assert_eq!(count("request_done"), v.completed, "done for {}", v.key);
        assert_eq!(count("request_shed"), v.shed, "shed for {}", v.key);
        assert_eq!(count("request_rejected"), v.rejected, "rejected for {}", v.key);
    }

    // Lifecycle events: both registrations, plus the connection open/
    // close pair and the server drain marker.
    assert_eq!(tags.get("variant_registered").copied().unwrap_or(0), 2);
    assert!(tags.get("conn_opened").copied().unwrap_or(0) >= 1);
    assert!(tags.get("conn_closed").copied().unwrap_or(0) >= 1);
    assert_eq!(tags.get("server_drain").copied().unwrap_or(0), 1);
    // Batches were formed for the completed requests.
    assert!(tags.get("batch_formed").copied().unwrap_or(0) >= 1);

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// Async-tier reconciliation: the `http_request` and `conn_pipelined`
/// events obey the same 1:1 contract as the request events — the counts
/// read back from the JSONL log equal the server stats snapshot's
/// `http_requests` / `pipelined_conns` counters exactly.
#[test]
fn aio_http_and_pipeline_events_reconcile_with_stats() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("strum-telemetry-aio-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sink = TelemetrySink::open(TelemetryConfig::under(&dir))?;
    let run_id = sink.run_id().to_string();

    let (engine, image) = fleet_engine(sink.clone(), 97, 0)?;
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        Some("127.0.0.1:0"),
        engine.clone(),
        WireServerOptions {
            conn_workers: 2,
            telemetry: sink.clone(),
            ..WireServerOptions::default()
        },
    )?;

    // HTTP traffic across every endpoint class: infers, a metrics read,
    // and a 404 — all count as http_request events.
    let mut http = HttpClient::new(server.http_addr().unwrap().to_string());
    for _ in 0..4 {
        let (status, body) = http.infer("base", &image, 0)?;
        assert_eq!(status, 200, "{}", body);
    }
    let (status, _) = http.request("GET", "/v1/metrics", None)?;
    assert_eq!(status, 200);
    let (status, _) = http.request("GET", "/nope", None)?;
    assert_eq!(status, 404);

    // One pipelined v2 connection: ten submits back to back before any
    // receive guarantees overlapping in-flight requests, so the conn
    // crosses the pipelined threshold exactly once.
    let mut pipelined = PipelinedClient::connect(&server.local_addr().unwrap().to_string())?;
    let mut corrs = Vec::new();
    for i in 0..10usize {
        corrs.push(pipelined.submit(["base", "mip2q-L7"][i % 2], &image, 0)?);
    }
    let mut seen = Vec::new();
    for _ in 0..corrs.len() {
        let (corr, resp) = pipelined.recv_infer()?;
        assert!(matches!(resp, WireResponse::Infer(_)));
        seen.push(corr);
    }
    seen.sort_unstable();
    corrs.sort_unstable();
    assert_eq!(seen, corrs, "every submit answered exactly once");

    let stats = server.stats();
    drop(http);
    drop(pipelined);
    server.shutdown();
    if let Ok(engine) = Arc::try_unwrap(engine) {
        engine.shutdown();
    }
    sink.flush();
    assert_eq!(sink.dropped(), 0, "bounded channel must not have overflowed");

    let files = segment_files(&dir, &run_id);
    assert!(!files.is_empty(), "no telemetry segments under {:?}", dir);
    let mut tags: BTreeMap<String, u64> = BTreeMap::new();
    for f in &files {
        for line in std::fs::read_to_string(f)?.lines() {
            let parsed = validate_line(line)
                .unwrap_or_else(|e| panic!("invalid telemetry line {:?}: {:#}", line, e));
            *tags.entry(parsed.tag).or_insert(0) += 1;
        }
    }

    assert_eq!(stats.http_requests, 6);
    assert_eq!(
        tags.get("http_request").copied().unwrap_or(0),
        stats.http_requests,
        "one http_request event per counted HTTP request"
    );
    assert_eq!(stats.pipelined_conns, 1);
    assert_eq!(
        tags.get("conn_pipelined").copied().unwrap_or(0),
        stats.pipelined_conns,
        "one conn_pipelined event per counted pipelined connection"
    );

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

#[test]
fn disabled_sink_serves_without_writing_anything() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("strum-telemetry-off-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sink = TelemetrySink::disabled();
    assert!(!sink.is_enabled());
    assert_eq!(sink.run_id(), "");

    let (engine, image) = fleet_engine(sink.clone(), 93, 0)?;
    for _ in 0..5 {
        engine.submit("base", image.clone()).expect("submit").wait()?;
    }
    let snap = engine.metrics();
    assert_eq!(snap.fleet.completed, 5);
    assert_eq!(snap.telemetry_dropped, 0);
    sink.flush(); // no-op, must not block
    assert!(!dir.exists(), "disabled sink must never create files");
    Ok(())
}

/// Tracing reconciliation over the async tier: every traced request's
/// stage spans land 1:1 against the metrics snapshot, layer profiling
/// samples exactly the 1-in-N trace ids, and summed layer time never
/// exceeds the execute span it was measured inside.
#[test]
fn traced_requests_emit_spans_that_reconcile_and_sample_layers() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("strum-telemetry-trace-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sink = TelemetrySink::open(TelemetryConfig::under(&dir))?;
    let run_id = sink.run_id().to_string();

    // trace_sample = 2: even trace ids carry layer spans, odd ones don't.
    let (engine, image) = fleet_engine(sink.clone(), 89, 2)?;
    let server = AioServer::bind(
        Some("127.0.0.1:0"),
        None,
        engine.clone(),
        WireServerOptions {
            conn_workers: 2,
            telemetry: sink.clone(),
            ..WireServerOptions::default()
        },
    )?;
    let mut client = WireClient::connect(&server.local_addr().unwrap().to_string())?;

    // Ten traced requests with consecutive ids (the loadgen --trace
    // shape), synchronous so each rides its own batch, plus three
    // untraced ones that must leave no spans at all.
    let base = 0x1000u64;
    let traced = 10u64;
    for i in 0..traced {
        let ctx = TraceCtx {
            trace_id: base + i,
            attempt: 0,
        };
        match client.infer_traced("base", &image, 0, Some(ctx))? {
            WireResponse::Infer(_) => {}
            WireResponse::Error { code, detail } => {
                panic!("traced infer failed {:?}: {}", code, detail)
            }
        }
    }
    for _ in 0..3 {
        assert!(matches!(
            client.infer("base", &image)?,
            WireResponse::Infer(_)
        ));
    }

    let snap = engine.metrics();
    drop(client);
    server.shutdown();
    if let Ok(engine) = Arc::try_unwrap(engine) {
        engine.shutdown();
    }
    sink.flush();
    assert_eq!(sink.dropped(), 0, "bounded channel must not have overflowed");

    // (trace id, stage) -> count; plus per-trace layer/execute micros.
    let mut stage_counts: BTreeMap<(u64, String), u64> = BTreeMap::new();
    let mut layer_sum: BTreeMap<u64, u64> = BTreeMap::new();
    let mut exec_us: BTreeMap<u64, u64> = BTreeMap::new();
    let mut span_traces: Vec<u64> = Vec::new();
    for f in &segment_files(&dir, &run_id) {
        for line in std::fs::read_to_string(f)?.lines() {
            let parsed = validate_line(line)
                .unwrap_or_else(|e| panic!("invalid telemetry line {:?}: {:#}", line, e));
            if parsed.tag != "span" {
                continue;
            }
            let t = parsed.trace.expect("span lines carry a trace id");
            let stage = parsed.stage.expect("span lines carry a stage");
            assert!(!parsed.abandoned, "no hedging here, nothing abandoned");
            span_traces.push(t);
            match stage.as_str() {
                "layer" => {
                    assert!(
                        parsed.detail.is_some(),
                        "layer spans carry the layer name"
                    );
                    *layer_sum.entry(t).or_insert(0) += parsed.dur_us;
                }
                "execute" => {
                    exec_us.insert(t, parsed.dur_us);
                }
                _ => {}
            }
            *stage_counts.entry((t, stage)).or_insert(0) += 1;
        }
    }

    // Spans exist only for the ten traced requests.
    span_traces.sort_unstable();
    span_traces.dedup();
    assert_eq!(
        span_traces,
        (base..base + traced).collect::<Vec<_>>(),
        "exactly the traced ids appear in the span log"
    );
    assert_eq!(snap.fleet.completed, traced + 3);

    // Every traced request shows the full stage pipeline exactly once.
    for i in 0..traced {
        let t = base + i;
        for stage in ["door", "queue_wait", "batch", "execute", "reply_write"] {
            assert_eq!(
                stage_counts.get(&(t, stage.to_string())).copied().unwrap_or(0),
                1,
                "stage {} for trace {:#x}",
                stage,
                t
            );
        }
        // 1-in-2 sampling determinism: even ids profiled, odd ids not.
        let layers = stage_counts
            .get(&(t, "layer".to_string()))
            .copied()
            .unwrap_or(0);
        if t % 2 == 0 {
            assert!(layers > 0, "sampled trace {:#x} has no layer spans", t);
            // Layers are timed inside the execute window.
            assert!(
                layer_sum[&t] <= exec_us[&t],
                "layers {}us exceed execute {}us for {:#x}",
                layer_sum[&t],
                exec_us[&t],
                t
            );
        } else {
            assert_eq!(layers, 0, "unsampled trace {:#x} was profiled", t);
        }
    }

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
