//! Compile→serve artifact pipeline integration tests — artifact-free in
//! the repo sense (synthetic nets), artifact-FULL in the `.strumc`
//! sense: compile-time quantize/encode must round-trip through the
//! versioned byte format into a serve-time plan that is bit-identical
//! to the quantize-at-registration path, the cache must make warm
//! registrations quantizer-free (asserted via the thread-local debug
//! counters), and every corruption of a `.strumc` byte stream must
//! surface as a typed error — never a panic, never a silent success.

use std::path::PathBuf;
use strum_dpu::artifact::{
    compile_net, reseal, ArtifactCache, ArtifactError, CacheOutcome, CompiledNet, MissReason,
    FORMAT_VERSION,
};
use strum_dpu::backend::graph::{calibrate_act_scales, synth_net_weights};
use strum_dpu::backend::NetworkPlan;
use strum_dpu::coordinator::Router;
use strum_dpu::encode::encode_layer_calls;
use strum_dpu::model::eval::{transform_network_calls, EvalConfig};
use strum_dpu::model::import::NetWeights;
use strum_dpu::model::zoo;
use strum_dpu::quant::Method;
use strum_dpu::util::prng::Rng;

fn random_images(n: usize, img: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * img * img * 3).map(|_| rng.f32()).collect()
}

/// Synthetic weights with statically calibrated activation scales — the
/// same shape of input a real `weights/<net>.{json,bin}` pair carries.
fn calibrated_weights(net: &str, img: usize, classes: usize, seed: u64) -> NetWeights {
    let mut w = synth_net_weights(net, img, classes, seed).unwrap();
    let calib = random_images(2, img, seed ^ 0x5EED);
    w.manifest.act_scales = calibrate_act_scales(&w, &calib, 2).unwrap();
    w
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "strum-artifact-test-{}-{}",
        std::process::id(),
        tag
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// The acceptance check: for every zoo net and both paper methods, a
/// plan decoded from serialized `.strumc` bytes produces logits
/// bit-identical to the quantize+encode-at-registration build path.
#[test]
fn from_artifact_bit_identical_to_build_on_all_zoo_nets() {
    let img = 12usize;
    let classes = 4usize;
    let px = img * img * 3;
    let images = random_images(2, img, 77);
    for net in zoo::net_names() {
        let weights = calibrated_weights(net, img, classes, 13);
        for (method, p) in [(Method::Dliq { q: 4 }, 0.5), (Method::Mip2q { l_max: 7 }, 0.5)] {
            let cfg = EvalConfig::paper(method, p);
            let built = NetworkPlan::build(&weights, &cfg).unwrap();
            let compiled = compile_net(&weights, &cfg).unwrap();
            // Through the full byte layout, not just the in-memory struct.
            let loaded = CompiledNet::from_bytes(&compiled.to_bytes()).unwrap();
            let plan = NetworkPlan::from_artifact(&loaded).unwrap();
            assert_eq!(plan.net, built.net);
            assert_eq!(plan.classes, built.classes);
            assert_eq!(plan.img, built.img);
            assert_eq!(plan.mean_rmse.to_bits(), built.mean_rmse.to_bits());
            for i in 0..2 {
                let image = &images[i * px..(i + 1) * px];
                let a = built.forward_one(image).unwrap();
                let b = plan.forward_one(image).unwrap();
                let a_bits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let b_bits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    a_bits, b_bits,
                    "{} {:?} image {}: artifact path diverged from build path",
                    net, method, i
                );
            }
        }
    }
}

/// Artifact stability: compile → serialize → load → re-serialize is
/// byte-identical, and re-compiling from the same weights reproduces
/// the exact same bytes (the pipeline is deterministic end to end).
#[test]
fn artifact_roundtrip_is_stable() {
    let weights = calibrated_weights("mini_resnet_a", 12, 5, 29);
    for (method, p) in [(Method::Dliq { q: 4 }, 0.5), (Method::Mip2q { l_max: 7 }, 0.25)] {
        let cfg = EvalConfig::paper(method, p);
        let bytes = compile_net(&weights, &cfg).unwrap().to_bytes();
        let reloaded = CompiledNet::from_bytes(&bytes).unwrap();
        assert_eq!(reloaded.to_bytes(), bytes, "{:?}: load→save drifted", method);
        let recompiled = compile_net(&weights, &cfg).unwrap();
        assert_eq!(recompiled.to_bytes(), bytes, "{:?}: re-compile drifted", method);
    }
}

/// Each corruption class maps to its own typed error: truncation, a
/// foreign magic, a format version skew, and checksum damage are all
/// distinguishable by the caller (the cache logs them differently).
#[test]
fn typed_load_errors_are_distinct() {
    let weights = calibrated_weights("mini_cnn_s", 8, 4, 31);
    let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
    let bytes = compile_net(&weights, &cfg).unwrap().to_bytes();

    // Hard truncation: shorter than any plausible header.
    let err = CompiledNet::from_bytes(&bytes[..4]).unwrap_err();
    assert!(matches!(err, ArtifactError::Truncated { .. }), "{}", err);

    // A file cut mid-body still reports truncation (declared length).
    let err = CompiledNet::from_bytes(&bytes[..bytes.len() - 5]).unwrap_err();
    assert!(matches!(err, ArtifactError::Truncated { .. }), "{}", err);

    // Foreign magic.
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    let err = CompiledNet::from_bytes(&bad).unwrap_err();
    assert!(matches!(err, ArtifactError::BadMagic), "{}", err);

    // Format version skew (resealed so only the version differs).
    let mut bad = bytes.clone();
    let v = u32::from_le_bytes(bad[8..12].try_into().unwrap()) + 1;
    bad[8..12].copy_from_slice(&v.to_le_bytes());
    reseal(&mut bad);
    let err = CompiledNet::from_bytes(&bad).unwrap_err();
    assert!(
        matches!(err, ArtifactError::VersionMismatch { kind: "format", .. }),
        "{}",
        err
    );

    // Body damage: the checksum trailer catches it before parsing.
    let mut bad = bytes.clone();
    let mid = bytes.len() / 2;
    bad[mid] ^= 0x40;
    let err = CompiledNet::from_bytes(&bad).unwrap_err();
    assert!(matches!(err, ArtifactError::ChecksumMismatch { .. }), "{}", err);

    // The pristine bytes still load.
    assert!(CompiledNet::from_bytes(&bytes).is_ok());
}

/// Property: corrupting random bytes (or truncating at random lengths)
/// of a valid artifact never panics and never loads silently — every
/// altered stream is rejected with a typed error.
#[test]
fn random_corruption_never_panics_or_silently_succeeds() {
    let weights = calibrated_weights("mini_cnn_s", 8, 4, 37);
    let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
    let bytes = compile_net(&weights, &cfg).unwrap().to_bytes();
    let mut rng = Rng::new(0xC0881);
    for trial in 0..200 {
        let mut bad = bytes.clone();
        // 1–3 byte corruptions, each guaranteed to change the byte.
        let flips = 1 + rng.range(0, 3);
        for _ in 0..flips {
            let pos = rng.range(0, bad.len());
            let delta = 1 + rng.range(0, 255) as u8;
            bad[pos] ^= delta;
        }
        if bad == bytes {
            // Two flips landed on the same byte and cancelled out.
            continue;
        }
        assert!(
            CompiledNet::from_bytes(&bad).is_err(),
            "trial {}: corrupted artifact loaded silently",
            trial
        );
    }
    for trial in 0..60 {
        let cut = rng.range(0, bytes.len());
        assert!(
            CompiledNet::from_bytes(&bytes[..cut]).is_err(),
            "trial {}: truncation to {} bytes loaded silently",
            trial,
            cut
        );
    }
}

/// The cold-start contract: once an artifact is cached, registration
/// (router → cache → decode → bind) performs ZERO quantize or encode
/// work — asserted with the thread-local `transform_network` /
/// `encode_layer` invocation counters — and still serves logits
/// bit-identical to a freshly built plan.
#[test]
fn cached_registration_does_no_quantize_or_encode_work() {
    let dir = temp_dir("no-requantize");
    let cache = ArtifactCache::with_version(&dir, 1);
    let img = 12usize;
    let weights = calibrated_weights("mini_vgg_a", img, 5, 41);
    let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);

    // Cold cache: the first registration compiles (and persists).
    let (_, outcome) = cache.load_or_compile(&weights, &cfg).unwrap();
    assert!(
        matches!(outcome, CacheOutcome::Miss(MissReason::NotFound)),
        "{}",
        outcome
    );

    // Warm cache: register through the router and count quantizer work.
    let built = NetworkPlan::build(&weights, &cfg).unwrap();
    let t0 = transform_network_calls();
    let e0 = encode_layer_calls();
    let mut router = Router::native();
    let (variant, outcome) = router
        .register_native_cached("mip2q", &weights, &cfg, &cache)
        .unwrap();
    assert!(outcome.is_hit(), "{}", outcome);
    assert_eq!(
        transform_network_calls(),
        t0,
        "cached registration re-ran transform_network"
    );
    assert_eq!(encode_layer_calls(), e0, "cached registration re-ran encode_layer");

    // And the served results are the build path's, bit for bit.
    let px = img * img * 3;
    let images = random_images(3, img, 43);
    use strum_dpu::backend::Backend;
    let got = variant.backend.infer_batch(images.clone(), 3).unwrap();
    for i in 0..3 {
        let want = built.forward_one(&images[i * px..(i + 1) * px]).unwrap();
        let got_bits: Vec<u32> = got[i * 5..(i + 1) * 5].iter().map(|v| v.to_bits()).collect();
        let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "image {}", i);
    }
    // Counting the comparison plan's own build keeps the accounting
    // honest: the build path DOES transform+encode.
    assert!(transform_network_calls() > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Zero-copy contract: an mmap-bound plan ([`CompiledNet::load_mapped`])
/// serves logits bit-identical to the copy-bound (`from_bytes`) plan on
/// every zoo net for both paper methods — and on unix its dense i8
/// banks really do borrow from the mapping instead of the heap.
#[test]
fn mmap_bind_bit_identical_to_copy_bind_on_all_zoo_nets() {
    let dir = temp_dir("mmap-bind");
    std::fs::create_dir_all(&dir).unwrap();
    let img = 12usize;
    let classes = 4usize;
    let px = img * img * 3;
    let images = random_images(2, img, 91);
    for net in zoo::net_names() {
        let weights = calibrated_weights(net, img, classes, 17);
        for (method, p) in [(Method::Dliq { q: 4 }, 0.5), (Method::Mip2q { l_max: 7 }, 0.5)] {
            let cfg = EvalConfig::paper(method, p);
            let compiled = compile_net(&weights, &cfg).unwrap();
            let path = dir.join(format!("{}-{}.strumc", net, method.name()));
            compiled.save(&path).unwrap();
            let copied = CompiledNet::from_bytes(&std::fs::read(&path).unwrap()).unwrap();
            let mapped = CompiledNet::load_mapped(&path).unwrap();
            #[cfg(unix)]
            assert!(
                mapped.layers.iter().all(|l| l.pack.is_mapped()),
                "{} {:?}: banks did not bind from the mapping",
                net,
                method
            );
            assert!(copied.layers.iter().all(|l| !l.pack.is_mapped()));
            let plan_copy = NetworkPlan::from_artifact(&copied).unwrap();
            let plan_map = NetworkPlan::from_artifact(&mapped).unwrap();
            for i in 0..2 {
                let image = &images[i * px..(i + 1) * px];
                let a = plan_copy.forward_one(image).unwrap();
                let b = plan_map.forward_one(image).unwrap();
                let a_bits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let b_bits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    a_bits, b_bits,
                    "{} {:?} image {}: mmap bind diverged from copy bind",
                    net, method, i
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Format-bump regression: a pre-bump `.strumc` in the cache (same slot
/// — versions are not part of the filename) surfaces as a typed format
/// mismatch, rebuilds in place, and the very next registration is a
/// pure read with ZERO quantize/encode calls.
#[test]
fn format_version_bump_rebuilds_transparently() {
    let dir = temp_dir("format-bump");
    let cache = ArtifactCache::with_version(&dir, 1);
    let weights = calibrated_weights("mini_cnn_s", 8, 4, 53);
    let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
    let (c, _) = cache.load_or_compile(&weights, &cfg).unwrap();
    let slot = cache.path_for(&c.identity);
    // Masquerade as a pre-bump artifact: older format version, valid
    // seal, same slot.
    let mut bytes = std::fs::read(&slot).unwrap();
    bytes[8..12].copy_from_slice(&(FORMAT_VERSION - 1).to_le_bytes());
    reseal(&mut bytes);
    std::fs::write(&slot, &bytes).unwrap();
    let (_, outcome) = cache.load_or_compile(&weights, &cfg).unwrap();
    assert!(
        matches!(
            outcome,
            CacheOutcome::Miss(MissReason::Load(ArtifactError::VersionMismatch {
                kind: "format",
                ..
            }))
        ),
        "{}",
        outcome
    );
    // The rebuild overwrote the stale file; the next load is quantizer-free.
    let t0 = transform_network_calls();
    let e0 = encode_layer_calls();
    let (_, outcome) = cache.load_or_compile(&weights, &cfg).unwrap();
    assert!(outcome.is_hit(), "{}", outcome);
    assert_eq!(transform_network_calls(), t0, "format-bump rebuild left quantize work behind");
    assert_eq!(encode_layer_calls(), e0, "format-bump rebuild left encode work behind");
    let _ = std::fs::remove_dir_all(&dir);
}
