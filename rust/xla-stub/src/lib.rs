//! Stub surface of the xla-rs bindings used by `strum_dpu::runtime`.
//!
//! Mirrors exactly the types and signatures the runtime module calls:
//! [`PjRtClient`], [`HloModuleProto`], [`XlaComputation`], [`Literal`],
//! [`PjRtLoadedExecutable`], [`PjRtBuffer`]. Construction of a client
//! fails at runtime with a clear message, so nothing downstream is ever
//! reachable — the stub exists purely so `--features pjrt` type-checks in
//! environments without `xla_extension`.

use std::fmt;
use std::path::Path;

/// Error type matching the shape the runtime wrapper formats with `{}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla-stub: PJRT runtime not available in this build (link the real \
         xla-rs bindings to use the pjrt backend)"
            .to_string(),
    ))
}

/// Element types the runtime moves across the boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (stub: never holds device data).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Computation handle (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Loaded executable handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}
