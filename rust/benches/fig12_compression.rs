//! Bench/report harness for Fig. 12: top-1 vs compression ratio r for
//! sparsity / DLIQ / MIP2Q. Needs artifacts.

use std::path::Path;
use strum_dpu::model::zoo;
use strum_dpu::report::{fig12, EvalCtx};
use strum_dpu::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("hlo").exists() {
        println!("SKIP fig12: artifacts missing (run `make train artifacts`)");
        return Ok(());
    }
    let limit = match std::env::var("STRUM_EVAL_LIMIT").ok().as_deref() {
        Some("full") => None,
        Some(v) => v.parse().ok(),
        None => Some(512),
    };
    let rt = Runtime::cpu()?;
    let ctx = EvalCtx::new(&rt, dir, limit)?;
    let t0 = std::time::Instant::now();
    let (series, json) = fig12::run(&ctx, zoo::SWEEP_NET)?;
    // Paper shape: at the smallest common r region, MIP2Q >= sparsity.
    let acc_at_min = |s: &strum_dpu::report::fig12::Series| s.points.first().map(|p| p.1).unwrap_or(0.0);
    let sp = acc_at_min(&series[0]);
    let mp = acc_at_min(&series[2]);
    println!(
        "at min-r: sparsity {:.1}% vs mip2q {:.1}%  (paper: mip2q wins small r)",
        sp * 100.0,
        mp * 100.0
    );
    println!("fig12 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    std::fs::create_dir_all("artifacts/reports")?;
    std::fs::write("artifacts/reports/fig12.json", json.to_string_pretty())?;
    Ok(())
}
