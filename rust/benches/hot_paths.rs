//! Micro-benchmarks of the L3 hot paths (the §Perf deliverable):
//! quantization, MIP2Q search, codec encode/decode, simulator throughput,
//! native int8 vs StruM dual-bank GEMM (with a `BENCH_native_gemm.json`
//! summary), PE datapath, the multi-variant serving engine (baseline /
//! DLIQ / MIP2Q on one shared worker pool, per-variant throughput + p95
//! from the typed `MetricsSnapshot` → `BENCH_serve_multivariant.json`),
//! cold-start variant registration (requantize path vs cached `.strumc`
//! artifact → `BENCH_coldstart.json`), wire serving over loopback TCP
//! (3-variant fleet round-trips + a tiny-deadline shed pass →
//! `BENCH_wire_bench.json`; `strum loadgen` owns the `BENCH_wire_serve.json`
//! schema), and end-to-end PJRT execute when artifacts
//! exist.
//!
//! STRUM_BENCH_QUICK=1 shrinks budgets ~10x. All JSON artifacts land in
//! `STRUM_BENCH_DIR` (default `.`) together with a checksummed
//! `MANIFEST_hot_paths.json` run manifest for `strum bench-diff`.

use std::path::Path;
use strum_dpu::artifact::{ArtifactCache, CompiledNet};
use strum_dpu::backend::gemm::gemm_i8;
use strum_dpu::backend::graph::{calibrate_act_scales, synth_net_weights};
use strum_dpu::backend::kernels::{self, Isa};
use strum_dpu::backend::strum_gemm::StrumGemm;
use strum_dpu::backend::{parallel, NetworkPlan};
use strum_dpu::coordinator::{Engine, EngineOptions, Router, SubmitError, Ticket};
use strum_dpu::encode::{decode_layer, encode_layer};
use strum_dpu::server::{WireClient, WireResponse, WireServer, WireServerOptions};
use strum_dpu::model::import::{DataSet, NetWeights};
use strum_dpu::quant::tensor::qlayer;
use strum_dpu::quant::{apply_strum, Method, StrumParams};
use strum_dpu::runtime::{Runtime, Tensor};
use strum_dpu::sim::config::SimConfig;
use strum_dpu::sim::dataflow::LayerShape;
use strum_dpu::sim::{simulate_layer, SimMode};
use strum_dpu::telemetry::{bench_dir, fresh_run_id, RunManifest};
use strum_dpu::util::bench::Bench;
use strum_dpu::util::json::Json;
use strum_dpu::util::prng::Rng;

fn big_layer(oc: usize, cols: usize, seed: u64) -> strum_dpu::quant::QLayer {
    let mut rng = Rng::new(seed);
    let data: Vec<i8> = (0..oc * cols)
        .map(|_| (rng.gaussian() * 45.0).clamp(-127.0, 127.0) as i8)
        .collect();
    qlayer("bench", oc, 1, cols, data, vec![0.01; oc])
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new();
    // Every JSON artifact goes to STRUM_BENCH_DIR (default `.`), and
    // each one is recorded in the run manifest saved at the end.
    let bench_out = bench_dir();
    let mut manifest = RunManifest::capture(&fresh_run_id());
    let layer = big_layer(256, 4096, 1); // 1M weights
    let n = layer.len() as f64;

    b.section("quantize (weights/s)");
    for method in [
        Method::StructuredSparsity,
        Method::Dliq { q: 4 },
        Method::Mip2q { l_max: 7 },
    ] {
        let params = StrumParams::paper(method, 0.5);
        b.run(&format!("apply_strum/{}", method.name()), n, || {
            apply_strum(&layer, &params)
        });
    }

    b.section("codec (weights/s)");
    let s = apply_strum(&layer, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));
    b.run("encode_layer/mip2q", n, || encode_layer(&s));
    let enc = encode_layer(&s);
    b.run("decode_layer/mip2q", n, || decode_layer(&enc).unwrap());

    b.section("native GEMM (GFLOP-equiv/s: 2·m·k·n per call)");
    // One conv-shaped GEMM: m = 64 im2col rows, k = 3·3·128 lanes,
    // n = 128 output channels.
    let (m, n_oc, rows, cols) = (64usize, 128usize, 9usize, 128usize);
    let k = rows * cols;
    let wq = {
        let raw = big_layer(n_oc, rows * cols, 7);
        qlayer("gemm", n_oc, rows, cols, raw.data, raw.scales)
    };
    let mut rng_a = Rng::new(8);
    let acts: Vec<i8> = (0..m * k)
        .map(|_| (rng_a.gaussian() * 40.0).clamp(-127.0, 127.0) as i8)
        .collect();
    let flops = (2 * m * k * n_oc) as f64;
    let mut out = vec![0i32; m * n_oc];
    let mut gemm_results: Vec<(String, f64, f64)> = Vec::new();
    // Scalar reference vs the dispatched SIMD path (the ≥2× acceptance
    // comparison lives in these two rows).
    b.run("gemm_i8/scalar-forced", flops, || {
        kernels::gemm_i8_blocked_isa(Isa::Scalar, &acts, &wq.data, m, k, n_oc, &mut out, None);
        out[0]
    });
    if let Some(r) = b.results.last() {
        gemm_results.push(("scalar-forced".into(), r.seconds.mean(), flops / r.seconds.mean() / 1e9));
    }
    b.run(
        &format!("gemm_i8/dense-int8-{}", kernels::active_isa().name()),
        flops,
        || {
            gemm_i8(&acts, &wq.data, m, k, n_oc, &mut out);
            out[0]
        },
    );
    if let Some(r) = b.results.last() {
        gemm_results.push(("dense-int8".into(), r.seconds.mean(), flops / r.seconds.mean() / 1e9));
    }
    for method in [
        Method::StructuredSparsity,
        Method::Dliq { q: 4 },
        Method::Mip2q { l_max: 7 },
    ] {
        let s = apply_strum(&wq, &StrumParams::paper(method, 0.5));
        let g = StrumGemm::from_encoded(&encode_layer(&s))?;
        b.run(&format!("strum_gemm/{}", method.name()), flops, || {
            g.matmul(&acts, m, &mut out);
            out[0]
        });
        if let Some(r) = b.results.last() {
            gemm_results.push((method.name(), r.seconds.mean(), flops / r.seconds.mean() / 1e9));
        }
    }
    let json = Json::obj(vec![
        ("m", Json::Num(m as f64)),
        ("k", Json::Num(k as f64)),
        ("n", Json::Num(n_oc as f64)),
        ("isa", Json::str(kernels::active_isa().name())),
        ("flops_per_call", Json::Num(flops)),
        (
            "kernels",
            Json::Arr(
                gemm_results
                    .iter()
                    .map(|(name, mean_s, gflops)| {
                        Json::obj(vec![
                            ("name", Json::str(name.as_str())),
                            ("mean_s", Json::Num(*mean_s)),
                            ("gflop_equiv_per_s", Json::Num(*gflops)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = bench_out.join("BENCH_native_gemm.json");
    std::fs::write(&path, json.to_string_pretty())?;
    manifest.add_payload("native_gemm", &path)?;
    println!("wrote {}", path.display());

    b.section("cycle simulator (MAC-slots/s)");
    let shape = LayerShape::conv("bench", 64, 256, 3, 16, 16);
    let wl = big_layer(64, 9 * 256, 2);
    let wl = qlayer("bench", 64, 9, 256, wl.data, wl.scales);
    let strum = apply_strum(&wl, &StrumParams::paper(Method::Mip2q { l_max: 7 }, 0.5));
    let macs = shape.macs() as f64;
    for mode in [SimMode::Int8Dense, SimMode::StrumStatic, SimMode::StrumPerf] {
        let cfg = SimConfig::flexnn(mode, Some(Method::Mip2q { l_max: 7 }));
        b.run(&format!("simulate_layer/{}", mode.name()), macs, || {
            simulate_layer(&shape, &strum, &cfg, 0.7, 0)
        });
    }

    b.section("native backend end-to-end (images/s, artifact-free)");
    {
        let img = 32usize;
        let classes = 10usize;
        let net = "mini_cnn_s";
        let mut weights = synth_net_weights(net, img, classes, 41)?;
        let px = img * img * 3;
        let mut rng = Rng::new(42);
        let calib: Vec<f32> = (0..4 * px).map(|_| rng.f32()).collect();
        weights.manifest.act_scales = calibrate_act_scales(&weights, &calib, 4)?;
        let cfg = strum_dpu::model::eval::EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
        let transformed = strum_dpu::model::eval::transform_network(&weights, &cfg)?;
        let plan = NetworkPlan::from_transformed(&weights, &transformed, true)?;
        let image: Vec<f32> = (0..px).map(|_| rng.f32()).collect();
        let mut e2e_results: Vec<(String, f64, f64)> = Vec::new();
        b.run("forward_one/unfused", 1.0, || plan.forward_one_unfused(&image).unwrap());
        if let Some(r) = b.results.last() {
            e2e_results.push(("unfused".into(), r.seconds.mean(), 1.0 / r.seconds.mean()));
        }
        b.run("forward_one/fused", 1.0, || plan.forward_one(&image).unwrap());
        if let Some(r) = b.results.last() {
            e2e_results.push(("fused".into(), r.seconds.mean(), 1.0 / r.seconds.mean()));
        }
        let batch = if b.is_quick() { 4usize } else { 16usize };
        let images: Vec<f32> = (0..batch * px).map(|_| rng.f32()).collect();
        b.run(&format!("infer_batch/b{}", batch), batch as f64, || {
            parallel::infer_batch(&plan, &images, batch).unwrap()
        });
        if let Some(r) = b.results.last() {
            e2e_results.push((
                format!("infer_batch_b{}", batch),
                r.seconds.mean(),
                batch as f64 / r.seconds.mean(),
            ));
        }
        let json = Json::obj(vec![
            ("net", Json::str(net)),
            ("img", Json::Num(img as f64)),
            ("isa", Json::str(kernels::active_isa().name())),
            (
                "paths",
                Json::Arr(
                    e2e_results
                        .iter()
                        .map(|(name, mean_s, ips)| {
                            Json::obj(vec![
                                ("name", Json::str(name.as_str())),
                                ("mean_s", Json::Num(*mean_s)),
                                ("images_per_s", Json::Num(*ips)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let path = bench_out.join("BENCH_backend_e2e.json");
        std::fs::write(&path, json.to_string_pretty())?;
        manifest.add_payload("backend_e2e", &path)?;
        println!("wrote {}", path.display());
    }

    b.section("cold start: variant registration (requantize vs cached artifact)");
    {
        // The compile/serve split's payoff: registering a variant from a
        // cached .strumc artifact (read + bind prepacked banks) vs
        // re-running float-load → transform → encode at every process
        // start — plus the mmap zero-copy bind, which skips even the
        // read-into-Vec and borrows bank bytes from the mapping.
        let img = 32usize;
        let classes = 10usize;
        let net = "mini_cnn_s";
        let mut weights = synth_net_weights(net, img, classes, 61)?;
        let px = img * img * 3;
        let mut rng = Rng::new(62);
        let calib: Vec<f32> = (0..4 * px).map(|_| rng.f32()).collect();
        weights.manifest.act_scales = calibrate_act_scales(&weights, &calib, 4)?;
        let cache_dir =
            std::env::temp_dir().join(format!("strum-coldstart-bench-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&cache_dir);
        let cache = ArtifactCache::new(&cache_dir);
        let mut rows: Vec<Json> = Vec::new();
        for (label, method, p) in [
            ("dliq-q4", Method::Dliq { q: 4 }, 0.5),
            ("mip2q-L7", Method::Mip2q { l_max: 7 }, 0.5),
        ] {
            let cfg = strum_dpu::model::eval::EvalConfig::paper(method, p);
            b.run(&format!("register/{}/requantize-path", label), 1.0, || {
                NetworkPlan::build(&weights, &cfg).unwrap().classes
            });
            let requantize_s = b.results.last().map(|r| r.seconds.mean()).unwrap_or(0.0);
            // Populate the cache once, then time the pure cached path:
            // file read → from_bytes → from_artifact.
            let (compiled, _) = cache.load_or_compile(&weights, &cfg)?;
            let path = cache.path_for(&compiled.identity);
            b.run(&format!("register/{}/cached-artifact", label), 1.0, || {
                let bytes = std::fs::read(&path).unwrap();
                let c = CompiledNet::from_bytes(&bytes).unwrap();
                NetworkPlan::from_artifact(&c).unwrap().classes
            });
            let cached_s = b.results.last().map(|r| r.seconds.mean()).unwrap_or(0.0);
            // Zero-copy variant of the same path: mmap the artifact and
            // bind the prepacked banks straight from the mapping — no
            // read-into-Vec, no decode, no repack.
            b.run(&format!("register/{}/mmap-bind", label), 1.0, || {
                let c = CompiledNet::load_mapped(&path).unwrap();
                NetworkPlan::from_artifact(&c).unwrap().classes
            });
            let mmap_s = b.results.last().map(|r| r.seconds.mean()).unwrap_or(0.0);
            rows.push(Json::obj(vec![
                ("variant", Json::str(label)),
                ("requantize_mean_s", Json::Num(requantize_s)),
                ("cached_mean_s", Json::Num(cached_s)),
                ("mmap_bind_mean_s", Json::Num(mmap_s)),
                (
                    "speedup",
                    Json::Num(if cached_s > 0.0 { requantize_s / cached_s } else { 0.0 }),
                ),
                (
                    "mmap_speedup",
                    Json::Num(if mmap_s > 0.0 { requantize_s / mmap_s } else { 0.0 }),
                ),
                (
                    "artifact_bytes",
                    Json::Num(std::fs::metadata(&path).map(|m| m.len() as f64).unwrap_or(0.0)),
                ),
            ]));
        }
        let json = Json::obj(vec![
            ("net", Json::str(net)),
            ("img", Json::Num(img as f64)),
            ("variants", Json::Arr(rows)),
        ]);
        let path = bench_out.join("BENCH_coldstart.json");
        std::fs::write(&path, json.to_string_pretty())?;
        manifest.add_payload("coldstart", &path)?;
        println!("wrote {}", path.display());
        let _ = std::fs::remove_dir_all(&cache_dir);
    }

    b.section("multi-variant serving engine (req/s, artifact-free)");
    {
        // Three precision points of one net on ONE shared worker pool —
        // the fleet the paper's DPU serves side by side. Closed-loop
        // waves keep the bounded queues below their QueueFull depth.
        let img = 16usize;
        let classes = 8usize;
        let net = "mini_cnn_s";
        let mut weights = synth_net_weights(net, img, classes, 51)?;
        let px = img * img * 3;
        let mut rng = Rng::new(52);
        let calib: Vec<f32> = (0..4 * px).map(|_| rng.f32()).collect();
        weights.manifest.act_scales = calibrate_act_scales(&weights, &calib, 4)?;
        let mut router = Router::native();
        let engine = Engine::start(EngineOptions {
            workers: 2,
            max_wait: std::time::Duration::from_millis(2),
            max_batch: Some(16),
            ..EngineOptions::default()
        });
        let specs = [
            ("base", Method::Baseline, 0.0),
            ("dliq-q4", Method::Dliq { q: 4 }, 0.5),
            ("mip2q-L7", Method::Mip2q { l_max: 7 }, 0.5),
        ];
        let mut handles = Vec::new();
        for (label, method, p) in specs {
            let cfg = strum_dpu::model::eval::EvalConfig::paper(method, p);
            let v = router.register_native_weights(label, &weights, &cfg)?;
            handles.push(engine.register(v)?);
        }
        let n_req = if b.is_quick() { 90usize } else { 600usize };
        let wave = 30usize;
        let image: Vec<f32> = (0..px).map(|_| rng.f32()).collect();
        let t0 = std::time::Instant::now();
        let mut done = 0usize;
        while done < n_req {
            let take = wave.min(n_req - done);
            let mut tickets: Vec<Ticket> = Vec::with_capacity(take);
            for i in 0..take {
                let h = &handles[(done + i) % handles.len()];
                loop {
                    match h.submit(image.clone()) {
                        Ok(t) => break tickets.push(t),
                        Err(SubmitError::QueueFull { .. }) => {
                            std::thread::sleep(std::time::Duration::from_micros(200))
                        }
                        Err(e) => return Err(e.into()),
                    }
                }
            }
            for t in tickets {
                t.wait()?;
            }
            done += take;
        }
        let wall = t0.elapsed().as_secs_f64();
        let snapshot = engine.metrics();
        println!("{}", snapshot.render());
        println!(
            "served {} requests across {} variants in {:.2}s ({:.1} req/s fleet)",
            n_req,
            handles.len(),
            wall,
            n_req as f64 / wall
        );
        let json = Json::obj(vec![
            ("net", Json::str(net)),
            ("img", Json::Num(img as f64)),
            ("workers", Json::Num(snapshot.workers as f64)),
            ("requests", Json::Num(n_req as f64)),
            ("wall_s", Json::Num(wall)),
            (
                "variants",
                Json::Arr(
                    snapshot
                        .variants
                        .iter()
                        .map(|v| {
                            Json::obj(vec![
                                ("key", Json::str(v.key.as_str())),
                                ("completed", Json::Num(v.completed as f64)),
                                ("throughput_rps", Json::Num(v.throughput_rps)),
                                ("p50_us", Json::Num(v.latency.p50_us)),
                                ("p95_us", Json::Num(v.latency.p95_us)),
                                ("mean_batch", Json::Num(v.mean_batch)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("fleet", snapshot.fleet.to_json()),
        ]);
        let path = bench_out.join("BENCH_serve_multivariant.json");
        std::fs::write(&path, json.to_string_pretty())?;
        manifest.add_payload("serve_multivariant", &path)?;
        println!("wrote {}", path.display());
        engine.shutdown();
    }

    b.section("wire serving: loopback TCP round-trips (3-variant fleet)");
    {
        use strum_dpu::util::stats::Summary;
        let img = 16usize;
        let classes = 10usize;
        let net = "mini_cnn_s";
        let mut weights = synth_net_weights(net, img, classes, 71)?;
        let px = img * img * 3;
        let mut rng = Rng::new(72);
        let calib: Vec<f32> = (0..4 * px).map(|_| rng.f32()).collect();
        weights.manifest.act_scales = calibrate_act_scales(&weights, &calib, 4)?;
        let mut router = Router::native();
        let engine = std::sync::Arc::new(Engine::start(EngineOptions {
            workers: 2,
            max_wait: std::time::Duration::from_millis(1),
            ..EngineOptions::default()
        }));
        let specs = [
            ("base", Method::Baseline, 0.0),
            ("dliq-q4", Method::Dliq { q: 4 }, 0.5),
            ("mip2q-L7", Method::Mip2q { l_max: 7 }, 0.5),
        ];
        for &(label, method, p) in specs.iter() {
            let cfg = strum_dpu::model::eval::EvalConfig::paper(method, p);
            let v = router.register_native_weights(label, &weights, &cfg)?;
            engine.register(v)?;
        }
        let server =
            WireServer::bind("127.0.0.1:0", engine.clone(), WireServerOptions::default())?;
        let addr = server.local_addr().to_string();
        let mut client = WireClient::connect(&addr)?;
        let image: Vec<f32> = (0..px).map(|_| rng.f32()).collect();
        for &(label, _, _) in specs.iter() {
            b.run(&format!("wire_infer/{}", label), 1.0, || {
                client.infer(label, &image).unwrap()
            });
        }
        // Measured burst round-robined across the fleet for the JSON
        // report's percentiles.
        let keys: Vec<&str> = specs.iter().map(|&(l, _, _)| l).collect();
        let n_req = if b.is_quick() { 60usize } else { 300usize };
        let mut lat = Summary::new();
        let (mut completed, mut errors) = (0usize, 0usize);
        let t0 = std::time::Instant::now();
        for i in 0..n_req {
            let sent = std::time::Instant::now();
            match client.infer(keys[i % keys.len()], &image)? {
                WireResponse::Infer(_) => {
                    completed += 1;
                    lat.push(sent.elapsed().as_secs_f64() * 1e6);
                }
                WireResponse::Error { .. } => errors += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        // Tiny-deadline pass: 1 ms budgets against 1 ms batching waits —
        // requests that miss come back as typed sheds, never hangs.
        // Counted separately from the main burst so the JSON's top-level
        // counters describe exactly one measurement.
        let n_tiny = n_req / 3;
        let (mut tiny_shed, mut tiny_done, mut tiny_errors) = (0usize, 0usize, 0usize);
        for i in 0..n_tiny {
            match client.infer_budget_ms(keys[i % keys.len()], &image, 1)? {
                WireResponse::Infer(_) => tiny_done += 1,
                WireResponse::Error { code, .. } if code.is_shed() => tiny_shed += 1,
                WireResponse::Error { .. } => tiny_errors += 1,
            }
        }
        println!(
            "wire burst: {} ok, {} errors, {:.1} req/s; tiny-deadline: {} shed / {} completed / {} errors",
            completed,
            errors,
            completed as f64 / wall.max(1e-9),
            tiny_shed,
            tiny_done,
            tiny_errors
        );
        let pct = |s: &Summary, q: f64| if s.is_empty() { 0.0 } else { s.percentile(q) };
        let json = Json::obj(vec![
            ("net", Json::str(net)),
            ("img", Json::Num(img as f64)),
            ("addr", Json::str(addr.as_str())),
            ("requests", Json::Num(n_req as f64)),
            ("completed", Json::Num(completed as f64)),
            ("errors", Json::Num(errors as f64)),
            ("wall_s", Json::Num(wall)),
            ("throughput_rps", Json::Num(completed as f64 / wall.max(1e-9))),
            (
                "latency_us",
                Json::obj(vec![
                    ("p50", Json::Num(pct(&lat, 50.0))),
                    ("p95", Json::Num(pct(&lat, 95.0))),
                    ("p99", Json::Num(pct(&lat, 99.0))),
                    (
                        "max",
                        Json::Num(if lat.is_empty() { 0.0 } else { lat.max() }),
                    ),
                    ("samples", Json::Num(lat.len() as f64)),
                ]),
            ),
            (
                "tiny_deadline",
                Json::obj(vec![
                    ("requests", Json::Num(n_tiny as f64)),
                    ("shed", Json::Num(tiny_shed as f64)),
                    ("completed", Json::Num(tiny_done as f64)),
                    ("errors", Json::Num(tiny_errors as f64)),
                ]),
            ),
            (
                "variants",
                Json::Arr(keys.iter().map(|k| Json::str(*k)).collect()),
            ),
        ]);
        let path = bench_out.join("BENCH_wire_bench.json");
        std::fs::write(&path, json.to_string_pretty())?;
        manifest.add_payload("wire_bench", &path)?;
        println!("wrote {}", path.display());
        drop(client);
        server.shutdown();
        drop(engine);
    }

    let dir = Path::new("artifacts");
    let rt = if dir.join("hlo").exists() { Runtime::cpu().ok() } else { None };
    if let Some(rt) = rt {
        b.section("PJRT end-to-end (images/s)");
        let net = "mini_resnet_a";
        let weights = NetWeights::load(dir, net)?;
        let cfg = strum_dpu::model::eval::EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
        let transformed = strum_dpu::model::eval::transform_network(&weights, &cfg)?;
        let args0 = strum_dpu::model::eval::prepare_args(&weights, &transformed, true)?;
        let data = DataSet::load(dir, "eval")?;
        for batch in [1usize, 16, 256] {
            let path = dir.join(format!("hlo/{}_b{}.hlo.txt", net, batch));
            if !path.exists() {
                continue;
            }
            let exe = rt.load_hlo(&path)?;
            let (imgs, _) = data.batch(0, batch);
            let mut args = vec![Tensor::f32(imgs, &[batch, 32, 32, 3])];
            args.extend(args0.iter().cloned());
            b.run(&format!("{}_b{}/execute", net, batch), batch as f64, || {
                exe.run_f32(&args).unwrap()
            });
        }
    } else {
        println!("(artifacts or PJRT runtime missing; skipping PJRT benches)");
    }

    // The manifest's whole-file FNV-1a checksum covers environment +
    // per-payload checksums, so `strum bench-diff` can both pair runs
    // and detect tampering/corruption.
    let manifest_path = bench_out.join("MANIFEST_hot_paths.json");
    manifest.save(&manifest_path)?;
    println!("wrote {}", manifest_path.display());
    Ok(())
}
