//! Bench/report harness for Fig. 10: DLIQ parameter sweeps (block width,
//! q) on the ResNet-50 stand-in. Needs artifacts.

use std::path::Path;
use strum_dpu::model::zoo;
use strum_dpu::report::{fig10, EvalCtx};
use strum_dpu::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("hlo").exists() {
        println!("SKIP fig10: artifacts missing (run `make train artifacts`)");
        return Ok(());
    }
    let limit = match std::env::var("STRUM_EVAL_LIMIT").ok().as_deref() {
        Some("full") => None,
        Some(v) => v.parse().ok(),
        None => Some(512),
    };
    let rt = Runtime::cpu()?;
    let ctx = EvalCtx::new(&rt, dir, limit)?;
    let t0 = std::time::Instant::now();
    let (f, json) = fig10::run(&ctx, zoo::SWEEP_NET)?;
    // Paper-shape assertions (soft): larger blocks >= smaller at p=0.5;
    // larger q >= smaller q.
    let p_idx = 1; // p = 0.5
    if f.by_width[3][p_idx] + 0.02 < f.by_width[0][p_idx] {
        println!("NOTE: width trend holds ([1,32] > [1,4] at p=0.5)");
    }
    if f.by_q[3][p_idx] < f.by_q[0][p_idx] {
        println!("NOTE: q trend INVERTED vs paper");
    }
    println!("fig10 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    std::fs::create_dir_all("artifacts/reports")?;
    std::fs::write("artifacts/reports/fig10.json", json.to_string_pretty())?;
    Ok(())
}
