//! Bench/report harness for Fig. 11: MIP2Q parameter sweeps (block width,
//! shift range L) on the ResNet-50 stand-in. Needs artifacts.

use std::path::Path;
use strum_dpu::model::zoo;
use strum_dpu::report::{fig11, EvalCtx};
use strum_dpu::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("hlo").exists() {
        println!("SKIP fig11: artifacts missing (run `make train artifacts`)");
        return Ok(());
    }
    let limit = match std::env::var("STRUM_EVAL_LIMIT").ok().as_deref() {
        Some("full") => None,
        Some(v) => v.parse().ok(),
        None => Some(512),
    };
    let rt = Runtime::cpu()?;
    let ctx = EvalCtx::new(&rt, dir, limit)?;
    let t0 = std::time::Instant::now();
    let (f, json) = fig11::run(&ctx, zoo::SWEEP_NET)?;
    // The paper's key finding: L=5 ~ L=7.
    let l5 = &f.by_l[2];
    let l7 = &f.by_l[3];
    let max_gap = l5
        .iter()
        .zip(l7.iter())
        .map(|(a, b)| (b - a).abs())
        .fold(0.0f64, f64::max);
    println!(
        "L=5 vs L=7 max accuracy gap: {:.2}% (paper: comparable)",
        max_gap * 100.0
    );
    println!("fig11 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    std::fs::create_dir_all("artifacts/reports")?;
    std::fs::write("artifacts/reports/fig11.json", json.to_string_pretty())?;
    Ok(())
}
