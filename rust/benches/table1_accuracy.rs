//! Bench/report harness for Table I: top-1 across the zoo ×
//! {baseline, sparsity, DLIQ, MIP2Q} × p ∈ {0.25, 0.5, 0.75}.
//!
//! Needs artifacts (`make train artifacts`). Sample count per point via
//! STRUM_EVAL_LIMIT (default 512; unset=512, "full" = whole eval split).

use std::path::Path;
use strum_dpu::model::zoo;
use strum_dpu::report::{table1, EvalCtx};
use strum_dpu::runtime::Runtime;

fn limit() -> Option<usize> {
    match std::env::var("STRUM_EVAL_LIMIT").ok().as_deref() {
        Some("full") => None,
        Some(v) => v.parse().ok(),
        None => Some(512),
    }
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("hlo").exists() {
        println!("SKIP table1: artifacts missing (run `make train artifacts`)");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let ctx = EvalCtx::new(&rt, dir, limit())?;
    println!("{}", table1::header());
    let t0 = std::time::Instant::now();
    let nets = zoo::net_names();
    let (rows, json) = table1::run(&ctx, &nets)?;
    println!("-- shape checks vs paper --");
    let notes = table1::shape_check(&rows);
    if notes.is_empty() {
        println!("   all paper-shape properties hold");
    }
    for n in notes {
        println!("   NOTE: {}", n);
    }
    println!("table1 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    std::fs::create_dir_all("artifacts/reports")?;
    std::fs::write("artifacts/reports/table1.json", json.to_string_pretty())?;
    Ok(())
}
