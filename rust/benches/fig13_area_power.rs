//! Bench/report harness for Fig. 13: PE / PE-array / DPU area & power for
//! the StruM PE variants (static a, dynamic b; L=7, L=5), analytic dense
//! activity plus — when artifacts exist — the cycle-sim-driven (SAIF-
//! equivalent) activity of a real zoo network.

use std::path::Path;
use strum_dpu::model::eval::{transform_network, EvalConfig};
use strum_dpu::model::import::NetWeights;
use strum_dpu::model::zoo;
use strum_dpu::quant::Method;
use strum_dpu::report::fig13;
use strum_dpu::sim::config::SimConfig;
use strum_dpu::sim::driver::simulate_network;
use strum_dpu::sim::SimMode;
use strum_dpu::util::json::Json;

fn main() -> anyhow::Result<()> {
    println!("Fig 13 — analytic dense workload (p = 0.5):");
    let (rows, json) = fig13::run(None);
    for n in fig13::paper_bands(&rows) {
        println!("  {}", n);
    }
    let mut out = vec![("fig13_dense".to_string(), json)];

    let dir = Path::new("artifacts");
    if dir.join("weights").exists() {
        let net = zoo::SWEEP_NET;
        let weights = NetWeights::load(dir, net)?;
        let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
        let layers: Vec<_> = weights
            .manifest
            .layers
            .iter()
            .zip(transform_network(&weights, &cfg)?)
            .map(|(lm, s)| (lm.shape_for_sim(), s))
            .collect();
        let (_, act) = simulate_network(
            &layers,
            &SimConfig::flexnn(SimMode::StrumStatic, Some(Method::Mip2q { l_max: 7 })),
            0.7,
            42,
        );
        println!("\nFig 13 — sim-driven activity ({} conv layers of {}):", layers.len(), net);
        let (rows2, json2) = fig13::run(Some(&act));
        for n in fig13::paper_bands(&rows2) {
            println!("  {}", n);
        }
        out.push(("fig13_sim".to_string(), json2));
    } else {
        println!("\n(no artifacts; skipping sim-driven activity table)");
    }
    std::fs::create_dir_all("artifacts/reports")?;
    let json = Json::Obj(out.into_iter().collect());
    std::fs::write("artifacts/reports/fig13.json", json.to_string_pretty())?;
    Ok(())
}
