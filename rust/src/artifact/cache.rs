//! Content-addressed on-disk cache of compiled `.strumc` artifacts.
//!
//! The serving layer registers variants through
//! [`ArtifactCache::load_or_compile`]: the identity header
//! ([`ArtifactIdentity`]) hashes
//! to a cache path; a valid artifact there is loaded (read + decode, no
//! quantizer), anything else — missing file, format/encoder version
//! skew, checksum damage, identity collision — triggers a transparent
//! recompile that overwrites the slot. Persisting the rebuilt artifact
//! is best-effort: a read-only cache directory degrades to the old
//! always-recompile behaviour instead of failing registration.

use super::{compile_net, ArtifactError, ArtifactIdentity, CompiledNet};
use crate::model::eval::EvalConfig;
use crate::model::import::NetWeights;
use crate::Result;
use std::fmt;
use std::path::{Path, PathBuf};

/// Why a cache lookup did not hit.
#[derive(Debug)]
pub enum MissReason {
    /// No artifact at the identity's path yet.
    NotFound,
    /// An artifact was there but failed to load (typed cause inside —
    /// version mismatch, checksum, truncation, ...).
    Load(ArtifactError),
    /// The artifact loaded but its identity header is not ours (content
    /// hash collision or a hand-swapped file).
    IdentityMismatch,
}

/// Outcome of [`ArtifactCache::load_or_compile`] (logged by the CLI and
/// asserted by the CI smoke + tests).
#[derive(Debug)]
pub enum CacheOutcome {
    /// Served from disk: zero quantize/encode work.
    Hit,
    /// Recompiled (and re-persisted) for the given reason.
    Miss(MissReason),
}

impl CacheOutcome {
    pub fn is_hit(&self) -> bool {
        matches!(self, CacheOutcome::Hit)
    }
}

impl fmt::Display for CacheOutcome {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CacheOutcome::Hit => write!(f, "hit"),
            CacheOutcome::Miss(MissReason::NotFound) => write!(f, "miss (not compiled yet)"),
            CacheOutcome::Miss(MissReason::Load(e)) => write!(f, "miss ({})", e),
            CacheOutcome::Miss(MissReason::IdentityMismatch) => {
                write!(f, "miss (identity mismatch)")
            }
        }
    }
}

/// A directory of compiled artifacts keyed by identity hash.
#[derive(Debug, Clone)]
pub struct ArtifactCache {
    dir: PathBuf,
    /// Encoder version artifacts must carry to hit (normally
    /// [`super::encoder_version`]; tests pin it to exercise rebuilds).
    encoder_version: u32,
}

impl ArtifactCache {
    pub fn new(dir: impl Into<PathBuf>) -> ArtifactCache {
        ArtifactCache {
            dir: dir.into(),
            encoder_version: super::encoder_version(),
        }
    }

    /// The conventional cache location under an artifacts tree.
    pub fn under(artifacts: &Path) -> ArtifactCache {
        Self::new(artifacts.join("cache"))
    }

    /// A cache pinned to an explicit encoder version (tests).
    pub fn with_version(dir: impl Into<PathBuf>, encoder_version: u32) -> ArtifactCache {
        ArtifactCache {
            dir: dir.into(),
            encoder_version,
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Cache path of an identity: human-greppable prefix + content hash.
    /// Versions are deliberately NOT part of the name — a version bump
    /// lands on the same slot, fails the load with a typed mismatch, and
    /// the rebuild overwrites the stale file instead of leaking it.
    pub fn path_for(&self, id: &ArtifactIdentity) -> PathBuf {
        self.dir
            .join(format!("{}-{}-{:016x}.strumc", id.net, id.method.name(), id.cache_key()))
    }

    /// Tries a pure load of the artifact for `id`.
    fn try_load(&self, id: &ArtifactIdentity) -> std::result::Result<CompiledNet, MissReason> {
        let path = self.path_for(id);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(MissReason::NotFound)
            }
            Err(e) => return Err(MissReason::Load(e.into())),
        };
        let compiled = CompiledNet::from_bytes(&bytes).map_err(MissReason::Load)?;
        if compiled.encoder_version != self.encoder_version {
            return Err(MissReason::Load(ArtifactError::VersionMismatch {
                kind: "encoder",
                found: compiled.encoder_version,
                want: self.encoder_version,
            }));
        }
        if compiled.identity != *id {
            return Err(MissReason::IdentityMismatch);
        }
        Ok(compiled)
    }

    /// Serve-time entry point: load the compiled artifact for
    /// (`weights`, `cfg`), or compile + persist it transparently. On a
    /// hit, no `transform_network`/`encode_layer` call happens — the
    /// debug counters in those modules assert it in tests.
    pub fn load_or_compile(
        &self,
        weights: &NetWeights,
        cfg: &EvalConfig,
    ) -> Result<(CompiledNet, CacheOutcome)> {
        let id = ArtifactIdentity::of(weights, cfg);
        let reason = match self.try_load(&id) {
            Ok(compiled) => return Ok((compiled, CacheOutcome::Hit)),
            Err(r) => r,
        };
        let mut compiled = compile_net(weights, cfg)?;
        compiled.encoder_version = self.encoder_version;
        if let Err(e) = compiled.save(&self.path_for(&id)) {
            // Degrade to always-recompile rather than failing serve on a
            // read-only cache directory.
            eprintln!(
                "warning: could not persist artifact for {} ({}); serving uncached",
                id.net, e
            );
        }
        Ok((compiled, CacheOutcome::Miss(reason)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::graph::{calibrate_act_scales, synth_net_weights};
    use crate::quant::Method;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "strum-cache-unit-{}-{}",
            std::process::id(),
            tag
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn weights() -> NetWeights {
        let mut w = synth_net_weights("mini_cnn_s", 8, 4, 7).unwrap();
        let calib: Vec<f32> = {
            let mut rng = crate::util::prng::Rng::new(9);
            (0..2 * 8 * 8 * 3).map(|_| rng.f32()).collect()
        };
        w.manifest.act_scales = calibrate_act_scales(&w, &calib, 2).unwrap();
        w
    }

    #[test]
    fn miss_then_hit() {
        let dir = temp_dir("miss-hit");
        let cache = ArtifactCache::with_version(&dir, 1);
        let w = weights();
        let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
        let (first, o1) = cache.load_or_compile(&w, &cfg).unwrap();
        assert!(matches!(o1, CacheOutcome::Miss(MissReason::NotFound)), "{}", o1);
        assert!(cache.path_for(&first.identity).exists());
        let (second, o2) = cache.load_or_compile(&w, &cfg).unwrap();
        assert!(o2.is_hit(), "{}", o2);
        assert_eq!(second.to_bytes(), first.to_bytes());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn encoder_bump_rebuilds_in_place() {
        let dir = temp_dir("bump");
        let w = weights();
        let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
        let v1 = ArtifactCache::with_version(&dir, 1);
        let (c1, _) = v1.load_or_compile(&w, &cfg).unwrap();
        // Same slot, newer runtime: typed version mismatch → rebuild.
        let v2 = ArtifactCache::with_version(&dir, 2);
        assert_eq!(v1.path_for(&c1.identity), v2.path_for(&c1.identity));
        let (c2, o) = v2.load_or_compile(&w, &cfg).unwrap();
        match &o {
            CacheOutcome::Miss(MissReason::Load(ArtifactError::VersionMismatch {
                kind,
                found,
                want,
            })) => {
                assert_eq!(*kind, "encoder");
                assert_eq!((*found, *want), (1, 2));
            }
            other => panic!("expected encoder version miss, got {}", other),
        }
        assert_eq!(c2.encoder_version, 2);
        // The slot was overwritten: v2 now hits, v1 now misses.
        assert!(v2.load_or_compile(&w, &cfg).unwrap().1.is_hit());
        assert!(!v1.load_or_compile(&w, &cfg).unwrap().1.is_hit());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn weight_change_moves_the_slot() {
        let dir = temp_dir("weights");
        let cache = ArtifactCache::with_version(&dir, 1);
        let w = weights();
        let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
        let (c1, _) = cache.load_or_compile(&w, &cfg).unwrap();
        let mut w2 = w.clone();
        w2.blob[3] += 0.125;
        let (c2, o) = cache.load_or_compile(&w2, &cfg).unwrap();
        assert!(matches!(o, CacheOutcome::Miss(MissReason::NotFound)));
        assert_ne!(cache.path_for(&c1.identity), cache.path_for(&c2.identity));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
