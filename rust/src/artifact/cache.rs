//! Content-addressed on-disk cache of compiled `.strumc` artifacts.
//!
//! The serving layer registers variants through
//! [`ArtifactCache::load_or_compile`]: the identity header
//! ([`ArtifactIdentity`]) hashes
//! to a cache path; a valid artifact there is loaded (mmap + zero-copy
//! bank bind, no quantizer, no decode, no repack), anything else —
//! missing file, format/encoder version
//! skew, checksum damage, identity collision — triggers a transparent
//! recompile that overwrites the slot. Persisting the rebuilt artifact
//! is best-effort: a read-only cache directory degrades to the old
//! always-recompile behaviour instead of failing registration.

use super::{compile_net, ArtifactError, ArtifactIdentity, CompiledNet};
use crate::model::eval::EvalConfig;
use crate::model::import::NetWeights;
use crate::Result;
use std::collections::HashSet;
use std::fmt;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Why a cache lookup did not hit.
#[derive(Debug)]
pub enum MissReason {
    /// No artifact at the identity's path yet.
    NotFound,
    /// An artifact was there but failed to load (typed cause inside —
    /// version mismatch, checksum, truncation, ...).
    Load(ArtifactError),
    /// The artifact loaded but its identity header is not ours (content
    /// hash collision or a hand-swapped file).
    IdentityMismatch,
}

/// Outcome of [`ArtifactCache::load_or_compile`] (logged by the CLI and
/// asserted by the CI smoke + tests).
#[derive(Debug)]
pub enum CacheOutcome {
    /// Served from disk: zero quantize/encode work.
    Hit,
    /// Recompiled (and re-persisted) for the given reason.
    Miss(MissReason),
}

impl CacheOutcome {
    pub fn is_hit(&self) -> bool {
        matches!(self, CacheOutcome::Hit)
    }
}

impl fmt::Display for CacheOutcome {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CacheOutcome::Hit => write!(f, "hit"),
            CacheOutcome::Miss(MissReason::NotFound) => write!(f, "miss (not compiled yet)"),
            CacheOutcome::Miss(MissReason::Load(e)) => write!(f, "miss ({})", e),
            CacheOutcome::Miss(MissReason::IdentityMismatch) => {
                write!(f, "miss (identity mismatch)")
            }
        }
    }
}

/// A directory of compiled artifacts keyed by identity hash.
#[derive(Debug, Clone)]
pub struct ArtifactCache {
    dir: PathBuf,
    /// Encoder version artifacts must carry to hit (normally
    /// [`super::encoder_version`]; tests pin it to exercise rebuilds).
    encoder_version: u32,
}

impl ArtifactCache {
    pub fn new(dir: impl Into<PathBuf>) -> ArtifactCache {
        ArtifactCache {
            dir: dir.into(),
            encoder_version: super::encoder_version(),
        }
    }

    /// The conventional cache location under an artifacts tree.
    pub fn under(artifacts: &Path) -> ArtifactCache {
        Self::new(artifacts.join("cache"))
    }

    /// A cache pinned to an explicit encoder version (tests).
    pub fn with_version(dir: impl Into<PathBuf>, encoder_version: u32) -> ArtifactCache {
        ArtifactCache {
            dir: dir.into(),
            encoder_version,
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Cache path of an identity: human-greppable prefix + content hash.
    /// Versions are deliberately NOT part of the name — a version bump
    /// lands on the same slot, fails the load with a typed mismatch, and
    /// the rebuild overwrites the stale file instead of leaking it.
    pub fn path_for(&self, id: &ArtifactIdentity) -> PathBuf {
        self.dir
            .join(format!("{}-{}-{:016x}.strumc", id.net, id.method.name(), id.cache_key()))
    }

    /// Tries a pure load of the artifact for `id`. Goes through the
    /// mmap-backed loader so a hit binds its weight banks zero-copy.
    fn try_load(&self, id: &ArtifactIdentity) -> std::result::Result<CompiledNet, MissReason> {
        let path = self.path_for(id);
        if !path.exists() {
            return Err(MissReason::NotFound);
        }
        let compiled = match CompiledNet::load_mapped(&path) {
            Ok(c) => c,
            Err(ArtifactError::Io(e)) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(MissReason::NotFound)
            }
            Err(e) => return Err(MissReason::Load(e)),
        };
        if compiled.encoder_version != self.encoder_version {
            return Err(MissReason::Load(ArtifactError::VersionMismatch {
                kind: "encoder",
                found: compiled.encoder_version,
                want: self.encoder_version,
            }));
        }
        if compiled.identity != *id {
            return Err(MissReason::IdentityMismatch);
        }
        Ok(compiled)
    }

    /// Serve-time entry point: load the compiled artifact for
    /// (`weights`, `cfg`), or compile + persist it transparently. On a
    /// hit, no `transform_network`/`encode_layer` call happens — the
    /// debug counters in those modules assert it in tests.
    pub fn load_or_compile(
        &self,
        weights: &NetWeights,
        cfg: &EvalConfig,
    ) -> Result<(CompiledNet, CacheOutcome)> {
        let id = ArtifactIdentity::of(weights, cfg);
        let reason = match self.try_load(&id) {
            Ok(compiled) => return Ok((compiled, CacheOutcome::Hit)),
            Err(r) => r,
        };
        let mut compiled = compile_net(weights, cfg)?;
        compiled.encoder_version = self.encoder_version;
        if let Err(e) = compiled.save(&self.path_for(&id)) {
            // Degrade to always-recompile rather than failing serve on a
            // read-only cache directory.
            eprintln!(
                "warning: could not persist artifact for {} ({}); serving uncached",
                id.net, e
            );
        }
        Ok((compiled, CacheOutcome::Miss(reason)))
    }

    /// Garbage-collects the cache directory: removes every `.strumc`
    /// slot whose identity header names a net in `live` under a weights
    /// fingerprint that is NOT that net's current one — orphans left
    /// behind by weight changes land on *new* slots, so the stale ones
    /// never get overwritten in place. Liveness is judged on the
    /// fingerprint alone, NOT the full (method, p) identity: an artifact
    /// compiled at any quantization point of a current net is valid and
    /// kept, so a sweep can never delete a `mip2q-L5@0.25` slot just
    /// because nobody enumerated that point. Slots of nets `live` does
    /// not mention at all are PROTECTED, not orphaned — the sweeper
    /// cannot judge weights it was not given (a custom net outside the
    /// zoo, or weights that failed to load, must never cost the cache).
    /// Unparseable (corrupt) slots are removed — they can never serve.
    /// Stale `*.tmp.*` files from interrupted writes are swept once
    /// older than `min_tmp_age` (the age guard keeps a concurrent
    /// writer's tmp+rename from being raced), and a concurrently-deleted
    /// file (two sweepers racing) is tolerated, not an abort.
    /// Unrecognized files are left alone.
    ///
    /// `scope` limits the sweep to slots of one net (filename prefix
    /// `"{net}-"`): files of other nets are skipped entirely.
    pub fn gc(&self, live: &[(String, u64)], scope: Option<&str>) -> Result<GcReport> {
        self.gc_with_tmp_age(live, scope, Duration::from_secs(600))
    }

    /// [`ArtifactCache::gc`] with an explicit tmp-file age threshold
    /// (tests pass zero to sweep a just-written temp file).
    pub fn gc_with_tmp_age(
        &self,
        live: &[(String, u64)],
        scope: Option<&str>,
        min_tmp_age: Duration,
    ) -> Result<GcReport> {
        let mut report = GcReport::default();
        let keep: HashSet<(&str, u64)> =
            live.iter().map(|(net, fp)| (net.as_str(), *fp)).collect();
        let known_nets: HashSet<&str> = live.iter().map(|(net, _)| net.as_str()).collect();
        let prefix = scope.map(|net| format!("{}-", net));
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            // No cache directory yet: nothing to sweep.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(report),
            Err(e) => return Err(e.into()),
        };
        for entry in entries {
            let entry = entry?;
            let path = entry.path();
            if !path.is_file() {
                continue;
            }
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(prefix) = &prefix {
                if !name.starts_with(prefix.as_str()) {
                    continue;
                }
            }
            if !name.ends_with(".strumc") {
                // `CompiledNet::save` writes through `<slot>.tmp.<pid>.<seq>`;
                // an OLD one on disk means a crashed writer. A young one
                // may belong to a live writer mid-rename — leave it.
                if name.contains(".tmp.") {
                    let old_enough = entry
                        .metadata()
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .map(|age| age >= min_tmp_age)
                        .unwrap_or(false);
                    if old_enough {
                        let bytes = entry.metadata().map(|m| m.len()).unwrap_or(0);
                        if remove_tolerant(&path)? {
                            report.removed_bytes += bytes;
                            report.removed_tmp += 1;
                        }
                    }
                }
                continue;
            }
            report.scanned += 1;
            // Liveness comes from the identity header inside the file,
            // not the filename: parse it (checksum-verified) and match
            // on (net, weights fingerprint). A slot of a net the live
            // set does not mention is protected (kept) — only corrupt
            // slots and stale fingerprints of KNOWN nets are orphans.
            let alive = match std::fs::read(&path)
                .ok()
                .and_then(|bytes| CompiledNet::from_bytes(&bytes).ok())
            {
                Some(c) => {
                    !known_nets.contains(c.identity.net.as_str())
                        || keep.contains(&(c.identity.net.as_str(), c.identity.weights_fp))
                }
                // Unreadable or corrupt: can never serve anyone.
                None => false,
            };
            if alive {
                report.kept += 1;
            } else {
                let bytes = entry.metadata().map(|m| m.len()).unwrap_or(0);
                if remove_tolerant(&path)? {
                    report.removed_bytes += bytes;
                    report.removed += 1;
                }
            }
        }
        Ok(report)
    }
}

/// Removes a file, tolerating a concurrent sweeper having won the race
/// (`NotFound` → `Ok(false)`); any other failure still surfaces.
fn remove_tolerant(path: &Path) -> Result<bool> {
    match std::fs::remove_file(path) {
        Ok(()) => Ok(true),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
        Err(e) => Err(e.into()),
    }
}

/// What [`ArtifactCache::gc`] swept.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct GcReport {
    /// `.strumc` slots inspected.
    pub scanned: usize,
    /// Slots matching a live identity, left in place.
    pub kept: usize,
    /// Orphaned slots removed.
    pub removed: usize,
    /// Stale temp files from interrupted writes removed.
    pub removed_tmp: usize,
    /// Bytes reclaimed (slots + temp files).
    pub removed_bytes: u64,
}

impl fmt::Display for GcReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "scanned {} artifact(s): kept {}, removed {} orphan(s) + {} stale temp file(s), \
             reclaimed {:.1} KiB",
            self.scanned,
            self.kept,
            self.removed,
            self.removed_tmp,
            self.removed_bytes as f64 / 1024.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::graph::{calibrate_act_scales, synth_net_weights};
    use crate::quant::Method;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "strum-cache-unit-{}-{}",
            std::process::id(),
            tag
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn weights() -> NetWeights {
        let mut w = synth_net_weights("mini_cnn_s", 8, 4, 7).unwrap();
        let calib: Vec<f32> = {
            let mut rng = crate::util::prng::Rng::new(9);
            (0..2 * 8 * 8 * 3).map(|_| rng.f32()).collect()
        };
        w.manifest.act_scales = calibrate_act_scales(&w, &calib, 2).unwrap();
        w
    }

    #[test]
    fn miss_then_hit() {
        let dir = temp_dir("miss-hit");
        let cache = ArtifactCache::with_version(&dir, 1);
        let w = weights();
        let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
        let (first, o1) = cache.load_or_compile(&w, &cfg).unwrap();
        assert!(matches!(o1, CacheOutcome::Miss(MissReason::NotFound)), "{}", o1);
        assert!(cache.path_for(&first.identity).exists());
        let (second, o2) = cache.load_or_compile(&w, &cfg).unwrap();
        assert!(o2.is_hit(), "{}", o2);
        assert_eq!(second.to_bytes(), first.to_bytes());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn encoder_bump_rebuilds_in_place() {
        let dir = temp_dir("bump");
        let w = weights();
        let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
        let v1 = ArtifactCache::with_version(&dir, 1);
        let (c1, _) = v1.load_or_compile(&w, &cfg).unwrap();
        // Same slot, newer runtime: typed version mismatch → rebuild.
        let v2 = ArtifactCache::with_version(&dir, 2);
        assert_eq!(v1.path_for(&c1.identity), v2.path_for(&c1.identity));
        let (c2, o) = v2.load_or_compile(&w, &cfg).unwrap();
        match &o {
            CacheOutcome::Miss(MissReason::Load(ArtifactError::VersionMismatch {
                kind,
                found,
                want,
            })) => {
                assert_eq!(*kind, "encoder");
                assert_eq!((*found, *want), (1, 2));
            }
            other => panic!("expected encoder version miss, got {}", other),
        }
        assert_eq!(c2.encoder_version, 2);
        // The slot was overwritten: v2 now hits, v1 now misses.
        assert!(v2.load_or_compile(&w, &cfg).unwrap().1.is_hit());
        assert!(!v1.load_or_compile(&w, &cfg).unwrap().1.is_hit());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_sweeps_stale_slots_and_keeps_live_ones() {
        let dir = temp_dir("gc");
        let cache = ArtifactCache::with_version(&dir, 1);
        let w = weights();
        let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
        let (live, _) = cache.load_or_compile(&w, &cfg).unwrap();
        // A second quantization point of the SAME weights: its (method,
        // p) is not enumerated anywhere, but its fingerprint is current,
        // so gc must keep it.
        let cfg_other = EvalConfig::paper(Method::Mip2q { l_max: 5 }, 0.25);
        let (other_point, _) = cache.load_or_compile(&w, &cfg_other).unwrap();
        let other_path = cache.path_for(&other_point.identity);
        // A weight edit moves the identity to a new slot; the old one is
        // now an orphan no registration will ever touch again.
        let mut w2 = w.clone();
        w2.blob[1] -= 0.5;
        let (stale, _) = cache.load_or_compile(&w2, &cfg).unwrap();
        let stale_path = cache.path_for(&stale.identity);
        assert!(stale_path.exists());
        // Plus a crashed writer's leftover temp file.
        let tmp = dir.join("mini_cnn_s-dliq-q4-deadbeef.tmp.999.0");
        std::fs::write(&tmp, b"partial").unwrap();

        let fp = live.identity.weights_fp;
        let live_set = vec![("mini_cnn_s".to_string(), fp)];
        // A live set that does not mention this net at all PROTECTS its
        // slots (the sweeper cannot judge weights it was not given) —
        // even the stale one survives.
        let foreign = cache.gc(&[("unrelated_net".to_string(), 7)], None).unwrap();
        assert_eq!((foreign.scanned, foreign.kept, foreign.removed), (3, 3, 0));
        assert!(stale_path.exists());
        // A scoped sweep of a DIFFERENT net must not touch these slots
        // even though its live set does not name them.
        let scoped = cache
            .gc_with_tmp_age(&[], Some("some_other_net"), Duration::ZERO)
            .unwrap();
        assert_eq!(scoped, GcReport::default());
        assert!(stale_path.exists());
        // The default tmp-age guard protects a just-written temp file (a
        // live writer may be mid-rename).
        let guarded = cache.gc(&live_set, None).unwrap();
        assert_eq!(guarded.removed_tmp, 0);
        assert!(tmp.exists());
        assert_eq!((guarded.scanned, guarded.kept, guarded.removed), (3, 2, 1));
        assert!(!stale_path.exists());
        assert!(other_path.exists(), "non-enumerated (method, p) slot must survive");

        // With the age guard waived, the stale temp file goes too.
        let report = cache.gc_with_tmp_age(&live_set, None, Duration::ZERO).unwrap();
        assert_eq!(report.removed_tmp, 1);
        assert!(report.removed_bytes > 0);
        assert!(!tmp.exists());
        // Both live slots still hit after the sweeps.
        assert!(cache.load_or_compile(&w, &cfg).unwrap().1.is_hit());
        assert!(cache.load_or_compile(&w, &cfg_other).unwrap().1.is_hit());
        // Sweeping again finds nothing to remove; the display renders.
        let again = cache.gc(&live_set, None).unwrap();
        assert_eq!((again.scanned, again.kept, again.removed, again.removed_tmp), (2, 2, 0, 0));
        assert!(format!("{}", again).contains("kept 2"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_on_missing_dir_is_empty_report() {
        let dir = temp_dir("gc-missing");
        let cache = ArtifactCache::with_version(&dir, 1);
        let report = cache.gc(&[], None).unwrap();
        assert_eq!(report, GcReport::default());
    }

    #[test]
    fn weight_change_moves_the_slot() {
        let dir = temp_dir("weights");
        let cache = ArtifactCache::with_version(&dir, 1);
        let w = weights();
        let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
        let (c1, _) = cache.load_or_compile(&w, &cfg).unwrap();
        let mut w2 = w.clone();
        w2.blob[3] += 0.125;
        let (c2, o) = cache.load_or_compile(&w2, &cfg).unwrap();
        assert!(matches!(o, CacheOutcome::Miss(MissReason::NotFound)));
        assert_ne!(cache.path_for(&c1.identity), cache.path_for(&c2.identity));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
