//! Compiled model artifacts (`.strumc`): the offline half of the
//! compile/serve split.
//!
//! StruM is post-training quantization — nothing about a (net, method, p)
//! point changes between process starts, so re-deriving it at every
//! registration (float load → [`transform_network`] → [`encode_layer`] →
//! plan build) is pure cold-start waste. [`compile_net`] runs that
//! pipeline ONCE and captures everything the serve path needs in a
//! [`CompiledNet`]: per-layer §IV-D encoded banks (via
//! [`crate::encode::bitstream`]), calibrated activation scales, biases,
//! and layer geometry. Serialized to disk it becomes a versioned
//! `.strumc` artifact; at serve time
//! [`NetworkPlan::from_artifact`](crate::backend::NetworkPlan::from_artifact)
//! is a pure read + decode + bind — no quantizer anywhere on the path
//! (asserted by the [`transform_network_calls`]/[`encode_layer_calls`]
//! debug counters).
//!
//! # On-disk format (all little-endian)
//!
//! ```text
//! magic            8  b"STRUMC\0\x1a"
//! format_version   u32   layout of THIS container
//! encoder_version  u32   semantics of the §IV-D bank encoder
//! total_len        u64   whole file, incl. the trailing checksum
//! identity header: net, method, p, block [l,w], act_quant,
//!                  unstructured, weights fingerprint (FNV-1a 64)
//! classes, img, mean_rmse, n_layers
//! per layer: name, kind, kh kw ic oc oh ow, act_scale, bias[],
//!            bank params, bank dims, scales[], bit length, payload bytes,
//!            prepacked execution banks (format v2, see below)
//! checksum         u64   FNV-1a 64 of every preceding byte
//! ```
//!
//! ## Prepacked bank section (format v2)
//!
//! Format v2 appends the kernel-layout execution banks
//! ([`crate::encode::PackedBanks`]) to every layer, directly after the
//! encoded payload:
//!
//! ```text
//! hi_len   u64        == oc·k
//! hi       hi_len×i8  dense high bank, kernel layout
//! low_tag  u8         0 = empty, 1 = DLIQ, 2 = MIP2Q CSR
//! DLIQ:    shift u32, codes_len u64 (== oc·k), codes codes_len×i8
//! MIP2Q:   n_taps u64, row_ptr (oc+1)×u32, col n_taps×u32,
//!          shift n_taps×u8, neg n_taps×u8 (0/1)
//! ```
//!
//! The banks used to be rebuilt from the decoded payload at every
//! registration; carrying them in the container makes serve-time bind
//! pure layout. [`CompiledNet::load`] mmaps the file and the two dense
//! i8 banks (`hi`, DLIQ `codes` — alignment-1, the bulk of the bytes)
//! are borrowed straight from the mapping (zero-copy); the small
//! alignment-sensitive arrays (CSR, scales, biases) are copied out.
//! The prepack layout is versioned by [`FORMAT_VERSION`], exactly like
//! the bank semantics are versioned by [`ENCODER_VERSION`]: a pre-bump
//! `.strumc` surfaces as `VersionMismatch{kind:"format"}` and the cache
//! transparently rebuilds it in place.
//!
//! Loading is defensive end to end: truncation, a foreign magic, a
//! format/encoder version skew, and any byte corruption each surface as a
//! distinct typed [`ArtifactError`] — never a panic, never a silently
//! wrong artifact (the checksum is verified before the body is parsed,
//! and every length field is bounds-checked against the remaining input).
//!
//! [`cache`] adds the content-addressed on-disk cache the serving layer
//! registers through; `strum compile` is the CLI front-end.
//!
//! [`transform_network`]: crate::model::eval::transform_network
//! [`encode_layer`]: crate::encode::encode_layer
//! [`transform_network_calls`]: crate::model::eval::transform_network_calls
//! [`encode_layer_calls`]: crate::encode::encode_layer_calls

pub mod cache;

pub use cache::{ArtifactCache, CacheOutcome, GcReport, MissReason};

use crate::encode::{encode_layer, EncodedLayer, LowBank, PackedBanks};
use crate::model::eval::{transform_network, EvalConfig};
use crate::model::import::{LayerMeta, NetWeights};
use crate::quant::{BlockShape, Method, StrumParams};
use crate::util::hash::{fnv1a64, Fnv1a};
use crate::util::mmap::{BankI8, MappedFile};
use crate::Result;
use anyhow::ensure;
use std::fmt;
use std::path::Path;
use std::sync::Arc;

/// Magic prefix of a `.strumc` file.
pub const MAGIC: [u8; 8] = *b"STRUMC\x00\x1a";
/// Container-layout version (bump when the byte layout changes).
/// v2 added the per-layer prepacked execution banks.
pub const FORMAT_VERSION: u32 = 2;
/// §IV-D bank-encoder version (bump when encode semantics change — the
/// cache rebuilds every artifact transparently on mismatch).
pub const ENCODER_VERSION: u32 = 1;

/// The effective encoder version: [`ENCODER_VERSION`] unless the
/// `STRUM_ENCODER_VERSION` env var overrides it (the CI cache-invalidation
/// smoke uses the override to simulate an encoder bump without shipping a
/// different binary).
pub fn encoder_version() -> u32 {
    match std::env::var("STRUM_ENCODER_VERSION") {
        Ok(s) => s.trim().parse().unwrap_or(ENCODER_VERSION),
        Err(_) => ENCODER_VERSION,
    }
}

/// Typed artifact-load failures. Each corruption class is distinct so
/// callers (and the cache) can tell a stale version from a damaged file.
#[derive(Debug)]
pub enum ArtifactError {
    /// File I/O failed (open/read/write).
    Io(std::io::Error),
    /// The byte stream ends before the declared content does.
    Truncated { expected: usize, got: usize },
    /// The file does not start with [`MAGIC`] — not a `.strumc` at all.
    BadMagic,
    /// Format or encoder version skew (`kind` says which).
    VersionMismatch {
        kind: &'static str,
        found: u32,
        want: u32,
    },
    /// The FNV-1a trailer does not match the content.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// Structurally invalid content (bad lengths, params out of range).
    Corrupt(String),
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Io(e) => write!(f, "artifact io error: {}", e),
            ArtifactError::Truncated { expected, got } => {
                write!(f, "truncated artifact: need {} bytes, have {}", expected, got)
            }
            ArtifactError::BadMagic => write!(f, "not a .strumc artifact (bad magic)"),
            ArtifactError::VersionMismatch { kind, found, want } => {
                write!(f, "{} version mismatch: artifact {}, runtime {}", kind, found, want)
            }
            ArtifactError::ChecksumMismatch { stored, computed } => write!(
                f,
                "artifact checksum mismatch: stored {:016x}, computed {:016x}",
                stored, computed
            ),
            ArtifactError::Corrupt(why) => write!(f, "corrupt artifact: {}", why),
        }
    }
}

impl std::error::Error for ArtifactError {}

impl From<std::io::Error> for ArtifactError {
    fn from(e: std::io::Error) -> Self {
        ArtifactError::Io(e)
    }
}

/// Everything that determines a compiled artifact's content (besides the
/// versions): the cache key fields. Two registrations with equal
/// identities may share one artifact byte-for-byte.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactIdentity {
    pub net: String,
    pub method: Method,
    pub p: f64,
    /// Block shape `(l, w)`.
    pub block: (usize, usize),
    pub act_quant: bool,
    pub unstructured: bool,
    /// FNV-1a 64 fingerprint of the source float weights + manifest.
    pub weights_fp: u64,
}

impl ArtifactIdentity {
    /// The identity of compiling `weights` under `cfg`.
    pub fn of(weights: &NetWeights, cfg: &EvalConfig) -> ArtifactIdentity {
        ArtifactIdentity {
            net: weights.manifest.net.clone(),
            method: cfg.method,
            p: cfg.p,
            block: cfg.block,
            act_quant: cfg.act_quant,
            unstructured: cfg.unstructured,
            weights_fp: weights_fingerprint(weights),
        }
    }

    /// Content-address hash over every identity field (NOT the versions:
    /// a version bump must land on the same cache path so the stale file
    /// is detected, rebuilt, and overwritten in place).
    pub fn cache_key(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.update(self.net.as_bytes());
        let (tag, param) = method_to_wire(self.method);
        h.update(&[tag, param, self.act_quant as u8, self.unstructured as u8]);
        h.update_u64(self.p.to_bits());
        h.update_u64(self.block.0 as u64);
        h.update_u64(self.block.1 as u64);
        h.update_u64(self.weights_fp);
        h.finish()
    }
}

/// Fingerprints a weight set: manifest geometry + activation scales +
/// every float bit of the blob. Guards the cache against silently serving
/// an artifact compiled from different weights.
pub fn weights_fingerprint(weights: &NetWeights) -> u64 {
    let m = &weights.manifest;
    let mut h = Fnv1a::new();
    h.update(m.net.as_bytes());
    h.update_u64(m.num_classes as u64);
    h.update_u64(m.layers.len() as u64);
    for l in &m.layers {
        h.update(l.name.as_bytes());
        h.update(l.kind.as_bytes());
        for d in [l.kh, l.kw, l.ic, l.oc, l.oh, l.ow] {
            h.update_u64(d as u64);
        }
    }
    h.update_u64(m.act_scales.len() as u64);
    for &s in &m.act_scales {
        h.update(&s.to_bits().to_le_bytes());
    }
    h.update_u64(weights.blob.len() as u64);
    for &v in &weights.blob {
        h.update(&v.to_bits().to_le_bytes());
    }
    h.finish()
}

/// One compiled layer: geometry + serve-time constants + the §IV-D bank.
#[derive(Debug, Clone)]
pub struct CompiledLayer {
    pub meta: LayerMeta,
    /// Static activation scale (0 = dynamic / act_quant off).
    pub act_scale: f32,
    pub bias: Vec<f32>,
    /// The encoded dual-bank weight stream.
    pub enc: EncodedLayer,
    /// Kernel-layout execution banks, prepacked at compile time so bind
    /// is a borrow/memcpy instead of a decode + repack.
    pub pack: PackedBanks,
}

/// A fully compiled network: the deployable artifact.
#[derive(Debug, Clone)]
pub struct CompiledNet {
    pub encoder_version: u32,
    pub identity: ArtifactIdentity,
    pub classes: usize,
    pub img: usize,
    /// Mean per-layer int-grid RMSE of the transform (diagnostics).
    pub mean_rmse: f64,
    pub layers: Vec<CompiledLayer>,
}

/// Compile time: float weights → StruM transform → §IV-D encode, once.
/// The output binds into a serveable plan via
/// [`NetworkPlan::from_artifact`](crate::backend::NetworkPlan::from_artifact)
/// with no quantizer on the path, bit-identical to
/// [`NetworkPlan::build`](crate::backend::NetworkPlan::build).
pub fn compile_net(weights: &NetWeights, cfg: &EvalConfig) -> Result<CompiledNet> {
    let m = &weights.manifest;
    ensure!(!m.layers.is_empty(), "{}: empty layer manifest", m.net);
    ensure!(
        m.act_scales.len() == m.layers.len(),
        "{}: {} act scales for {} layers",
        m.net,
        m.act_scales.len(),
        m.layers.len()
    );
    let transformed = transform_network(weights, cfg)?;
    ensure!(
        transformed.len() == m.layers.len(),
        "{}: {} transformed layers for {} manifest layers",
        m.net,
        transformed.len(),
        m.layers.len()
    );
    let mut layers = Vec::with_capacity(m.layers.len());
    for (li, (meta, s)) in m.layers.iter().zip(transformed.iter()).enumerate() {
        ensure!(
            meta.name == s.name,
            "layer order mismatch: manifest {} vs transform {}",
            meta.name,
            s.name
        );
        let (_, bias) = weights.param(&format!("{}_b", meta.name))?;
        ensure!(bias.len() == meta.oc, "layer {}: bias len", meta.name);
        let act_scale = if cfg.act_quant { m.act_scales[li] } else { 0.0 };
        layers.push(CompiledLayer {
            meta: meta.clone(),
            act_scale,
            bias: bias.to_vec(),
            enc: encode_layer(s),
            pack: PackedBanks::from_layer(s)?,
        });
    }
    let mean_rmse =
        transformed.iter().map(|s| s.grid_rmse).sum::<f64>() / transformed.len() as f64;
    Ok(CompiledNet {
        encoder_version: encoder_version(),
        identity: ArtifactIdentity::of(weights, cfg),
        classes: m.num_classes,
        img: m.layers[0].oh,
        mean_rmse,
        layers,
    })
}

impl CompiledNet {
    /// Total encoded-bank payload size in bytes (reporting).
    pub fn encoded_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.enc.bytes.len()).sum()
    }

    /// Serializes to the versioned `.strumc` byte layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.buf.extend_from_slice(&MAGIC);
        w.u32(FORMAT_VERSION);
        w.u32(self.encoder_version);
        w.u64(0); // total_len placeholder, patched below
        let id = &self.identity;
        w.string(&id.net);
        let (tag, param) = method_to_wire(id.method);
        w.buf.push(tag);
        w.buf.push(param);
        w.u64(id.p.to_bits());
        w.u32(id.block.0 as u32);
        w.u32(id.block.1 as u32);
        w.buf.push(id.act_quant as u8);
        w.buf.push(id.unstructured as u8);
        w.u64(id.weights_fp);
        w.u32(self.classes as u32);
        w.u32(self.img as u32);
        w.u64(self.mean_rmse.to_bits());
        w.u32(self.layers.len() as u32);
        for l in &self.layers {
            w.string(&l.meta.name);
            w.string(&l.meta.kind);
            for d in [l.meta.kh, l.meta.kw, l.meta.ic, l.meta.oc, l.meta.oh, l.meta.ow] {
                w.u32(d as u32);
            }
            w.u32(l.act_scale.to_bits());
            w.f32s(&l.bias);
            let (tag, param) = method_to_wire(l.enc.params.method);
            w.buf.push(tag);
            w.buf.push(param);
            w.u64(l.enc.params.p.to_bits());
            w.u32(l.enc.params.block.l as u32);
            w.u32(l.enc.params.block.w as u32);
            w.u32(l.enc.oc as u32);
            w.u32(l.enc.rows as u32);
            w.u32(l.enc.cols as u32);
            w.f32s(&l.enc.scales);
            w.u64(l.enc.bits as u64);
            w.u64(l.enc.bytes.len() as u64);
            w.buf.extend_from_slice(&l.enc.bytes);
            // Prepacked execution banks (format v2). `from_layer` is
            // deterministic, so this section is byte-stable across
            // recompiles of the same net.
            w.u64(l.pack.hi.len() as u64);
            w.i8s(&l.pack.hi);
            match &l.pack.low {
                LowBank::Empty => w.buf.push(0),
                LowBank::Dliq { shift, codes } => {
                    w.buf.push(1);
                    w.u32(*shift);
                    w.u64(codes.len() as u64);
                    w.i8s(codes);
                }
                LowBank::Pow2 { row_ptr, col, shift, neg } => {
                    w.buf.push(2);
                    w.u64(col.len() as u64);
                    for &v in row_ptr {
                        w.u32(v);
                    }
                    for &v in col {
                        w.u32(v);
                    }
                    w.buf.extend_from_slice(shift);
                    w.buf.extend(neg.iter().map(|&n| n as u8));
                }
            }
        }
        let mut bytes = w.buf;
        seal(&mut bytes);
        bytes
    }

    /// Parses a `.strumc` byte stream, validating magic, format version,
    /// declared length, and checksum before touching the body. Every
    /// corruption class maps to a typed [`ArtifactError`]. Weight banks
    /// are copied out of the stream (copy-bind); [`Self::load`] maps the
    /// file and borrows them instead.
    pub fn from_bytes(bytes: &[u8]) -> std::result::Result<CompiledNet, ArtifactError> {
        Self::parse(bytes, None)
    }

    /// Shared parse core. When `src` is a live mapping of exactly these
    /// bytes, the alignment-1 i8 banks (`hi`, DLIQ codes) are borrowed
    /// from it zero-copy; otherwise they are owned copies. `Cursor.pos`
    /// is an absolute file offset (the body is a prefix of the file), so
    /// it doubles as the mapping offset.
    fn parse(
        bytes: &[u8],
        src: Option<&Arc<MappedFile>>,
    ) -> std::result::Result<CompiledNet, ArtifactError> {
        // Header gate: magic → version → declared length → checksum.
        const HEAD: usize = 8 + 4 + 4 + 8;
        if bytes.len() < 8 {
            return Err(ArtifactError::Truncated { expected: 8, got: bytes.len() });
        }
        if bytes[..8] != MAGIC {
            return Err(ArtifactError::BadMagic);
        }
        if bytes.len() < HEAD + 8 {
            return Err(ArtifactError::Truncated { expected: HEAD + 8, got: bytes.len() });
        }
        let format_version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if format_version != FORMAT_VERSION {
            return Err(ArtifactError::VersionMismatch {
                kind: "format",
                found: format_version,
                want: FORMAT_VERSION,
            });
        }
        let total_len = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
        if total_len != bytes.len() as u64 {
            return Err(ArtifactError::Truncated {
                expected: total_len as usize,
                got: bytes.len(),
            });
        }
        let body = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        let computed = fnv1a64(body);
        if stored != computed {
            return Err(ArtifactError::ChecksumMismatch { stored, computed });
        }

        // Body parse. The checksum already vouches for integrity; the
        // bounds checks below keep even adversarial (validly-sealed)
        // streams from panicking or over-allocating.
        let mut c = Cursor { buf: body, pos: 8 };
        let _format = c.u32()?;
        let encoder_version = c.u32()?;
        let _total = c.u64()?;
        let net = c.string("net")?;
        let method = method_from_wire(c.u8()?, c.u8()?)?;
        let p = f64::from_bits(c.u64()?);
        if !(0.0..=1.0).contains(&p) {
            return Err(ArtifactError::Corrupt(format!("identity p {} out of range", p)));
        }
        let bl = c.u32()? as usize;
        let bw = c.u32()? as usize;
        if bl == 0 || bw == 0 || bl > 65536 || bw > 65536 {
            return Err(ArtifactError::Corrupt(format!("block shape [{}, {}]", bl, bw)));
        }
        let act_quant = c.u8()? != 0;
        let unstructured = c.u8()? != 0;
        let weights_fp = c.u64()?;
        let classes = c.u32()? as usize;
        let img = c.u32()? as usize;
        let mean_rmse = f64::from_bits(c.u64()?);
        let n_layers = c.u32()? as usize;
        if n_layers == 0 || n_layers > c.remaining() {
            return Err(ArtifactError::Corrupt(format!("{} layers", n_layers)));
        }
        let mut layers = Vec::with_capacity(n_layers.min(1024));
        for li in 0..n_layers {
            let name = c.string("layer name")?;
            let kind = c.string("layer kind")?;
            let mut dims = [0usize; 6];
            for d in dims.iter_mut() {
                *d = c.u32()? as usize;
            }
            let [kh, kw, ic, oc, oh, ow] = dims;
            let act_scale = f32::from_bits(c.u32()?);
            let bias = c.f32_vec("bias")?;
            if bias.len() != oc {
                return Err(ArtifactError::Corrupt(format!(
                    "layer {}: {} biases for {} channels",
                    li,
                    bias.len(),
                    oc
                )));
            }
            let method = method_from_wire(c.u8()?, c.u8()?)?;
            let lp = f64::from_bits(c.u64()?);
            if !(0.0..=1.0).contains(&lp) {
                return Err(ArtifactError::Corrupt(format!("layer {} p {}", li, lp)));
            }
            let l = c.u32()? as usize;
            let w = c.u32()? as usize;
            if l == 0 || w == 0 || l > 65536 || w > 65536 {
                return Err(ArtifactError::Corrupt(format!("layer {} block [{}, {}]", li, l, w)));
            }
            let b_oc = c.u32()? as usize;
            let b_rows = c.u32()? as usize;
            let b_cols = c.u32()? as usize;
            // Decoded size must stay sane relative to the payload (a
            // compressed layer is never smaller than ~1/9 of its grid).
            let elems = (b_oc as u128) * (b_rows as u128) * (b_cols as u128);
            if elems > (1u128 << 32) {
                return Err(ArtifactError::Corrupt(format!(
                    "layer {}: bank {}x{}x{} too large",
                    li, b_oc, b_rows, b_cols
                )));
            }
            let scales = c.f32_vec("scales")?;
            if scales.len() != b_oc {
                return Err(ArtifactError::Corrupt(format!(
                    "layer {}: {} scales for {} channels",
                    li,
                    scales.len(),
                    b_oc
                )));
            }
            let bits = c.u64()? as usize;
            let nbytes = c.u64()? as usize;
            if nbytes > c.remaining() {
                return Err(ArtifactError::Corrupt(format!(
                    "layer {}: payload {} bytes, {} left",
                    li,
                    nbytes,
                    c.remaining()
                )));
            }
            if bits > nbytes * 8 {
                return Err(ArtifactError::Corrupt(format!(
                    "layer {}: {} bits in {} bytes",
                    li, bits, nbytes
                )));
            }
            let payload = c.bytes(nbytes)?.to_vec();

            // Prepacked execution banks (format v2).
            let bank_k = b_rows * b_cols;
            let hi_len = c.u64()? as usize;
            if hi_len != b_oc * bank_k {
                return Err(ArtifactError::Corrupt(format!(
                    "layer {}: hi bank {} bytes for {}x{} grid",
                    li, hi_len, b_oc, bank_k
                )));
            }
            let hi = c.i8_bank(hi_len, src, "hi bank")?;
            let low = match c.u8()? {
                0 => LowBank::Empty,
                1 => {
                    let shift = c.u32()?;
                    let codes_len = c.u64()? as usize;
                    if codes_len != hi_len {
                        return Err(ArtifactError::Corrupt(format!(
                            "layer {}: dliq bank {} bytes for {}x{} grid",
                            li, codes_len, b_oc, bank_k
                        )));
                    }
                    LowBank::Dliq { shift, codes: c.i8_bank(codes_len, src, "dliq bank")? }
                }
                2 => {
                    let n_taps = c.u64()? as usize;
                    // Coarse bound before allocating: the section needs
                    // 4 bytes per row_ptr entry and 6 per tap.
                    let need = (b_oc + 1)
                        .checked_mul(4)
                        .and_then(|r| n_taps.checked_mul(6).map(|t| r + t));
                    if need.map(|n| n > c.remaining()).unwrap_or(true) {
                        return Err(ArtifactError::Corrupt(format!(
                            "layer {}: {} pow2 taps overrun body",
                            li, n_taps
                        )));
                    }
                    let mut row_ptr = Vec::with_capacity(b_oc + 1);
                    for _ in 0..=b_oc {
                        row_ptr.push(c.u32()?);
                    }
                    let mut col = Vec::with_capacity(n_taps);
                    for _ in 0..n_taps {
                        col.push(c.u32()?);
                    }
                    let shift = c.bytes(n_taps)?.to_vec();
                    let mut neg = Vec::with_capacity(n_taps);
                    for &b in c.bytes(n_taps)? {
                        match b {
                            0 => neg.push(false),
                            1 => neg.push(true),
                            other => {
                                return Err(ArtifactError::Corrupt(format!(
                                    "layer {}: pow2 neg byte {}",
                                    li, other
                                )))
                            }
                        }
                    }
                    LowBank::Pow2 { row_ptr, col, shift, neg }
                }
                tag => {
                    return Err(ArtifactError::Corrupt(format!(
                        "layer {}: low bank tag {}",
                        li, tag
                    )))
                }
            };
            let pack = PackedBanks { oc: b_oc, k: bank_k, hi, low };
            if let Err(e) = pack.validate() {
                return Err(ArtifactError::Corrupt(format!("layer {}: {}", li, e)));
            }

            layers.push(CompiledLayer {
                meta: LayerMeta { name: name.clone(), kind, kh, kw, ic, oc, oh, ow },
                act_scale,
                bias,
                enc: EncodedLayer {
                    name,
                    params: StrumParams {
                        method,
                        block: BlockShape { l, w },
                        p: lp,
                    },
                    oc: b_oc,
                    rows: b_rows,
                    cols: b_cols,
                    scales,
                    bytes: payload,
                    bits,
                },
                pack,
            });
        }
        if c.remaining() != 0 {
            return Err(ArtifactError::Corrupt(format!(
                "{} trailing bytes after last layer",
                c.remaining()
            )));
        }
        Ok(CompiledNet {
            encoder_version,
            identity: ArtifactIdentity {
                net,
                method,
                p,
                block: (bl, bw),
                act_quant,
                unstructured,
                weights_fp,
            },
            classes,
            img,
            mean_rmse,
            layers,
        })
    }

    /// Writes the artifact atomically (temp file + rename) so concurrent
    /// readers never observe a half-written `.strumc`.
    pub fn save(&self, path: &Path) -> std::result::Result<(), ArtifactError> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        // Unique per process AND per call: two threads recompiling the
        // same cold slot must not interleave writes into one temp file.
        static SAVE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = SAVE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = path.with_extension(format!("tmp.{}.{}", std::process::id(), seq));
        std::fs::write(&tmp, self.to_bytes())?;
        match std::fs::rename(&tmp, path) {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                Err(e.into())
            }
        }
    }

    /// Loads a `.strumc` file through a read-only mapping: the full
    /// magic/version/length/checksum gates run against the mapped bytes,
    /// then the dense i8 weight banks are borrowed from the mapping
    /// (zero-copy bind — the kernel reads weights straight out of the
    /// page cache). Falls back to an owned [`Self::from_bytes`] read when
    /// the platform has no mmap or the mapping fails. No encoder-version
    /// check: callers pin their own expected version (the cache) or go
    /// through [`Self::load`].
    pub fn load_mapped(path: &Path) -> std::result::Result<CompiledNet, ArtifactError> {
        match MappedFile::open(path) {
            Some(map) => Self::parse(map.as_slice(), Some(&map)),
            None => Self::from_bytes(&std::fs::read(path)?),
        }
    }

    /// Loads a standalone `.strumc` file (via [`Self::load_mapped`]),
    /// enforcing the runtime's effective encoder version.
    /// [`Self::from_bytes`] checks the container format only (the cache
    /// pins its own expected encoder version); this entry point is for
    /// artifacts passed around as files (`strum compile --out`), where a
    /// stale encoding must surface as a typed
    /// [`ArtifactError::VersionMismatch`] instead of silently decoding
    /// old banks with new semantics.
    pub fn load(path: &Path) -> std::result::Result<CompiledNet, ArtifactError> {
        let compiled = Self::load_mapped(path)?;
        let want = encoder_version();
        if compiled.encoder_version != want {
            return Err(ArtifactError::VersionMismatch {
                kind: "encoder",
                found: compiled.encoder_version,
                want,
            });
        }
        Ok(compiled)
    }
}

/// The deploy-relevant prefix of a `.strumc` file: versions + identity,
/// readable without validating the body checksum.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactHeader {
    pub format_version: u32,
    pub encoder_version: u32,
    pub identity: ArtifactIdentity,
}

impl ArtifactHeader {
    /// The deploy version key the gateway's rolling-deploy watcher
    /// tracks: a new weights fingerprint (new weights push) or a new
    /// encoder version (new toolchain) is a new deployable version.
    pub fn version_key(&self) -> String {
        format!(
            "{}/fp:{:016x}/enc:{}",
            self.identity.net, self.identity.weights_fp, self.encoder_version
        )
    }
}

/// Reads just the identity prefix of a `.strumc` file — magic, format,
/// encoder version, and the [`ArtifactIdentity`] fields — WITHOUT
/// verifying the declared length or body checksum. This is deliberate:
/// the rolling-deploy watcher must notice a *corrupt* push as a new
/// version (so the deploy is attempted, fails replica health, and rolls
/// back with telemetry) rather than silently ignoring it; full
/// validation happens where the bytes are trusted, in
/// [`CompiledNet::load`].
pub fn read_identity(path: &Path) -> std::result::Result<ArtifactHeader, ArtifactError> {
    // The identity prefix is a few dozen bytes plus the net-name string;
    // read a bounded head instead of the whole artifact (weight banks
    // dominate the file and the deploy watcher polls this in a loop).
    const IDENTITY_READ_CAP: u64 = 64 * 1024;
    let bytes = {
        use std::io::Read as _;
        let mut head = Vec::with_capacity(4096);
        std::fs::File::open(path)?.take(IDENTITY_READ_CAP).read_to_end(&mut head)?;
        head
    };
    if bytes.len() < 8 || bytes[..8] != MAGIC {
        return Err(ArtifactError::BadMagic);
    }
    let mut c = Cursor { buf: &bytes, pos: 8 };
    let format_version = c.u32()?;
    if format_version != FORMAT_VERSION {
        return Err(ArtifactError::VersionMismatch {
            kind: "format",
            found: format_version,
            want: FORMAT_VERSION,
        });
    }
    let encoder_version = c.u32()?;
    let _total = c.u64()?;
    let net = c.string("net")?;
    let method = method_from_wire(c.u8()?, c.u8()?)?;
    let p = f64::from_bits(c.u64()?);
    let block = (c.u32()? as usize, c.u32()? as usize);
    let act_quant = c.u8()? != 0;
    let unstructured = c.u8()? != 0;
    let weights_fp = c.u64()?;
    Ok(ArtifactHeader {
        format_version,
        encoder_version,
        identity: ArtifactIdentity {
            net,
            method,
            p,
            block,
            act_quant,
            unstructured,
            weights_fp,
        },
    })
}

/// Recomputes the declared length + trailing checksum of a raw artifact
/// buffer in place (test/tooling helper for patching header fields).
pub fn reseal(bytes: &mut Vec<u8>) {
    assert!(bytes.len() >= 32, "not an artifact buffer");
    bytes.truncate(bytes.len() - 8);
    let total = (bytes.len() + 8) as u64;
    bytes[16..24].copy_from_slice(&total.to_le_bytes());
    let sum = fnv1a64(bytes);
    bytes.extend_from_slice(&sum.to_le_bytes());
}

/// First-time seal: patch total_len and append the checksum.
fn seal(bytes: &mut Vec<u8>) {
    let total = (bytes.len() + 8) as u64;
    bytes[16..24].copy_from_slice(&total.to_le_bytes());
    let sum = fnv1a64(bytes);
    bytes.extend_from_slice(&sum.to_le_bytes());
}

fn method_to_wire(m: Method) -> (u8, u8) {
    match m {
        Method::Baseline => (0, 0),
        Method::StructuredSparsity => (1, 0),
        Method::Dliq { q } => (2, q),
        Method::Mip2q { l_max } => (3, l_max),
    }
}

fn method_from_wire(tag: u8, param: u8) -> std::result::Result<Method, ArtifactError> {
    match tag {
        0 => Ok(Method::Baseline),
        1 => Ok(Method::StructuredSparsity),
        // Bounds mirror the decoder's own asserts: a hostile param must
        // become a typed error here, not a panic downstream.
        2 if (1..=8).contains(&param) => Ok(Method::Dliq { q: param }),
        3 if param <= 7 => Ok(Method::Mip2q { l_max: param }),
        _ => Err(ArtifactError::Corrupt(format!("method tag {} param {}", tag, param))),
    }
}

/// Append-only little-endian byte writer for the artifact layout.
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Writer {
        Writer { buf: Vec::new() }
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u32(x.to_bits());
        }
    }
    fn i8s(&mut self, xs: &[i8]) {
        self.buf.extend(xs.iter().map(|&x| x as u8));
    }
}

/// Bounds-checked little-endian reader over the (already checksummed)
/// artifact body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> std::result::Result<&'a [u8], ArtifactError> {
        if n > self.remaining() {
            return Err(ArtifactError::Corrupt(format!(
                "read of {} bytes at offset {} overruns {}-byte body",
                n,
                self.pos,
                self.buf.len()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> std::result::Result<u8, ArtifactError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> std::result::Result<u32, ArtifactError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> std::result::Result<u64, ArtifactError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn string(&mut self, what: &str) -> std::result::Result<String, ArtifactError> {
        let n = self.u32()? as usize;
        if n > self.remaining() {
            return Err(ArtifactError::Corrupt(format!("{} length {}", what, n)));
        }
        String::from_utf8(self.bytes(n)?.to_vec())
            .map_err(|_| ArtifactError::Corrupt(format!("{} is not utf-8", what)))
    }

    /// Reads `n` bytes as an i8 bank: a zero-copy borrow from `src` when
    /// the stream is a live mapping, an owned copy otherwise. `self.pos`
    /// is the absolute file offset because the body is a file prefix.
    fn i8_bank(
        &mut self,
        n: usize,
        src: Option<&Arc<MappedFile>>,
        what: &str,
    ) -> std::result::Result<BankI8, ArtifactError> {
        let off = self.pos;
        let raw = self.bytes(n)?;
        if let Some(map) = src {
            if let Some(bank) = BankI8::borrowed(map, off, n) {
                return Ok(bank);
            }
            return Err(ArtifactError::Corrupt(format!("{} window outside mapping", what)));
        }
        Ok(BankI8::from(raw.iter().map(|&b| b as i8).collect::<Vec<i8>>()))
    }

    fn f32_vec(&mut self, what: &str) -> std::result::Result<Vec<f32>, ArtifactError> {
        let n = self.u32()? as usize;
        if n.checked_mul(4).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(ArtifactError::Corrupt(format!("{} length {}", what, n)));
        }
        let raw = self.bytes(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::graph::{calibrate_act_scales, synth_net_weights};

    fn small_weights() -> NetWeights {
        let mut w = synth_net_weights("mini_cnn_s", 8, 4, 3).unwrap();
        let calib: Vec<f32> = {
            let mut rng = crate::util::prng::Rng::new(5);
            (0..2 * 8 * 8 * 3).map(|_| rng.f32()).collect()
        };
        w.manifest.act_scales = calibrate_act_scales(&w, &calib, 2).unwrap();
        w
    }

    #[test]
    fn method_wire_roundtrip() {
        for m in [
            Method::Baseline,
            Method::StructuredSparsity,
            Method::Dliq { q: 4 },
            Method::Mip2q { l_max: 7 },
        ] {
            let (t, p) = method_to_wire(m);
            assert_eq!(method_from_wire(t, p).unwrap(), m);
        }
        assert!(method_from_wire(9, 0).is_err());
        assert!(method_from_wire(2, 0).is_err()); // dliq q=0
        assert!(method_from_wire(2, 9).is_err()); // dliq q=9
        assert!(method_from_wire(3, 8).is_err()); // mip2q l_max=8
    }

    #[test]
    fn bytes_roundtrip_preserves_everything() {
        let w = small_weights();
        let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
        let c = compile_net(&w, &cfg).unwrap();
        let bytes = c.to_bytes();
        let back = CompiledNet::from_bytes(&bytes).unwrap();
        assert_eq!(back.identity, c.identity);
        assert_eq!(back.classes, c.classes);
        assert_eq!(back.img, c.img);
        assert_eq!(back.mean_rmse.to_bits(), c.mean_rmse.to_bits());
        assert_eq!(back.layers.len(), c.layers.len());
        for (a, b) in back.layers.iter().zip(c.layers.iter()) {
            assert_eq!(a.meta.name, b.meta.name);
            assert_eq!(a.enc.bytes, b.enc.bytes);
            assert_eq!(a.enc.bits, b.enc.bits);
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.act_scale.to_bits(), b.act_scale.to_bits());
            assert_eq!(a.pack, b.pack, "prepacked banks survive the roundtrip");
            assert!(!a.pack.is_mapped(), "from_bytes banks are owned");
        }
        // Re-serialization is byte-stable.
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn mapped_load_borrows_banks_and_matches_copy_bind() {
        let w = small_weights();
        for (mi, cfg) in [
            EvalConfig::paper(Method::Dliq { q: 4 }, 0.5),
            EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5),
        ]
        .into_iter()
        .enumerate()
        {
            let c = compile_net(&w, &cfg).unwrap();
            let path = std::env::temp_dir()
                .join(format!("strum-mapped-{}-{}.strumc", std::process::id(), mi));
            c.save(&path).unwrap();
            let owned = CompiledNet::from_bytes(&std::fs::read(&path).unwrap()).unwrap();
            let mapped = CompiledNet::load_mapped(&path).unwrap();
            assert_eq!(mapped.identity, owned.identity);
            for (a, b) in mapped.layers.iter().zip(owned.layers.iter()) {
                assert_eq!(a.pack, b.pack, "mapped banks are bit-identical to owned");
            }
            // On unix the dense i8 banks really do borrow the mapping.
            #[cfg(unix)]
            assert!(mapped.layers.iter().all(|l| l.pack.is_mapped()));
            // Re-serialization from the mapped form is byte-stable too.
            assert_eq!(mapped.to_bytes(), std::fs::read(&path).unwrap());
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn standalone_load_enforces_encoder_version() {
        let w = small_weights();
        let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
        let mut c = compile_net(&w, &cfg).unwrap();
        let path = std::env::temp_dir()
            .join(format!("strum-standalone-{}.strumc", std::process::id()));
        // An artifact from a different encoder generation must not load
        // standalone (the cache applies its own pinned check).
        c.encoder_version = ENCODER_VERSION + 1;
        c.save(&path).unwrap();
        let err = CompiledNet::load(&path).unwrap_err();
        assert!(
            matches!(err, ArtifactError::VersionMismatch { kind: "encoder", .. }),
            "{}",
            err
        );
        c.encoder_version = ENCODER_VERSION;
        c.save(&path).unwrap();
        assert!(CompiledNet::load(&path).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn read_identity_survives_body_corruption() {
        let w = small_weights();
        let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.25);
        let c = compile_net(&w, &cfg).unwrap();
        let path = std::env::temp_dir()
            .join(format!("strum-identity-{}.strumc", std::process::id()));
        c.save(&path).unwrap();

        let head = read_identity(&path).unwrap();
        assert_eq!(head.encoder_version, c.encoder_version);
        assert_eq!(head.identity, c.identity);
        assert!(head.version_key().starts_with("mini_cnn_s/fp:"));

        // Flip a body byte WITHOUT resealing: the full loader must
        // refuse the file, but the identity prefix must still read —
        // the deploy watcher keys rollbacks off it.
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 9;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            CompiledNet::load(&path),
            Err(ArtifactError::ChecksumMismatch { .. })
        ));
        assert_eq!(read_identity(&path).unwrap(), head);

        // A different weights push is a different version key.
        let mut w2 = w.clone();
        w2.blob[0] += 1.0;
        let c2 = compile_net(&w2, &cfg).unwrap();
        c2.save(&path).unwrap();
        assert_ne!(read_identity(&path).unwrap().version_key(), head.version_key());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn identity_key_ignores_versions_but_sees_weights() {
        let w = small_weights();
        let cfg = EvalConfig::paper(Method::Dliq { q: 4 }, 0.5);
        let id = ArtifactIdentity::of(&w, &cfg);
        let mut w2 = w.clone();
        w2.blob[0] += 1.0;
        let id2 = ArtifactIdentity::of(&w2, &cfg);
        assert_ne!(id.cache_key(), id2.cache_key());
        let cfg2 = EvalConfig::paper(Method::Dliq { q: 4 }, 0.25);
        assert_ne!(id.cache_key(), ArtifactIdentity::of(&w, &cfg2).cache_key());
        // Same inputs → same key (deterministic content address).
        assert_eq!(id.cache_key(), ArtifactIdentity::of(&w, &cfg).cache_key());
    }
}
