//! PJRT runtime: load AOT-lowered HLO text, compile once, execute many.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md). Python never runs on this path — the
//! binary is self-contained once `artifacts/` exists.

pub mod client;
pub mod executable;

pub use client::Runtime;
pub use executable::{Executable, Tensor};
