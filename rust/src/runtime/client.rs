//! PJRT CPU client wrapper with a compile cache.
//!
//! Compiled only with the `pjrt` cargo feature; the default build replaces
//! [`Runtime`] with a same-shape stub whose constructor returns an error,
//! so every caller keeps compiling and the native backend
//! (`crate::backend`) carries the request path instead.

use super::executable::Executable;
use crate::Result;
use std::path::Path;

#[cfg(feature = "pjrt")]
mod imp {
    use super::*;
    use anyhow::Context;
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::{Arc, Mutex};

    /// A process-wide PJRT runtime: one CPU client + compiled-executable
    /// cache keyed by HLO path (compilation is the expensive step;
    /// execution is cheap and thread-safe).
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("{}", e))
                .context("creating PJRT CPU client")?;
            Ok(Runtime {
                client,
                cache: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Loads + compiles an HLO text file (cached).
        pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
            if let Some(exe) = self.cache.lock().unwrap().get(path) {
                return Ok(exe.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parsing HLO {}: {}", path.display(), e))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {}", path.display(), e))?;
            let exe = Arc::new(Executable::new(exe, path.display().to_string()));
            self.cache
                .lock()
                .unwrap()
                .insert(path.to_path_buf(), exe.clone());
            Ok(exe)
        }

        /// Number of compiled executables currently cached.
        pub fn cached(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::*;
    use std::sync::Arc;

    /// Stub runtime for builds without the `pjrt` feature. Construction
    /// fails with an actionable message; use `--backend native` (or the
    /// `pjrt` feature + xla-rs bindings) instead.
    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Err(anyhow::anyhow!(
                "this build has no PJRT runtime (compiled without the `pjrt` \
                 feature); use the native backend (`--backend native`) or \
                 rebuild with `--features pjrt` against the xla-rs bindings"
            ))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
            Err(anyhow::anyhow!(
                "cannot load {}: built without the `pjrt` feature",
                path.display()
            ))
        }

        pub fn cached(&self) -> usize {
            0
        }
    }
}

pub use imp::Runtime;
