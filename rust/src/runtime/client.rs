//! PJRT CPU client wrapper with a compile cache.

use super::executable::Executable;
use crate::Result;
use anyhow::Context;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// A process-wide PJRT runtime: one CPU client + compiled-executable cache
/// keyed by HLO path (compilation is the expensive step; execution is
/// cheap and thread-safe).
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Loads + compiles an HLO text file (cached).
    pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing HLO {}: {}", path.display(), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {}", path.display(), e))?;
        let exe = Arc::new(Executable::new(exe, path.display().to_string()));
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
