//! Typed execution helpers over `xla::PjRtLoadedExecutable`.
//!
//! [`Tensor`] and [`argmax_rows`] are backend-neutral (the native backend
//! and the coordinator use them too); [`Executable`] is PJRT-backed under
//! the `pjrt` feature and a same-shape erroring stub otherwise.

use crate::Result;

/// A host tensor handed to / received from an executable.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Tensor {
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        debug_assert_eq!(data.len() as i64, d.iter().product::<i64>().max(1));
        Tensor::F32 { data, dims: d }
    }
    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Tensor {
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        Tensor::I32 { data, dims: d }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Tensor::F32 { data, dims } => xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape: {}", e))?,
            Tensor::I32 { data, dims } => xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape: {}", e))?,
        })
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::Tensor;
    use crate::Result;

    /// A compiled executable with convenience entry points. Thread-safe:
    /// PJRT executables support concurrent execution.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub source: String,
    }

    // SAFETY: the PJRT CPU client's loaded executables are internally
    // synchronized; the raw pointer wrapper in the xla crate just lacks the
    // marker. Execution from multiple threads is the documented PJRT model.
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    impl Executable {
        pub fn new(exe: xla::PjRtLoadedExecutable, source: String) -> Executable {
            Executable { exe, source }
        }

        /// Executes with the given inputs; returns the tuple elements as
        /// f32 vectors (the zoo forwards return a 1-tuple of logits).
        pub fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<_>>()?;
            let out = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow::anyhow!("execute {}: {}", self.source, e))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch result: {}", e))?;
            let elems = lit
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("decompose: {}", e))?;
            elems
                .into_iter()
                .map(|e| e.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {}", e)))
                .collect()
        }

        /// Executes and returns int32 tuple elements.
        pub fn run_i32(&self, inputs: &[Tensor]) -> Result<Vec<Vec<i32>>> {
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<_>>()?;
            let out = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow::anyhow!("execute {}: {}", self.source, e))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch result: {}", e))?;
            let elems = lit
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("decompose: {}", e))?;
            elems
                .into_iter()
                .map(|e| e.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec: {}", e)))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::Tensor;
    use crate::Result;

    /// Stub executable for builds without the `pjrt` feature. Never
    /// constructed (the stub [`super::super::Runtime`] refuses to load
    /// HLO); methods error defensively.
    pub struct Executable {
        pub source: String,
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
            Err(anyhow::anyhow!(
                "execute {}: built without the `pjrt` feature",
                self.source
            ))
        }

        pub fn run_i32(&self, _inputs: &[Tensor]) -> Result<Vec<Vec<i32>>> {
            Err(anyhow::anyhow!(
                "execute {}: built without the `pjrt` feature",
                self.source
            ))
        }
    }
}

pub use imp::Executable;

/// Row-wise argmax over a logits buffer `[batch, classes]`.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        let logits = vec![0.1, 0.9, 0.0, 2.0, -1.0, 1.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn tensor_dims() {
        let t = Tensor::f32(vec![0.0; 6], &[2, 3]);
        match t {
            Tensor::F32 { dims, .. } => assert_eq!(dims, vec![2, 3]),
            _ => unreachable!(),
        }
    }
}
