//! `strum` — the StruM-DPU command-line coordinator.
//!
//! Subcommands:
//!   quantize   Apply a StruM transform to a network; print stats + codec checks
//!   compile    Quantize + encode once → versioned .strumc artifact(s) in the cache
//!   cache-gc   Sweep orphaned .strumc slots out of the artifact cache
//!   eval       Top-1 accuracy of a (net, method, p) point through PJRT
//!   sim        Cycle-simulate a network on the FlexNN DPU model
//!   hw         Hardware cost model summary (PE variants)
//!   report     Regenerate paper artifacts: table1 | fig10 | fig11 | fig12 | fig13 | ablation | all
//!   serve      Run the multi-variant serving engine: synthetic load, or a TCP
//!              wire front-end with --listen ADDR; --telemetry-out DIR streams
//!              structured JSONL events (see `telemetry::schema`)
//!   gateway    Supervise N `strum serve` replicas behind one wire endpoint:
//!              health-checked shed-aware routing, bounded retry/hedging,
//!              rolling deploys with auto-rollback, fault injection for chaos tests
//!   loadgen    Open-loop wire load generator against a running `strum serve --listen`
//!              or `strum gateway` (--target gateway adds per-replica BENCH rows)
//!   bench-diff Compare two run manifests (MANIFEST_*.json) and gate on regressions
//!   selfcheck  Runtime round-trip (HLO load/execute) sanity check
//!
//! Global flags: --artifacts DIR (default ./artifacts), plus per-command
//! flags listed in each `usage` string.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use strum_dpu::artifact::{weights_fingerprint, ArtifactCache, CompiledNet};
use strum_dpu::backend::graph::{calibrate_act_scales, synth_net_weights};
use strum_dpu::backend::BackendKind;
use strum_dpu::coordinator::{Engine, EngineOptions, Router, SubmitError, VariantHandle};
use strum_dpu::gateway::{DeployPolicy, Gateway, GatewayOptions, HedgePolicy, ReplicaSpec};
use strum_dpu::server::{
    AioServer, FaultPlan, HttpClient, WireClient, WireResponse, WireServer, WireServerOptions,
};
use strum_dpu::encode::{decode_layer, encode_layer};
use strum_dpu::encode::compression::ratio_for;
use strum_dpu::hw::power::Activity;
use strum_dpu::model::eval::{transform_network, EvalConfig};
use strum_dpu::model::import::{DataSet, NetWeights};
use strum_dpu::model::zoo;
use strum_dpu::quant::Method;
use strum_dpu::report::{ablation, fig10, fig11, fig12, fig13, table1, EvalCtx};
use strum_dpu::runtime::Runtime;
use strum_dpu::sim::config::SimConfig;
use strum_dpu::sim::driver::simulate_network;
use strum_dpu::sim::SimMode;
use strum_dpu::telemetry::{
    bench_dir, diff_manifests, fmt_trace, history_manifests, parse_trace, render_history,
    render_rates, render_table, render_waterfall, scan_dir, RunManifest, TailFilter,
    TelemetryConfig, TelemetrySink, TraceCtx,
};
use strum_dpu::util::cli::Args;
use strum_dpu::util::json::Json;
use strum_dpu::util::prng::Rng;
use strum_dpu::util::stats::Summary;
use strum_dpu::Result;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let cmd = raw.first().cloned().unwrap_or_else(|| "help".into());
    let args = Args::parse(&raw[1.min(raw.len())..]);
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {:#}", e);
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str("artifacts", "artifacts"))
}

fn parse_method(args: &Args) -> Result<Method> {
    let name = args.str("method", "mip2q-L7");
    Method::parse(&name).ok_or_else(|| anyhow::anyhow!("unknown method '{}'", name))
}

/// Default execution backend: PJRT when compiled in, else native.
fn default_backend() -> &'static str {
    if cfg!(feature = "pjrt") {
        "pjrt"
    } else {
        "native"
    }
}

fn parse_backend(args: &Args) -> Result<BackendKind> {
    let name = args.str("backend", default_backend());
    BackendKind::parse(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown backend '{}' (pjrt|native)", name))
}

/// Fault plan for chaos tests: `--fault-plan SPEC` wins, else the
/// `STRUM_FAULT_PLAN` environment (how a gateway arms one replica of a
/// supervised fleet), else nothing.
fn fault_plan(args: &Args) -> Result<Option<FaultPlan>> {
    match args.opt_str("fault-plan") {
        Some(spec) => Ok(Some(FaultPlan::parse(&spec)?)),
        None => FaultPlan::from_env(),
    }
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "quantize" => cmd_quantize(args),
        "compile" => cmd_compile(args),
        "cache-gc" => cmd_cache_gc(args),
        "eval" => cmd_eval(args),
        "sim" => cmd_sim(args),
        "hw" => cmd_hw(args),
        "report" => cmd_report(args),
        "serve" => cmd_serve(args),
        "gateway" => cmd_gateway(args),
        "loadgen" => cmd_loadgen(args),
        "bench-diff" => cmd_bench_diff(args),
        "tail" => cmd_tail(args),
        "selfcheck" => cmd_selfcheck(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "strum — StruM structured mixed precision DPU coordinator\n\
         usage: strum <quantize|compile|cache-gc|eval|sim|hw|report|serve|gateway|loadgen|bench-diff|tail|selfcheck> [flags]\n\
         common: --artifacts DIR --net NAME --method {{baseline|sparsity|dliq-qN|mip2q-LN}} --p F\n\
         compile: strum compile --net N [--all-nets] [--variants base,dliq,mip2q] [--out FILE]\n\
                 quantize + encode once and write versioned .strumc artifact(s) into\n\
                 the content-addressed cache under <artifacts>/cache/; a later serve\n\
                 or eval run binds them with zero re-quantization. --all-nets sweeps\n\
                 every zoo net, printing per-artifact cache hit/miss. Falls back to\n\
                 the same synthetic net serve uses when artifacts are missing.\n\
         cache-gc: strum cache-gc [--net N | --all-nets] [--assume-synthetic]\n\
                 remove orphaned .strumc slots — those whose weights fingerprint no\n\
                 longer matches their net's current weights — plus stale temp files\n\
                 from crashed writers. Slots at ANY quantization point of current\n\
                 weights are kept, as are slots of nets whose weights cannot be\n\
                 loaded (pass --assume-synthetic to judge those against the\n\
                 synthetic fallback); --net scopes the sweep to that net only.\n\
         eval:   strum eval --net N [--backend {{pjrt|native}}] [--limit N]\n\
         report: strum report <table1|fig10|fig11|fig12|fig13|ablation|all> [--limit N] [--out FILE]\n\
         serve:  strum serve --net N --variants base,dliq,mip2q --requests 2000 --rate 500\n\
                 [--backend {{pjrt|native}}] [--workers N] [--queue-depth N] [--max-wait-ms 4]\n\
                 [--max-batch N] [--pin-workers] [--metrics-out FILE]\n\
                 [--telemetry-out DIR [--telemetry-interval-s N]]\n\
                 [--listen ADDR [--http-listen ADDR] [--legacy-threads]\n\
                  [--duration-s N] [--conn-workers N]]\n\
                 one shared worker pool serves every variant; variant specs are\n\
                 base|dliq|mip2q aliases or method names, with optional @p (e.g.\n\
                 mip2q-L5@0.25) and an optional :W DRR priority weight (e.g.\n\
                 base:4,dliq:1 gives base 4x the scheduler credit);\n\
                 without --variants the single --method/--p point is served.\n\
                 With --backend native and no artifacts, a synthetic net + dataset is served.\n\
                 Native variants register through the .strumc artifact cache — run\n\
                 `strum compile` first and cold start is a read+decode, not a re-quantization.\n\
                 --listen binds the TCP wire front-end (127.0.0.1:0 picks a free\n\
                 port, printed as 'listening on ADDR') instead of the synthetic\n\
                 self-load; stop with --duration-s or a signal. The front-end is\n\
                 the async tier: one poller owns every connection (v2 clients\n\
                 pipeline out of order by correlation id; v1 clients are served\n\
                 in order). --http-listen ADDR additionally exposes HTTP/1.1:\n\
                 POST /v1/infer (JSON), GET /v1/metrics (JSON), GET /metrics\n\
                 (Prometheus text), printed as 'http listening on ADDR'.\n\
                 --legacy-threads falls back to the deprecated thread-per-conn\n\
                 tier (binary protocol only).\n\
                 --telemetry-out DIR streams schema-versioned JSONL events (request\n\
                 done/shed/rejected, batches, conn lifecycle, periodic gauges) to\n\
                 rotating telemetry-<run_id>.NNNN.jsonl segments under DIR; the\n\
                 per-event cost on the request path is one bounded-channel push.\n\
                 --telemetry-interval-s N (default 5) paces the gauge snapshots.\n\
                 --trace-sample N profiles per-layer execute spans for every Nth\n\
                 traced request (trace_id mod N == 0); 0 (default) keeps the layer\n\
                 hooks off. Stage spans (door/queue/batch/execute/reply) flow for\n\
                 every traced request when telemetry is on.\n\
                 --artifact FILE additionally registers the compiled .strumc net\n\
                 (the rolling-deploy serve path); --fault-plan SPEC (or the\n\
                 STRUM_FAULT_PLAN env) arms deliberate misbehaviour for chaos\n\
                 tests: kill-after=N,drop-conn-every=N,delay-ms=N,corrupt-every=N.\n\
         gateway: strum gateway --replicas 3 --variants base,mip2q --listen ADDR\n\
                 [--net N] [--workers N] [--attach A1,A2] [--fault-replica IDX:SPEC]\n\
                 [--no-retry] [--hedge | --hedge-ms N] [--probe-interval-ms 250]\n\
                 [--fail-after 2] [--forward-timeout-s 10] [--conn-workers N]\n\
                 [--watch-artifact FILE [--deploy-replicas N] [--probation-s 5]\n\
                  [--regress-threshold 0.2] [--deploy-timeout-s 30] [--fail-on-rollback]]\n\
                 [--telemetry-out DIR] [--duration-s N]\n\
                 spawns N supervised `strum serve --listen 127.0.0.1:0` replicas\n\
                 (ephemeral ports scraped from their stdout), restarts crashes\n\
                 with capped jittered backoff, health-probes the fleet over the\n\
                 wire metrics op, and serves the same protocol on --listen with\n\
                 per-variant least-outstanding routing, ONE bounded retry on\n\
                 shed/connection errors, and optional tail hedging (--hedge uses\n\
                 the observed p95 delay). --watch-artifact polls a .strumc for a\n\
                 new version, rolls a fresh cohort, shifts traffic, and auto-\n\
                 rolls-back on regression during probation; with\n\
                 --fail-on-rollback a rollback makes the process exit nonzero.\n\
                 --fault-replica arms one replica's STRUM_FAULT_PLAN for chaos\n\
                 smokes. Exits with a per-replica fleet summary.\n\
         loadgen: strum loadgen --addr HOST:PORT [--requests 500 | --duration-s N]\n\
                 [--rate 500] [--concurrency 4] [--deadline-ms N] [--variants k1,k2]\n\
                 [--proto {{binary|http}}] [--connections N] [--target gateway]\n\
                 [--out BENCH_wire_serve.json] [--bench-dir DIR] [--seed N] [--img N]\n\
                 [--trace HEX]\n\
                 --trace HEX traces every request: request i carries trace id\n\
                 HEX+i on the v2 wire frames (binary) or as an X-Strum-Trace\n\
                 header (http), so `strum tail DIR --trace <id>` reconstructs\n\
                 any request's waterfall from the server's --telemetry-out log.\n\
                 --proto http drives the server's HTTP tier (--addr names the\n\
                 --http-listen port) with the same Poisson core; the output JSON\n\
                 records which proto ran. --connections N holds N extra idle\n\
                 sockets open across the run and fails unless every one\n\
                 survives (raise `ulimit -n` for thousand-connection soaks).\n\
                 --target gateway snapshots the gateway's fleet metrics before and\n\
                 after the run and adds per-replica served/throughput rows plus\n\
                 retry/hedge/rollback counters to the output (default out name\n\
                 becomes BENCH_fleet.json).\n\
                 open-loop Poisson arrivals against a running wire server; variant\n\
                 keys and image geometry are discovered from the server's metrics\n\
                 op unless --variants overrides them. Reports p50/p95/p99 latency\n\
                 plus shed/error counts and writes them as JSON to --out inside\n\
                 --bench-dir (default $STRUM_BENCH_DIR or .), plus a checksummed\n\
                 MANIFEST_<out-stem>.json run manifest for `strum bench-diff`.\n\
         bench-diff: strum bench-diff BASE_MANIFEST NEW_MANIFEST [--threshold-pct 10]\n\
                 verify both manifests' FNV-1a checksums (whole-file + per payload),\n\
                 pair payloads by name, and compare every shared numeric metric\n\
                 (throughput up = good, latency percentiles down = good, shed counts\n\
                 gate only against a nonzero base). Prints a per-metric table and\n\
                 exits nonzero on any regression past the threshold or any\n\
                 checksum/integrity failure — the CI regression gate.\n\
                 strum bench-diff --history DIR1 DIR2 [DIR3 ...] instead renders a\n\
                 trajectory table across N runs (each arg a manifest file or a dir\n\
                 holding MANIFEST_*.json), checksum-verified and ordered by manifest\n\
                 timestamp, with a direction-adjusted drift column (last vs first).\n\
                 History never gates on drift, only on integrity failures.\n\
         tail:   strum tail DIR [--run-id R] [--trace HEX] [--event TAG]\n\
                 [--variant K] [--rates [--window-s 1]] [--limit N]\n\
                 query the JSONL telemetry segments under DIR (as written by\n\
                 --telemetry-out): every line is schema-validated, filters AND\n\
                 together, and output is one line per event (newest last).\n\
                 --trace HEX instead reconstructs that request's waterfall —\n\
                 gateway attempts (hedge losers tagged abandoned), queue wait,\n\
                 batch, execute, per-layer profile — with a layers-vs-execute\n\
                 cross-check. --rates buckets request outcomes into --window-s\n\
                 second windows and prints per-window done/shed/rejected + done/s."
    );
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let net = args.str("net", zoo::SWEEP_NET);
    let method = parse_method(args)?;
    let p = args.f64("p", 0.5);
    let cfg = EvalConfig {
        block: (args.usize("l", 1), args.usize("w", 16)),
        ..EvalConfig::paper(method, p)
    };
    let weights = NetWeights::load(&dir, &net)?;
    let transformed = transform_network(&weights, &cfg)?;
    println!(
        "{:<10} {:>9} {:>7} {:>9} {:>10} {:>10} {:>9}",
        "layer", "elems", "p_meas", "rmse", "enc_bits", "ratio", "eq_ratio"
    );
    let mut total_bits = 0usize;
    let mut total_elems = 0usize;
    for s in &transformed {
        s.check_structure().map_err(|e| anyhow::anyhow!(e))?;
        let enc = encode_layer(s);
        let dec = decode_layer(&enc)?;
        anyhow::ensure!(dec.values == s.values, "codec roundtrip mismatch");
        println!(
            "{:<10} {:>9} {:>7.3} {:>9.3} {:>10} {:>10.4} {:>9.4}",
            s.name,
            s.len(),
            s.measured_p(),
            s.grid_rmse,
            enc.bits,
            enc.measured_ratio(),
            ratio_for(method, p),
        );
        total_bits += enc.bits;
        total_elems += enc.padded_elems();
    }
    println!(
        "TOTAL {} weights, encoded {:.1} KiB, overall ratio {:.4}",
        total_elems,
        total_bits as f64 / 8.0 / 1024.0,
        total_bits as f64 / (8.0 * total_elems as f64)
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let net = args.str("net", zoo::SWEEP_NET);
    let method = parse_method(args)?;
    let p = args.f64("p", 0.5);
    let backend = parse_backend(args)?;
    let data = DataSet::load(&dir, "eval")?;
    let cfg = EvalConfig {
        block: (args.usize("l", 1), args.usize("w", 16)),
        act_quant: !args.flag("no-act-quant"),
        batch: args.usize("batch", 256),
        limit: args.opt_str("limit").and_then(|s| s.parse().ok()),
        unstructured: args.flag("unstructured"),
        ..EvalConfig::paper(method, p)
    };
    let r = match backend {
        BackendKind::Pjrt => {
            let rt = Runtime::cpu()?;
            strum_dpu::model::eval::evaluate(&rt, &dir, &net, &data, &cfg)?
        }
        BackendKind::Native => strum_dpu::model::eval::evaluate_native(&dir, &net, &data, &cfg)?,
    };
    println!(
        "net={} method={} p={} block=[{},{}] backend={} n={}  top1={:.2}%  mean_rmse={:.3}",
        r.net,
        method.name(),
        r.p,
        cfg.block.0,
        cfg.block.1,
        backend.name(),
        r.n,
        r.top1 * 100.0,
        r.mean_rmse
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let net = args.str("net", zoo::SWEEP_NET);
    let method = parse_method(args)?;
    let p = args.f64("p", 0.5);
    let mode = match args.str("mode", "strum-static").as_str() {
        "int8-dense" => SimMode::Int8Dense,
        "sparse" => SimMode::SparseFindFirst,
        "strum-static" => SimMode::StrumStatic,
        "strum-dynamic" => SimMode::StrumDynamic,
        "strum-perf" => SimMode::StrumPerf,
        m => anyhow::bail!("unknown mode {}", m),
    };
    let weights = NetWeights::load(&dir, &net)?;
    let cfg = EvalConfig::paper(method, p);
    let transformed = transform_network(&weights, &cfg)?;
    let layers: Vec<_> = weights
        .manifest
        .layers
        .iter()
        .zip(transformed)
        .map(|(lm, s)| (lm.shape_for_sim(), s))
        .collect();
    let sim_cfg = SimConfig::flexnn(mode, Some(method));
    let density = args.f64("act-density", 0.7);
    let (sims, agg) = simulate_network(&layers, &sim_cfg, density, 42);
    println!(
        "{:<10} {:>12} {:>12} {:>8} {:>12} {:>12}",
        "layer", "cycles", "ideal", "util", "mult_ops", "low_ops"
    );
    for s in &sims {
        println!(
            "{:<10} {:>12} {:>12} {:>8.3} {:>12} {:>12}",
            s.name, s.cycles, s.ideal_cycles, s.utilization, s.mult_ops, s.low_ops
        );
    }
    let cfg_hw = strum_dpu::hw::dpu::DpuConfig::flexnn_16x16();
    let variant = match mode {
        SimMode::StrumStatic => strum_dpu::hw::PeVariant::StaticMip2q { l_max: 7 },
        SimMode::StrumDynamic => strum_dpu::hw::PeVariant::DynamicMip2q { l_max: 7 },
        _ => strum_dpu::hw::PeVariant::BaselineInt8,
    };
    let pr = strum_dpu::hw::power::power(variant, &agg, &cfg_hw);
    println!(
        "TOTAL cycles={}  mode={}  power/cycle: PE {:.0}  array {:.0}  DPU {:.0}",
        agg.cycles,
        mode.name(),
        pr.pe_level(),
        pr.array_level(),
        pr.dpu_level()
    );
    Ok(())
}

fn cmd_hw(_args: &Args) -> Result<()> {
    let (_, _) = fig13::run(None);
    println!();
    ablation::dliq_vs_mip2q_pe();
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.positional.first().cloned().unwrap_or_else(|| "all".into());
    let dir = artifacts_dir(args);
    let limit = args.opt_str("limit").and_then(|s| s.parse().ok());
    let rt = Runtime::cpu()?;
    let ctx = EvalCtx::new(&rt, &dir, limit)?;
    let net = args.str("net", zoo::SWEEP_NET);
    let mut out = Vec::new();

    if which == "table1" || which == "all" {
        println!("{}", table1::header());
        let nets = zoo::net_names();
        let (rows, json) = table1::run(&ctx, &nets)?;
        for n in table1::shape_check(&rows) {
            println!("  note: {}", n);
        }
        out.push(("table1", json));
    }
    if which == "fig10" || which == "all" {
        let (_, json) = fig10::run(&ctx, &net)?;
        out.push(("fig10", json));
    }
    if which == "fig11" || which == "all" {
        let (_, json) = fig11::run(&ctx, &net)?;
        out.push(("fig11", json));
    }
    if which == "fig12" || which == "all" {
        let (_, json) = fig12::run(&ctx, &net)?;
        out.push(("fig12", json));
    }
    if which == "fig13" || which == "all" {
        // Analytic dense activity + the sim-driven variant on a real net.
        let (rows, json) = fig13::run(None);
        for n in fig13::paper_bands(&rows) {
            println!("  {}", n);
        }
        out.push(("fig13", json));
        let weights = NetWeights::load(&dir, &net)?;
        let cfg = EvalConfig::paper(Method::Mip2q { l_max: 7 }, 0.5);
        let transformed = transform_network(&weights, &cfg)?;
        let layers: Vec<_> = weights
            .manifest
            .layers
            .iter()
            .zip(transformed)
            .map(|(lm, s)| (lm.shape_for_sim(), s))
            .collect();
        let (_, agg) = simulate_network(
            &layers,
            &SimConfig::flexnn(SimMode::StrumStatic, Some(Method::Mip2q { l_max: 7 })),
            0.7,
            42,
        );
        println!("\nFig 13 (sim-driven activity from {} conv layers):", net);
        let (rows2, json2) = fig13::run(Some(&agg));
        for n in fig13::paper_bands(&rows2) {
            println!("  {}", n);
        }
        out.push(("fig13_sim", json2));
        let _ = Activity::default();
    }
    if which == "ablation" || which == "all" {
        let j1 = ablation::block_shape_invariance(&ctx, &net)?;
        let j2 = ablation::slowest_pe_balance(&dir, &net)?;
        let j3 = ablation::dliq_vs_mip2q_pe();
        out.push(("ablation_block_shape", j1));
        out.push(("ablation_slowest_pe", j2));
        out.push(("ablation_dliq_pe", j3));
    }

    if let Some(path) = args.opt_str("out") {
        let json = Json::Obj(
            out.into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        );
        std::fs::write(&path, json.to_string_pretty())?;
        println!("wrote {}", path);
    }
    Ok(())
}

/// Parses one `--variants` token: a `base|dliq|mip2q` alias or a full
/// method name (`mip2q-L5`), with an optional `@p` suffix overriding the
/// low-set fraction (e.g. `mip2q-L5@0.25`) and an optional `:W` suffix
/// assigning a DRR priority weight — the variant's scheduler credit per
/// round, so `base:4,dliq:1` drains ~4 base requests per dliq request
/// under contention. Weight 0 (the default) keeps engine defaults.
fn parse_variant_spec(token: &str) -> Result<(Method, f64, usize)> {
    let (body, weight) = match token.rsplit_once(':') {
        Some((head, w)) if !head.is_empty() => match w.parse::<usize>() {
            Ok(w) if w > 0 => (head, w),
            _ => anyhow::bail!(
                "bad priority weight '{}' in variant '{}' (want a positive integer)",
                w,
                token
            ),
        },
        _ => (token, 0),
    };
    let (name, p_str) = match body.split_once('@') {
        Some((a, b)) => (a, Some(b)),
        None => (body, None),
    };
    let (method, default_p) = match name {
        "base" | "baseline" => (Method::Baseline, 0.0),
        "dliq" => (Method::Dliq { q: 4 }, 0.5),
        "mip2q" => (Method::Mip2q { l_max: 7 }, 0.5),
        other => (
            Method::parse(other).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown variant '{}' (base|dliq|mip2q or a method name like mip2q-L5)",
                    other
                )
            })?,
            0.5,
        ),
    };
    let p = match p_str {
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("bad p '{}' in variant '{}'", s, token))?,
        None => default_p,
    };
    Ok((method, p, weight))
}

/// The variant fleet for compile/serve: `--variants base,dliq,mip2q`
/// (each optionally `@p` and `:weight`), else the single `--method`/
/// `--p` point at default weight.
fn parse_variant_specs(args: &Args) -> Result<Vec<(Method, f64, usize)>> {
    let specs: Vec<(Method, f64, usize)> = match args.opt_str("variants") {
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(parse_variant_spec)
            .collect::<Result<_>>()?,
        None => {
            let method = parse_method(args)?;
            vec![(method, args.f64("p", 0.5), 0)]
        }
    };
    anyhow::ensure!(!specs.is_empty(), "--variants is empty");
    Ok(specs)
}

/// The deterministic synthetic fallback net used when artifacts are
/// missing. `strum compile` and a later `strum serve` must build
/// byte-identical weights here, so the cache fingerprints line up and
/// the serve run hits the compiled artifact. `--synth-seed` varies the
/// weights (and therefore the weights fingerprint) — how a test pushes
/// a genuinely *new* artifact version through the deploy watcher
/// without real model files.
fn synth_seed(args: &Args) -> u64 {
    args.usize("synth-seed", 11) as u64
}

fn synthetic_weights(net: &str, seed: u64) -> Result<NetWeights> {
    let (img, classes) = (16usize, 10usize);
    let mut w = synth_net_weights(net, img, classes, seed)?;
    let mut rng = Rng::new(0xCA11B);
    let px = img * img * 3;
    let calib: Vec<f32> = (0..4 * px).map(|_| rng.f32()).collect();
    w.manifest.act_scales = calibrate_act_scales(&w, &calib, 4)?;
    Ok(w)
}

/// Compile time of the artifact pipeline: float-load → transform →
/// encode → serialize, once per (net, method, p) point, into the
/// content-addressed `.strumc` cache. Serving then binds from these
/// bytes with no `transform_network`/`encode_layer` on the path.
fn cmd_compile(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let specs = parse_variant_specs(args)?;
    // --all-nets sweeps the whole zoo in one invocation (the ROADMAP
    // artifact follow-up): precompile every net × variant so serve-time
    // cold starts are pure cache hits fleet-wide.
    let nets: Vec<String> = if args.flag("all-nets") {
        zoo::net_names().iter().map(|s| s.to_string()).collect()
    } else {
        vec![args.str("net", zoo::SWEEP_NET)]
    };
    let out = args.opt_str("out");
    anyhow::ensure!(
        out.is_none() || (specs.len() == 1 && nets.len() == 1),
        "--out takes exactly one net and one variant (got {} × {})",
        nets.len(),
        specs.len()
    );
    let cache = ArtifactCache::under(&dir);
    let (mut hits, mut misses) = (0usize, 0usize);
    for net in &nets {
        let weights = match NetWeights::load(&dir, net) {
            Ok(w) => w,
            Err(e) => {
                println!("artifacts unavailable ({:#}); compiling the synthetic {}", e, net);
                synthetic_weights(net, synth_seed(args))?
            }
        };
        for &(method, p, _) in &specs {
            let cfg = EvalConfig::paper(method, p);
            let t0 = std::time::Instant::now();
            let (compiled, outcome) = cache.load_or_compile(&weights, &cfg)?;
            if outcome.is_hit() {
                hits += 1;
            } else {
                misses += 1;
            }
            let path = cache.path_for(&compiled.identity);
            println!(
                "{} {} p={}: {} layers, {:.1} KiB encoded, cache {} ({:.1} ms) → {}",
                net,
                method.name(),
                p,
                compiled.layers.len(),
                compiled.encoded_bytes() as f64 / 1024.0,
                outcome,
                t0.elapsed().as_secs_f64() * 1e3,
                path.display()
            );
            if let Some(out) = &out {
                compiled.save(std::path::Path::new(out)).map_err(anyhow::Error::from)?;
                println!("wrote {}", out);
            }
        }
    }
    if hits + misses > 1 {
        println!(
            "compiled {} artifact slot(s): {} cache hit(s), {} miss(es)",
            hits + misses,
            hits,
            misses
        );
    }
    Ok(())
}

/// Sweeps orphaned artifact slots. Liveness is judged per slot on the
/// (net, weights fingerprint) pair in its identity header: a slot whose
/// fingerprint no longer matches the net's current weights (a weight
/// edit or a renamed net moved registrations to a new slot) is an
/// orphan no registration can reach; a slot at ANY quantization point
/// of current weights is kept — `cache-gc` never deletes a valid
/// `mip2q-L5@0.25` artifact just because nobody enumerated that point.
fn cmd_cache_gc(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    // A single --net SCOPES the sweep to that net's slots (other nets'
    // artifacts are skipped, never treated as orphans just because they
    // were not enumerated here); the default / --all-nets sweep covers
    // the whole directory against the full zoo live set.
    let (nets, scope): (Vec<String>, Option<String>) = match args.opt_str("net") {
        Some(net) if !args.flag("all-nets") => (vec![net.clone()], Some(net)),
        _ => (
            zoo::net_names().iter().map(|s| s.to_string()).collect(),
            None,
        ),
    };
    // Nets whose real weights cannot be loaded are OMITTED from the live
    // set — gc protects slots of nets it was not given fingerprints for,
    // so a temporarily-unreadable artifacts dir can never cost the
    // cache. `--assume-synthetic` opts into judging such nets against
    // the deterministic synthetic fallback fingerprints instead (the
    // no-artifacts CI flow, where the cache really was built that way).
    let assume_synthetic = args.flag("assume-synthetic");
    let mut live = Vec::new();
    for net in &nets {
        match NetWeights::load(&dir, net) {
            Ok(w) => live.push((net.clone(), weights_fingerprint(&w))),
            Err(e) if assume_synthetic => {
                println!(
                    "{}: weights unavailable ({:#}); judging against the synthetic fingerprint",
                    net, e
                );
                live.push((net.clone(), weights_fingerprint(&synthetic_weights(net, synth_seed(args))?)));
            }
            Err(e) => {
                println!(
                    "warning: weights for {} unavailable ({:#}); its slots are protected \
                     (pass --assume-synthetic to judge them against the synthetic fallback)",
                    net, e
                );
            }
        }
    }
    let cache = ArtifactCache::under(&dir);
    let report = cache.gc(&live, scope.as_deref())?;
    println!(
        "cache-gc under {}{} ({} live net fingerprint{}): {}",
        cache.dir().display(),
        scope.map(|s| format!(" [scope {}]", s)).unwrap_or_default(),
        live.len(),
        if live.len() == 1 { "" } else { "s" },
        report
    );
    Ok(())
}

/// A registered serving fleet: the engine (shared with the wire server
/// when `--listen` is given), the per-variant handles, and the dataset
/// driving the synthetic load path.
struct Fleet {
    engine: Arc<Engine>,
    handles: Vec<VariantHandle>,
    data: DataSet,
    /// Shared structured-event sink (disabled unless --telemetry-out):
    /// the engine and the wire server both log under its one run_id.
    telemetry: TelemetrySink,
}

/// Builds the engine + variant fleet `strum serve` fronts: loads (or
/// synthesizes) weights, registers every `--variants` point through the
/// artifact cache, and honors `:W` priority weights as DRR quanta.
fn build_fleet(args: &Args) -> Result<Fleet> {
    let dir = artifacts_dir(args);
    let net = args.str("net", zoo::SWEEP_NET);
    let backend = parse_backend(args)?;
    // The variant fleet: --variants base,dliq,mip2q, else the single
    // --method/--p point (old single-variant CLI still works).
    let specs = parse_variant_specs(args)?;

    let mut router = match backend {
        BackendKind::Pjrt => {
            let rt = Arc::new(Runtime::cpu()?);
            println!("platform: {}", rt.platform());
            Router::new(rt)
        }
        BackendKind::Native => {
            println!("platform: native integer engine (no PJRT/XLA)");
            Router::native()
        }
    };

    // Weights are loaded once and shared across the native variants
    // (PJRT's register_kind stages its own artifacts per variant); the
    // native backend falls back to a synthetic calibrated net and
    // random dataset when artifacts are absent (the CI smoke path — no
    // files needed at all).
    let (weights, data): (Option<NetWeights>, DataSet) = match backend {
        BackendKind::Pjrt => (None, DataSet::load(&dir, "eval")?),
        BackendKind::Native => {
            let loaded = NetWeights::load(&dir, &net)
                .and_then(|w| DataSet::load(&dir, "eval").map(|d| (w, d)));
            match loaded {
                Ok((w, d)) => (Some(w), d),
                Err(e) => {
                    let w = synthetic_weights(&net, synth_seed(args))?;
                    let (img, classes) =
                        (w.manifest.layers[0].oh, w.manifest.num_classes);
                    let n = 64usize;
                    println!(
                        "artifacts unavailable ({:#}); serving a synthetic {} ({}x{}x3, {} classes)",
                        e, net, img, img, classes
                    );
                    let mut rng = Rng::new(0xDA7A5E7);
                    let px = img * img * 3;
                    let images: Vec<f32> = (0..n * px).map(|_| rng.f32()).collect();
                    let labels: Vec<i32> =
                        (0..n).map(|_| rng.range(0, classes) as i32).collect();
                    (Some(w), DataSet { images, labels, n, img })
                }
            }
        }
    };

    // Telemetry is opt-in: --telemetry-out DIR opens the JSONL sink the
    // engine (and, in --listen mode, the wire server) emit into; without
    // it the sink is a no-op handle and emission is one branch.
    let telemetry = match args.opt_str("telemetry-out") {
        Some(dir) => {
            let sink = TelemetrySink::open(TelemetryConfig::under(&dir))?;
            println!("telemetry: JSONL events under {} (run_id {})", dir, sink.run_id());
            sink
        }
        None => TelemetrySink::disabled(),
    };
    let gauge_every = args.f64("telemetry-interval-s", 5.0);

    // ONE engine, one shared worker pool, every variant registered on it.
    let engine = Arc::new(Engine::start(EngineOptions {
        workers: args.usize("workers", 2),
        queue_depth: args.usize("queue-depth", 1024),
        max_wait: Duration::from_millis(args.usize("max-wait-ms", 4) as u64),
        max_batch: args.opt_str("max-batch").and_then(|s| s.parse().ok()),
        quantum: args.usize("quantum", 0),
        telemetry: telemetry.clone(),
        telemetry_interval: (gauge_every > 0.0)
            .then(|| Duration::from_secs_f64(gauge_every)),
        pin_workers: args.flag("pin-workers"),
        trace_sample: args.usize("trace-sample", 0) as u32,
    }));
    let cache = ArtifactCache::under(&dir);
    let mut handles = Vec::new();
    for &(method, p, weight) in &specs {
        let key = format!("{}:{}:p{}:{}", net, method.name(), p, backend.name());
        let cfg = EvalConfig::paper(method, p);
        // Native variants register through the compiled-artifact cache:
        // with a prior `strum compile` (or serve) run, this is a pure
        // read + decode — no transform/encode work at cold start.
        let v = match &weights {
            Some(w) => {
                let (v, outcome) = router.register_native_cached(&key, w, &cfg, &cache)?;
                println!(
                    "registered {} (batches: {:?}; artifact cache: {}{})",
                    key,
                    v.batches(),
                    outcome,
                    if weight > 0 {
                        format!("; weight {}", weight)
                    } else {
                        String::new()
                    }
                );
                v
            }
            None => {
                let v = router.register_kind(&key, &dir, &net, &cfg, backend)?;
                println!("registered {} (batches: {:?})", key, v.batches());
                v
            }
        };
        handles.push(if weight > 0 {
            engine.register_weight(v, weight)?
        } else {
            engine.register(v)?
        });
    }
    // The rolling-deploy serve path: --artifact FILE additionally binds
    // a compiled .strumc net. A corrupt or version-skewed artifact fails
    // HERE, before the server binds — the process dies without printing
    // `listening on`, which is exactly what the gateway's deploy health
    // gate keys off.
    if let Some(path) = args.opt_str("artifact") {
        anyhow::ensure!(
            backend == BackendKind::Native,
            "--artifact is a native-backend serve path"
        );
        let compiled =
            CompiledNet::load(std::path::Path::new(&path)).map_err(anyhow::Error::from)?;
        let id = &compiled.identity;
        let key = format!("{}:{}:p{}:{}", id.net, id.method.name(), id.p, backend.name());
        if router.get(&key).is_some() {
            // A --variants spec already registered this exact point
            // from the same weights; the artifact adds nothing.
            println!("artifact {} matches already-registered {}", path, key);
        } else {
            let v = router.register_native_compiled(&key, &compiled)?;
            println!("registered {} from artifact {} (batches: {:?})", key, path, v.batches());
            handles.push(engine.register(v)?);
        }
    }
    println!(
        "serving {} variant(s) on {} shared workers",
        handles.len(),
        engine.worker_count()
    );
    Ok(Fleet {
        engine,
        handles,
        data,
        telemetry,
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let fleet = build_fleet(args)?;
    match args.opt_str("listen") {
        Some(listen) => serve_wire(args, fleet, &listen),
        None => serve_synthetic(args, fleet),
    }
}

/// The original self-load mode: open-loop Poisson arrivals at `--rate`
/// req/s, round-robin across the variant fleet, in-process submits.
fn serve_synthetic(args: &Args, fleet: Fleet) -> Result<()> {
    let n_requests = args.usize("requests", 1000);
    let rate = args.f64("rate", 400.0);
    let Fleet {
        engine,
        handles,
        data,
        telemetry,
    } = fleet;
    let px = data.img * data.img * 3;
    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    let mut shed = 0usize;
    let t0 = std::time::Instant::now();
    let mut next = 0.0f64;
    for i in 0..n_requests {
        next += rng.exponential(rate);
        let target = Duration::from_secs_f64(next);
        if let Some(d) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(d);
        }
        let idx = i % data.n;
        let vi = i % handles.len();
        match handles[vi].submit(data.images[idx * px..(idx + 1) * px].to_vec()) {
            Ok(ticket) => pending.push((vi, idx, ticket)),
            // Bounded queues shed load instead of buffering unboundedly.
            Err(SubmitError::QueueFull { .. }) => shed += 1,
            Err(e) => return Err(anyhow::anyhow!(e)),
        }
    }
    let mut served = vec![0usize; handles.len()];
    let mut correct = vec![0usize; handles.len()];
    for (vi, idx, ticket) in pending {
        let reply = ticket.wait_deadline(Duration::from_secs(30))?;
        served[vi] += 1;
        if reply.class as i32 == data.labels[idx] {
            correct[vi] += 1;
        }
    }
    let snapshot = engine.metrics();
    println!("{}", snapshot.render());
    for (vi, h) in handles.iter().enumerate() {
        if served[vi] > 0 {
            println!(
                "{}: accuracy over {} served requests: {:.2}%",
                h.key(),
                served[vi],
                correct[vi] as f64 / served[vi] as f64 * 100.0
            );
        }
    }
    if shed > 0 {
        println!("{} requests shed by QueueFull backpressure", shed);
    }
    if let Some(path) = args.opt_str("metrics-out") {
        std::fs::write(&path, snapshot.to_json().to_string_pretty())?;
        println!("wrote {}", path);
    }
    // Clean-shutdown contract the CI smoke step relies on. The engine
    // drains and joins its pool when the last Arc drops.
    anyhow::ensure!(snapshot.fleet.completed > 0, "no requests completed");
    drop(handles);
    drop(engine);
    // Dropping the sink last drains the event channel to disk.
    telemetry.flush();
    drop(telemetry);
    Ok(())
}

/// `--listen` mode: bind the TCP wire front-end over the fleet's engine
/// and serve remote clients (`strum loadgen`, `WireClient`) instead of
/// the synthetic self-load. `127.0.0.1:0` binds an ephemeral port; the
/// resolved address is printed as `listening on ADDR` for scripts to
/// scrape. Runs for `--duration-s` seconds, or until killed when 0.
fn serve_wire(args: &Args, fleet: Fleet, listen: &str) -> Result<()> {
    enum Front {
        Aio(AioServer),
        Legacy(WireServer),
    }
    let opts = WireServerOptions {
        conn_workers: args.usize("conn-workers", 4),
        telemetry: fleet.telemetry.clone(),
        fault: fault_plan(args)?,
    };
    let http_listen = args.opt_str("http-listen");
    let front = if args.flag("legacy-threads") {
        anyhow::ensure!(
            http_listen.is_none(),
            "--http-listen needs the async tier; drop --legacy-threads"
        );
        Front::Legacy(WireServer::bind(listen, fleet.engine.clone(), opts)?)
    } else {
        Front::Aio(AioServer::bind(
            Some(listen),
            http_listen.as_deref(),
            fleet.engine.clone(),
            opts,
        )?)
    };
    // Scrape order contract: the binary address always prints first
    // (scripts read the first `listening on`), the HTTP one after it.
    match &front {
        Front::Aio(s) => {
            if let Some(a) = s.local_addr() {
                println!("listening on {}", a);
            }
            if let Some(a) = s.http_addr() {
                println!("http listening on {}", a);
            }
        }
        Front::Legacy(s) => println!("listening on {}", s.local_addr()),
    }
    let duration = args.f64("duration-s", 0.0);
    if duration <= 0.0 {
        println!("serving until killed (pass --duration-s N for a bounded run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs_f64(duration));
    let stats = match front {
        Front::Aio(s) => {
            let stats = s.stats();
            s.shutdown();
            stats
        }
        Front::Legacy(s) => {
            let stats = s.stats();
            s.shutdown();
            stats
        }
    };
    let snapshot = fleet.engine.metrics();
    println!("{}", snapshot.render());
    println!(
        "wire: connections={} requests={} shed_presubmit={} protocol_errors={} \
         http_requests={} pipelined_conns={}",
        stats.connections,
        stats.requests,
        stats.shed_presubmit,
        stats.protocol_errors,
        stats.http_requests,
        stats.pipelined_conns
    );
    if let Some(path) = args.opt_str("metrics-out") {
        std::fs::write(&path, snapshot.to_json().to_string_pretty())?;
        println!("wrote {}", path);
    }
    // Drain any buffered telemetry events before exit.
    fleet.telemetry.flush();
    Ok(())
}

/// `strum gateway`: supervise a replica fleet behind one wire endpoint.
/// Children are this same binary running `serve --listen 127.0.0.1:0`;
/// their ephemeral ports are scraped from stdout, so nothing needs port
/// coordination. The gateway speaks the identical wire protocol on
/// `--listen` — clients cannot tell it from a single replica.
fn cmd_gateway(args: &Args) -> Result<()> {
    let replicas = args.usize("replicas", 3);
    let attach: Vec<String> = args
        .opt_str("attach")
        .map(|l| {
            l.split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(String::from)
                .collect()
        })
        .unwrap_or_default();
    let telemetry = match args.opt_str("telemetry-out") {
        Some(dir) => {
            let sink = TelemetrySink::open(TelemetryConfig::under(&dir))?;
            println!("telemetry: JSONL events under {} (run_id {})", dir, sink.run_id());
            sink
        }
        None => TelemetrySink::disabled(),
    };

    // The supervised-replica launch spec: every pass-through flag the
    // children need rides in argv; the variants fleet must match across
    // replicas or routing keys would differ per replica.
    let spec = if replicas > 0 {
        let mut cargs: Vec<String> = vec![
            "serve".into(),
            "--backend".into(),
            "native".into(),
            "--listen".into(),
            "127.0.0.1:0".into(),
        ];
        // telemetry-out rides along so replica engines log spans into
        // the same directory as the gateway (distinct run_ids keep the
        // segments apart; `strum tail` scans them together, so one
        // traced request's gateway + engine spans land in one query).
        for flag in [
            "variants",
            "net",
            "workers",
            "queue-depth",
            "max-wait-ms",
            "synth-seed",
            "telemetry-out",
            "trace-sample",
        ] {
            if let Some(v) = args.opt_str(flag) {
                cargs.push(format!("--{}", flag));
                cargs.push(v);
            }
        }
        Some(ReplicaSpec {
            binary: std::env::current_exe()?,
            args: cargs,
            env: Vec::new(),
        })
    } else {
        None
    };

    // --fault-replica IDX:SPEC arms exactly one supervised slot with a
    // fault plan through its environment (the chaos-smoke hook).
    let fault_replica = match args.opt_str("fault-replica") {
        Some(s) => {
            let (idx, plan) = s
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("--fault-replica wants IDX:SPEC, got '{}'", s))?;
            let idx: usize = idx
                .parse()
                .map_err(|_| anyhow::anyhow!("bad replica index '{}' in --fault-replica", idx))?;
            anyhow::ensure!(idx < replicas, "--fault-replica index {} out of range", idx);
            // Validate the spec now — a typo should fail the gateway,
            // not silently arm nothing in the child.
            FaultPlan::parse(plan)?;
            Some((idx, plan.to_string()))
        }
        None => None,
    };

    let hedge = if args.flag("hedge") {
        Some(HedgePolicy::P95)
    } else {
        args.opt_str("hedge-ms")
            .and_then(|s| s.parse::<u64>().ok())
            .map(HedgePolicy::FixedMs)
    };
    let watch = args.opt_str("watch-artifact").map(|p| DeployPolicy {
        artifact: PathBuf::from(p),
        replicas: args.usize("deploy-replicas", replicas.max(1)),
        poll: Duration::from_millis(args.usize("deploy-poll-ms", 500) as u64),
        health_timeout: Duration::from_secs_f64(args.f64("deploy-timeout-s", 30.0)),
        probation: Duration::from_secs_f64(args.f64("probation-s", 5.0)),
        regress_threshold: args.f64("regress-threshold", 0.2),
        fail_on_rollback: args.flag("fail-on-rollback"),
    });

    let expected = replicas + attach.len();
    let gw = Gateway::start(GatewayOptions {
        replicas,
        spec,
        attach,
        fault_replica,
        probe_interval: Duration::from_millis(args.usize("probe-interval-ms", 250) as u64),
        fail_threshold: args.usize("fail-after", 2) as u32,
        retry: !args.flag("no-retry"),
        hedge,
        forward_timeout: Duration::from_secs_f64(args.f64("forward-timeout-s", 10.0)),
        restart_backoff_base: Duration::from_millis(
            args.usize("restart-backoff-ms", 100) as u64
        ),
        restart_backoff_cap: Duration::from_secs(5),
        watch,
        telemetry: telemetry.clone(),
    })?;

    // Gate the front-end on fleet health: a client connecting the
    // moment the address prints must find routable replicas (loadgen's
    // first act is a metrics probe that needs a healthy upstream).
    let boot_wait = Duration::from_secs_f64(args.f64("boot-timeout-s", 60.0));
    if !gw.wait_healthy(expected, boot_wait) {
        let healthy = gw.snapshot().healthy();
        anyhow::ensure!(
            healthy > 0,
            "no replica became healthy within {:?}",
            boot_wait
        );
        println!(
            "warning: only {}/{} replicas healthy after {:?}; serving anyway",
            healthy, expected, boot_wait
        );
    }

    // The gateway fronts the fleet on the async tier: the same
    // `GatewayHandler` serves binary frames and, with `--http-listen`,
    // HTTP/JSON — each blocking route occupies one dispatch worker.
    let gw_listen = args.str("listen", "127.0.0.1:0");
    let gw_http = args.opt_str("http-listen");
    let server = AioServer::bind_handler(
        Some(gw_listen.as_str()),
        gw_http.as_deref(),
        gw.handler(),
        WireServerOptions {
            conn_workers: args.usize("conn-workers", 4),
            telemetry: telemetry.clone(),
            fault: fault_plan(args)?,
        },
    )?;
    println!(
        "gateway listening on {} fronting {} replica(s)",
        server.local_addr().expect("wire listener bound"),
        expected
    );
    if let Some(a) = server.http_addr() {
        println!("http listening on {}", a);
    }

    let duration = args.f64("duration-s", 0.0);
    if duration <= 0.0 {
        println!("serving until killed (pass --duration-s N for a bounded run)");
    }
    let t0 = Instant::now();
    loop {
        if duration > 0.0 && t0.elapsed() >= Duration::from_secs_f64(duration) {
            break;
        }
        if gw.rollback_fired() {
            println!("gateway: deploy rolled back under --fail-on-rollback; shutting down");
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }

    let stats = server.stats();
    server.shutdown();
    let failed = gw.rollback_fired();
    let view = gw.snapshot();
    gw.shutdown();
    println!("{}", view.render());
    println!(
        "wire: connections={} requests={} shed_presubmit={} protocol_errors={} \
         http_requests={} pipelined_conns={}",
        stats.connections,
        stats.requests,
        stats.shed_presubmit,
        stats.protocol_errors,
        stats.http_requests,
        stats.pipelined_conns
    );
    if let Some(path) = args.opt_str("metrics-out") {
        std::fs::write(&path, view.to_json().to_string_pretty())?;
        println!("wrote {}", path);
    }
    telemetry.flush();
    anyhow::ensure!(!failed, "deploy rolled back (--fail-on-rollback)");
    Ok(())
}

/// One replica row parsed out of the gateway's fleet metrics.
struct ReplicaRow {
    id: u64,
    cohort: u64,
    state: String,
    served: u64,
    restarts: u64,
}

/// Parses the `replicas` array of a gateway metrics document.
fn fleet_rows(metrics: &Json) -> Vec<ReplicaRow> {
    metrics
        .get("replicas")
        .and_then(|r| r.as_arr())
        .map(|arr| {
            arr.iter()
                .filter_map(|r| {
                    Some(ReplicaRow {
                        id: r.get("id")?.as_usize()? as u64,
                        cohort: r.get("cohort")?.as_usize()? as u64,
                        state: r.get("state")?.as_str()?.to_string(),
                        served: r.get("served")?.as_usize()? as u64,
                        restarts: r.get("restarts")?.as_usize()? as u64,
                    })
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Fetches a server's metrics document over either protocol: the wire
/// metrics op, or `GET /v1/metrics` when loadgen targets the HTTP tier
/// (the JSON body is the identical document).
fn fetch_metrics_json(addr: &str, http: bool) -> Result<Json> {
    let text = if http {
        let mut client = HttpClient::new(addr);
        let (status, body) = client.request("GET", "/v1/metrics", None)?;
        anyhow::ensure!(status == 200, "GET /v1/metrics returned {}", status);
        body
    } else {
        WireClient::connect(addr)?.metrics()?
    };
    Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("server sent unparseable metrics JSON: {:?}", e))
}

/// Open-loop wire load generator: Poisson arrivals at `--rate` req/s
/// split across `--concurrency` connections, each request carrying the
/// `--deadline-ms` budget. Latency percentiles plus shed/error counts
/// are printed and written as JSON to `--out` (the `BENCH_wire_serve`
/// artifact). `--target gateway` adds per-replica fleet rows (the
/// `BENCH_fleet` artifact).
fn cmd_loadgen(args: &Args) -> Result<()> {
    let addr = args.str("addr", "127.0.0.1:7411");
    let rate = args.f64("rate", 500.0);
    anyhow::ensure!(rate > 0.0, "--rate must be positive");
    let concurrency = args.usize("concurrency", 4).max(1);
    let deadline_ms = args.usize("deadline-ms", 0) as u32;
    // --proto http drives the async tier's HTTP/JSON endpoints with the
    // same Poisson arrival core; --addr then names the HTTP listener.
    let proto_http = match args.str("proto", "binary").as_str() {
        "http" => true,
        "binary" => false,
        other => anyhow::bail!("unknown --proto '{}' (binary|http)", other),
    };
    // --target gateway: also snapshot the gateway's fleet metrics before
    // and after the run, emitting per-replica throughput rows.
    let target_kind = args.str("target", "server");
    let gateway_target = match target_kind.as_str() {
        "gateway" => true,
        "server" => false,
        other => anyhow::bail!("unknown --target '{}' (server|gateway)", other),
    };
    // Artifacts land in --bench-dir (default $STRUM_BENCH_DIR, else .),
    // never unconditionally in the CWD.
    let dir = match args.opt_str("bench-dir") {
        Some(d) => {
            std::fs::create_dir_all(&d)?;
            PathBuf::from(d)
        }
        None => bench_dir(),
    };
    let default_out = if gateway_target {
        "BENCH_fleet.json"
    } else {
        "BENCH_wire_serve.json"
    };
    let out = dir.join(args.str("out", default_out));
    let seed = args.usize("seed", 7) as u64;
    // --trace HEX: request i carries trace id HEX+i on the wire, so any
    // single request's waterfall is addressable in `strum tail --trace`.
    let trace_base: Option<u64> = match args.opt_str("trace") {
        Some(s) => Some(
            parse_trace(&s).ok_or_else(|| anyhow::anyhow!("bad --trace '{}' (want hex)", s))?,
        ),
        None => None,
    };

    // Discover the fleet from the server's metrics op: variant keys and
    // the image geometry each expects.
    let metrics = fetch_metrics_json(&addr, proto_http)?;
    let discovered: Vec<(String, usize)> = metrics
        .get("variants")
        .and_then(|v| v.as_arr())
        .map(|arr| {
            arr.iter()
                .filter_map(|v| {
                    let key = v.get("key")?.as_str()?.to_string();
                    let img = v.get("img")?.as_usize()?;
                    Some((key, img))
                })
                .collect()
        })
        .unwrap_or_default();
    let targets: Vec<(String, usize)> = match args.opt_str("variants") {
        Some(list) => {
            let fallback_img = args.usize("img", 16);
            list.split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(|k| {
                    let img = discovered
                        .iter()
                        .find(|(dk, _)| dk == k)
                        .map(|(_, i)| *i)
                        .unwrap_or(fallback_img);
                    (k.to_string(), img)
                })
                .collect()
        }
        None => discovered,
    };
    anyhow::ensure!(
        !targets.is_empty(),
        "no variants to target (server reported none; pass --variants)"
    );
    if gateway_target {
        anyhow::ensure!(
            metrics.get("gateway").and_then(|g| g.as_bool()).unwrap_or(false),
            "--target gateway, but {} does not report gateway metrics",
            addr
        );
    }
    // Pre-run per-replica served counts, for throughput deltas.
    let pre_fleet: Vec<ReplicaRow> = if gateway_target {
        fleet_rows(&metrics)
    } else {
        Vec::new()
    };

    // --connections N: hold N extra *idle* sockets open across the whole
    // run and assert every one survives it — the async tier's poller
    // must carry them for free (no thread, no wakeups). Sized runs need
    // a raised fd limit (`ulimit -n`), which is why the dial error
    // mentions it.
    let idle_target = args.usize("connections", 0);
    let mut idle_conns: Vec<std::net::TcpStream> = Vec::with_capacity(idle_target);
    for i in 0..idle_target {
        let s = std::net::TcpStream::connect(&addr).map_err(|e| {
            anyhow::anyhow!(
                "idle connection {}/{} failed: {} (raise `ulimit -n`?)",
                i + 1,
                idle_target,
                e
            )
        })?;
        s.set_nonblocking(true)?;
        idle_conns.push(s);
    }
    if idle_target > 0 {
        println!(
            "soak: {} idle connection(s) held open through the run",
            idle_target
        );
    }

    // The open-loop arrival schedule: requests fire at their scheduled
    // instants regardless of how fast earlier ones complete (within each
    // connection's request/response ordering).
    let mut rng = Rng::new(seed);
    let mut at = 0.0f64;
    let arrivals: Vec<f64> = match args.opt_str("duration-s").and_then(|s| s.parse::<f64>().ok())
    {
        Some(d) if d > 0.0 => {
            let mut v = Vec::new();
            loop {
                at += rng.exponential(rate);
                if at >= d {
                    break;
                }
                if v.len() >= 1_000_000 {
                    println!(
                        "note: arrival schedule capped at 1,000,000 requests \
                         ({:.1}s of the requested {:.1}s)",
                        at, d
                    );
                    break;
                }
                v.push(at);
            }
            v
        }
        _ => {
            let n = args.usize("requests", 500);
            (0..n)
                .map(|_| {
                    at += rng.exponential(rate);
                    at
                })
                .collect()
        }
    };
    let n = arrivals.len();
    anyhow::ensure!(n > 0, "no requests scheduled");
    println!(
        "wire loadgen: {} request(s) to {} across {} variant(s), {:.0} req/s target, \
         concurrency {}, deadline {} ms",
        n,
        addr,
        targets.len(),
        rate,
        concurrency,
        deadline_ms
    );
    if let Some(base) = trace_base {
        println!(
            "tracing: ids {}..{} (base + request index)",
            fmt_trace(base),
            fmt_trace(base.wrapping_add(n as u64 - 1))
        );
    }

    #[derive(Default)]
    struct Outcome {
        lat_us: Vec<f64>,
        completed: usize,
        shed: usize,
        errors: usize,
        transport: usize,
        per_code: std::collections::BTreeMap<String, usize>,
    }

    /// One worker's connection, either protocol.
    enum LoadConn {
        Bin(WireClient),
        Http(HttpClient),
    }

    /// One request's classified outcome, protocol-independent.
    enum Verdict {
        Done,
        Refused { name: String, shed: bool },
        Transport,
    }

    let t0 = Instant::now();
    let outcomes: Vec<Outcome> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for ti in 0..concurrency {
            let arrivals = &arrivals;
            let targets = &targets;
            let addr = addr.clone();
            let mut rng = Rng::new(seed ^ (0x9E3779B9 + ti as u64));
            joins.push(scope.spawn(move || {
                let mut client = if proto_http {
                    LoadConn::Http(HttpClient::new(addr))
                } else {
                    LoadConn::Bin(WireClient::new(addr))
                };
                let mut out = Outcome::default();
                let mut idx = ti;
                while idx < arrivals.len() {
                    let (key, img) = &targets[idx % targets.len()];
                    let px = img * img * 3;
                    let image: Vec<f32> = (0..px).map(|_| rng.f32()).collect();
                    let target_t = t0 + Duration::from_secs_f64(arrivals[idx]);
                    if let Some(wait) = target_t.checked_duration_since(Instant::now()) {
                        std::thread::sleep(wait);
                    }
                    let sent = Instant::now();
                    let trace_id = trace_base.map(|b| b.wrapping_add(idx as u64));
                    let verdict = match &mut client {
                        LoadConn::Bin(c) => {
                            let ctx = trace_id.map(|t| TraceCtx {
                                trace_id: t,
                                attempt: 0,
                            });
                            match c.infer_traced(key, &image, deadline_ms, ctx) {
                                Ok(WireResponse::Infer(_)) => Verdict::Done,
                                Ok(WireResponse::Error { code, .. }) => Verdict::Refused {
                                    name: code.name().to_string(),
                                    shed: code.is_shed(),
                                },
                                Err(_) => Verdict::Transport,
                            }
                        }
                        LoadConn::Http(c) => match c.infer_traced(key, &image, deadline_ms, trace_id)
                        {
                            Ok((200, _)) => Verdict::Done,
                            Ok((_, body)) => {
                                // Non-200 bodies carry the typed error
                                // name; classify sheds exactly like the
                                // binary path does with is_shed().
                                let name = Json::parse(&body)
                                    .ok()
                                    .and_then(|j| {
                                        j.get("error")
                                            .and_then(|e| e.as_str())
                                            .map(str::to_string)
                                    })
                                    .unwrap_or_else(|| "http_error".to_string());
                                let shed = matches!(
                                    name.as_str(),
                                    "expired" | "shed" | "deadline_expired"
                                );
                                Verdict::Refused { name, shed }
                            }
                            Err(_) => Verdict::Transport,
                        },
                    };
                    match verdict {
                        Verdict::Done => {
                            out.completed += 1;
                            out.lat_us.push(sent.elapsed().as_secs_f64() * 1e6);
                        }
                        Verdict::Refused { name, shed } => {
                            *out.per_code.entry(name).or_insert(0) += 1;
                            if shed {
                                out.shed += 1;
                            } else {
                                out.errors += 1;
                            }
                        }
                        Verdict::Transport => {
                            out.transport += 1;
                            out.errors += 1;
                        }
                    }
                    idx += concurrency;
                }
                out
            }));
        }
        joins
            .into_iter()
            .map(|j| j.join().expect("loadgen worker panicked"))
            .collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    // Soak verdict: an idle socket that is still open blocks on peek
    // (WouldBlock); EOF or reset means the server dropped it under load.
    let idle_alive = idle_conns
        .iter()
        .filter(|s| {
            let mut b = [0u8; 1];
            matches!(s.peek(&mut b), Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock)
        })
        .count();
    if idle_target > 0 {
        println!(
            "soak: {}/{} idle connection(s) survived the run",
            idle_alive, idle_target
        );
    }

    let mut lat = Summary::new();
    let (mut completed, mut shed, mut errors, mut transport) = (0usize, 0usize, 0usize, 0usize);
    let mut per_code: std::collections::BTreeMap<String, usize> =
        std::collections::BTreeMap::new();
    for o in &outcomes {
        completed += o.completed;
        shed += o.shed;
        errors += o.errors;
        transport += o.transport;
        for v in &o.lat_us {
            lat.push(*v);
        }
        for (k, c) in &o.per_code {
            *per_code.entry(k.clone()).or_insert(0) += c;
        }
    }
    for (code, count) in &per_code {
        println!("  {}: {}", code, count);
    }
    if transport > 0 {
        println!("  transport_errors: {}", transport);
    }
    // An all-shed run has no latency samples; report zeros, not NaN
    // (NaN is also invalid JSON).
    let pct = |q: f64| if lat.is_empty() { 0.0 } else { lat.percentile(q) };
    let lat_max = if lat.is_empty() { 0.0 } else { lat.max() };
    let lat_mean = if lat.is_empty() { 0.0 } else { lat.mean() };
    println!(
        "completed={} shed={} errors={} wall_s={:.2} thrpt={:.1} req/s \
         p50_us={:.0} p95_us={:.0} p99_us={:.0} max_us={:.0}",
        completed,
        shed,
        errors,
        wall,
        completed as f64 / wall.max(1e-9),
        pct(50.0),
        pct(95.0),
        pct(99.0),
        lat_max,
    );
    let mut json = Json::obj(vec![
        ("addr", Json::str(addr.as_str())),
        ("proto", Json::str(if proto_http { "http" } else { "binary" })),
        ("idle_connections", Json::Num(idle_target as f64)),
        ("idle_alive", Json::Num(idle_alive as f64)),
        ("requests", Json::Num(n as f64)),
        ("rate_target", Json::Num(rate)),
        ("concurrency", Json::Num(concurrency as f64)),
        ("deadline_ms", Json::Num(deadline_ms as f64)),
        ("wall_s", Json::Num(wall)),
        ("completed", Json::Num(completed as f64)),
        ("shed", Json::Num(shed as f64)),
        ("errors", Json::Num(errors as f64)),
        ("transport_errors", Json::Num(transport as f64)),
        ("throughput_rps", Json::Num(completed as f64 / wall.max(1e-9))),
        (
            "latency_us",
            Json::obj(vec![
                ("p50", Json::Num(pct(50.0))),
                ("p95", Json::Num(pct(95.0))),
                ("p99", Json::Num(pct(99.0))),
                ("max", Json::Num(lat_max)),
                ("mean", Json::Num(lat_mean)),
                ("samples", Json::Num(lat.len() as f64)),
            ]),
        ),
        (
            "codes",
            Json::Obj(
                per_code
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), Json::Num(v as f64)))
                    .collect(),
            ),
        ),
        (
            "variants",
            Json::Arr(targets.iter().map(|(k, _)| Json::str(k.as_str())).collect()),
        ),
    ]);
    if gateway_target {
        match fetch_metrics_json(&addr, proto_http) {
            Ok(post) => {
                let rows = fleet_rows(&post);
                let pre_served =
                    |id: u64| pre_fleet.iter().find(|r| r.id == id).map(|r| r.served).unwrap_or(0);
                println!("fleet (per-replica over this run):");
                let mut row_json = Vec::new();
                for r in &rows {
                    let delta = r.served.saturating_sub(pre_served(r.id));
                    let rps = delta as f64 / wall.max(1e-9);
                    println!(
                        "  replica id={} cohort={} state={} served={} thrpt={:.1} req/s restarts={}",
                        r.id, r.cohort, r.state, delta, rps, r.restarts
                    );
                    row_json.push(Json::obj(vec![
                        ("id", Json::Num(r.id as f64)),
                        ("cohort", Json::Num(r.cohort as f64)),
                        ("state", Json::str(r.state.as_str())),
                        ("served", Json::Num(delta as f64)),
                        ("throughput_rps", Json::Num(rps)),
                        ("restarts", Json::Num(r.restarts as f64)),
                    ]));
                }
                let counter = |k: &str| post.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
                let fleet_obj = Json::obj(vec![
                    ("replicas", Json::Arr(row_json)),
                    ("retries", Json::Num(counter("retries"))),
                    ("hedges", Json::Num(counter("hedges"))),
                    ("hedge_wins", Json::Num(counter("hedge_wins"))),
                    ("upstream_errors", Json::Num(counter("upstream_errors"))),
                    ("deploys", Json::Num(counter("deploys"))),
                    ("rollbacks", Json::Num(counter("rollbacks"))),
                    ("active_cohort", Json::Num(counter("active_cohort"))),
                ]);
                if let Json::Obj(map) = &mut json {
                    map.insert("fleet".to_string(), fleet_obj);
                }
            }
            Err(e) => println!("warning: post-run fleet metrics unavailable: {:#}", e),
        }
    }
    std::fs::write(&out, json.to_string_pretty())?;
    println!("wrote {}", out.display());

    // Emit the run manifest beside the payload so `strum bench-diff` can
    // pair and checksum-verify this run against another.
    let stem = out
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("wire_serve")
        .to_string();
    let mut manifest = RunManifest::capture(&strum_dpu::telemetry::fresh_run_id());
    manifest.add_payload(&stem, &out)?;
    let manifest_path = dir.join(format!("MANIFEST_{}.json", stem));
    manifest.save(&manifest_path)?;
    println!("wrote {}", manifest_path.display());
    // The soak assertion fires after the artifacts are written, so a
    // failed run still leaves its evidence on disk.
    anyhow::ensure!(
        idle_alive == idle_target,
        "idle-connection soak failed: only {}/{} connections survived",
        idle_alive,
        idle_target
    );
    Ok(())
}

/// Pairs two run manifests, verifies their FNV-1a checksums, and diffs
/// every shared numeric metric with direction-aware thresholds. Exits
/// nonzero (via the returned error) on regression or integrity failure,
/// which is what the CI bench gate keys off.
/// Resolves one `--history` argument to manifest paths: a file is taken
/// as-is, a directory contributes every `MANIFEST_*.json` inside it.
fn manifests_under(arg: &str) -> Result<Vec<PathBuf>> {
    let path = PathBuf::from(arg);
    if !path.is_dir() {
        return Ok(vec![path]);
    }
    let mut found: Vec<PathBuf> = std::fs::read_dir(&path)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("MANIFEST_") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    found.sort();
    anyhow::ensure!(!found.is_empty(), "no MANIFEST_*.json under {}", arg);
    Ok(found)
}

fn cmd_bench_diff(args: &Args) -> Result<()> {
    // The flag parser reads `--history DIR1 DIR2` as history=DIR1 with
    // DIR2 positional, so the option value (when not a bare boolean) is
    // the first run and the positionals are the rest.
    let history_val = args.opt_str("history");
    if args.flag("history") || history_val.is_some() {
        let mut raw: Vec<String> = Vec::new();
        if let Some(v) = history_val {
            if !matches!(v.as_str(), "true" | "1" | "yes") {
                raw.push(v);
            }
        }
        raw.extend(args.positional.iter().cloned());
        let mut paths: Vec<PathBuf> = Vec::new();
        for arg in &raw {
            paths.extend(manifests_under(arg)?);
        }
        let report = history_manifests(&paths)?;
        print!("{}", render_history(&report));
        anyhow::ensure!(
            report.checksum_failures.is_empty(),
            "bench-diff --history: {} integrity failure(s)",
            report.checksum_failures.len()
        );
        return Ok(());
    }
    let base = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: strum bench-diff BASE NEW [--threshold-pct N]"))?;
    let new = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: strum bench-diff BASE NEW [--threshold-pct N]"))?;
    let threshold = args.f64("threshold-pct", 10.0);
    let report = diff_manifests(
        std::path::Path::new(base),
        std::path::Path::new(new),
        threshold,
    )?;
    println!("{}", render_table(&report, threshold));
    anyhow::ensure!(
        !report.failed(),
        "bench-diff: {} regression(s) past {:.1}% and {} integrity failure(s)",
        report.regressions().count(),
        threshold,
        report.checksum_failures.len()
    );
    Ok(())
}

fn cmd_tail(args: &Args) -> Result<()> {
    let dir = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: strum tail DIR [--run-id R] [--trace HEX] [--event TAG] [--variant K] [--rates [--window-s N]] [--limit N]"))?;
    let trace = match args.opt_str("trace") {
        Some(s) => Some(
            parse_trace(&s).ok_or_else(|| anyhow::anyhow!("bad --trace '{}' (want hex)", s))?,
        ),
        None => None,
    };
    let filter = TailFilter {
        run_id: args.opt_str("run-id"),
        trace,
        event: args.opt_str("event"),
        variant: args.opt_str("variant"),
    };
    let scan = scan_dir(std::path::Path::new(dir), &filter)?;
    anyhow::ensure!(
        scan.files > 0,
        "no telemetry-*.jsonl segments under {}",
        dir
    );
    if let Some(t) = trace {
        print!("{}", render_waterfall(&scan.lines, t));
    } else if args.flag("rates") {
        let window_s = args.usize("window-s", 1) as u64;
        print!("{}", render_rates(&scan.lines, window_s));
    } else {
        let limit = args.usize("limit", 0);
        let start = if limit > 0 && scan.lines.len() > limit {
            scan.lines.len() - limit
        } else {
            0
        };
        for l in &scan.lines[start..] {
            let mut row = format!("{:>13}  {:<18}", l.ts_ms, l.tag);
            if let Some(k) = &l.key {
                row.push_str(&format!("  key={}", k));
            }
            if let Some(t) = l.trace {
                row.push_str(&format!("  trace={}", fmt_trace(t)));
            }
            if let Some(s) = &l.stage {
                row.push_str(&format!("  stage={}  attempt={}", s, l.attempt));
                if l.dur_us > 0 {
                    row.push_str(&format!("  dur_us={}", l.dur_us));
                }
                if l.abandoned {
                    row.push_str("  abandoned");
                }
                if let Some(d) = &l.detail {
                    row.push_str(&format!("  detail={}", d));
                }
            }
            println!("{}", row);
        }
    }
    eprintln!(
        "tail: {} file(s), {} line(s) scanned, {} matched, {} invalid",
        scan.files,
        scan.total_lines,
        scan.lines.len(),
        scan.invalid_lines
    );
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    // Integer StruM microkernel: rust-side decomposition vs HLO result.
    let exe = rt.load_hlo(&dir.join("hlo/strum_matmul_int.hlo.txt"))?;
    let (m, k, n) = (64usize, 256usize, 64usize);
    let mut rng = Rng::new(1);
    let x: Vec<i32> = (0..m * k).map(|_| rng.range(0, 255) as i32 - 127).collect();
    let hi: Vec<i32> = (0..k * n)
        .map(|_| if rng.chance(0.5) { rng.range(0, 255) as i32 - 127 } else { 0 })
        .collect();
    let lo: Vec<i32> = hi
        .iter()
        .map(|&h| {
            if h == 0 {
                let s = if rng.chance(0.5) { -1 } else { 1 };
                s * (1 << rng.range(0, 8))
            } else {
                0
            }
        })
        .collect();
    let out = exe.run_i32(&[
        strum_dpu::runtime::Tensor::i32(x.clone(), &[m, k]),
        strum_dpu::runtime::Tensor::i32(hi.clone(), &[k, n]),
        strum_dpu::runtime::Tensor::i32(lo.clone(), &[k, n]),
    ])?;
    // Host reference.
    let mut expect = vec![0i64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let xv = x[i * k + kk] as i64;
            for j in 0..n {
                expect[i * n + j] += xv * (hi[kk * n + j] + lo[kk * n + j]) as i64;
            }
        }
    }
    for (a, b) in out[0].iter().zip(expect.iter()) {
        anyhow::ensure!(*a as i64 == *b, "kernel mismatch: {} vs {}", a, b);
    }
    println!("strum_matmul_int HLO matches host reference bit-for-bit ({}x{}x{})", m, k, n);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_spec_parses_weights() {
        let (m, p, w) = parse_variant_spec("base:4").unwrap();
        assert_eq!(m, Method::Baseline);
        assert_eq!(p, 0.0);
        assert_eq!(w, 4);
        let (m, p, w) = parse_variant_spec("mip2q-L5@0.25:2").unwrap();
        assert_eq!(m, Method::Mip2q { l_max: 5 });
        assert_eq!(p, 0.25);
        assert_eq!(w, 2);
        // No weight suffix keeps the engine default (0).
        let (m, p, w) = parse_variant_spec("dliq").unwrap();
        assert_eq!(m, Method::Dliq { q: 4 });
        assert_eq!((p, w), (0.5, 0));
        let (_, p, w) = parse_variant_spec("mip2q@0.75").unwrap();
        assert_eq!((p, w), (0.75, 0));
    }

    #[test]
    fn variant_spec_rejects_bad_weights() {
        assert!(parse_variant_spec("base:0").is_err());
        assert!(parse_variant_spec("base:x").is_err());
        assert!(parse_variant_spec("base:-1").is_err());
        assert!(parse_variant_spec("nonsense").is_err());
    }
}
