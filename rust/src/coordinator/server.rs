//! The serving loop: request intake → dynamic batcher → backend workers.
//!
//! One batcher thread owns the queue and applies [`BatchPolicy`]; worker
//! threads execute flushed batches on the variant's [`crate::backend::Backend`]
//! (PJRT executables or the native integer engine) and send per-request
//! replies. `Coordinator::submit` is the client API (used by `strum
//! serve`, `examples/serve_infer.rs`, and the integration tests); it
//! validates the image size up front so a malformed request gets an error
//! reply instead of silently truncated/zero-padded pixels.

use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use super::router::Variant;
use crate::runtime::executable::argmax_rows;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Reply to one inference request.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub class: usize,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Batch the request rode in (occupancy, padded size).
    pub batch: (usize, usize),
}

struct Request {
    image: Vec<f32>,
    tx: mpsc::Sender<crate::Result<InferReply>>,
    enqueued: Instant,
}

/// Coordinator tunables.
#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    pub max_wait: Duration,
    pub workers: usize,
    /// Cap the dynamic batch (None = variant's largest executable).
    pub max_batch: Option<usize>,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            max_wait: Duration::from_millis(4),
            workers: 2,
            max_batch: None,
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<Request>>,
    cv: Condvar,
    stop: AtomicBool,
    metrics: Metrics,
}

/// A running inference service for one variant.
pub struct Coordinator {
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
    pub variant: Arc<Variant>,
    started: Instant,
}

impl Coordinator {
    pub fn start(variant: Arc<Variant>, opts: CoordinatorOptions) -> Coordinator {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            metrics: Metrics::default(),
        });
        let policy = BatchPolicy {
            // Never flush more than the backend's largest batch shape —
            // a user-set cap above it would overflow the padded buffer.
            max_batch: opts
                .max_batch
                .unwrap_or(usize::MAX)
                .min(variant.max_batch()),
            max_wait: opts.max_wait,
        };
        // Worker pool consumes flushed batches.
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let mut threads = Vec::new();
        for _ in 0..opts.workers.max(1) {
            let rx = batch_rx.clone();
            let v = variant.clone();
            let sh = shared.clone();
            threads.push(std::thread::spawn(move || loop {
                let batch = {
                    let guard = rx.lock().unwrap();
                    match guard.recv_timeout(Duration::from_millis(50)) {
                        Ok(b) => b,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if sh.stop.load(Ordering::Relaxed) {
                                return;
                            }
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => return,
                    }
                };
                execute_batch(&v, &sh, batch);
            }));
        }
        // Batcher thread owns the queue.
        {
            let sh = shared.clone();
            let v = variant.clone();
            threads.push(std::thread::spawn(move || loop {
                let mut q = sh.queue.lock().unwrap();
                loop {
                    if sh.stop.load(Ordering::Relaxed) && q.is_empty() {
                        return;
                    }
                    let now = Instant::now();
                    let oldest = q.front().map(|r| r.enqueued);
                    let take = policy.decide(q.len(), oldest, now);
                    if take > 0 {
                        let batch: Vec<Request> = q.drain(..take).collect();
                        drop(q);
                        let _ = batch_tx.send(batch);
                        let _ = v; // variant kept alive for the policy's lifetime
                        break;
                    }
                    let nap = policy.nap(oldest, now);
                    let (guard, _) = sh.cv.wait_timeout(q, nap.max(Duration::from_micros(200))).unwrap();
                    q = guard;
                }
            }));
        }
        Coordinator {
            shared,
            threads,
            variant,
            started: Instant::now(),
        }
    }

    /// Submits one image; returns the reply channel. Requests whose image
    /// is not exactly `img·img·3` floats are rejected with an error reply
    /// instead of being silently truncated or zero-padded downstream.
    pub fn submit(&self, image: Vec<f32>) -> mpsc::Receiver<crate::Result<InferReply>> {
        let (tx, rx) = mpsc::channel();
        let px = self.variant.image_len();
        if image.len() != px {
            let _ = tx.send(Err(anyhow::anyhow!(
                "image has {} floats, expected {} ({}x{}x3) for variant {}",
                image.len(),
                px,
                self.variant.img,
                self.variant.img,
                self.variant.key
            )));
            return rx;
        }
        self.shared.metrics.record_request();
        self.shared.queue.lock().unwrap().push_back(Request {
            image,
            tx,
            enqueued: Instant::now(),
        });
        self.shared.cv.notify_all();
        rx
    }

    pub fn metrics_report(&self) -> String {
        self.shared.metrics.report(self.started.elapsed())
    }

    pub fn latency_summary(&self) -> crate::util::stats::Summary {
        self.shared.metrics.latency_summary()
    }

    /// Stops the service after draining the queue.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn execute_batch(v: &Variant, sh: &Shared, batch: Vec<Request>) {
    let n = batch.len();
    let bsz = v.pick_batch(n);
    sh.metrics.record_batch(n, bsz);
    let px = v.image_len();
    let mut images = vec![0f32; bsz * px];
    for (i, r) in batch.iter().enumerate() {
        // Sizes are validated at submit; a mismatch here is a bug.
        debug_assert_eq!(r.image.len(), px);
        images[i * px..(i + 1) * px].copy_from_slice(&r.image);
    }
    match v.backend.infer_batch(images, bsz) {
        Ok(logits) => {
            let preds = argmax_rows(&logits, v.classes);
            for (i, r) in batch.into_iter().enumerate() {
                let latency = r.enqueued.elapsed();
                sh.metrics.record_done(latency);
                let _ = r.tx.send(Ok(InferReply {
                    class: preds[i],
                    logits: logits[i * v.classes..(i + 1) * v.classes].to_vec(),
                    latency,
                    batch: (n, bsz),
                }));
            }
        }
        Err(e) => {
            let msg = format!("{}", e);
            for r in batch {
                let _ = r.tx.send(Err(anyhow::anyhow!("batch failed: {}", msg)));
            }
        }
    }
}
