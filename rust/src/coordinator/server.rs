//! Single-variant compatibility shim over the multi-variant [`Engine`].
//!
//! `Coordinator` predates the fleet-level engine: it served exactly one
//! variant with a dedicated batcher + worker pool. It is kept for one
//! release as a thin wrapper — `start` boots a private [`Engine`] with
//! one registered variant, `submit` forwards to the engine's handle-based
//! API (returning the typed [`Ticket`]/[`SubmitError`] pair instead of
//! the old raw `mpsc::Receiver`), and metrics come back as the typed
//! [`MetricsSnapshot`]. New code should use [`Engine`] directly and
//! register all variants on one shared pool.
//!
//! [`Ticket`]: super::engine::Ticket
//! [`SubmitError`]: super::engine::SubmitError
//! [`MetricsSnapshot`]: super::metrics::MetricsSnapshot

use super::engine::{Engine, EngineOptions, SubmitError, Ticket, VariantHandle};
use super::metrics::MetricsSnapshot;
use super::router::Variant;
use std::sync::Arc;
use std::time::Duration;

/// Coordinator tunables (single-variant subset of [`EngineOptions`]).
#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    pub max_wait: Duration,
    pub workers: usize,
    /// Cap the dynamic batch (None = variant's largest executable).
    pub max_batch: Option<usize>,
    /// Bounded queue depth; submits beyond it get
    /// [`SubmitError::QueueFull`].
    pub queue_depth: usize,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            max_wait: Duration::from_millis(4),
            workers: 2,
            max_batch: None,
            queue_depth: 1024,
        }
    }
}

/// A running single-variant inference service (shim over [`Engine`]).
pub struct Coordinator {
    engine: Engine,
    handle: VariantHandle,
    pub variant: Arc<Variant>,
}

impl Coordinator {
    pub fn start(variant: Arc<Variant>, opts: CoordinatorOptions) -> Coordinator {
        let engine = Engine::start(EngineOptions {
            workers: opts.workers,
            queue_depth: opts.queue_depth,
            max_wait: opts.max_wait,
            max_batch: opts.max_batch,
            quantum: 0,
        });
        let handle = engine
            .register(variant.clone())
            .expect("fresh engine accepts the first variant");
        Coordinator {
            engine,
            handle,
            variant,
        }
    }

    /// Submits one image; returns a [`Ticket`] or a typed refusal
    /// (`BadImage` for wrong-sized images, `QueueFull` backpressure,
    /// `ShuttingDown` after shutdown — the old API enqueued forever).
    pub fn submit(&self, image: Vec<f32>) -> Result<Ticket, SubmitError> {
        self.handle.submit(image)
    }

    /// Typed metrics snapshot (single-variant fleet).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.engine.metrics()
    }

    pub fn latency_summary(&self) -> crate::util::stats::Summary {
        self.engine.latency_summary(self.handle.key())
    }

    /// Stops the service after draining the queue.
    pub fn shutdown(self) {
        self.engine.shutdown()
    }
}
