//! Fleet-level serving engine: ONE shared worker pool serving many
//! model variants side by side.
//!
//! This is the multi-tenant redesign of the old one-coordinator-per-
//! variant layout (the single-variant `Coordinator` shim is gone —
//! register one variant on an `Engine` instead).
//! [`Engine::start`] spawns a single pool of worker
//! threads sized to the machine; [`Engine::register`] hot-adds a variant
//! (its own bounded queue + [`BatchPolicy`]) and returns a
//! [`VariantHandle`] for submission; [`Engine::retire`] drains and
//! removes a variant while the rest keep serving. Freed workers pick the
//! next flushable batch with a deficit-round-robin scheduler over the
//! per-variant queues, so a hot variant (say DLIQ under a traffic spike)
//! can saturate idle capacity but can never starve the baseline: every
//! variant with a flushable batch is granted `quantum` request-credits
//! per scheduler round and batches are cut to the credit it has banked.
//!
//! Submission is handle-based: `submit` returns a [`Ticket`] (`wait`,
//! `wait_deadline`, `try_take`) or a typed [`SubmitError`] — bounded
//! queues reject with `QueueFull` instead of buffering unboundedly,
//! malformed images bounce with `BadImage` at the door, and a
//! post-shutdown submit gets `ShuttingDown` instead of enqueueing into
//! a pool that will never drain (the old API deadlocked here).
//!
//! Requests can carry a per-request deadline
//! ([`VariantHandle::submit_deadline`]): one that has already expired is refused at the
//! door ([`SubmitError::Expired`]), and one whose deadline passes while
//! it waits in the queue is shed by the worker *before* execution — the
//! ticket resolves to a typed [`ReplyError::Shed`] instead of burning
//! backend cycles on an answer nobody is waiting for. Reply-path
//! failures are all typed ([`ReplyError`]) so callers (the wire server
//! in [`crate::server`] above all) can map them to protocol codes by
//! downcast instead of string-matching.
//!
//! Workers sleep on a condvar indefinitely while every queue is empty;
//! a bounded nap is used only when some queued request has a batching
//! deadline pending. There is no dedicated batcher thread — the workers
//! themselves run the flush policy — so serving N variants costs
//! `workers` threads total, not `N × (workers + 1)`.
//!
//! ## Observability
//!
//! The engine is instrumented with [`crate::telemetry`]: every
//! `record_done`/`record_shed`/`record_rejected` metrics update also
//! emits exactly one structured [`Event`] (so JSONL event counts
//! reconcile 1:1 with the [`MetricsSnapshot`] counters), plus batch
//! formation and variant register/retire lifecycle events, and — when
//! [`EngineOptions::telemetry_interval`] is set — periodic
//! `engine_gauges` snapshots from a dedicated ticker thread. Emission
//! is a `try_send` into the sink's bounded channel: the hot path never
//! serializes or blocks, and overflow shows up as `telemetry_dropped`
//! in the snapshot. A disabled sink (the default) costs one branch.

use super::batcher::BatchPolicy;
use super::metrics::{
    FleetSnapshot, HistogramSnapshot, Metrics, MetricsSnapshot, VariantSnapshot, WindowSnapshot,
    METRICS_SCHEMA_VERSION,
};
use super::router::Variant;
use crate::runtime::executable::argmax_rows;
use crate::telemetry::{Event, ShedStage, TelemetrySink, TraceCtx};
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Reply to one inference request.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub class: usize,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Batch the request rode in (occupancy, padded size).
    pub batch: (usize, usize),
}

/// Why a submit was refused. Every arm is a client-visible contract:
/// `QueueFull` is backpressure (retry later or shed load), `BadImage`
/// is a malformed request, `UnknownVariant` a routing miss, and
/// `ShuttingDown`/`Retired` mean the target no longer accepts work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The variant's bounded queue is at its configured depth.
    QueueFull { key: String, depth: usize },
    /// Image length is not `img · img · 3` floats for the variant.
    BadImage {
        key: String,
        expected: usize,
        got: usize,
    },
    /// No live variant is registered under this key.
    UnknownVariant { key: String },
    /// The variant is draining and no longer accepts new requests.
    Retired { key: String },
    /// The engine has been shut down.
    ShuttingDown,
    /// The request's deadline had already passed at submit time; it was
    /// shed at the door without touching the queue.
    Expired { key: String },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { key, depth } => {
                write!(f, "variant {}: queue full (depth {})", key, depth)
            }
            SubmitError::BadImage { key, expected, got } => write!(
                f,
                "variant {}: image has {} floats, expected {}",
                key, got, expected
            ),
            SubmitError::UnknownVariant { key } => write!(f, "unknown variant {}", key),
            SubmitError::Retired { key } => write!(f, "variant {} is retired", key),
            SubmitError::ShuttingDown => write!(f, "engine is shutting down"),
            SubmitError::Expired { key } => {
                write!(f, "variant {}: deadline already expired at submit (shed)", key)
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Typed reply-path failures delivered through a [`Ticket`]. Every arm
/// is a contract the wire server maps to a protocol error code:
/// `Shed` means the engine dropped the request before execution because
/// its deadline had passed, `DeadlineExpired` means the *wait* gave up
/// (the request may still complete — [`Ticket::try_take`] can collect a
/// late result), `Dropped` means the engine went away mid-request, and
/// `Batch` carries a backend execution failure. Obtained from an
/// `anyhow` error via `err.downcast_ref::<ReplyError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplyError {
    /// Shed before execution: the deadline passed while queued.
    Shed,
    /// `wait_deadline` timed out; the request itself may still finish.
    DeadlineExpired,
    /// The serving engine dropped the request (shutdown race).
    Dropped,
    /// The backend failed the whole batch.
    Batch(String),
}

impl fmt::Display for ReplyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplyError::Shed => write!(f, "request shed: deadline passed before execution"),
            ReplyError::DeadlineExpired => {
                write!(f, "no reply within the wait deadline")
            }
            ReplyError::Dropped => write!(f, "serving engine dropped the request"),
            ReplyError::Batch(msg) => write!(f, "batch failed: {}", msg),
        }
    }
}

impl std::error::Error for ReplyError {}

/// Handle to one in-flight request.
pub struct Ticket {
    rx: mpsc::Receiver<crate::Result<InferReply>>,
}

impl Ticket {
    /// Blocks until the reply arrives.
    pub fn wait(self) -> crate::Result<InferReply> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ReplyError::Dropped.into()),
        }
    }

    /// Blocks at most `d`; a timeout is a typed
    /// [`ReplyError::DeadlineExpired`]. The request may still complete —
    /// the ticket is only borrowed, so a later [`Ticket::try_take`] can
    /// still collect the late reply.
    pub fn wait_deadline(&self, d: Duration) -> crate::Result<InferReply> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ReplyError::DeadlineExpired.into()),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ReplyError::Dropped.into()),
        }
    }

    /// Non-blocking poll: `None` while the request is still in flight.
    pub fn try_take(&self) -> Option<crate::Result<InferReply>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(ReplyError::Dropped.into())),
        }
    }
}

/// Completion callback for [`Engine::submit_callback`]: invoked exactly
/// once, on an engine worker thread, when the request resolves. Keep it
/// cheap (enqueue + wake) — it runs inside the batch-completion path.
pub type ReplyCallback = Box<dyn FnOnce(crate::Result<InferReply>) + Send + 'static>;

/// Where a request's reply goes: a channel behind a [`Ticket`] (the
/// blocking API) or a one-shot completion callback (the async wire tier,
/// which must never park a thread per in-flight request).
enum ReplyTo {
    Channel(mpsc::Sender<crate::Result<InferReply>>),
    Callback(Mutex<Option<ReplyCallback>>),
}

impl ReplyTo {
    fn callback(cb: ReplyCallback) -> ReplyTo {
        ReplyTo::Callback(Mutex::new(Some(cb)))
    }

    /// Delivers the reply. At most one delivery wins; a second send (or
    /// a send to a dropped ticket) is a no-op.
    fn send(&self, r: crate::Result<InferReply>) {
        match self {
            ReplyTo::Channel(tx) => {
                let _ = tx.send(r);
            }
            ReplyTo::Callback(cb) => {
                if let Some(f) = cb.lock().unwrap().take() {
                    f(r);
                }
            }
        }
    }
}

/// Engine tunables. `workers == 0` sizes the pool to the machine.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Shared worker pool size (0 = available cores).
    pub workers: usize,
    /// Per-variant bounded queue depth; submits beyond it get
    /// [`SubmitError::QueueFull`].
    pub queue_depth: usize,
    /// Default batching deadline for registered variants.
    pub max_wait: Duration,
    /// Default batch cap (None = variant's largest executable).
    pub max_batch: Option<usize>,
    /// Deficit-round-robin quantum in requests per scheduler round
    /// (0 = the variant's max batch, i.e. plain batch-granted RR).
    pub quantum: usize,
    /// Structured-event sink ([`TelemetrySink::disabled`] = no-op).
    pub telemetry: TelemetrySink,
    /// Period of the `engine_gauges` ticker; `None` disables it even
    /// when the sink is live.
    pub telemetry_interval: Option<Duration>,
    /// Pin worker `i` to core `i % cores`
    /// ([`crate::util::affinity::pin_current_thread`]). Best-effort:
    /// platforms without `sched_setaffinity` run unpinned, identically.
    pub pin_workers: bool,
    /// Per-layer profiling sample rate for traced requests: a traced
    /// request is profiled iff `trace_sample > 0 && trace_id %
    /// trace_sample == 0` (deterministic, so tests and `strum tail` can
    /// predict which ids carry layer spans). `0` disables layer
    /// profiling entirely; stage spans still flow for every traced
    /// request. The untraced hot path costs one branch + two reads.
    pub trace_sample: u32,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            workers: 2,
            queue_depth: 1024,
            max_wait: Duration::from_millis(4),
            max_batch: None,
            quantum: 0,
            telemetry: TelemetrySink::disabled(),
            telemetry_interval: None,
            pin_workers: false,
            trace_sample: 0,
        }
    }
}

struct Request {
    image: Vec<f32>,
    reply: ReplyTo,
    enqueued: Instant,
    /// Shed (typed `ReplyError::Shed`) instead of executed if still
    /// queued past this instant.
    deadline: Option<Instant>,
    /// Trace context when the caller requested tracing (`None` on the
    /// untraced hot path — no span events are ever constructed then).
    trace: Option<TraceCtx>,
}

/// One registered variant: queue + policy + metrics + DRR credit.
struct Slot {
    variant: Arc<Variant>,
    /// The variant key as a shared `Arc<str>` so per-request telemetry
    /// events clone a pointer, not a heap string.
    key_arc: Arc<str>,
    policy: BatchPolicy,
    depth: usize,
    quantum: usize,
    deficit: usize,
    queue: VecDeque<Request>,
    metrics: Arc<Metrics>,
    /// Batches of this variant currently executing on workers.
    inflight: Arc<AtomicUsize>,
    retiring: bool,
    registered: Instant,
}

struct EngineState {
    slots: Vec<Slot>,
    /// DRR position: index of the slot whose turn comes next.
    cursor: usize,
    stopping: bool,
}

/// Fleet totals + merged latency histogram observed at the previous
/// [`snapshot_of`] call — the baseline `MetricsSnapshot.window` deltas
/// are computed against. The first window spans boot → first snapshot.
struct WindowBase {
    at: Instant,
    completed: u64,
    shed: u64,
    rejected: u64,
    hist: HistogramSnapshot,
}

struct EngineShared {
    state: Mutex<EngineState>,
    cv: Condvar,
    started: Instant,
    workers: usize,
    telemetry: TelemetrySink,
    trace_sample: u32,
    window_base: Mutex<WindowBase>,
}

/// A batch a worker pulled off a variant queue.
struct Job {
    variant: Arc<Variant>,
    key_arc: Arc<str>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicUsize>,
    batch: Vec<Request>,
}

/// Submission handle for one registered variant. Cheap to clone; remains
/// valid (returning typed errors) after the variant is retired or the
/// engine shut down.
#[derive(Clone)]
pub struct VariantHandle {
    key: String,
    shared: Arc<EngineShared>,
}

impl VariantHandle {
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Submits one image to this variant.
    pub fn submit(&self, image: Vec<f32>) -> Result<Ticket, SubmitError> {
        submit_shared(&self.shared, &self.key, image, None, None)
    }

    /// Submits one image with a per-request deadline. An already-expired
    /// deadline is refused at the door ([`SubmitError::Expired`]); one
    /// that expires while the request is queued sheds the request before
    /// execution (the ticket resolves to [`ReplyError::Shed`]).
    pub fn submit_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Ticket, SubmitError> {
        submit_shared(&self.shared, &self.key, image, deadline, None)
    }

    /// [`VariantHandle::submit_deadline`] plus a trace context: the
    /// request's stage spans are emitted through the engine's telemetry
    /// sink under `trace` (see [`crate::telemetry::SPAN_STAGES`]).
    pub fn submit_traced(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
        trace: Option<TraceCtx>,
    ) -> Result<Ticket, SubmitError> {
        submit_shared(&self.shared, &self.key, image, deadline, trace)
    }
}

/// The multi-variant serving engine: one shared worker pool, per-variant
/// bounded queues, deficit-round-robin batch scheduling.
pub struct Engine {
    shared: Arc<EngineShared>,
    threads: Vec<std::thread::JoinHandle<()>>,
    defaults: EngineOptions,
}

impl Engine {
    /// Starts the shared worker pool (no variants yet).
    pub fn start(opts: EngineOptions) -> Engine {
        let workers = if opts.workers == 0 {
            crate::util::pool::num_threads()
        } else {
            opts.workers
        };
        let shared = Arc::new(EngineShared {
            state: Mutex::new(EngineState {
                slots: Vec::new(),
                cursor: 0,
                stopping: false,
            }),
            cv: Condvar::new(),
            started: Instant::now(),
            workers,
            telemetry: opts.telemetry.clone(),
            trace_sample: opts.trace_sample,
            window_base: Mutex::new(WindowBase {
                at: Instant::now(),
                completed: 0,
                shed: 0,
                rejected: 0,
                hist: HistogramSnapshot::default(),
            }),
        });
        let defaults = EngineOptions { workers, ..opts };
        let mut threads = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = shared.clone();
            let pin = defaults.pin_workers;
            threads.push(std::thread::spawn(move || {
                if pin {
                    crate::util::affinity::pin_current_thread(i);
                }
                worker_loop(&sh)
            }));
        }
        // Gauge ticker: periodic engine_gauges snapshots through the
        // same sink. Terminates with the pool via `stopping` + condvar.
        if shared.telemetry.is_enabled() {
            if let Some(period) = defaults.telemetry_interval {
                let sh = shared.clone();
                threads.push(std::thread::spawn(move || gauge_ticker(&sh, period)));
            }
        }
        Engine {
            shared,
            threads,
            defaults,
        }
    }

    /// Registers `variant` with the engine-default policy.
    pub fn register(&self, variant: Arc<Variant>) -> crate::Result<VariantHandle> {
        self.register_weight(variant, 0)
    }

    /// Registers `variant` with an explicit policy and queue depth —
    /// hot-add: the shared pool starts serving it immediately. The
    /// policy's `max_batch` is clamped to the backend's largest batch
    /// shape (a cap above it would overflow the padded batch buffer)
    /// and floored at 1 (a zero cap could never flush).
    pub fn register_with(
        &self,
        variant: Arc<Variant>,
        policy: BatchPolicy,
        queue_depth: usize,
    ) -> crate::Result<VariantHandle> {
        self.register_weighted(variant, policy, queue_depth, 0)
    }

    /// Registers `variant` with the engine-default policy and an explicit
    /// DRR priority weight (see [`Engine::register_weighted`]).
    pub fn register_weight(
        &self,
        variant: Arc<Variant>,
        weight: usize,
    ) -> crate::Result<VariantHandle> {
        let d = self.defaults();
        let policy = BatchPolicy {
            max_batch: d.max_batch.unwrap_or(usize::MAX),
            max_wait: d.max_wait,
        };
        self.register_weighted(variant, policy, d.queue_depth, weight)
    }

    /// Full-control registration: explicit policy, queue depth, and DRR
    /// priority `weight` — the variant's per-round scheduler credit in
    /// requests. `weight == 0` falls back to [`EngineOptions::quantum`]
    /// (and from there to the variant's max batch), so unweighted
    /// variants keep the plain round-robin behaviour. A variant with
    /// weight 4 next to one with weight 1 drains roughly 4 requests for
    /// every 1 under contention, without ever starving the lighter one.
    pub fn register_weighted(
        &self,
        variant: Arc<Variant>,
        policy: BatchPolicy,
        queue_depth: usize,
        weight: usize,
    ) -> crate::Result<VariantHandle> {
        let d = self.defaults();
        let policy = BatchPolicy {
            max_batch: policy.max_batch.min(variant.max_batch()).max(1),
            max_wait: policy.max_wait,
        };
        let quantum = if weight > 0 {
            weight
        } else if d.quantum == 0 {
            policy.max_batch
        } else {
            d.quantum
        };
        let key = variant.key.clone();
        let key_arc: Arc<str> = Arc::from(key.as_str());
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.stopping {
                anyhow::bail!("engine is shutting down");
            }
            if st.slots.iter().any(|s| s.variant.key == key) {
                anyhow::bail!("variant {} is already registered", key);
            }
            self.shared.telemetry.emit(Event::VariantRegistered {
                key: key_arc.clone(),
                net: variant.net.clone(),
                backend: variant.backend.kind().name().to_string(),
            });
            st.slots.push(Slot {
                variant,
                key_arc,
                policy,
                depth: queue_depth.max(1),
                quantum,
                deficit: 0,
                queue: VecDeque::new(),
                metrics: Arc::new(Metrics::default()),
                inflight: Arc::new(AtomicUsize::new(0)),
                retiring: false,
                registered: Instant::now(),
            });
        }
        Ok(VariantHandle {
            key,
            shared: self.shared.clone(),
        })
    }

    /// Drains and removes a variant: already-queued requests are still
    /// served (deadline waived so the drain is prompt), new submits get
    /// [`SubmitError::Retired`], and once the queue is empty and no batch
    /// is in flight the slot is dropped. Blocks until the drain finishes.
    pub fn retire(&self, key: &str) -> crate::Result<()> {
        {
            let mut st = self.shared.state.lock().unwrap();
            let slot = st
                .slots
                .iter_mut()
                .find(|s| s.variant.key == key)
                .ok_or_else(|| anyhow::anyhow!("unknown variant {}", key))?;
            slot.retiring = true;
        }
        self.shared.cv.notify_all();
        let mut st = self.shared.state.lock().unwrap();
        loop {
            let Some(i) = st.slots.iter().position(|s| s.variant.key == key) else {
                return Ok(());
            };
            if st.slots[i].queue.is_empty() && st.slots[i].inflight.load(Ordering::Acquire) == 0 {
                self.shared.telemetry.emit(Event::VariantRetired {
                    key: st.slots[i].key_arc.clone(),
                });
                st.slots.remove(i);
                if st.cursor > i {
                    st.cursor -= 1;
                }
                if st.cursor >= st.slots.len() {
                    st.cursor = 0;
                }
                return Ok(());
            }
            let (guard, _) = self
                .shared
                .cv
                .wait_timeout(st, Duration::from_millis(2))
                .unwrap();
            st = guard;
        }
    }

    /// Submits one image to the variant registered under `key`.
    pub fn submit(&self, key: &str, image: Vec<f32>) -> Result<Ticket, SubmitError> {
        submit_shared(&self.shared, key, image, None, None)
    }

    /// Submits one image under `key` with a per-request deadline (see
    /// [`VariantHandle::submit_deadline`]).
    pub fn submit_deadline(
        &self,
        key: &str,
        image: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Ticket, SubmitError> {
        submit_shared(&self.shared, key, image, deadline, None)
    }

    /// [`Engine::submit_deadline`] plus a trace context (see
    /// [`VariantHandle::submit_traced`]).
    pub fn submit_traced(
        &self,
        key: &str,
        image: Vec<f32>,
        deadline: Option<Instant>,
        trace: Option<TraceCtx>,
    ) -> Result<Ticket, SubmitError> {
        submit_shared(&self.shared, key, image, deadline, trace)
    }

    /// Submits one image whose reply is delivered through `cb` instead
    /// of a [`Ticket`] — the async wire tier's submit path, where no
    /// thread may park per in-flight request. The callback fires exactly
    /// once, on an engine worker thread, when the request completes, is
    /// shed from the queue, or fails; door-stage refusals never enqueue
    /// and hand the callback back untouched so the caller can answer
    /// synchronously with the typed [`SubmitError`].
    pub fn submit_callback(
        &self,
        key: &str,
        image: Vec<f32>,
        deadline: Option<Instant>,
        cb: ReplyCallback,
    ) -> Result<(), (SubmitError, ReplyCallback)> {
        self.submit_callback_traced(key, image, deadline, None, cb)
    }

    /// [`Engine::submit_callback`] plus a trace context (the async wire
    /// tier's traced submit path).
    pub fn submit_callback_traced(
        &self,
        key: &str,
        image: Vec<f32>,
        deadline: Option<Instant>,
        trace: Option<TraceCtx>,
        cb: ReplyCallback,
    ) -> Result<(), (SubmitError, ReplyCallback)> {
        match submit_reply(&self.shared, key, image, deadline, trace, ReplyTo::callback(cb)) {
            Ok(()) => Ok(()),
            Err((e, reply)) => match reply {
                ReplyTo::Callback(m) => {
                    let cb = m.into_inner().unwrap().expect("callback not yet invoked");
                    Err((e, cb))
                }
                ReplyTo::Channel(_) => unreachable!("submitted a callback reply"),
            },
        }
    }

    /// Live variant keys, sorted.
    pub fn keys(&self) -> Vec<String> {
        let st = self.shared.state.lock().unwrap();
        let mut k: Vec<String> = st
            .slots
            .iter()
            .filter(|s| !s.retiring)
            .map(|s| s.variant.key.clone())
            .collect();
        k.sort();
        k
    }

    /// Size of the shared worker pool (the engine's total serving thread
    /// count — there is no separate batcher thread).
    pub fn worker_count(&self) -> usize {
        self.shared.workers
    }

    /// Typed metrics: one row per variant plus the fleet rollup.
    pub fn metrics(&self) -> MetricsSnapshot {
        snapshot_of(&self.shared)
    }

    /// The engine's telemetry sink handle (disabled unless configured).
    pub fn telemetry(&self) -> &TelemetrySink {
        &self.shared.telemetry
    }

    /// Latency summary for one variant (empty if the key is unknown).
    pub fn latency_summary(&self, key: &str) -> crate::util::stats::Summary {
        let st = self.shared.state.lock().unwrap();
        st.slots
            .iter()
            .find(|s| s.variant.key == key)
            .map(|s| s.metrics.latency_summary())
            .unwrap_or_default()
    }

    /// Stops the pool after draining every queue (pending deadlines are
    /// waived so shutdown is prompt). Subsequent submits through live
    /// handles get [`SubmitError::ShuttingDown`].
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.stopping = true;
        }
        self.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    fn defaults(&self) -> &EngineOptions {
        &self.defaults
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Builds the typed snapshot from the shared state — used by both
/// [`Engine::metrics`] and the gauge ticker thread.
fn snapshot_of(shared: &EngineShared) -> MetricsSnapshot {
    let st = shared.state.lock().unwrap();
    let variants: Vec<VariantSnapshot> = st
        .slots
        .iter()
        .map(|s| {
            s.metrics.snapshot(
                &s.variant.key,
                &s.variant.net,
                s.variant.backend.kind().name(),
                s.variant.img,
                s.variant.classes,
                s.registered.elapsed(),
                s.queue.len(),
            )
        })
        .collect();
    // Weight each retained sample by the traffic it stands for
    // (seen/retained per reservoir) so a low-traffic variant's
    // saturated reservoir doesn't skew the fleet percentiles.
    let mut merged_lat: Vec<(f64, f64)> = Vec::new();
    for s in &st.slots {
        let samples = s.metrics.latency_samples();
        if samples.is_empty() {
            continue;
        }
        let w = s.metrics.latency_seen() as f64 / samples.len() as f64;
        merged_lat.extend(samples.into_iter().map(|v| (v, w)));
    }
    let fleet = FleetSnapshot::rollup(&variants, shared.started.elapsed(), &merged_lat);
    // Windowed view: deltas since the PREVIOUS snapshot call (first
    // window spans boot → first call). A retired variant's counters
    // leave the fleet totals, so deltas saturate at zero rather than
    // underflow across a retire.
    let mut merged_hist = HistogramSnapshot::default();
    for v in &variants {
        merged_hist.merge(&v.hist);
    }
    let window = {
        let mut base = shared.window_base.lock().unwrap();
        let w = WindowSnapshot::from_deltas(
            base.at.elapsed().as_secs_f64(),
            fleet.completed.saturating_sub(base.completed),
            fleet.shed.saturating_sub(base.shed),
            fleet.rejected.saturating_sub(base.rejected),
            &merged_hist.delta_since(&base.hist),
        );
        *base = WindowBase {
            at: Instant::now(),
            completed: fleet.completed,
            shed: fleet.shed,
            rejected: fleet.rejected,
            hist: merged_hist,
        };
        w
    };
    let uptime_s = shared.started.elapsed().as_secs_f64();
    MetricsSnapshot {
        schema_version: METRICS_SCHEMA_VERSION,
        wall_s: uptime_s,
        uptime_s,
        workers: shared.workers,
        telemetry_dropped: shared.telemetry.dropped(),
        kernel_isa: crate::backend::kernels::active_isa().name().to_string(),
        variants,
        fleet,
        window,
    }
}

/// Periodic `engine_gauges` emitter; exits when the engine stops.
/// Sleeps on the engine condvar so shutdown interrupts the wait, but
/// holds its own deadline: the condvar is notified on every submit, so
/// wakeups alone must not pace emission.
fn gauge_ticker(shared: &EngineShared, period: Duration) {
    let mut next = Instant::now() + period;
    // Previous tick's snapshot: each emitted row carries both cumulative
    // counters and the interval deltas vs. this, so dashboards read
    // per-interval rates straight off a row instead of differencing
    // successive snapshots by hand.
    let mut prev: Option<MetricsSnapshot> = None;
    loop {
        {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.stopping {
                    return;
                }
                let now = Instant::now();
                if now >= next {
                    break;
                }
                st = shared.cv.wait_timeout(st, next - now).unwrap().0;
            }
        }
        next += period;
        let snap = snapshot_of(shared);
        shared.telemetry.emit(Event::gauges_delta(&snap, prev.as_ref()));
        prev = Some(snap);
    }
}

fn submit_shared(
    shared: &EngineShared,
    key: &str,
    image: Vec<f32>,
    deadline: Option<Instant>,
    trace: Option<TraceCtx>,
) -> Result<Ticket, SubmitError> {
    let (tx, rx) = mpsc::channel();
    submit_reply(shared, key, image, deadline, trace, ReplyTo::Channel(tx))
        .map_err(|(e, _reply)| e)?;
    Ok(Ticket { rx })
}

/// Shared submit path. Refusals return the untouched [`ReplyTo`]
/// alongside the typed error so a callback submitter can reclaim its
/// callback (a channel submitter just drops it).
fn submit_reply(
    shared: &EngineShared,
    key: &str,
    image: Vec<f32>,
    deadline: Option<Instant>,
    trace: Option<TraceCtx>,
    reply: ReplyTo,
) -> Result<(), (SubmitError, ReplyTo)> {
    let mut st = shared.state.lock().unwrap();
    if st.stopping {
        return Err((SubmitError::ShuttingDown, reply));
    }
    let Some(slot) = st.slots.iter_mut().find(|s| s.variant.key == key) else {
        return Err((SubmitError::UnknownVariant { key: key.into() }, reply));
    };
    if slot.retiring {
        return Err((SubmitError::Retired { key: key.into() }, reply));
    }
    let px = slot.variant.image_len();
    if image.len() != px {
        return Err((
            SubmitError::BadImage {
                key: key.into(),
                expected: px,
                got: image.len(),
            },
            reply,
        ));
    }
    // Already-late work never enters the queue: shedding at the door is
    // the cheapest shed there is.
    if let Some(d) = deadline {
        if Instant::now() >= d {
            slot.metrics.record_shed();
            shared.telemetry.emit(Event::RequestShed {
                key: slot.key_arc.clone(),
                stage: ShedStage::Door,
            });
            return Err((SubmitError::Expired { key: key.into() }, reply));
        }
    }
    if slot.queue.len() >= slot.depth {
        slot.metrics.record_rejected();
        shared.telemetry.emit(Event::RequestRejected {
            key: slot.key_arc.clone(),
            depth: slot.depth,
        });
        return Err((
            SubmitError::QueueFull {
                key: key.into(),
                depth: slot.depth,
            },
            reply,
        ));
    }
    slot.metrics.record_request();
    // Door-admit span: a zero-duration marker stamping the instant the
    // request entered the queue (the waterfall's anchor point).
    if let Some(t) = trace {
        if shared.telemetry.is_enabled() {
            shared.telemetry.emit(Event::Span {
                trace: t.trace_id,
                attempt: t.attempt as u32,
                stage: "door",
                key: Some(slot.key_arc.clone()),
                dur_us: 0,
                abandoned: false,
                detail: None,
            });
        }
    }
    slot.queue.push_back(Request {
        image,
        reply,
        enqueued: Instant::now(),
        deadline,
        trace,
    });
    drop(st);
    shared.cv.notify_all();
    Ok(())
}

/// Deficit-round-robin pick over the variant queues (state lock held).
/// Starting from the cursor, the first variant whose policy says "flush"
/// gets `quantum` request-credits and a batch cut to the credit it has
/// banked — so a variant flushing giant batches spends several turns'
/// credit on each one while lightly-loaded variants are served every
/// time their turn comes. Retiring slots and a stopping engine waive the
/// deadline so drains are prompt.
fn pick(st: &mut EngineState, now: Instant) -> Option<Job> {
    let n = st.slots.len();
    for i in 0..n {
        let idx = (st.cursor + i) % n;
        let slot = &mut st.slots[idx];
        let want = if st.stopping || slot.retiring {
            slot.queue.len().min(slot.policy.max_batch)
        } else {
            slot.policy.decide(
                slot.queue.len(),
                slot.queue.front().map(|r| r.enqueued),
                now,
            )
        };
        if want == 0 {
            continue;
        }
        // Top up this slot's credit; cap the bank so an idle variant
        // cannot hoard unbounded credit. The cap exceeds max_batch, so
        // any flushable batch is reachable within a bounded number of
        // turns (guaranteed progress, no starvation either way).
        slot.deficit = (slot.deficit + slot.quantum).min(slot.policy.max_batch + slot.quantum);
        // quantum >= 1, so deficit >= 1 here: progress is always made.
        let take = want.min(slot.deficit);
        slot.deficit -= take;
        let batch: Vec<Request> = slot.queue.drain(..take).collect();
        slot.inflight.fetch_add(1, Ordering::AcqRel);
        let job = Job {
            variant: slot.variant.clone(),
            key_arc: slot.key_arc.clone(),
            metrics: slot.metrics.clone(),
            inflight: slot.inflight.clone(),
            batch,
        };
        st.cursor = (idx + 1) % n;
        return Some(job);
    }
    None
}

/// Soonest batching deadline across all queues: `None` when every queue
/// is empty (sleep indefinitely — satellite fix for the old 5000-wakeup/s
/// idle spin), else a bounded, never-zero nap.
fn nap_all(st: &EngineState, now: Instant) -> Option<Duration> {
    let mut best: Option<Duration> = None;
    for slot in &st.slots {
        if let Some(d) = slot
            .policy
            .nap(slot.queue.front().map(|r| r.enqueued), now)
        {
            best = Some(match best {
                Some(b) => b.min(d),
                None => d,
            });
        }
    }
    best
}

fn worker_loop(shared: &EngineShared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                let now = Instant::now();
                if let Some(job) = pick(&mut st, now) {
                    break Some(job);
                }
                if st.stopping {
                    break None;
                }
                st = match nap_all(&st, now) {
                    None => shared.cv.wait(st).unwrap(),
                    Some(d) => shared.cv.wait_timeout(st, d).unwrap().0,
                };
            }
        };
        let Some(job) = job else { return };
        execute_batch(&job, &shared.telemetry, shared.trace_sample);
        job.inflight.fetch_sub(1, Ordering::AcqRel);
        // Wake napping peers (queued work may be flushable now that this
        // worker is free) and any retire()/shutdown waiter.
        shared.cv.notify_all();
    }
}

fn execute_batch(job: &Job, telemetry: &TelemetrySink, trace_sample: u32) {
    let v = &job.variant;
    // Shed already-late requests before spending backend cycles: their
    // deadline passed while they sat in the queue, so nobody is waiting
    // for the answer. The survivors run as a (smaller) batch.
    let now = Instant::now();
    let (live, late): (Vec<&Request>, Vec<&Request>) = job
        .batch
        .iter()
        .partition(|r| r.deadline.map_or(true, |d| now < d));
    for r in late {
        job.metrics.record_shed();
        telemetry.emit(Event::RequestShed {
            key: job.key_arc.clone(),
            stage: ShedStage::Queue,
        });
        r.reply.send(Err(ReplyError::Shed.into()));
    }
    if live.is_empty() {
        return;
    }
    let n = live.len();
    let bsz = v.pick_batch(n);
    job.metrics.record_batch(n, bsz);
    telemetry.emit(Event::BatchFormed {
        key: job.key_arc.clone(),
        occupancy: n as u32,
        padded: bsz as u32,
    });
    // Stage spans at batch formation: queue wait so far plus a batch
    // marker, per traced request. Untraced requests skip both branches.
    let spans_on = telemetry.is_enabled();
    let formed = Instant::now();
    if spans_on {
        for r in &live {
            if let Some(t) = r.trace {
                telemetry.emit(Event::Span {
                    trace: t.trace_id,
                    attempt: t.attempt as u32,
                    stage: "queue_wait",
                    key: Some(job.key_arc.clone()),
                    dur_us: formed.saturating_duration_since(r.enqueued).as_micros() as u64,
                    abandoned: false,
                    detail: None,
                });
                telemetry.emit(Event::Span {
                    trace: t.trace_id,
                    attempt: t.attempt as u32,
                    stage: "batch",
                    key: Some(job.key_arc.clone()),
                    dur_us: 0,
                    abandoned: false,
                    detail: Some(format!("occ={} padded={}", n, bsz)),
                });
            }
        }
    }
    let px = v.image_len();
    let mut images = vec![0f32; bsz * px];
    for (i, r) in live.iter().enumerate() {
        // Sizes are validated at submit; a mismatch here is a bug.
        debug_assert_eq!(r.image.len(), px);
        images[i * px..(i + 1) * px].copy_from_slice(&r.image);
    }
    // 1-in-N layer profiling: the first live traced request whose id
    // samples in carries this batch's per-layer spans. With
    // `trace_sample == 0` (or no traced request in the batch) the
    // backend runs the plain unprofiled path — the hot-path cost of the
    // whole feature is this branch plus two reads.
    let profiled: Option<TraceCtx> = if trace_sample > 0 && spans_on {
        live.iter()
            .filter_map(|r| r.trace)
            .find(|t| t.trace_id % trace_sample as u64 == 0)
    } else {
        None
    };
    let exec_start = Instant::now();
    let result = if profiled.is_some() {
        v.backend.infer_batch_profiled(images, bsz)
    } else {
        v.backend.infer_batch(images, bsz).map(|l| (l, Vec::new()))
    };
    let exec_us = exec_start.elapsed().as_micros() as u64;
    match result {
        Ok((logits, layers)) => {
            // Layer spans are measured INSIDE the execute window, so
            // their sum can never exceed the execute span below.
            if let Some(t) = profiled {
                for l in layers {
                    telemetry.emit(Event::Span {
                        trace: t.trace_id,
                        attempt: t.attempt as u32,
                        stage: "layer",
                        key: Some(job.key_arc.clone()),
                        dur_us: l.dur_us,
                        abandoned: false,
                        detail: Some(l.name),
                    });
                }
            }
            let preds = argmax_rows(&logits, v.classes);
            for (i, r) in live.iter().enumerate() {
                let latency = r.enqueued.elapsed();
                job.metrics.record_done(latency);
                telemetry.emit(Event::RequestDone {
                    key: job.key_arc.clone(),
                    latency_us: latency.as_micros() as u64,
                    deadline_budget_ms: r
                        .deadline
                        .map(|d| d.saturating_duration_since(r.enqueued).as_millis() as u64),
                    batch_occupancy: n as u32,
                    batch_padded: bsz as u32,
                });
                if spans_on {
                    if let Some(t) = r.trace {
                        telemetry.emit(Event::Span {
                            trace: t.trace_id,
                            attempt: t.attempt as u32,
                            stage: "execute",
                            key: Some(job.key_arc.clone()),
                            dur_us: exec_us,
                            abandoned: false,
                            detail: None,
                        });
                    }
                }
                let write_start = Instant::now();
                r.reply.send(Ok(InferReply {
                    class: preds[i],
                    logits: logits[i * v.classes..(i + 1) * v.classes].to_vec(),
                    latency,
                    batch: (n, bsz),
                }));
                if spans_on {
                    if let Some(t) = r.trace {
                        telemetry.emit(Event::Span {
                            trace: t.trace_id,
                            attempt: t.attempt as u32,
                            stage: "reply_write",
                            key: Some(job.key_arc.clone()),
                            dur_us: write_start.elapsed().as_micros() as u64,
                            abandoned: false,
                            detail: None,
                        });
                    }
                }
            }
        }
        Err(e) => {
            let msg = format!("{}", e);
            for r in &live {
                r.reply.send(Err(ReplyError::Batch(msg.clone()).into()));
            }
        }
    }
}
