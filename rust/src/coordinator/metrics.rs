//! Serving metrics: counters, bounded latency reservoirs, and the typed
//! [`MetricsSnapshot`] the engine reports (per-variant rows + a fleet
//! rollup), serializable via [`crate::util::json`].
//!
//! Latency and batch-size samples go through a fixed-capacity reservoir
//! sampler (Vitter's Algorithm R, seeded from [`crate::util::prng`]) so
//! memory stays bounded under sustained load — the old `Vec` sinks grew
//! without limit, ~16 bytes/request forever.

use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Default reservoir capacity: enough for stable p99 estimates, ~32 KiB
/// per variant regardless of how long the engine runs.
pub const RESERVOIR_CAP: usize = 4096;

/// Version of the metrics-snapshot JSON layout. v2 added top-level
/// `schema_version`, `uptime_s`, and `telemetry_dropped`; v3 added
/// `kernel_isa`; v4 added per-variant log2 latency histograms (`hist`)
/// and the top-level `window` interval-delta block; consumers must
/// treat a missing field as an older version (additive changes, parse
/// tolerantly).
pub const METRICS_SCHEMA_VERSION: u32 = 4;

/// Number of log2 latency buckets. Bucket 0 holds `0 µs`; bucket
/// `i ∈ 1..63` holds values whose bit length is `i`, i.e. the range
/// `[2^(i-1), 2^i − 1]` µs (so the upper edge of bucket 7 is the
/// 127 µs "±127 edge"); bucket 63 is the overflow bucket (≥ 2^62 µs).
pub const HIST_BUCKETS: usize = 64;

/// Lock-free shards a [`LatencyHistogram`] spreads its counters over.
/// Worker threads hash onto a shard (round-robin at first touch) so
/// concurrent `record` calls on different workers rarely contend on
/// one cache line; shards are merged at snapshot time.
const HIST_SHARDS: usize = 8;

/// Log2 bucket index for a latency in microseconds.
pub fn bucket_index(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        (64 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive upper edge (µs) of bucket `i`, `None` for the overflow
/// bucket (`+Inf` in Prometheus exposition).
pub fn bucket_le_us(i: usize) -> Option<u64> {
    if i >= HIST_BUCKETS - 1 {
        None
    } else {
        Some((1u64 << i) - 1)
    }
}

#[repr(align(64))]
struct HistShard {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for HistShard {
    fn default() -> Self {
        HistShard {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

/// Fixed-layout log2 latency histogram: [`HIST_BUCKETS`] buckets over
/// microseconds, sharded per worker thread so the record path is two
/// relaxed atomic adds with no lock and no allocation. Unlike the
/// reservoir (a *sample*), the histogram counts every request exactly
/// once, so bucket counts difference cleanly into per-interval windows
/// and export directly as Prometheus `_bucket`/`_sum`/`_count`
/// families.
pub struct LatencyHistogram {
    shards: Box<[HistShard]>,
}

impl std::fmt::Debug for LatencyHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let m = self.merged();
        write!(f, "LatencyHistogram(count={}, sum_us={})", m.count, m.sum_us)
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            shards: (0..HIST_SHARDS).map(|_| HistShard::default()).collect(),
        }
    }
}

impl LatencyHistogram {
    fn shard(&self) -> &HistShard {
        thread_local! {
            static SHARD_IDX: usize = {
                static NEXT: AtomicU64 = AtomicU64::new(0);
                NEXT.fetch_add(1, Ordering::Relaxed) as usize % HIST_SHARDS
            };
        }
        &self.shards[SHARD_IDX.with(|i| *i)]
    }

    /// Records one latency. Lock-free: a relaxed add into this thread's
    /// shard.
    pub fn record(&self, us: u64) {
        let s = self.shard();
        s.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        s.sum_us.fetch_add(us, Ordering::Relaxed);
        s.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Merges every shard into one immutable snapshot.
    pub fn merged(&self) -> HistogramSnapshot {
        let mut out = HistogramSnapshot::default();
        for s in self.shards.iter() {
            for (i, b) in s.buckets.iter().enumerate() {
                out.buckets[i] += b.load(Ordering::Relaxed);
            }
            out.sum_us += s.sum_us.load(Ordering::Relaxed);
            out.count += s.count.load(Ordering::Relaxed);
        }
        out
    }
}

/// One merged, immutable view of a [`LatencyHistogram`] (or a delta of
/// two — see [`HistogramSnapshot::delta_since`]).
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Per-bucket counts (NOT cumulative; the Prometheus exposition
    /// accumulates them into `le` form at render time).
    pub buckets: [u64; HIST_BUCKETS],
    /// Sum of every recorded latency, µs.
    pub sum_us: u64,
    /// Total recorded latencies.
    pub count: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot {
            buckets: [0; HIST_BUCKETS],
            sum_us: 0,
            count: 0,
        }
    }
}

impl HistogramSnapshot {
    /// Bucket-wise `self − earlier`: the histogram of requests recorded
    /// in the interval between the two snapshots. Saturating, so a
    /// counter reset (process restart) degrades to zeros instead of
    /// wrapping.
    pub fn delta_since(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let mut out = HistogramSnapshot::default();
        for i in 0..HIST_BUCKETS {
            out.buckets[i] = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        out.sum_us = self.sum_us.saturating_sub(earlier.sum_us);
        out.count = self.count.saturating_sub(earlier.count);
        out
    }

    /// Merges another snapshot into this one (fleet rollups).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for i in 0..HIST_BUCKETS {
            self.buckets[i] += other.buckets[i];
        }
        self.sum_us += other.sum_us;
        self.count += other.count;
    }

    /// Quantile estimate (`q ∈ [0, 1]`) by linear interpolation inside
    /// the covering bucket — the histogram twin of the reservoir
    /// percentiles, exact to within one bucket's width.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).max(1.0);
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let next = cum + n;
            if (next as f64) >= target {
                let lo = if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 };
                let hi = match bucket_le_us(i) {
                    Some(le) => le as f64 + 1.0,
                    None => lo * 2.0,
                };
                let frac = (target - cum as f64) / n as f64;
                return lo + (hi - lo) * frac.clamp(0.0, 1.0);
            }
            cum = next;
        }
        0.0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "buckets",
                Json::Arr(self.buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("sum_us", Json::Num(self.sum_us as f64)),
            ("count", Json::Num(self.count as f64)),
        ])
    }

    /// Tolerant inverse of [`HistogramSnapshot::to_json`] (missing or
    /// short fields read as zero) — the Prometheus renderer parses the
    /// snapshot back out of the metrics JSON with this.
    pub fn from_json(v: &Json) -> HistogramSnapshot {
        let mut out = HistogramSnapshot::default();
        if let Some(arr) = v.get("buckets").and_then(Json::as_arr) {
            for (i, b) in arr.iter().take(HIST_BUCKETS).enumerate() {
                out.buckets[i] = b.as_f64().unwrap_or(0.0) as u64;
            }
        }
        out.sum_us = v.get("sum_us").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        out.count = v.get("count").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        out
    }
}

/// Interval-delta block of a [`MetricsSnapshot`]: what happened since
/// the *previous* snapshot was taken (fleet-wide), rather than since
/// boot. The engine keeps the previous observation internally, so each
/// snapshot call closes one window and opens the next; a periodic
/// scraper (the gauge ticker, a Prometheus poll) therefore sees clean
/// per-interval deltas without differencing by hand. The first window
/// of a process covers boot → first snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WindowSnapshot {
    /// Window length in seconds.
    pub window_s: f64,
    /// Requests completed in the window.
    pub completed: u64,
    /// Requests shed in the window.
    pub shed: u64,
    /// Submits rejected in the window.
    pub rejected: u64,
    /// Latency quantiles over the window's histogram delta, µs.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl WindowSnapshot {
    /// Builds the window block from counter/histogram deltas.
    pub fn from_deltas(
        window_s: f64,
        completed: u64,
        shed: u64,
        rejected: u64,
        hist: &HistogramSnapshot,
    ) -> WindowSnapshot {
        WindowSnapshot {
            window_s,
            completed,
            shed,
            rejected,
            p50_us: hist.quantile_us(0.50),
            p95_us: hist.quantile_us(0.95),
            p99_us: hist.quantile_us(0.99),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("window_s", Json::Num(self.window_s)),
            ("completed", Json::Num(self.completed as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
        ])
    }
}

/// Fixed-capacity uniform sample of an unbounded stream (Algorithm R).
/// After `seen` pushes, each of them is retained with probability
/// `cap / seen` — percentiles over the reservoir estimate the stream's.
#[derive(Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        Reservoir {
            cap: cap.max(1),
            seen: 0,
            samples: Vec::new(),
            rng: Rng::new(seed),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Keep x with probability cap/seen, evicting a uniform victim.
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// Total values pushed (≥ the retained sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Thread-safe metrics sink, one per registered variant.
#[derive(Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    /// Submits refused with `QueueFull` backpressure.
    pub rejected: AtomicU64,
    /// Requests shed for a passed deadline (at the door or in queue).
    pub shed: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    latencies_us: Mutex<Reservoir>,
    batch_sizes: Mutex<Reservoir>,
    /// Log2 latency histogram (every request counted, lock-free).
    hist: LatencyHistogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_slots: AtomicU64::new(0),
            // Fixed seeds: sampling stays reproducible run to run.
            latencies_us: Mutex::new(Reservoir::new(RESERVOIR_CAP, 0x5EED_1A7E)),
            batch_sizes: Mutex::new(Reservoir::new(RESERVOIR_CAP, 0x5EED_BA7C)),
            hist: LatencyHistogram::default(),
        }
    }
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, real: usize, padded_to: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots
            .fetch_add((padded_to - real) as u64, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(real as f64);
    }

    pub fn record_done(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.hist.record(latency.as_micros() as u64);
        self.latencies_us
            .lock()
            .unwrap()
            .push(latency.as_secs_f64() * 1e6);
    }

    /// Merged view of the per-worker histogram shards.
    pub fn histogram(&self) -> HistogramSnapshot {
        self.hist.merged()
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::from_slice(self.latencies_us.lock().unwrap().samples())
    }

    /// Retained latency samples (µs) — callers merge these for rollups.
    pub fn latency_samples(&self) -> Vec<f64> {
        self.latencies_us.lock().unwrap().samples().to_vec()
    }

    /// Total latencies recorded (≥ the retained sample count); the ratio
    /// seen/retained is the traffic weight of each retained sample.
    pub fn latency_seen(&self) -> u64 {
        self.latencies_us.lock().unwrap().seen()
    }

    pub fn mean_batch_size(&self) -> f64 {
        Summary::from_slice(self.batch_sizes.lock().unwrap().samples()).mean()
    }

    /// Snapshot of this sink as one typed per-variant row. `img` and
    /// `classes` describe the variant's tensor geometry — wire clients
    /// (the load generator above all) discover request shapes from the
    /// metrics op instead of hard-coding them.
    #[allow(clippy::too_many_arguments)]
    pub fn snapshot(
        &self,
        key: &str,
        net: &str,
        backend: &str,
        img: usize,
        classes: usize,
        wall: Duration,
        queued: usize,
    ) -> VariantSnapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        VariantSnapshot {
            key: key.to_string(),
            net: net.to_string(),
            backend: backend.to_string(),
            img,
            classes,
            requests: self.requests.load(Ordering::Relaxed),
            completed,
            rejected: self.rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            mean_batch: self.mean_batch_size(),
            queued,
            throughput_rps: completed as f64 / wall.as_secs_f64().max(1e-9),
            latency: LatencyStats::from_summary(&self.latency_summary()),
            hist: self.hist.merged(),
        }
    }
}

/// Percentile summary of a latency reservoir, in microseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyStats {
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// Retained reservoir samples the percentiles were computed from.
    pub samples: usize,
}

impl LatencyStats {
    pub fn from_summary(s: &Summary) -> LatencyStats {
        if s.is_empty() {
            return LatencyStats {
                p50_us: 0.0,
                p95_us: 0.0,
                p99_us: 0.0,
                max_us: 0.0,
                samples: 0,
            };
        }
        LatencyStats {
            p50_us: s.percentile(50.0),
            p95_us: s.percentile(95.0),
            p99_us: s.percentile(99.0),
            max_us: s.max(),
            samples: s.len(),
        }
    }

    /// Percentiles over `(value_us, weight)` pairs, where each retained
    /// reservoir sample stands for `weight` real requests. Reservoirs
    /// with different sampling rates (a saturated hot variant next to a
    /// barely-sampled cold one) merge without biasing the estimate.
    pub fn from_weighted(pairs: &[(f64, f64)]) -> LatencyStats {
        if pairs.is_empty() {
            return LatencyStats::from_summary(&Summary::new());
        }
        let mut sorted = pairs.to_vec();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let total: f64 = sorted.iter().map(|&(_, w)| w).sum();
        let pct = |q: f64| -> f64 {
            let target = total * q / 100.0;
            let mut cum = 0.0;
            for &(v, w) in &sorted {
                cum += w;
                if cum >= target {
                    return v;
                }
            }
            sorted.last().unwrap().0
        };
        LatencyStats {
            p50_us: pct(50.0),
            p95_us: pct(95.0),
            p99_us: pct(99.0),
            max_us: sorted.last().unwrap().0,
            samples: pairs.len(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("max_us", Json::Num(self.max_us)),
            ("samples", Json::Num(self.samples as f64)),
        ])
    }
}

/// One variant's serving counters + latency percentiles.
#[derive(Debug, Clone)]
pub struct VariantSnapshot {
    pub key: String,
    pub net: String,
    pub backend: String,
    /// Input image side length (requests are `img·img·3` floats).
    pub img: usize,
    /// Logit row width.
    pub classes: usize,
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Requests shed for a passed deadline (door + in-queue).
    pub shed: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub mean_batch: f64,
    /// Queue occupancy at snapshot time.
    pub queued: usize,
    pub throughput_rps: f64,
    pub latency: LatencyStats,
    /// Log2 latency histogram (since boot; every request counted).
    pub hist: HistogramSnapshot,
}

impl VariantSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::str(self.key.as_str())),
            ("net", Json::str(self.net.as_str())),
            ("backend", Json::str(self.backend.as_str())),
            ("img", Json::Num(self.img as f64)),
            ("classes", Json::Num(self.classes as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("padded_slots", Json::Num(self.padded_slots as f64)),
            (
                "mean_batch",
                Json::Num(if self.mean_batch.is_finite() {
                    self.mean_batch
                } else {
                    0.0
                }),
            ),
            ("queued", Json::Num(self.queued as f64)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("latency", self.latency.to_json()),
            ("hist", self.hist.to_json()),
        ])
    }
}

/// Cross-variant rollup: summed counters, fleet throughput, and
/// percentiles over the merged latency reservoirs.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Deadline sheds summed across variants.
    pub shed: u64,
    pub batches: u64,
    pub throughput_rps: f64,
    pub latency: LatencyStats,
}

impl FleetSnapshot {
    /// Builds the rollup from per-variant rows plus the merged,
    /// traffic-weighted latency samples `(value_us, weight)` —
    /// percentiles do not compose, so the raw reservoirs are merged
    /// (weighted by how much traffic each retained sample represents)
    /// rather than averaging per-variant percentiles.
    pub fn rollup(
        variants: &[VariantSnapshot],
        wall: Duration,
        merged_lat_us: &[(f64, f64)],
    ) -> Self {
        let completed: u64 = variants.iter().map(|v| v.completed).sum();
        FleetSnapshot {
            requests: variants.iter().map(|v| v.requests).sum(),
            completed,
            rejected: variants.iter().map(|v| v.rejected).sum(),
            shed: variants.iter().map(|v| v.shed).sum(),
            batches: variants.iter().map(|v| v.batches).sum(),
            throughput_rps: completed as f64 / wall.as_secs_f64().max(1e-9),
            latency: LatencyStats::from_weighted(merged_lat_us),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("latency", self.latency.to_json()),
        ])
    }
}

/// Typed engine metrics: the whole fleet at one instant.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// JSON layout version ([`METRICS_SCHEMA_VERSION`]).
    pub schema_version: u32,
    /// Engine uptime in seconds (kept as `wall_s` in JSON alongside
    /// `uptime_s` for one deprecation cycle).
    pub wall_s: f64,
    /// Engine uptime in seconds — the canonical name.
    pub uptime_s: f64,
    /// Shared worker pool size.
    pub workers: usize,
    /// Telemetry events dropped because the sink's channel was full
    /// (0 when telemetry is disabled).
    pub telemetry_dropped: u64,
    /// Active kernel ISA tier (`scalar`/`sse2`/`avx2`/`avx512`) — the
    /// runtime-detected (or `STRUM_KERNEL`-forced) dispatch choice, the
    /// serving-side twin of the run manifest's `kernel_isa` field.
    pub kernel_isa: String,
    pub variants: Vec<VariantSnapshot>,
    pub fleet: FleetSnapshot,
    /// Fleet-wide interval deltas since the previous snapshot call
    /// (boot → first call for the first window).
    pub window: WindowSnapshot,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("uptime_s", Json::Num(self.uptime_s)),
            ("workers", Json::Num(self.workers as f64)),
            ("telemetry_dropped", Json::Num(self.telemetry_dropped as f64)),
            ("kernel_isa", Json::Str(self.kernel_isa.clone())),
            (
                "variants",
                Json::Arr(self.variants.iter().map(|v| v.to_json()).collect()),
            ),
            ("fleet", self.fleet.to_json()),
            ("window", self.window.to_json()),
        ])
    }

    /// Human-readable multi-line report (what `strum serve` prints).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for v in &self.variants {
            out.push_str(&format!(
                "{:<28} requests={} completed={} rejected={} shed={} batches={} mean_batch={:.1} \
                 queued={} thrpt={:.1} req/s latency_us p50={:.0} p95={:.0} p99={:.0} max={:.0}\n",
                v.key,
                v.requests,
                v.completed,
                v.rejected,
                v.shed,
                v.batches,
                if v.mean_batch.is_finite() { v.mean_batch } else { 0.0 },
                v.queued,
                v.throughput_rps,
                v.latency.p50_us,
                v.latency.p95_us,
                v.latency.p99_us,
                v.latency.max_us,
            ));
        }
        out.push_str(&format!(
            "fleet: workers={} wall={:.2}s requests={} completed={} rejected={} shed={} \
             thrpt={:.1} req/s latency_us p50={:.0} p95={:.0} p99={:.0}",
            self.workers,
            self.wall_s,
            self.fleet.requests,
            self.fleet.completed,
            self.fleet.rejected,
            self.fleet.shed,
            self.fleet.throughput_rps,
            self.fleet.latency.p50_us,
            self.fleet.latency.p95_us,
            self.fleet.latency.p99_us,
        ));
        out.push_str(&format!(
            "\nwindow: {:.2}s completed={} shed={} rejected={} latency_us p50={:.0} p95={:.0} p99={:.0}",
            self.window.window_s,
            self.window.completed,
            self.window.shed,
            self.window.rejected,
            self.window.p50_us,
            self.window.p95_us,
            self.window.p99_us,
        ));
        out
    }
}

/// Counters parsed back from a replica's metrics-op JSON — the inverse
/// of [`MetricsSnapshot::to_json`] for the fields a supervisor needs.
/// The gateway's health checker probes each replica over the wire
/// metrics op and differences successive `WireCounts` to get
/// per-interval error/shed rates; parsing is tolerant (missing fields
/// read as zero) so an older replica binary still health-checks.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WireCounts {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub shed: u64,
    pub uptime_s: f64,
    /// Per-variant `(key, completed)` rows, in snapshot order.
    pub variants: Vec<(String, u64)>,
}

impl WireCounts {
    /// Parses the JSON string returned by the wire metrics op.
    pub fn from_metrics_json(json: &str) -> crate::Result<WireCounts> {
        let j = Json::parse(json).map_err(|e| anyhow::anyhow!("metrics json: {}", e))?;
        let num = |v: Option<&Json>| v.and_then(Json::as_f64).unwrap_or(0.0);
        let fleet = j.get("fleet");
        let counter = |key: &str| num(fleet.and_then(|f| f.get(key))) as u64;
        let variants = j
            .get("variants")
            .and_then(Json::as_arr)
            .map(|rows| {
                rows.iter()
                    .filter_map(|row| {
                        let key = row.get("key")?.as_str()?.to_string();
                        Some((key, num(row.get("completed")) as u64))
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(WireCounts {
            requests: counter("requests"),
            completed: counter("completed"),
            rejected: counter("rejected"),
            shed: counter("shed"),
            uptime_s: num(j.get("uptime_s")),
            variants,
        })
    }

    /// Fraction of requests in `self − earlier` that were shed or
    /// rejected (0 when no new requests arrived). `earlier` must be an
    /// older probe of the *same process*; a restart resets counters,
    /// which the caller detects via [`WireCounts::uptime_s`] going
    /// backwards and re-bases instead of differencing.
    pub fn unhealthy_rate_since(&self, earlier: &WireCounts) -> f64 {
        let requests = self.requests.saturating_sub(earlier.requests);
        if requests == 0 {
            return 0.0;
        }
        let bad = self.shed.saturating_sub(earlier.shed)
            + self.rejected.saturating_sub(earlier.rejected);
        bad as f64 / requests as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let m = Metrics::default();
        m.record_request();
        m.record_request();
        m.record_rejected();
        m.record_shed();
        m.record_batch(2, 4);
        m.record_done(Duration::from_micros(100));
        m.record_done(Duration::from_micros(300));
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(m.shed.load(Ordering::Relaxed), 1);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 2);
        assert_eq!(m.latency_summary().median(), 200.0);
        let snap = m.snapshot("k", "net", "native", 2, 4, Duration::from_secs(1), 3);
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.shed, 1);
        assert_eq!((snap.img, snap.classes), (2, 4));
        assert_eq!(snap.queued, 3);
        assert!((snap.throughput_rps - 2.0).abs() < 0.2);
        assert_eq!(snap.latency.samples, 2);
    }

    #[test]
    fn reservoir_caps_memory_at_n_samples() {
        let cap = 64usize;
        let mut r = Reservoir::new(cap, 42);
        for i in 0..100_000u64 {
            r.push(i as f64);
        }
        // The whole point of the satellite fix: memory stays at cap no
        // matter how many values stream through.
        assert_eq!(r.len(), cap);
        assert_eq!(r.seen(), 100_000);
    }

    #[test]
    fn reservoir_below_cap_keeps_everything() {
        let mut r = Reservoir::new(100, 7);
        for i in 0..50 {
            r.push(i as f64);
        }
        assert_eq!(r.samples(), (0..50).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn reservoir_sample_is_representative() {
        // Stream 0..100k uniformly; the retained sample's median should
        // land near the stream median (uniform retention probability).
        let mut r = Reservoir::new(1024, 3);
        let n = 100_000;
        for i in 0..n {
            r.push(i as f64);
        }
        let med = Summary::from_slice(r.samples()).median();
        assert!(
            (med - n as f64 / 2.0).abs() < n as f64 * 0.1,
            "median {} far from {}",
            med,
            n / 2
        );
    }

    #[test]
    fn snapshot_serializes_and_renders() {
        let m = Metrics::default();
        m.record_request();
        m.record_batch(1, 1);
        m.record_done(Duration::from_micros(500));
        let v = m.snapshot("net:base", "net", "native", 8, 10, Duration::from_secs(2), 0);
        let weighted: Vec<(f64, f64)> =
            m.latency_samples().into_iter().map(|x| (x, 1.0)).collect();
        let fleet = FleetSnapshot::rollup(std::slice::from_ref(&v), Duration::from_secs(2), &weighted);
        let snap = MetricsSnapshot {
            schema_version: METRICS_SCHEMA_VERSION,
            wall_s: 2.0,
            uptime_s: 2.0,
            workers: 4,
            telemetry_dropped: 0,
            kernel_isa: "scalar".to_string(),
            variants: vec![v],
            fleet,
            window: WindowSnapshot::default(),
        };
        let j = snap.to_json();
        assert_eq!(j.get("workers").unwrap().as_usize().unwrap(), 4);
        // v4: per-variant histogram + top-level window ride the JSON.
        let vh = j.get("variants").unwrap().as_arr().unwrap()[0]
            .get("hist")
            .expect("variant hist");
        assert_eq!(vh.get("count").unwrap().as_usize(), Some(1));
        assert!(j.get("window").is_some());
        assert_eq!(j.get("kernel_isa").unwrap().as_str(), Some("scalar"));
        assert_eq!(
            j.get("schema_version").unwrap().as_usize().unwrap(),
            METRICS_SCHEMA_VERSION as usize
        );
        assert_eq!(j.get("uptime_s").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("telemetry_dropped").unwrap().as_usize(), Some(0));
        let vars = j.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(vars.len(), 1);
        assert_eq!(vars[0].get("completed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            j.get("fleet").unwrap().get("completed").unwrap().as_usize(),
            Some(1)
        );
        // Round-trips through the in-tree parser.
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
        let text = snap.render();
        assert!(text.contains("net:base"));
        assert!(text.contains("fleet: workers=4"));
    }

    #[test]
    fn fleet_rollup_sums_counters() {
        let mk = |completed: u64, rejected: u64| VariantSnapshot {
            key: "k".into(),
            net: "n".into(),
            backend: "native".into(),
            img: 8,
            classes: 4,
            requests: completed + rejected,
            completed,
            rejected,
            shed: 2,
            batches: 1,
            padded_slots: 0,
            mean_batch: 1.0,
            queued: 0,
            throughput_rps: 0.0,
            latency: LatencyStats::from_summary(&Summary::new()),
            hist: HistogramSnapshot::default(),
        };
        let f = FleetSnapshot::rollup(
            &[mk(10, 2), mk(5, 1)],
            Duration::from_secs(1),
            &[(100.0, 1.0), (200.0, 1.0), (300.0, 1.0)],
        );
        assert_eq!(f.completed, 15);
        assert_eq!(f.rejected, 3);
        assert_eq!(f.shed, 4);
        assert_eq!(f.requests, 18);
        assert_eq!(f.latency.p50_us, 200.0);
        assert_eq!(f.latency.max_us, 300.0);
    }

    #[test]
    fn weighted_percentiles_respect_traffic_share() {
        // A hot variant's saturated reservoir: 4 retained samples at
        // 100µs each standing for 250 requests, next to a cold variant's
        // 4 samples at 10ms standing for 1 request each. True fleet p50
        // is 100µs; an unweighted merge would report the 10ms side.
        let pairs: Vec<(f64, f64)> = std::iter::repeat((100.0, 250.0))
            .take(4)
            .chain(std::iter::repeat((10_000.0, 1.0)).take(4))
            .collect();
        let l = LatencyStats::from_weighted(&pairs);
        assert_eq!(l.p50_us, 100.0);
        assert_eq!(l.p95_us, 100.0);
        assert_eq!(l.max_us, 10_000.0);
        assert_eq!(l.samples, 8);
        // Degenerate inputs stay sane.
        assert_eq!(LatencyStats::from_weighted(&[]).samples, 0);
        assert_eq!(LatencyStats::from_weighted(&[(5.0, 1.0)]).p99_us, 5.0);
    }

    #[test]
    fn from_weighted_empty_input_is_all_zero() {
        let l = LatencyStats::from_weighted(&[]);
        assert_eq!(
            (l.p50_us, l.p95_us, l.p99_us, l.max_us, l.samples),
            (0.0, 0.0, 0.0, 0.0, 0)
        );
    }

    #[test]
    fn from_weighted_single_sample_is_every_percentile() {
        let l = LatencyStats::from_weighted(&[(42.0, 17.0)]);
        assert_eq!(l.p50_us, 42.0);
        assert_eq!(l.p95_us, 42.0);
        assert_eq!(l.p99_us, 42.0);
        assert_eq!(l.max_us, 42.0);
        assert_eq!(l.samples, 1);
    }

    #[test]
    fn equal_weights_agree_with_unweighted_step_percentile() {
        // With all-equal weights, from_weighted degenerates to the plain
        // step-function percentile over the sorted values. (Summary
        // interpolates between ranks, so agreement is to within one
        // adjacent-sample gap, not exact.)
        let values: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let pairs: Vec<(f64, f64)> = values.iter().map(|&v| (v, 1.0)).collect();
        let w = LatencyStats::from_weighted(&pairs);
        let s = Summary::from_slice(&values);
        assert_eq!(w.p50_us, 50.0);
        assert_eq!(w.p95_us, 95.0);
        assert_eq!(w.p99_us, 99.0);
        for (got, interp) in [
            (w.p50_us, s.percentile(50.0)),
            (w.p95_us, s.percentile(95.0)),
            (w.p99_us, s.percentile(99.0)),
        ] {
            assert!((got - interp).abs() <= 1.0, "{} vs {}", got, interp);
        }
    }

    #[test]
    fn skewed_weights_shift_percentiles_toward_heavy_samples() {
        // Same values, but 99% of the traffic weight sits on the lowest
        // value: every percentile up to p99 collapses onto it.
        let mut pairs: Vec<(f64, f64)> = (2..=100).map(|i| (i as f64, 1.0)).collect();
        pairs.push((1.0, 9_900.0));
        let l = LatencyStats::from_weighted(&pairs);
        assert_eq!(l.p50_us, 1.0);
        assert_eq!(l.p95_us, 1.0);
        assert_eq!(l.p99_us, 1.0);
        assert_eq!(l.max_us, 100.0);
    }

    #[test]
    fn reservoir_cap_one_still_works() {
        let mut r = Reservoir::new(1, 9);
        for i in 0..1000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 1);
        assert_eq!(r.seen(), 1000);
    }

    #[test]
    fn wire_counts_roundtrip_through_snapshot_json() {
        let m = Metrics::default();
        for _ in 0..5 {
            m.record_request();
        }
        m.record_rejected();
        m.record_shed();
        m.record_done(Duration::from_micros(100));
        m.record_done(Duration::from_micros(200));
        m.record_done(Duration::from_micros(300));
        let v = m.snapshot("net:base:p0:native", "net", "native", 4, 10, Duration::from_secs(2), 0);
        let snap = MetricsSnapshot {
            schema_version: METRICS_SCHEMA_VERSION,
            wall_s: 2.0,
            uptime_s: 2.0,
            workers: 4,
            telemetry_dropped: 0,
            kernel_isa: "scalar".to_string(),
            fleet: FleetSnapshot::rollup(std::slice::from_ref(&v), Duration::from_secs(2), &[]),
            variants: vec![v],
            window: WindowSnapshot::default(),
        };
        let counts = WireCounts::from_metrics_json(&snap.to_json().to_string_pretty()).unwrap();
        assert_eq!(counts.requests, 5);
        assert_eq!(counts.completed, 3);
        assert_eq!(counts.rejected, 1);
        assert_eq!(counts.shed, 1);
        assert_eq!(counts.uptime_s, 2.0);
        assert_eq!(
            counts.variants,
            vec![("net:base:p0:native".to_string(), 3)]
        );
    }

    #[test]
    fn wire_counts_rate_differences_probes() {
        let a = WireCounts {
            requests: 100,
            shed: 2,
            rejected: 0,
            ..Default::default()
        };
        let b = WireCounts {
            requests: 200,
            shed: 12,
            rejected: 10,
            ..Default::default()
        };
        assert!((b.unhealthy_rate_since(&a) - 0.2).abs() < 1e-12);
        // No new traffic → healthy by definition, not NaN.
        assert_eq!(a.unhealthy_rate_since(&a), 0.0);
        // Tolerant parse: missing fields read as zero, not errors.
        let empty = WireCounts::from_metrics_json("{}").unwrap();
        assert_eq!(empty, WireCounts::default());
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // Bucket 0 is the zero-latency bucket; i >= 1 covers
        // [2^(i-1), 2^i - 1] us. The paper-adjacent edge case: int8's
        // +-127 boundary maps to bucket 7 whose upper edge is exactly 127.
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(127), 7);
        assert_eq!(bucket_le_us(7), Some(127));
        assert_eq!(bucket_index(128), 8);
        assert_eq!(bucket_le_us(8), Some(255));
        // Overflow bucket: everything past 2^62 collapses into bucket 63,
        // which renders as +Inf (no finite upper edge).
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_le_us(HIST_BUCKETS - 1), None);
        assert_eq!(bucket_le_us(0), Some(0));
    }

    #[test]
    fn histogram_records_and_merges_shards() {
        let h = LatencyHistogram::default();
        h.record(0);
        h.record(127);
        h.record(128);
        h.record(1_000_000);
        let s = h.merged();
        assert_eq!(s.count, 4);
        assert_eq!(s.sum_us, 0 + 127 + 128 + 1_000_000);
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[7], 1);
        assert_eq!(s.buckets[8], 1);
        assert_eq!(s.buckets[bucket_index(1_000_000)], 1);
    }

    #[test]
    fn histogram_snapshot_delta_and_quantiles() {
        let h = LatencyHistogram::default();
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(10_000);
        }
        let s = h.merged();
        // p50 lands in the bucket covering 100us: [64, 127].
        let p50 = s.quantile_us(0.5);
        assert!((64.0..=128.0).contains(&p50), "p50 {}", p50);
        // p99 lands in the bucket covering 10ms: [8192, 16383].
        let p99 = s.quantile_us(0.99);
        assert!((8192.0..=16384.0).contains(&p99), "p99 {}", p99);

        // Delta semantics: windowed view counts only what happened since.
        let before = s.clone();
        for _ in 0..5 {
            h.record(100);
        }
        let after = h.merged();
        let d = after.delta_since(&before);
        assert_eq!(d.count, 5);
        assert_eq!(d.sum_us, 500);
        assert_eq!(d.buckets[bucket_index(100)], 5);
    }

    #[test]
    fn histogram_snapshot_json_roundtrip() {
        let h = LatencyHistogram::default();
        h.record(42);
        h.record(4200);
        let s = h.merged();
        let back = HistogramSnapshot::from_json(&s.to_json());
        assert_eq!(back, s);
        // Tolerant parse: garbage reads as empty, not a panic.
        assert_eq!(
            HistogramSnapshot::from_json(&Json::obj(vec![])),
            HistogramSnapshot::default()
        );
    }

    #[test]
    fn window_snapshot_from_deltas() {
        let h = LatencyHistogram::default();
        for _ in 0..10 {
            h.record(200);
        }
        let w = WindowSnapshot::from_deltas(2.0, 10, 1, 2, &h.merged());
        assert_eq!(w.completed, 10);
        assert_eq!(w.shed, 1);
        assert_eq!(w.rejected, 2);
        assert!((w.window_s - 2.0).abs() < 1e-9);
        assert!(w.p50_us >= 128.0 && w.p50_us <= 256.0, "p50 {}", w.p50_us);
        let j = w.to_json();
        assert_eq!(j.get("completed").unwrap().as_usize(), Some(10));
    }
}
