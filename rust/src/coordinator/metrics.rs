//! Serving metrics: counters + latency summaries.

use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Thread-safe metrics sink shared by batcher and workers.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    latencies_us: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, real: usize, padded_to: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots
            .fetch_add((padded_to - real) as u64, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(real as f64);
    }

    pub fn record_done(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_us
            .lock()
            .unwrap()
            .push(latency.as_secs_f64() * 1e6);
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::from_slice(&self.latencies_us.lock().unwrap())
    }

    pub fn mean_batch_size(&self) -> f64 {
        Summary::from_slice(&self.batch_sizes.lock().unwrap()).mean()
    }

    pub fn report(&self, wall: Duration) -> String {
        let lat = self.latency_summary();
        let done = self.completed.load(Ordering::Relaxed);
        format!(
            "requests={} completed={} batches={} mean_batch={:.1} padded={} \
             thrpt={:.1} req/s  latency_us p50={:.0} p95={:.0} p99={:.0} max={:.0}",
            self.requests.load(Ordering::Relaxed),
            done,
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.padded_slots.load(Ordering::Relaxed),
            done as f64 / wall.as_secs_f64().max(1e-9),
            lat.percentile(50.0),
            lat.percentile(95.0),
            lat.percentile(99.0),
            lat.max(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let m = Metrics::default();
        m.record_request();
        m.record_request();
        m.record_batch(2, 4);
        m.record_done(Duration::from_micros(100));
        m.record_done(Duration::from_micros(300));
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 2);
        assert_eq!(m.latency_summary().median(), 200.0);
        assert!(m.report(Duration::from_secs(1)).contains("completed=2"));
    }
}
