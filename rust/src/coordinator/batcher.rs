//! Deadline-driven dynamic batching policy.
//!
//! Requests accumulate in a per-variant queue; a batch flushes when
//! either (a) enough requests are waiting to fill the variant's largest
//! executable, or (b) the oldest queued request has waited `max_wait`.
//! The flushed batch is padded up to the smallest exported batch size ≥
//! its occupancy, keeping tail latency bounded while letting
//! throughput-heavy load ride the big executables.
//!
//! The policy is pure logic (tested without threads); the engine's
//! workers drive it. [`BatchPolicy::nap`] returns `None` on an empty
//! queue — the caller sleeps on its condvar indefinitely instead of
//! polling (the old fixed 200µs floor woke the batcher ~5000×/s idle) —
//! and a bounded, never-zero nap only while a deadline is pending.

use std::time::{Duration, Instant};

/// Floor for deadline naps: waking earlier than this buys nothing and a
/// zero-duration nap would degenerate into a busy loop.
pub const MIN_NAP: Duration = Duration::from_micros(50);

/// Batching policy state machine (pure logic — tested without threads).
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// Decides whether to flush now given queue occupancy and the oldest
    /// enqueue time. Returns the number of requests to take (0 = wait).
    /// A queue deeper than `max_batch` drains in `max_batch` chunks;
    /// `max_wait == 0` flushes every request immediately.
    pub fn decide(&self, queued: usize, oldest: Option<Instant>, now: Instant) -> usize {
        if queued == 0 {
            return 0;
        }
        if queued >= self.max_batch {
            return self.max_batch;
        }
        match oldest {
            Some(t) if now.duration_since(t) >= self.max_wait => queued,
            _ => 0,
        }
    }

    /// How long the caller may sleep before re-checking [`decide`]:
    /// `None` when the queue is empty (no deadline pending — sleep until
    /// a submit wakes you), else the time to the oldest request's
    /// deadline, floored at [`MIN_NAP`] so it is never a zero-duration
    /// busy loop.
    ///
    /// [`decide`]: BatchPolicy::decide
    pub fn nap(&self, oldest: Option<Instant>, now: Instant) -> Option<Duration> {
        let t = oldest?;
        let left = self
            .max_wait
            .checked_sub(now.duration_since(t))
            .unwrap_or(Duration::ZERO);
        Some(left.max(MIN_NAP))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_full_batch_immediately() {
        let p = BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) };
        let now = Instant::now();
        assert_eq!(p.decide(16, Some(now), now), 16);
        assert_eq!(p.decide(40, Some(now), now), 16);
    }

    #[test]
    fn overfull_queue_drains_in_max_batch_chunks() {
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let now = Instant::now();
        // 21 queued: the policy hands out 8, 8, then (after the deadline)
        // the 5-request remainder — never more than max_batch at once.
        let mut queued = 21usize;
        let mut chunks = Vec::new();
        loop {
            let take = p.decide(queued, Some(now), now + Duration::from_millis(6));
            if take == 0 {
                break;
            }
            assert!(take <= p.max_batch);
            chunks.push(take);
            queued -= take;
        }
        assert_eq!(chunks, vec![8, 8, 5]);
        assert_eq!(queued, 0);
    }

    #[test]
    fn waits_below_batch_until_deadline() {
        let p = BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        assert_eq!(p.decide(3, Some(t0), t0), 0);
        let later = t0 + Duration::from_millis(6);
        assert_eq!(p.decide(3, Some(t0), later), 3);
    }

    #[test]
    fn zero_max_wait_flushes_immediately() {
        let p = BatchPolicy { max_batch: 16, max_wait: Duration::ZERO };
        let now = Instant::now();
        // A single queued request flushes at once — no batching delay.
        assert_eq!(p.decide(1, Some(now), now), 1);
        assert_eq!(p.decide(5, Some(now), now), 5);
    }

    #[test]
    fn empty_queue_never_flushes() {
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let now = Instant::now();
        assert_eq!(p.decide(0, None, now), 0);
    }

    #[test]
    fn nap_is_unbounded_on_empty_queue() {
        // No queued request → no deadline → the worker should sleep on
        // its condvar until a submit arrives, not poll.
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        assert_eq!(p.nap(None, Instant::now()), None);
    }

    #[test]
    fn nap_shrinks_as_deadline_approaches_but_never_zero() {
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        let t0 = Instant::now();
        let nap0 = p.nap(Some(t0), t0).unwrap();
        let nap1 = p.nap(Some(t0), t0 + Duration::from_millis(7)).unwrap();
        assert!(nap1 < nap0);
        // Past the deadline the nap clamps to the floor, not zero: a
        // zero-duration wait_timeout would spin.
        let late = p.nap(Some(t0), t0 + Duration::from_millis(20)).unwrap();
        assert!(late > Duration::ZERO);
        assert_eq!(late, MIN_NAP);
        // Even with a zero max_wait the nap is nonzero.
        let pz = BatchPolicy { max_batch: 8, max_wait: Duration::ZERO };
        assert!(pz.nap(Some(t0), t0).unwrap() > Duration::ZERO);
    }
}
