//! Deadline-driven dynamic batcher.
//!
//! Requests accumulate in a queue; a batch flushes when either (a) enough
//! requests are waiting to fill the variant's largest executable, or (b)
//! the oldest queued request has waited `max_wait`. The flushed batch is
//! padded up to the smallest exported batch size ≥ its occupancy, keeping
//! tail latency bounded while letting throughput-heavy load ride the big
//! executables.

use std::time::{Duration, Instant};

/// One queued inference request (image + reply slot handled by server).
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Batching policy state machine (pure logic — tested without threads).
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// Decides whether to flush now given queue occupancy and the oldest
    /// enqueue time. Returns the number of requests to take (0 = wait).
    pub fn decide(&self, queued: usize, oldest: Option<Instant>, now: Instant) -> usize {
        if queued == 0 {
            return 0;
        }
        if queued >= self.max_batch {
            return self.max_batch;
        }
        match oldest {
            Some(t) if now.duration_since(t) >= self.max_wait => queued,
            _ => 0,
        }
    }

    /// How long the batcher may sleep before the oldest request's deadline.
    pub fn nap(&self, oldest: Option<Instant>, now: Instant) -> Duration {
        match oldest {
            None => self.max_wait,
            Some(t) => self
                .max_wait
                .checked_sub(now.duration_since(t))
                .unwrap_or(Duration::ZERO),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_full_batch_immediately() {
        let p = BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) };
        let now = Instant::now();
        assert_eq!(p.decide(16, Some(now), now), 16);
        assert_eq!(p.decide(40, Some(now), now), 16);
    }

    #[test]
    fn waits_below_batch_until_deadline() {
        let p = BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        assert_eq!(p.decide(3, Some(t0), t0), 0);
        let later = t0 + Duration::from_millis(6);
        assert_eq!(p.decide(3, Some(t0), later), 3);
    }

    #[test]
    fn empty_queue_never_flushes() {
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let now = Instant::now();
        assert_eq!(p.decide(0, None, now), 0);
    }

    #[test]
    fn nap_shrinks_as_deadline_approaches() {
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) };
        let t0 = Instant::now();
        let nap0 = p.nap(Some(t0), t0);
        let nap1 = p.nap(Some(t0), t0 + Duration::from_millis(7));
        assert!(nap1 < nap0);
        assert_eq!(p.nap(Some(t0), t0 + Duration::from_millis(20)), Duration::ZERO);
    }
}
