//! Inference coordinator (Layer 3 request path).
//!
//! The paper's contribution lives in the quantizer + hardware; this is
//! the serving layer that mirrors the DPU's ability to host many (net,
//! method, p) precision points side by side. The center of the API is
//! the fleet-level [`Engine`]: ONE shared worker pool serves every
//! registered [`Variant`] (baseline / DLIQ / MIP2Q concurrently), each
//! variant owning a bounded queue and a deadline-driven [`BatchPolicy`],
//! with a deficit-round-robin scheduler handing freed workers the next
//! flushable batch so no variant can starve the others. Submission is
//! handle-based ([`VariantHandle::submit`] → [`Ticket`] or typed
//! [`SubmitError`]) and metrics are typed ([`MetricsSnapshot`], JSON-
//! serializable via `util/json`). Python is never on this path; threads
//! + channels (tokio is not in the vendored closure — see Cargo.toml).
//!
//! The old single-variant `Coordinator` shim has been deleted: register
//! exactly one variant on an [`Engine`] for the same behaviour on the
//! same thread budget. Native registrations flow through the
//! compiled-artifact cache ([`Router::register_native_cached`]) so a
//! warm cold-start decodes `.strumc` banks instead of re-quantizing.
//!
//! Deadline semantics: a submit may carry an absolute deadline
//! ([`VariantHandle::submit_deadline`]). Already-late work is refused at
//! the door (`SubmitError::Expired`), work whose deadline lapses while
//! queued is shed by the worker before execution (`ReplyError::Shed`
//! through the ticket — no backend cycles burned), and
//! [`Ticket::wait_deadline`] bounds the wait itself
//! (`ReplyError::DeadlineExpired`, with the late result still takeable
//! via [`Ticket::try_take`]). Scheduler fairness is tunable per variant:
//! [`Engine::register_weighted`] maps a priority weight to the DRR
//! quantum, so `base:4,dliq:1` style specs drain 4:1 under contention
//! without starving anyone. The TCP wire front-end over this API lives
//! in [`crate::server`].
//!
//! ## Observability
//!
//! Two complementary signals come out of the engine. The pull side is
//! [`Engine::metrics`]: a typed, schema-versioned [`MetricsSnapshot`]
//! (per-variant counters + reservoir-sampled latency percentiles +
//! fleet rollup, `metrics::METRICS_SCHEMA_VERSION` in its JSON). The
//! push side is [`crate::telemetry`]: pass a live `TelemetrySink` in
//! [`EngineOptions::telemetry`] and every counter update also emits one
//! structured JSONL event (request done/shed/rejected, batch formed,
//! variant registered/retired, periodic `engine_gauges` when
//! [`EngineOptions::telemetry_interval`] is set), so log-derived counts
//! reconcile exactly with the snapshot. Events ride a bounded channel
//! to a flusher thread — the request path never blocks on disk; events
//! dropped under overload surface as `telemetry_dropped` in the
//! snapshot.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;

pub use batcher::BatchPolicy;
pub use engine::{
    Engine, EngineOptions, InferReply, ReplyCallback, ReplyError, SubmitError, Ticket,
    VariantHandle,
};
pub use metrics::{
    bucket_index, bucket_le_us, FleetSnapshot, HistogramSnapshot, LatencyHistogram, LatencyStats,
    MetricsSnapshot, VariantSnapshot, WindowSnapshot, WireCounts, HIST_BUCKETS,
    METRICS_SCHEMA_VERSION,
};
pub use router::{Router, Variant};
