//! Inference coordinator (Layer 3 request path).
//!
//! The paper's contribution lives in the quantizer + hardware, so the
//! coordinator is the thin-but-real serving layer the system prompt's
//! architecture requires: a deadline-driven dynamic batcher in front of a
//! pluggable execution [`crate::backend::Backend`] (the native integer
//! engine or PJRT executables), with model-variant routing (baseline /
//! DLIQ / MIP2Q side by side) and latency/throughput metrics. Python is
//! never on this path; threads + channels (tokio is not in the vendored
//! closure — see Cargo.toml).

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use router::{Router, Variant};
pub use server::{Coordinator, CoordinatorOptions, InferReply};
