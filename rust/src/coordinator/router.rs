//! Model-variant routing: each variant = one (net, StruM transform) with
//! its prepared weight arguments and the set of batch-size executables
//! exported by `make artifacts`. Weights are dequantized and staged ONCE
//! at registration — the request path only binds the image tensor.

use crate::model::eval::{prepare_args, transform_network, EvalConfig};
use crate::model::import::NetWeights;
use crate::runtime::{Executable, Runtime, Tensor};
use crate::Result;
use anyhow::anyhow;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// One servable model variant.
pub struct Variant {
    pub key: String,
    pub net: String,
    pub classes: usize,
    pub img: usize,
    /// Ascending (batch size, executable).
    pub executables: Vec<(usize, Arc<Executable>)>,
    /// Static args (act_scales + weights), shared across requests.
    pub static_args: Vec<Tensor>,
}

impl Variant {
    /// Smallest exported batch ≥ n (or the largest available).
    pub fn pick_batch(&self, n: usize) -> (usize, &Arc<Executable>) {
        for (b, exe) in &self.executables {
            if *b >= n {
                return (*b, exe);
            }
        }
        let (b, exe) = self.executables.last().expect("no executables");
        (*b, exe)
    }

    pub fn max_batch(&self) -> usize {
        self.executables.last().map(|(b, _)| *b).unwrap_or(1)
    }
}

/// Routing table: variant key → prepared variant.
pub struct Router {
    pub rt: Arc<Runtime>,
    variants: HashMap<String, Arc<Variant>>,
}

impl Router {
    pub fn new(rt: Arc<Runtime>) -> Router {
        Router {
            rt,
            variants: HashMap::new(),
        }
    }

    /// Registers `net` under `key` with the given transform, discovering
    /// exported batch sizes from `artifacts/hlo/`.
    pub fn register(
        &mut self,
        key: &str,
        artifacts: &Path,
        net: &str,
        cfg: &EvalConfig,
    ) -> Result<Arc<Variant>> {
        let weights = NetWeights::load(artifacts, net)?;
        let transformed = transform_network(&weights, cfg)?;
        let static_args = prepare_args(&weights, &transformed, cfg.act_quant)?;
        let mut executables = Vec::new();
        let hlo_dir = artifacts.join("hlo");
        let prefix = format!("{}_b", net);
        let mut batches: Vec<usize> = std::fs::read_dir(&hlo_dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().to_string_lossy().to_string();
                name.strip_prefix(&prefix)
                    .and_then(|rest| rest.strip_suffix(".hlo.txt"))
                    .and_then(|b| b.parse::<usize>().ok())
            })
            .collect();
        batches.sort_unstable();
        if batches.is_empty() {
            return Err(anyhow!("no exported HLO for {} in {}", net, hlo_dir.display()));
        }
        for b in batches {
            let exe = self
                .rt
                .load_hlo(&hlo_dir.join(format!("{}_b{}.hlo.txt", net, b)))?;
            executables.push((b, exe));
        }
        let v = Arc::new(Variant {
            key: key.to_string(),
            net: net.to_string(),
            classes: weights.manifest.num_classes,
            img: 32,
            executables,
            static_args,
        });
        self.variants.insert(key.to_string(), v.clone());
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<Arc<Variant>> {
        self.variants.get(key).cloned()
    }

    pub fn keys(&self) -> Vec<String> {
        let mut k: Vec<String> = self.variants.keys().cloned().collect();
        k.sort();
        k
    }
}
