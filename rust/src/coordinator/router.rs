//! Model-variant routing: each variant = one (net, StruM transform) bound
//! to an execution [`Backend`] — PJRT executables or the native integer
//! engine. All weight staging (dequantize for PJRT, encode→dual-bank for
//! native) happens ONCE at registration; the request path only binds the
//! image tensor. Native registration can additionally go through the
//! compiled-artifact cache ([`Router::register_native_cached`]) so even
//! the one-time staging skips the quantizer on warm cold-starts.

use crate::artifact::{ArtifactCache, CacheOutcome};
use crate::backend::{Backend, BackendKind, NativeBackend, PjrtBackend};
use crate::model::eval::EvalConfig;
use crate::model::import::NetWeights;
use crate::runtime::Runtime;
use crate::Result;
use anyhow::anyhow;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// One servable model variant.
pub struct Variant {
    pub key: String,
    pub net: String,
    pub classes: usize,
    pub img: usize,
    pub backend: Arc<dyn Backend>,
}

impl Variant {
    fn from_backend(key: &str, backend: Arc<dyn Backend>) -> Variant {
        Variant {
            key: key.to_string(),
            net: backend.net().to_string(),
            classes: backend.classes(),
            img: backend.img(),
            backend,
        }
    }

    /// Batch size the backend wants `n` queued requests padded to.
    pub fn pick_batch(&self, n: usize) -> usize {
        self.backend.pick_batch(n)
    }

    pub fn max_batch(&self) -> usize {
        self.backend.batch_sizes().last().copied().unwrap_or(1)
    }

    /// Ascending batch sizes the backend executes natively.
    pub fn batches(&self) -> Vec<usize> {
        self.backend.batch_sizes().to_vec()
    }

    /// Expected image length in floats (`img · img · 3`).
    pub fn image_len(&self) -> usize {
        self.img * self.img * 3
    }
}

/// Routing table: variant key → prepared variant.
pub struct Router {
    /// PJRT runtime, present only when the router can register PJRT
    /// variants (a native-only router carries no runtime at all).
    pub rt: Option<Arc<Runtime>>,
    variants: HashMap<String, Arc<Variant>>,
}

impl Router {
    /// A router that can serve both PJRT and native variants.
    pub fn new(rt: Arc<Runtime>) -> Router {
        Router {
            rt: Some(rt),
            variants: HashMap::new(),
        }
    }

    /// A native-only router: no PJRT client, no XLA anywhere.
    pub fn native() -> Router {
        Router {
            rt: None,
            variants: HashMap::new(),
        }
    }

    /// Registers `net` under `key` on the PJRT backend (compatibility
    /// entry point — see [`Router::register_kind`]).
    pub fn register(
        &mut self,
        key: &str,
        artifacts: &Path,
        net: &str,
        cfg: &EvalConfig,
    ) -> Result<Arc<Variant>> {
        self.register_kind(key, artifacts, net, cfg, BackendKind::Pjrt)
    }

    /// Registers `net` under `key` with the given transform on the chosen
    /// backend, loading whatever artifacts that backend needs (HLO +
    /// weights for PJRT, weights alone for native).
    pub fn register_kind(
        &mut self,
        key: &str,
        artifacts: &Path,
        net: &str,
        cfg: &EvalConfig,
        kind: BackendKind,
    ) -> Result<Arc<Variant>> {
        let backend: Arc<dyn Backend> = match kind {
            BackendKind::Pjrt => {
                let rt = self
                    .rt
                    .as_ref()
                    .ok_or_else(|| {
                        anyhow!("router has no PJRT runtime (built with Router::native)")
                    })?;
                Arc::new(PjrtBackend::load(rt, artifacts, net, cfg)?)
            }
            BackendKind::Native => Arc::new(NativeBackend::load(artifacts, net, cfg)?),
        };
        self.insert(key, backend)
    }

    /// Registers a native variant from in-memory weights (tests, synthetic
    /// workloads — no artifact files involved).
    pub fn register_native_weights(
        &mut self,
        key: &str,
        weights: &NetWeights,
        cfg: &EvalConfig,
    ) -> Result<Arc<Variant>> {
        let backend: Arc<dyn Backend> = Arc::new(NativeBackend::new(weights, cfg)?);
        self.insert(key, backend)
    }

    /// Registers a native variant through the compiled-artifact cache:
    /// on a hit the backend binds from the `.strumc` bytes with zero
    /// quantize/encode work; on a miss it compiles once and persists.
    /// Returns the cache outcome alongside the variant so callers can
    /// surface it (CLI/CI assert cold starts really are cached).
    pub fn register_native_cached(
        &mut self,
        key: &str,
        weights: &NetWeights,
        cfg: &EvalConfig,
        cache: &ArtifactCache,
    ) -> Result<(Arc<Variant>, CacheOutcome)> {
        let (compiled, outcome) = cache.load_or_compile(weights, cfg)?;
        let backend: Arc<dyn Backend> = Arc::new(NativeBackend::from_compiled(&compiled)?);
        Ok((self.insert(key, backend)?, outcome))
    }

    /// Registers a variant straight from a standalone compiled artifact
    /// (`strum serve --artifact FILE`): decode-only bind, no weights or
    /// cache on the path. This is the replica-fleet deploy unit — a
    /// corrupt or version-skewed file fails here, at startup, where a
    /// supervisor can see it.
    pub fn register_native_compiled(
        &mut self,
        key: &str,
        compiled: &crate::artifact::CompiledNet,
    ) -> Result<Arc<Variant>> {
        let backend: Arc<dyn Backend> = Arc::new(NativeBackend::from_compiled(compiled)?);
        self.insert(key, backend)
    }

    fn insert(&mut self, key: &str, backend: Arc<dyn Backend>) -> Result<Arc<Variant>> {
        let v = Arc::new(Variant::from_backend(key, backend));
        self.variants.insert(key.to_string(), v.clone());
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<Arc<Variant>> {
        self.variants.get(key).cloned()
    }

    pub fn keys(&self) -> Vec<String> {
        let mut k: Vec<String> = self.variants.keys().cloned().collect();
        k.sort();
        k
    }
}
