//! Async wire tier: one readiness-based poller thread serving every
//! connection — binary protocol and HTTP — over non-blocking
//! `std::net` sockets, with a small dispatch pool between the poller
//! and the [`Engine`].
//!
//! # Architecture
//!
//! ```text
//!              ┌──────────────────────────────────────────────┐
//!   sockets ──▶│ poller (1 thread, poll(2) via a tiny FFI     │
//!              │ shim — no tokio, no libc crate)              │
//!              │  · accepts on the binary + HTTP listeners    │
//!              │  · reads/parses frames & HTTP requests       │
//!              │  · writes replies when sockets are writable  │
//!              └──────┬─────────────────────────▲─────────────┘
//!                work │ queue        completions│ + wake fd
//!              ┌──────▼─────────────────────────┴─────────────┐
//!              │ dispatch workers (N threads)                 │
//!              │  · fault injection, request accounting       │
//!              │  · hand requests to the handler; replies come│
//!              │    back as completion callbacks (the engine  │
//!              │    path never blocks a worker on a Ticket)   │
//!              └──────────────────────────────────────────────┘
//! ```
//!
//! The poller owns *all* connection state; nothing else touches a
//! socket. Cross-thread communication is two queues: decoded work
//! flows down to the dispatch pool, encoded completions flow back up,
//! and a `socketpair`-based wake fd interrupts `poll(2)` whenever a
//! completion (or shutdown) needs the poller's attention — no
//! busy-polling anywhere, unlike the legacy blocking tier's 100 ms
//! stop-flag read loop. One process holds 10k+ idle connections: an
//! idle connection costs one pollfd entry and its buffers, not a
//! thread.
//!
//! ## Ordering and pipelining
//!
//! Every parsed request gets a per-connection sequence number.
//! Connections that must be answered in order (binary v1 — no
//! correlation ids — and HTTP/1.1, where ordering is the protocol's
//! matching rule) buffer out-of-order completions in a `BTreeMap`
//! until their turn; binary v2 connections write completions the
//! moment they arrive, since the echoed correlation id does the
//! matching. The version byte travels per frame, and the first frame
//! fixes the connection's delivery mode: a v1-opened connection may
//! upgrade to v2 frames (ordered delivery never violates v2's
//! contract), but a v1 frame on a v2-opened connection is refused with
//! a typed `BadFrame` — its in-order contract can no longer be
//! honored once replies flow out of order. At most [`MAX_PIPELINE`]
//! requests may be outstanding per connection — past that the poller
//! simply stops reading from that socket (natural TCP backpressure)
//! until replies drain; requests already buffered past the cap resume
//! parsing as completions free slots.
//!
//! ## Shutdown
//!
//! `shutdown()` sets the stop flag and writes the wake byte. The
//! poller closes its listeners, stops parsing new input, drains every
//! outstanding reply (bounded by a drain deadline), then appends a
//! typed `ShuttingDown` refusal (binary) or `503` (HTTP) to each
//! still-open connection so peers learn the server is gone from a
//! frame, not a reset — the same contract as the legacy tier.

use super::fault::FaultState;
use super::http::{self, HttpParse};
use super::proto::{self, ErrorCode, FramedRequest, Request, Response};
use super::{ServerStats, ServerStatsSnapshot, WireHandler, WireServerOptions};
use crate::coordinator::{Engine, InferReply, ReplyCallback, ReplyError, SubmitError};
use crate::telemetry::{Event, TelemetrySink, TraceCtx};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outstanding-request cap per connection; past it the poller stops
/// reading that socket until replies drain (TCP backpressure, not an
/// error).
pub const MAX_PIPELINE: usize = 128;

/// Poll timeout. Nothing *requires* a wakeup this often — completions
/// and shutdown interrupt the poll via the wake fd — it only bounds
/// how stale the idle-connection sweep can get.
const POLL_TIMEOUT_MS: i32 = 1000;

/// How long shutdown waits for in-flight replies before force-closing.
const DRAIN_DEADLINE: Duration = Duration::from_secs(10);

/// Per-`read(2)` buffer size.
const READ_CHUNK: usize = 16 * 1024;

// ------------------------------------------------------- poll(2) shim

#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "macos")]
type Nfds = u32;
#[cfg(not(target_os = "macos"))]
type Nfds = std::os::raw::c_ulong;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
}

/// `poll(2)` with EINTR retry. The oldest of the crate's three FFI shims
/// (with `util::mmap` and `util::affinity`, all in the same style):
/// three i32/i16 fields and an errno check, small enough to audit at a
/// glance.
fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

// ------------------------------------------------------------ handler

/// A completion continuation: called exactly once with the response.
pub type DoneFn = Box<dyn FnOnce(Response) + Send + 'static>;

/// Completion-style request handler. Unlike [`WireHandler`] this never
/// blocks the calling thread waiting for the engine: the response is
/// delivered to `done` whenever it is ready (possibly on another
/// thread, possibly before `handle_async` returns).
pub trait AsyncWireHandler: Send + Sync + 'static {
    fn handle_async(
        &self,
        req: Request,
        arrived: Instant,
        stats: &ServerStats,
        trace: Option<TraceCtx>,
        done: DoneFn,
    );
}

/// The two ways a handler can be mounted: completion-native (the
/// engine), or a blocking [`WireHandler`] (the gateway's router) run
/// to completion on a dispatch worker — same concurrency as the
/// legacy tier's conn workers.
enum HandlerKind {
    Async(Arc<dyn AsyncWireHandler>),
    Blocking(Arc<dyn WireHandler>),
}

impl HandlerKind {
    fn call(
        &self,
        req: Request,
        arrived: Instant,
        stats: &ServerStats,
        trace: Option<TraceCtx>,
        done: DoneFn,
    ) {
        match self {
            HandlerKind::Async(h) => h.handle_async(req, arrived, stats, trace, done),
            HandlerKind::Blocking(h) => done(h.handle(req, arrived, stats, trace)),
        }
    }
}

/// The engine's completion-native implementation: same deadline
/// semantics as the blocking [`WireHandler`] impl in `conn` (door shed
/// → submit → reply mapped arm-for-arm), but the reply arrives via
/// [`Engine::submit_callback`] instead of parking a thread on a
/// `Ticket`.
impl AsyncWireHandler for Engine {
    fn handle_async(
        &self,
        req: Request,
        arrived: Instant,
        stats: &ServerStats,
        trace: Option<TraceCtx>,
        done: DoneFn,
    ) {
        match req {
            Request::Metrics => {
                done(Response::MetricsJson(
                    self.metrics().to_json().to_string_pretty(),
                ));
            }
            Request::Infer {
                key,
                deadline_budget_ms,
                image,
            } => {
                let deadline = (deadline_budget_ms > 0)
                    .then(|| arrived + Duration::from_millis(deadline_budget_ms as u64));
                if let Some(d) = deadline {
                    if Instant::now() >= d {
                        stats.record_shed_presubmit();
                        done(Response::Error {
                            code: ErrorCode::Expired,
                            detail: format!(
                                "budget of {} ms elapsed before submit",
                                deadline_budget_ms
                            ),
                        });
                        return;
                    }
                }
                let cb: ReplyCallback =
                    Box::new(move |res| done(reply_to_response(res, deadline)));
                if let Err((e, cb)) = self.submit_callback_traced(&key, image, deadline, trace, cb)
                {
                    // Refused at submit: feed the typed error through the
                    // same mapper the success path uses.
                    cb(Err(anyhow::Error::new(e)));
                }
            }
        }
    }
}

/// Maps an engine reply to a wire response — the callback-path twin of
/// the blocking tier's wait mapping. An `Ok` that lands after the
/// deadline reports `DeadlineExpired`, mirroring `wait_deadline`
/// abandoning a late reply.
fn reply_to_response(res: crate::Result<InferReply>, deadline: Option<Instant>) -> Response {
    match res {
        Ok(r) => {
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Response::Error {
                        code: ErrorCode::DeadlineExpired,
                        detail: "reply missed the deadline budget".into(),
                    };
                }
            }
            Response::Logits {
                class: r.class as u32,
                latency_us: r.latency.as_micros() as u64,
                occupancy: r.batch.0.min(u16::MAX as usize) as u16,
                padded: r.batch.1.min(u16::MAX as usize) as u16,
                logits: r.logits,
            }
        }
        Err(e) => {
            let code = if let Some(re) = e.downcast_ref::<ReplyError>() {
                match re {
                    ReplyError::Shed => ErrorCode::Shed,
                    ReplyError::DeadlineExpired => ErrorCode::DeadlineExpired,
                    ReplyError::Dropped => ErrorCode::ShuttingDown,
                    ReplyError::Batch(_) => ErrorCode::Batch,
                }
            } else if let Some(se) = e.downcast_ref::<SubmitError>() {
                ErrorCode::from_submit(se)
            } else {
                ErrorCode::Batch
            };
            Response::Error {
                code,
                detail: e.to_string(),
            }
        }
    }
}

// ---------------------------------------------------------- plumbing

#[derive(Clone, Copy, PartialEq, Eq)]
enum HttpKind {
    Infer,
    MetricsJson,
    Prometheus,
}

/// How to encode a completion for the wire — fixed at parse time, so
/// the encoding thread (worker or engine) needs no connection state.
enum EncodeMode {
    V1,
    V2 {
        corr_id: u32,
    },
    Http {
        kind: HttpKind,
        keep_alive: bool,
        method: String,
        path: String,
        start: Instant,
    },
}

enum WorkItem {
    One {
        conn: u64,
        seq: u64,
        req: Request,
        arrived: Instant,
        trace: Option<TraceCtx>,
        mode: EncodeMode,
    },
    /// A v2 streaming batch: fans out to one engine submit per image,
    /// joins into a single `OP_LOGITS_BATCH` completion.
    Batch {
        conn: u64,
        seq: u64,
        corr_id: u32,
        key: String,
        deadline_budget_ms: u32,
        px: usize,
        images: Vec<f32>,
        arrived: Instant,
        trace: Option<TraceCtx>,
    },
}

/// One encoded reply headed back to the poller.
struct Completion {
    conn: u64,
    seq: u64,
    bytes: Vec<u8>,
    /// Close the connection once this reply has flushed.
    close: bool,
    /// Fault injection: drop the connection now, without flushing.
    drop_now: bool,
}

struct AioShared {
    handler: HandlerKind,
    stopping: AtomicBool,
    stats: ServerStats,
    telemetry: TelemetrySink,
    fault: Option<FaultState>,
    work: Mutex<VecDeque<WorkItem>>,
    work_cv: Condvar,
    completions: Mutex<Vec<Completion>>,
    /// Write side of the poller's wake socketpair (non-blocking; a full
    /// pipe is fine — pending bytes already guarantee a wakeup).
    wake_tx: UnixStream,
}

impl AioShared {
    fn wake(&self) {
        let _ = (&self.wake_tx).write(&[1u8]);
    }

    fn complete(&self, c: Completion) {
        self.completions.lock().unwrap().push(c);
        self.wake();
    }

    fn push_work(&self, item: WorkItem) {
        self.work.lock().unwrap().push_back(item);
        self.work_cv.notify_one();
    }
}

/// Length-prefixes one payload (the poller writes whole frames from
/// buffers, never through `write_frame`'s flushing writer).
fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

fn refusal_frame() -> Vec<u8> {
    frame(&proto::encode_response(&Response::Error {
        code: ErrorCode::ShuttingDown,
        detail: "server is draining".into(),
    }))
}

// ------------------------------------------------------------- server

/// Readiness-based front-end serving the binary protocol and HTTP on
/// one poller. Construct with [`AioServer::bind`] (engine,
/// completion-native) or [`AioServer::bind_handler`] (any blocking
/// [`WireHandler`], e.g. the gateway router).
pub struct AioServer {
    addr: Option<SocketAddr>,
    http_addr: Option<SocketAddr>,
    shared: Arc<AioShared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl AioServer {
    /// Binds the engine behind the async tier. At least one of `listen`
    /// (binary protocol) / `http_listen` must be given.
    pub fn bind(
        listen: Option<&str>,
        http_listen: Option<&str>,
        engine: Arc<Engine>,
        opts: WireServerOptions,
    ) -> crate::Result<AioServer> {
        Self::bind_kind(listen, http_listen, HandlerKind::Async(engine), opts)
    }

    /// [`bind`](AioServer::bind) for a completion-native handler.
    pub fn bind_async(
        listen: Option<&str>,
        http_listen: Option<&str>,
        handler: Arc<impl AsyncWireHandler>,
        opts: WireServerOptions,
    ) -> crate::Result<AioServer> {
        Self::bind_kind(listen, http_listen, HandlerKind::Async(handler), opts)
    }

    /// [`bind`](AioServer::bind) for a blocking [`WireHandler`] — the
    /// gateway router mounts here; each request occupies a dispatch
    /// worker for its duration, exactly like the legacy tier's conn
    /// workers.
    pub fn bind_handler(
        listen: Option<&str>,
        http_listen: Option<&str>,
        handler: Arc<impl WireHandler>,
        opts: WireServerOptions,
    ) -> crate::Result<AioServer> {
        Self::bind_kind(listen, http_listen, HandlerKind::Blocking(handler), opts)
    }

    fn bind_kind(
        listen: Option<&str>,
        http_listen: Option<&str>,
        handler: HandlerKind,
        opts: WireServerOptions,
    ) -> crate::Result<AioServer> {
        anyhow::ensure!(
            listen.is_some() || http_listen.is_some(),
            "AioServer needs at least one listen address"
        );
        let bind_one = |addr: &str| -> crate::Result<TcpListener> {
            let l = TcpListener::bind(addr)?;
            l.set_nonblocking(true)?;
            Ok(l)
        };
        let binary = listen.map(bind_one).transpose()?;
        let httpl = http_listen.map(bind_one).transpose()?;
        let addr = binary.as_ref().map(|l| l.local_addr()).transpose()?;
        let http_addr = httpl.as_ref().map(|l| l.local_addr()).transpose()?;

        let (wake_rx, wake_tx) = UnixStream::pair()?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;

        let shared = Arc::new(AioShared {
            handler,
            stopping: AtomicBool::new(false),
            stats: ServerStats::default(),
            telemetry: opts.telemetry.clone(),
            fault: opts.fault.filter(|p| !p.is_empty()).map(FaultState::new),
            work: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            completions: Mutex::new(Vec::new()),
            wake_tx,
        });
        let workers = opts.conn_workers.max(1);
        let mut threads = Vec::with_capacity(workers + 1);
        {
            let sh = shared.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("aio-poll".into())
                    .spawn(move || poller(&sh, binary, httpl, wake_rx))?,
            );
        }
        for i in 0..workers {
            let sh = shared.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("aio-worker-{}", i))
                    .spawn(move || dispatch_worker(&sh))?,
            );
        }
        Ok(AioServer {
            addr,
            http_addr,
            shared,
            threads,
        })
    }

    /// Bound binary-protocol address, if a binary listener was opened.
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.addr
    }

    /// Bound HTTP address, if an HTTP listener was opened.
    pub fn http_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    pub fn stats(&self) -> ServerStatsSnapshot {
        self.shared.stats.snapshot()
    }

    /// Graceful drain: stop accepting, let in-flight requests answer,
    /// refuse still-open connections with a typed frame / 503, join
    /// every thread. Bounded by an internal drain deadline.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.threads.is_empty() {
            return;
        }
        let s = self.shared.stats.snapshot();
        self.shared.telemetry.emit(Event::ServerDrain {
            connections: s.connections,
            requests: s.requests,
        });
        self.shared.stopping.store(true, Ordering::Release);
        self.shared.wake();
        self.shared.work_cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for AioServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

// --------------------------------------------------- dispatch workers

fn dispatch_worker(sh: &Arc<AioShared>) {
    loop {
        let item = {
            let mut q = sh.work.lock().unwrap();
            loop {
                if let Some(it) = q.pop_front() {
                    break Some(it);
                }
                if sh.stopping.load(Ordering::Acquire) {
                    break None;
                }
                q = sh
                    .work_cv
                    .wait_timeout(q, Duration::from_millis(200))
                    .unwrap()
                    .0;
            }
        };
        let Some(item) = item else { return };
        match item {
            WorkItem::One {
                conn,
                seq,
                req,
                arrived,
                trace,
                mode,
            } => run_one(sh, conn, seq, req, arrived, trace, mode),
            WorkItem::Batch {
                conn,
                seq,
                corr_id,
                key,
                deadline_budget_ms,
                px,
                images,
                arrived,
                trace,
            } => run_batch(
                sh,
                conn,
                seq,
                corr_id,
                key,
                deadline_budget_ms,
                px,
                images,
                arrived,
                trace,
            ),
        }
    }
}

/// Runs one fault action (shared with the batch path). Returns `true`
/// if the connection should be dropped without a reply.
fn apply_fault(sh: &Arc<AioShared>, action: &super::fault::FaultAction, conn: u64, seq: u64) -> bool {
    if let Some(d) = action.delay {
        std::thread::sleep(d);
    }
    if action.kill {
        eprintln!("fault: kill-after tripped, exiting");
        std::process::exit(super::fault::FAULT_KILL_EXIT);
    }
    if action.drop_conn {
        sh.complete(Completion {
            conn,
            seq,
            bytes: Vec::new(),
            close: true,
            drop_now: true,
        });
        return true;
    }
    false
}

fn run_one(
    sh: &Arc<AioShared>,
    conn: u64,
    seq: u64,
    req: Request,
    arrived: Instant,
    trace: Option<TraceCtx>,
    mode: EncodeMode,
) {
    // Fault injection arms on infer ops only — metrics probes stay
    // truthful so health checkers see the misbehaving replica (parity
    // with the blocking tier).
    let action = match (&req, &sh.fault) {
        (Request::Infer { .. }, Some(f)) => f.next_action(),
        _ => Default::default(),
    };
    if apply_fault(sh, &action, conn, seq) {
        return;
    }
    if matches!(req, Request::Infer { .. }) {
        sh.stats.record_request();
    }
    let shc = sh.clone();
    let corrupt = action.corrupt;
    let done: DoneFn = Box::new(move |resp: Response| {
        let (bytes, close) = encode_completion(&shc, &mode, &resp, corrupt);
        shc.complete(Completion {
            conn,
            seq,
            bytes,
            close,
            drop_now: false,
        });
    });
    sh.handler.call(req, arrived, &sh.stats, trace, done);
}

#[allow(clippy::too_many_arguments)]
fn run_batch(
    sh: &Arc<AioShared>,
    conn: u64,
    seq: u64,
    corr_id: u32,
    key: String,
    deadline_budget_ms: u32,
    px: usize,
    images: Vec<f32>,
    arrived: Instant,
    trace: Option<TraceCtx>,
) {
    let count = images.len() / px.max(1);
    // The decoder rejects zero-image batches, but never trust that from
    // here: a batch that fans out into nothing would post no completion
    // and leak the connection's outstanding slot forever.
    if count == 0 {
        let bytes = frame(&proto::encode_response_v2(
            corr_id,
            &Response::Error {
                code: ErrorCode::BadFrame,
                detail: "batch carries no images".into(),
            },
        ));
        sh.complete(Completion {
            conn,
            seq,
            bytes,
            close: false,
            drop_now: false,
        });
        return;
    }
    let action = match &sh.fault {
        Some(f) => f.next_action(),
        None => Default::default(),
    };
    if apply_fault(sh, &action, conn, seq) {
        return;
    }
    let corrupt = action.corrupt;
    // Fan out one engine submit per image; the last completion to land
    // encodes the joined OP_LOGITS_BATCH frame. Rows keep submission
    // order regardless of completion order.
    let slots: Arc<Mutex<Vec<Option<Response>>>> = Arc::new(Mutex::new(vec![None; count]));
    let remaining = Arc::new(AtomicUsize::new(count));
    for i in 0..count {
        sh.stats.record_request();
        let req = Request::Infer {
            key: key.clone(),
            deadline_budget_ms,
            image: images[i * px..(i + 1) * px].to_vec(),
        };
        let shc = sh.clone();
        let slots = slots.clone();
        let remaining = remaining.clone();
        let done: DoneFn = Box::new(move |resp: Response| {
            slots.lock().unwrap()[i] = Some(resp);
            if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let rows: Vec<Response> = slots
                    .lock()
                    .unwrap()
                    .iter_mut()
                    .map(|s| s.take().expect("every row completed"))
                    .collect();
                let bytes = if corrupt {
                    frame(&[0xFF, 0xFF, 0xFF, 0xFF])
                } else {
                    frame(&proto::encode_logits_batch(corr_id, &rows))
                };
                shc.complete(Completion {
                    conn,
                    seq,
                    bytes,
                    close: false,
                    drop_now: false,
                });
            }
        });
        // Every image of a traced streaming batch shares the frame's
        // trace context — the spans distinguish them by batch row.
        sh.handler.call(req, arrived, &sh.stats, trace, done);
    }
}

/// Encodes one response per the request's [`EncodeMode`]; returns the
/// wire bytes and whether the connection closes after them. HTTP
/// completions emit their `http_request` telemetry here — the one
/// place every routed HTTP response passes through.
fn encode_completion(
    sh: &AioShared,
    mode: &EncodeMode,
    resp: &Response,
    corrupt: bool,
) -> (Vec<u8>, bool) {
    match mode {
        EncodeMode::V1 => {
            let bytes = if corrupt {
                frame(&[0xFF, 0xFF, 0xFF, 0xFF])
            } else {
                frame(&proto::encode_response(resp))
            };
            (bytes, false)
        }
        EncodeMode::V2 { corr_id } => {
            let bytes = if corrupt {
                frame(&[0xFF, 0xFF, 0xFF, 0xFF])
            } else {
                frame(&proto::encode_response_v2(*corr_id, resp))
            };
            (bytes, false)
        }
        EncodeMode::Http {
            kind,
            keep_alive,
            method,
            path,
            start,
        } => {
            let status = match resp {
                Response::Error { code, .. } => http::status_for(*code),
                _ => 200,
            };
            sh.stats.record_http_request();
            sh.telemetry.emit(Event::HttpRequest {
                method: method.clone(),
                path: path.clone(),
                status,
                latency_us: start.elapsed().as_micros() as u64,
            });
            let bytes = if corrupt {
                b"garbage that is not HTTP\r\n".to_vec()
            } else {
                http::render_response(resp, *keep_alive, matches!(kind, HttpKind::Prometheus))
            };
            (bytes, !keep_alive)
        }
    }
}

// -------------------------------------------------------------- poller

#[derive(Clone, Copy, PartialEq, Eq)]
enum ConnKind {
    Binary,
    Http,
}

struct Conn {
    stream: TcpStream,
    peer: String,
    kind: ConnKind,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    outpos: usize,
    next_seq: u64,
    next_write_seq: u64,
    /// Out-of-order completions waiting their turn (ordered conns).
    pending: BTreeMap<u64, (Vec<u8>, bool)>,
    outstanding: usize,
    served: u64,
    /// `None` until the first binary frame decides (v1 ⇒ ordered, v2 ⇒
    /// free); HTTP connections are always ordered.
    ordered: Option<bool>,
    reported_pipelined: bool,
    read_closed: bool,
    closing: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, peer: String, kind: ConnKind) -> Conn {
        Conn {
            stream,
            peer,
            kind,
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            outpos: 0,
            next_seq: 0,
            next_write_seq: 0,
            pending: BTreeMap::new(),
            outstanding: 0,
            served: 0,
            ordered: match kind {
                ConnKind::Http => Some(true),
                ConnKind::Binary => None,
            },
            reported_pipelined: false,
            read_closed: false,
            closing: false,
            dead: false,
        }
    }

    fn unflushed(&self) -> bool {
        self.outpos < self.outbuf.len()
    }

    /// Applies one completion: ordered connections flush strictly by
    /// sequence number, unordered ones append immediately.
    fn deliver(&mut self, seq: u64, bytes: Vec<u8>, close: bool, drop_now: bool) {
        if drop_now {
            self.dead = true;
            return;
        }
        if self.ordered.unwrap_or(true) {
            self.pending.insert(seq, (bytes, close));
            while let Some((b, c)) = self.pending.remove(&self.next_write_seq) {
                self.next_write_seq += 1;
                self.outstanding -= 1;
                self.served += 1;
                self.outbuf.extend_from_slice(&b);
                if c {
                    self.closing = true;
                }
            }
        } else {
            self.outstanding -= 1;
            self.served += 1;
            self.outbuf.extend_from_slice(&bytes);
            if close {
                self.closing = true;
            }
        }
    }
}

fn poller(
    sh: &Arc<AioShared>,
    mut binary: Option<TcpListener>,
    mut httpl: Option<TcpListener>,
    wake_rx: UnixStream,
) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut drain_deadline: Option<Instant> = None;
    let mut fds: Vec<PollFd> = Vec::new();
    let mut order: Vec<u64> = Vec::new();

    loop {
        let stopping = sh.stopping.load(Ordering::Acquire);
        if stopping {
            if drain_deadline.is_none() {
                drain_deadline = Some(Instant::now() + DRAIN_DEADLINE);
                // Close the listeners, refusing anything still in the
                // kernel backlog with a typed frame / 503.
                if let Some(l) = binary.take() {
                    drain_backlog(&l, ConnKind::Binary);
                }
                if let Some(l) = httpl.take() {
                    drain_backlog(&l, ConnKind::Http);
                }
            }
            let drained = conns.values().all(|c| c.outstanding == 0 && !c.unflushed());
            if drained || Instant::now() >= drain_deadline.unwrap() {
                final_refusals(sh, conns);
                return;
            }
        }

        // Rebuild the pollfd set. Index 0 is the wake fd, then the
        // listeners, then every connection (order[] maps fd slots back
        // to connection ids).
        fds.clear();
        order.clear();
        fds.push(PollFd {
            fd: wake_rx.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        });
        let listeners_at = fds.len();
        for l in binary.iter().chain(httpl.iter()) {
            fds.push(PollFd {
                fd: l.as_raw_fd(),
                events: POLLIN,
                revents: 0,
            });
        }
        let conns_at = fds.len();
        for (&id, c) in conns.iter() {
            let mut events = 0i16;
            if !c.read_closed && !c.closing && !stopping && c.outstanding < MAX_PIPELINE {
                events |= POLLIN;
            }
            if c.unflushed() {
                events |= POLLOUT;
            }
            fds.push(PollFd {
                fd: c.stream.as_raw_fd(),
                events,
                revents: 0,
            });
            order.push(id);
        }

        if poll_fds(&mut fds, POLL_TIMEOUT_MS).is_err() {
            // poll(2) itself failing (other than EINTR, retried inside)
            // means the fd set is broken; spinning would burn a core.
            std::thread::sleep(Duration::from_millis(10));
            continue;
        }

        // Drain the wake fd (bytes carry no meaning beyond the wakeup).
        if fds[0].revents & POLLIN != 0 {
            let mut sink = [0u8; 64];
            while matches!((&wake_rx).read(&mut sink), Ok(n) if n > 0) {}
        }

        // Accept on readable listeners.
        if !stopping {
            let mut slot = listeners_at;
            for (l, kind) in binary
                .iter()
                .map(|l| (l, ConnKind::Binary))
                .chain(httpl.iter().map(|l| (l, ConnKind::Http)))
            {
                if fds[slot].revents & POLLIN != 0 {
                    accept_ready(sh, l, kind, &mut conns, &mut next_id);
                }
                slot += 1;
            }
        }

        // Socket readiness.
        for (i, &id) in order.iter().enumerate() {
            let revents = fds[conns_at + i].revents;
            if revents == 0 {
                continue;
            }
            let Some(conn) = conns.get_mut(&id) else { continue };
            if revents & (POLLERR | POLLNVAL) != 0 {
                conn.dead = true;
                continue;
            }
            if revents & (POLLIN | POLLHUP) != 0 {
                try_read(conn);
                if !stopping {
                    parse_input(sh, id, conn);
                }
            }
            if revents & POLLOUT != 0 {
                try_write(conn);
            }
        }

        // Apply completions from the dispatch/engine side, then push
        // any freshly buffered bytes eagerly (most sockets are
        // writable; waiting a poll round would add latency for
        // nothing).
        let ready: Vec<Completion> = std::mem::take(&mut *sh.completions.lock().unwrap());
        for c in ready {
            if let Some(conn) = conns.get_mut(&c.conn) {
                conn.deliver(c.seq, c.bytes, c.close, c.drop_now);
            }
        }
        // Completions free pipeline slots; resume parsing any requests
        // that were buffered past the cap. No new socket bytes will
        // arrive to re-trigger parse_input — the data already sits in
        // inbuf, so backpressure must release here or never.
        if !stopping {
            for (&id, conn) in conns.iter_mut() {
                if !conn.inbuf.is_empty()
                    && !conn.read_closed
                    && !conn.closing
                    && !conn.dead
                    && conn.outstanding < MAX_PIPELINE
                {
                    parse_input(sh, id, conn);
                }
            }
        }
        for conn in conns.values_mut() {
            if conn.unflushed() && !conn.dead {
                try_write(conn);
            }
        }

        // Sweep closed connections.
        conns.retain(|_, c| {
            let done_writing = !c.unflushed();
            let remove = c.dead
                || (c.closing && done_writing)
                || (c.read_closed && c.outstanding == 0 && done_writing);
            if remove {
                sh.telemetry.emit(Event::ConnClosed {
                    peer: c.peer.clone(),
                    requests: c.served,
                });
            }
            !remove
        });
    }
}

fn accept_ready(
    sh: &Arc<AioShared>,
    listener: &TcpListener,
    kind: ConnKind,
    conns: &mut HashMap<u64, Conn>,
    next_id: &mut u64,
) {
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                sh.stats.record_connection();
                let peer = peer.to_string();
                sh.telemetry.emit(Event::ConnOpened { peer: peer.clone() });
                let id = *next_id;
                *next_id += 1;
                conns.insert(id, Conn::new(stream, peer, kind));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            // Transient accept failure (EMFILE, aborted handshake):
            // leave it for the next tick rather than spinning here.
            Err(_) => break,
        }
    }
}

fn try_read(conn: &mut Conn) {
    let mut buf = [0u8; READ_CHUNK];
    // Bounded per tick so one firehose connection cannot starve the
    // rest; leftover bytes re-arm via level-triggered poll.
    for _ in 0..16 {
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.read_closed = true;
                return;
            }
            Ok(n) => {
                conn.inbuf.extend_from_slice(&buf[..n]);
                if n < buf.len() {
                    return;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
    }
}

fn try_write(conn: &mut Conn) {
    while conn.unflushed() {
        match conn.stream.write(&conn.outbuf[conn.outpos..]) {
            Ok(0) => {
                conn.dead = true;
                return;
            }
            Ok(n) => conn.outpos += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
    }
    if !conn.unflushed() {
        conn.outbuf.clear();
        conn.outpos = 0;
    } else if conn.outpos > 64 * 1024 {
        conn.outbuf.drain(..conn.outpos);
        conn.outpos = 0;
    }
}

/// Parses as many complete requests as the buffer holds, enqueueing
/// work items. Stops at the pipeline cap (backpressure) or on a
/// protocol error (typed reply, then close).
fn parse_input(sh: &Arc<AioShared>, id: u64, conn: &mut Conn) {
    while !conn.closing && !conn.dead && conn.outstanding < MAX_PIPELINE {
        match conn.kind {
            ConnKind::Binary => {
                if conn.inbuf.len() < 4 {
                    return;
                }
                let len = u32::from_le_bytes(conn.inbuf[..4].try_into().unwrap()) as usize;
                if len > proto::MAX_FRAME {
                    sh.stats.record_protocol_error();
                    // Stop reading immediately — the stream may still be
                    // feeding bytes, but nothing after a protocol error
                    // is trustworthy (the typed reply below may queue
                    // behind in-flight replies before `closing` arms).
                    conn.read_closed = true;
                    answer_inline(
                        conn,
                        frame(&proto::encode_response(&Response::Error {
                            code: ErrorCode::BadFrame,
                            detail: format!("frame of {} bytes exceeds the cap", len),
                        })),
                        true,
                    );
                    return;
                }
                if conn.inbuf.len() < 4 + len {
                    return;
                }
                let payload: Vec<u8> = conn.inbuf[4..4 + len].to_vec();
                conn.inbuf.drain(..4 + len);
                let arrived = Instant::now();
                match proto::decode_request_framed(&payload) {
                    Ok(framed) => {
                        let is_v1 = matches!(framed, FramedRequest::V1(_));
                        match conn.ordered {
                            None => conn.ordered = Some(is_v1),
                            // A v1 frame after v2 negotiation carries no
                            // correlation id, and this connection already
                            // writes replies out of order — v1's strict
                            // in-order contract can't be honored anymore.
                            // Refuse the downgrade with a typed error.
                            // (The upgrade direction, v2 frames on a
                            // v1-opened connection, is fine: ordered
                            // delivery never violates v2's contract.)
                            Some(false) if is_v1 => {
                                sh.stats.record_protocol_error();
                                conn.read_closed = true;
                                answer_inline(
                                    conn,
                                    frame(&proto::encode_response(&Response::Error {
                                        code: ErrorCode::BadFrame,
                                        detail: "v1 frame on a connection negotiated to v2; \
                                                 version downgrade mid-connection is not allowed"
                                            .into(),
                                    })),
                                    true,
                                );
                                return;
                            }
                            _ => {}
                        }
                        let seq = begin_request(sh, conn);
                        let item = match framed {
                            FramedRequest::V1(req) => WorkItem::One {
                                conn: id,
                                seq,
                                req,
                                arrived,
                                trace: None,
                                mode: EncodeMode::V1,
                            },
                            FramedRequest::V2 { corr_id, req, trace } => WorkItem::One {
                                conn: id,
                                seq,
                                req,
                                arrived,
                                trace,
                                mode: EncodeMode::V2 { corr_id },
                            },
                            FramedRequest::V2Batch {
                                corr_id,
                                key,
                                deadline_budget_ms,
                                count: _,
                                px,
                                images,
                                trace,
                            } => WorkItem::Batch {
                                conn: id,
                                seq,
                                corr_id,
                                key,
                                deadline_budget_ms,
                                px,
                                images,
                                arrived,
                                trace,
                            },
                        };
                        sh.push_work(item);
                    }
                    Err(e) => {
                        sh.stats.record_protocol_error();
                        conn.read_closed = true;
                        answer_inline(
                            conn,
                            frame(&proto::encode_response(&Response::Error {
                                code: ErrorCode::BadFrame,
                                detail: e.to_string(),
                            })),
                            true,
                        );
                        return;
                    }
                }
            }
            ConnKind::Http => {
                let start = Instant::now();
                match http::try_parse(&conn.inbuf) {
                    HttpParse::Partial => return,
                    HttpParse::Bad(why) => {
                        sh.stats.record_protocol_error();
                        conn.read_closed = true;
                        http_inline(sh, conn, "?", "?", 400, "bad_request", &why, false, start);
                        return;
                    }
                    HttpParse::Ready { req, consumed } => {
                        conn.inbuf.drain(..consumed);
                        route_http(sh, id, conn, req, start);
                    }
                }
            }
        }
    }
}

/// Assigns the next sequence number, bumps the outstanding count, and
/// reports the first moment this connection actually pipelines (≥ 2
/// outstanding requests).
fn begin_request(sh: &Arc<AioShared>, conn: &mut Conn) -> u64 {
    let seq = conn.next_seq;
    conn.next_seq += 1;
    conn.outstanding += 1;
    if conn.outstanding >= 2 && !conn.reported_pipelined {
        conn.reported_pipelined = true;
        sh.stats.record_pipelined_conn();
        sh.telemetry.emit(Event::ConnPipelined {
            peer: conn.peer.clone(),
            depth: conn.outstanding as u64,
        });
    }
    seq
}

/// Delivers a poller-generated reply through the ordinary sequencing
/// machinery (so it interleaves correctly with in-flight requests).
fn answer_inline(conn: &mut Conn, bytes: Vec<u8>, close: bool) {
    let seq = conn.next_seq;
    conn.next_seq += 1;
    conn.outstanding += 1;
    conn.deliver(seq, bytes, close, false);
}

#[allow(clippy::too_many_arguments)]
fn http_inline(
    sh: &Arc<AioShared>,
    conn: &mut Conn,
    method: &str,
    path: &str,
    status: u16,
    error: &str,
    detail: &str,
    keep_alive: bool,
    start: Instant,
) {
    sh.stats.record_http_request();
    sh.telemetry.emit(Event::HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        status,
        latency_us: start.elapsed().as_micros() as u64,
    });
    answer_inline(
        conn,
        http::error_response(status, error, detail, keep_alive),
        !keep_alive,
    );
}

fn route_http(sh: &Arc<AioShared>, id: u64, conn: &mut Conn, req: http::HttpRequest, start: Instant) {
    let arrived = Instant::now();
    let keep_alive = req.keep_alive;
    let kind = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/infer") => HttpKind::Infer,
        ("GET", "/v1/metrics") => HttpKind::MetricsJson,
        ("GET", "/metrics") => HttpKind::Prometheus,
        (m, p) => {
            http_inline(
                sh,
                conn,
                m,
                p,
                404,
                "not_found",
                &format!("no route {} {}", m, p),
                keep_alive,
                start,
            );
            return;
        }
    };
    let wire_req = match kind {
        HttpKind::Infer => match http::parse_infer_body(&req.body) {
            Ok((key, deadline_ms, image)) => Request::Infer {
                key,
                deadline_budget_ms: deadline_ms,
                image,
            },
            Err(why) => {
                http_inline(
                    sh,
                    conn,
                    &req.method,
                    &req.path,
                    400,
                    "bad_request",
                    &why,
                    keep_alive,
                    start,
                );
                return;
            }
        },
        HttpKind::MetricsJson | HttpKind::Prometheus => Request::Metrics,
    };
    let seq = begin_request(sh, conn);
    // An `X-Strum-Trace` header enters the span pipeline exactly like a
    // v2 trace tail; HTTP carries no retry machinery, so attempt is 0.
    let trace = req.trace.map(|trace_id| TraceCtx {
        trace_id,
        attempt: 0,
    });
    sh.push_work(WorkItem::One {
        conn: id,
        seq,
        req: wire_req,
        arrived,
        trace,
        mode: EncodeMode::Http {
            kind,
            keep_alive,
            method: req.method,
            path: req.path,
            start,
        },
    });
}

/// Refuses whatever sits in the kernel accept backlog at shutdown with
/// a typed frame / 503 instead of a reset.
fn drain_backlog(listener: &TcpListener, kind: ConnKind) {
    while let Ok((mut stream, _)) = listener.accept() {
        let _ = stream.set_nonblocking(false);
        let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
        let bytes = match kind {
            ConnKind::Binary => refusal_frame(),
            ConnKind::Http => http::error_response(503, "shutting_down", "server is draining", false),
        };
        let _ = stream.write_all(&bytes);
    }
}

/// End of drain: every connection gets its remaining buffered replies
/// plus a typed refusal, written best-effort with a bounded timeout,
/// and its `conn_closed` telemetry event.
fn final_refusals(sh: &Arc<AioShared>, conns: HashMap<u64, Conn>) {
    for (_, mut conn) in conns {
        if !conn.dead && !conn.closing {
            let bytes = match conn.kind {
                ConnKind::Binary => refusal_frame(),
                ConnKind::Http => {
                    http::error_response(503, "shutting_down", "server is draining", false)
                }
            };
            conn.outbuf.extend_from_slice(&bytes);
        }
        if !conn.dead && conn.unflushed() {
            let _ = conn.stream.set_nonblocking(false);
            let _ = conn.stream.set_write_timeout(Some(Duration::from_secs(1)));
            let _ = conn.stream.write_all(&conn.outbuf[conn.outpos..]);
        }
        sh.telemetry.emit(Event::ConnClosed {
            peer: conn.peer.clone(),
            requests: conn.served,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poll_shim_reports_readiness() {
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        let mut fds = [PollFd {
            fd: a.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        }];
        // Nothing to read yet: poll times out with zero ready fds.
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
        (&b).write_all(&[1]).unwrap();
        fds[0].revents = 0;
        assert_eq!(poll_fds(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].revents & POLLIN != 0);
    }

    #[test]
    fn ordered_delivery_buffers_until_turn() {
        let stream = {
            // Any connected socket works; use a loopback pair.
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let c = TcpStream::connect(l.local_addr().unwrap()).unwrap();
            let _ = l.accept().unwrap();
            c
        };
        let mut conn = Conn::new(stream, "test".into(), ConnKind::Binary);
        conn.ordered = Some(true);
        conn.next_seq = 3;
        conn.outstanding = 3;
        conn.deliver(2, b"c".to_vec(), false, false);
        assert!(conn.outbuf.is_empty(), "seq 2 must wait for 0 and 1");
        conn.deliver(0, b"a".to_vec(), false, false);
        assert_eq!(conn.outbuf, b"a", "seq 0 flushes alone");
        conn.deliver(1, b"b".to_vec(), false, false);
        assert_eq!(conn.outbuf, b"abc", "1 unlocks the buffered 2");
        assert_eq!(conn.outstanding, 0);
        assert_eq!(conn.served, 3);
    }

    #[test]
    fn unordered_delivery_is_immediate() {
        let stream = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let c = TcpStream::connect(l.local_addr().unwrap()).unwrap();
            let _ = l.accept().unwrap();
            c
        };
        let mut conn = Conn::new(stream, "test".into(), ConnKind::Binary);
        conn.ordered = Some(false);
        conn.next_seq = 2;
        conn.outstanding = 2;
        conn.deliver(1, b"late".to_vec(), false, false);
        assert_eq!(conn.outbuf, b"late");
        conn.deliver(0, b"early".to_vec(), false, false);
        assert_eq!(conn.outbuf, b"lateearly");
    }
}
