//! Per-connection request loop for the **legacy blocking tier**
//! (`strum serve --legacy-threads`): framed read → deadline stamp →
//! handler dispatch → framed reply, one request at a time per
//! connection (pipelining safety comes from the strict
//! request/response ordering).
//!
//! Deprecated as a serving default — the stop-flag-polling read loop
//! below wastes a wakeup per [`READ_POLL`] per idle connection, and a
//! thread per connection caps fleet size. The async tier
//! ([`super::aio`]) replaces both with one poller and a wake fd; this
//! tier remains as a fallback and as the simplest reference
//! implementation of the protocol's serving semantics (the engine
//! `WireHandler` impl below is the behavioural spec the async tier's
//! callback path mirrors arm-for-arm).
//!
//! The loop is handler-agnostic ([`WireHandler`]): the engine answers
//! requests locally; the gateway answers them by routing to replicas.
//! This module also implements [`WireHandler`] for [`Engine`] — the
//! deadline-propagation logic below is that implementation.
//!
//! Deadline propagation: the absolute deadline is derived from the
//! frame's *arrival instant* plus the client's relative budget. From
//! there the request can be shed at three points, each with its own
//! typed wire code: before submit (`Expired` — the handler got to the
//! frame too late), in the engine queue (`Shed` — the worker dropped it
//! before execution), or at the wait (`DeadlineExpired` — the reply
//! missed the budget; the engine may still finish it, but nobody is
//! listening). None of the three can hang the connection.
//!
//! Graceful drain: a read aborted by the stop flag answers a typed
//! `ShuttingDown` frame before closing, so a peer that was between
//! requests learns the server is gone from a *frame*, not from a reset
//! socket (see the module-level "Failure model").

use super::fault::FaultState;
use super::proto::{self, ErrorCode, ProtoError, Request, Response};
use super::{ServerStats, WireHandler};
use crate::coordinator::{Engine, ReplyError};
use crate::telemetry::TraceCtx;
use std::io;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Read poll interval: how often a blocked read wakes to check the
/// server's stop flag (bounds shutdown latency without busy-waiting).
const READ_POLL: Duration = Duration::from_millis(100);

/// A connection that produces no complete frame within this window is
/// dropped — a silent or stalled peer cannot pin a conn worker (and
/// with it a slice of the fixed pool) indefinitely. Healthy idle
/// clients reconnect transparently: `WireClient` lazily redials on the
/// next call.
const CONN_READ_DEADLINE: Duration = Duration::from_secs(60);

/// Writes the one-frame `ShuttingDown` refusal used everywhere a
/// connection is turned away during drain (acceptor race, backlog
/// drain, idle reads aborted by the stop flag).
pub(crate) fn write_refusal(w: &mut impl io::Write) -> io::Result<()> {
    proto::write_frame(
        w,
        &proto::encode_response(&Response::Error {
            code: ErrorCode::ShuttingDown,
            detail: "server is draining".into(),
        }),
    )
}

/// Serves one connection to completion. Returns the number of framed
/// requests answered (for the `conn_closed` telemetry event) when the
/// peer closes, the stream breaks, a protocol error is answered, or the
/// server stops.
pub(crate) fn serve_conn(
    mut stream: TcpStream,
    handler: &dyn WireHandler,
    stats: &ServerStats,
    stopping: &AtomicBool,
    fault: Option<&FaultState>,
) -> u64 {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let mut served = 0u64;
    loop {
        // Timeout wake-ups between frames poll the stop flag and the
        // per-frame read deadline; once a frame has started it is read
        // to completion unless the server is stopping or the peer has
        // stalled past the deadline (those bytes could not be answered
        // in time anyway).
        let wait_started = Instant::now();
        let mut stop_abort = false;
        let read = proto::read_frame_poll(&mut stream, || {
            if stopping.load(Ordering::Acquire) {
                stop_abort = true;
                return true;
            }
            wait_started.elapsed() >= CONN_READ_DEADLINE
        });
        let payload = match read {
            Ok(Some(p)) => p,
            Ok(None) => {
                // A drained stop gets a typed refusal; a clean peer EOF
                // gets nothing (there is nobody left to read it).
                if stop_abort {
                    let _ = write_refusal(&mut stream);
                }
                return served;
            }
            Err(ProtoError::FrameTooLarge { len }) => {
                stats.record_protocol_error();
                let _ = respond(
                    &mut stream,
                    &Response::Error {
                        code: ErrorCode::BadFrame,
                        detail: format!("frame of {} bytes exceeds the cap", len),
                    },
                );
                return served;
            }
            // Mid-frame truncation / I/O failure: the stream is not
            // frame-aligned any more, so there is nothing safe to say.
            Err(_) => {
                stats.record_protocol_error();
                return served;
            }
        };
        let arrived = Instant::now();
        let req = match proto::decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                stats.record_protocol_error();
                let _ = respond(
                    &mut stream,
                    &Response::Error {
                        code: ErrorCode::BadFrame,
                        detail: e.to_string(),
                    },
                );
                return served;
            }
        };
        // Fault injection applies to infer requests only: metrics
        // probes stay truthful so health checkers see an accurate view
        // of a replica that is misbehaving at the request layer.
        let action = match (&req, fault) {
            (Request::Infer { .. }, Some(f)) => f.next_action(),
            _ => Default::default(),
        };
        if matches!(req, Request::Infer { .. }) {
            stats.record_request();
        }
        // The legacy tier speaks v1 only, and v1 frames never carry a
        // trace tail — requests through this loop are always untraced.
        let resp = handler.handle(req, arrived, stats, None);
        if let Some(d) = action.delay {
            std::thread::sleep(d);
        }
        if action.kill {
            eprintln!("fault: kill-after tripped, exiting");
            std::process::exit(super::fault::FAULT_KILL_EXIT);
        }
        if action.drop_conn {
            return served;
        }
        let wrote = if action.corrupt {
            // A garbage frame the peer's decoder must reject — length
            // prefix valid, payload version byte nonsense.
            proto::write_frame(&mut stream, &[0xFF, 0xFF, 0xFF, 0xFF])
        } else {
            respond(&mut stream, &resp)
        };
        if wrote.is_err() {
            return served;
        }
        served += 1;
    }
}

/// The engine is the canonical wire handler: requests are answered by
/// local inference through the multi-variant queue.
impl WireHandler for Engine {
    fn handle(
        &self,
        req: Request,
        arrived: Instant,
        stats: &ServerStats,
        trace: Option<TraceCtx>,
    ) -> Response {
        match req {
            Request::Metrics => Response::MetricsJson(self.metrics().to_json().to_string_pretty()),
            Request::Infer {
                key,
                deadline_budget_ms,
                image,
            } => handle_infer(self, stats, &key, image, deadline_budget_ms, arrived, trace),
        }
    }
}

/// One inference: door-shed check → submit with deadline → bounded wait.
#[allow(clippy::too_many_arguments)]
fn handle_infer(
    engine: &Engine,
    stats: &ServerStats,
    key: &str,
    image: Vec<f32>,
    deadline_budget_ms: u32,
    arrived: Instant,
    trace: Option<TraceCtx>,
) -> Response {
    let deadline =
        (deadline_budget_ms > 0).then(|| arrived + Duration::from_millis(deadline_budget_ms as u64));
    // Shed before submit: the budget burned down while the frame waited
    // its turn on this connection.
    if let Some(d) = deadline {
        if Instant::now() >= d {
            stats.record_shed_presubmit();
            return Response::Error {
                code: ErrorCode::Expired,
                detail: format!(
                    "budget of {} ms elapsed before submit",
                    deadline_budget_ms
                ),
            };
        }
    }
    // (An `Expired` from the engine's own door check is NOT counted as
    // a server-level presubmit shed — the engine already records it in
    // the variant's shed metric, and counting both layers would tally
    // the same request twice.)
    let ticket = match engine.submit_traced(key, image, deadline, trace) {
        Ok(t) => t,
        Err(e) => {
            return Response::Error {
                code: ErrorCode::from_submit(&e),
                detail: e.to_string(),
            };
        }
    };
    let result = match deadline {
        // `wait_deadline` bounds tail latency: a reply that misses the
        // budget is abandoned (typed), never waited on indefinitely.
        Some(d) => ticket.wait_deadline(d.saturating_duration_since(Instant::now())),
        None => ticket.wait(),
    };
    match result {
        Ok(r) => Response::Logits {
            class: r.class as u32,
            latency_us: r.latency.as_micros() as u64,
            occupancy: r.batch.0.min(u16::MAX as usize) as u16,
            padded: r.batch.1.min(u16::MAX as usize) as u16,
            logits: r.logits,
        },
        Err(e) => {
            let code = match e.downcast_ref::<ReplyError>() {
                Some(ReplyError::Shed) => ErrorCode::Shed,
                Some(ReplyError::DeadlineExpired) => ErrorCode::DeadlineExpired,
                Some(ReplyError::Dropped) => ErrorCode::ShuttingDown,
                Some(ReplyError::Batch(_)) | None => ErrorCode::Batch,
            };
            Response::Error {
                code,
                detail: e.to_string(),
            }
        }
    }
}

fn respond(stream: &mut TcpStream, resp: &Response) -> io::Result<()> {
    proto::write_frame(stream, &proto::encode_response(resp))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refusal_is_one_typed_shutting_down_frame() {
        let mut buf = Vec::new();
        write_refusal(&mut buf).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let payload = proto::read_frame(&mut r).unwrap().expect("one frame");
        match proto::decode_response(&payload).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::ShuttingDown),
            other => panic!("expected a typed refusal, got {:?}", other),
        }
        // Nothing after the refusal frame.
        assert!(proto::read_frame(&mut r).unwrap().is_none());
    }
}
