//! Wire client: typed request/response calls over one cached TCP
//! connection, with lazy connect, bounded reconnect backoff, and one
//! transparent in-call retry.
//!
//! Server-side refusals (queue full, deadline shed, unknown variant, …)
//! are *data*, not errors: they come back as
//! [`WireResponse::Error`] with a typed [`ErrorCode`], so a load
//! generator can count sheds without string-matching. Transport and
//! protocol failures are `anyhow` errors wrapping a typed
//! [`WireCallError`] that carries the connect-attempt count — a caller
//! (the gateway's health checker, the router's failover path) can
//! distinguish "transient blip, first dial succeeded on retry" from
//! "dead: every backoff attempt refused".
//!
//! Connect semantics: a dial that fails is retried up to
//! [`WireClient::with_connect_attempts`] times with capped exponential
//! backoff and multiplicative jitter (via [`crate::util::prng`], so
//! replicas restarted en masse don't re-dial in lockstep).
//!
//! Retry semantics: a call that fails on a *reused* connection is
//! retried once on a fresh one (the cached socket may have idled out);
//! a call that fails on a fresh connection is reported. Inference is
//! idempotent, so the rare double-execute a retry can cause is safe.
//! A read **timeout** is terminal and never retried — the server may
//! still be executing the request.

use super::proto::{self, ErrorCode, ProtoError, Request, Response, TraceCtx};
use crate::util::prng::Rng;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Default per-call read timeout. Every call is bounded — a server whose
/// connection workers are all occupied (excess connections queue behind
/// the pool) produces a typed transport error here, never an indefinite
/// hang, honoring the "shed or fail, never hang" contract end to end.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Default dial attempts per call (first try + backed-off retries).
pub const DEFAULT_CONNECT_ATTEMPTS: u32 = 3;

/// First backoff step; doubles per attempt, jittered ×[0.5, 1.5).
const BACKOFF_BASE: Duration = Duration::from_millis(20);

/// Backoff ceiling.
const BACKOFF_CAP: Duration = Duration::from_secs(2);

/// One successful wire inference.
#[derive(Debug, Clone)]
pub struct WireInfer {
    pub class: usize,
    /// Queue→reply latency measured by the engine, microseconds.
    pub latency_us: u64,
    /// Batch the request rode in (occupancy, padded size).
    pub batch: (usize, usize),
    pub logits: Vec<f32>,
}

/// Outcome of one wire call: the server answered with logits or with a
/// typed refusal.
#[derive(Debug, Clone)]
pub enum WireResponse {
    Infer(WireInfer),
    Error { code: ErrorCode, detail: String },
}

impl WireResponse {
    /// Unwraps the inference, turning a typed refusal into an error.
    pub fn into_infer(self) -> crate::Result<WireInfer> {
        match self {
            WireResponse::Infer(r) => Ok(r),
            WireResponse::Error { code, detail } => {
                Err(anyhow::anyhow!("server refused: {} ({})", code, detail))
            }
        }
    }

    pub fn error_code(&self) -> Option<ErrorCode> {
        match self {
            WireResponse::Infer(_) => None,
            WireResponse::Error { code, .. } => Some(*code),
        }
    }
}

/// A transport/protocol failure with its retry history attached.
/// Surfaced through `anyhow` (downcast to inspect): `connect_attempts`
/// tells a supervisor whether the peer answered the dial at all, and
/// `timed_out` marks the one failure mode a caller must never blindly
/// re-submit (the request may still be executing server-side).
#[derive(Debug)]
pub struct WireCallError {
    pub addr: String,
    /// TCP dials performed across the whole call (0 when a cached
    /// connection failed mid-call without any redial).
    pub connect_attempts: u32,
    /// The call died waiting on a reply, not dialing or writing.
    pub timed_out: bool,
    pub detail: String,
}

impl std::fmt::Display for WireCallError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.timed_out {
            write!(
                f,
                "wire call to {} timed out ({}); not retried — the server may still be executing",
                self.addr, self.detail
            )
        } else {
            write!(
                f,
                "wire call to {} failed after {} connect attempt(s): {}",
                self.addr, self.connect_attempts, self.detail
            )
        }
    }
}

impl std::error::Error for WireCallError {}

/// Client for the `strum` wire protocol.
pub struct WireClient {
    addr: String,
    stream: Option<TcpStream>,
    read_timeout: Duration,
    connect_attempts: u32,
    rng: Rng,
}

impl WireClient {
    /// Lazy client: connects on first call.
    pub fn new(addr: impl Into<String>) -> WireClient {
        let addr = addr.into();
        // Deterministic per-address jitter stream: two clients dialing
        // the same restarted replica still de-correlate because each
        // process mixes its own pid in.
        let mut seed = 0xcbf29ce484222325u64 ^ (std::process::id() as u64);
        for b in addr.bytes() {
            seed = (seed ^ b as u64).wrapping_mul(0x100000001b3);
        }
        WireClient {
            addr,
            stream: None,
            read_timeout: DEFAULT_READ_TIMEOUT,
            connect_attempts: DEFAULT_CONNECT_ATTEMPTS,
            rng: Rng::new(seed),
        }
    }

    /// Overrides the per-call read timeout (floored at 1 ms — a zero
    /// timeout would mean "no timeout" to the OS and reintroduce the
    /// unbounded hang this exists to prevent).
    pub fn with_read_timeout(mut self, timeout: Duration) -> WireClient {
        self.read_timeout = timeout.max(Duration::from_millis(1));
        self
    }

    /// Overrides the dial attempts per call (floored at 1). Routers use
    /// 1: on a fleet, failing over to another replica beats waiting out
    /// a backoff against a dead one.
    pub fn with_connect_attempts(mut self, attempts: u32) -> WireClient {
        self.connect_attempts = attempts.max(1);
        self
    }

    /// Eager client: fails fast if the server is unreachable (single
    /// dial, no backoff — backoff applies to calls, where the caller
    /// has expressed intent to wait).
    pub fn connect(addr: impl Into<String>) -> crate::Result<WireClient> {
        let mut c = WireClient::new(addr).with_connect_attempts(1);
        c.ensure()
            .map_err(|e| anyhow::anyhow!("connect to {} failed: {}", c.addr, e))?;
        c.connect_attempts = DEFAULT_CONNECT_ATTEMPTS;
        Ok(c)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Drops the cached connection; the next call reconnects.
    pub fn disconnect(&mut self) {
        self.stream = None;
    }

    /// Backoff before dial `attempt` (1-based; attempt 0 dials
    /// immediately): `BACKOFF_BASE · 2^(attempt-1)`, jittered
    /// ×[0.5, 1.5), capped at [`BACKOFF_CAP`].
    fn backoff(&mut self, attempt: u32) -> Duration {
        let exp = BACKOFF_BASE.saturating_mul(1u32 << (attempt - 1).min(16));
        let jitter = 0.5 + self.rng.f64();
        exp.mul_f64(jitter).min(BACKOFF_CAP)
    }

    /// Ensures a live connection, dialing with bounded backoff. Returns
    /// the number of dials performed (0 = cached connection reused).
    fn ensure(&mut self) -> io::Result<u32> {
        if self.stream.is_some() {
            return Ok(0);
        }
        let mut last = None;
        for attempt in 0..self.connect_attempts {
            if attempt > 0 {
                let pause = self.backoff(attempt);
                std::thread::sleep(pause);
            }
            match TcpStream::connect(&self.addr) {
                Ok(s) => {
                    let _ = s.set_nodelay(true);
                    let _ = s.set_read_timeout(Some(self.read_timeout));
                    let _ = s.set_write_timeout(Some(self.read_timeout));
                    self.stream = Some(s);
                    return Ok(attempt + 1);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("connect_attempts floored at 1"))
    }

    fn call(&mut self, payload: &[u8]) -> crate::Result<Response> {
        self.call_with(payload, None)
    }

    /// One request/response exchange. `v2_corr` switches the reply
    /// decoder to the framed (v2) form and checks the echoed correlation
    /// id — this client keeps one request in flight, so a mismatch is a
    /// protocol error, not an out-of-order reply.
    fn call_with(&mut self, payload: &[u8], v2_corr: Option<u32>) -> crate::Result<Response> {
        let mut dials = 0u32;
        for attempt in 0..2u8 {
            let reused = self.stream.is_some();
            let mut timed_out = false;
            let result = (|| -> Result<Response, ProtoError> {
                dials += self.ensure()?;
                let s = self.stream.as_mut().expect("ensure just connected");
                proto::write_frame(s, payload)?;
                let frame = proto::read_frame_poll(s, || {
                    timed_out = true;
                    true
                })?;
                let p = match frame {
                    Some(p) => p,
                    None => return Err(ProtoError::Truncated { what: "response" }),
                };
                match v2_corr {
                    None => proto::decode_response(&p),
                    Some(want) => match proto::decode_response_framed(&p)? {
                        proto::FramedResponse::V2 { corr_id, resp } if corr_id == want => Ok(resp),
                        proto::FramedResponse::V2 { corr_id, .. } => Err(ProtoError::Corrupt(
                            format!("correlation id {} answers request {}", corr_id, want),
                        )),
                        _ => Err(ProtoError::Corrupt(
                            "expected a single v2 reply".into(),
                        )),
                    },
                }
            })();
            match result {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    self.disconnect();
                    // A timeout is terminal, never retried: the server
                    // may still be executing the request, and silently
                    // re-submitting would double the offered load
                    // exactly when the server is saturated.
                    if timed_out {
                        return Err(anyhow::Error::new(WireCallError {
                            addr: self.addr.clone(),
                            connect_attempts: dials,
                            timed_out: true,
                            detail: format!("no reply within {:?}", self.read_timeout),
                        }));
                    }
                    // Retry once only for a stale cached connection
                    // (idled out / server-side drop between calls).
                    let retryable =
                        matches!(e, ProtoError::Io(_) | ProtoError::Truncated { .. });
                    if attempt == 0 && reused && retryable {
                        continue;
                    }
                    return Err(anyhow::Error::new(WireCallError {
                        addr: self.addr.clone(),
                        connect_attempts: dials,
                        timed_out: false,
                        detail: e.to_string(),
                    }));
                }
            }
        }
        unreachable!("retry loop returns on the second attempt");
    }

    /// Submits one image with no deadline.
    pub fn infer(&mut self, key: &str, image: &[f32]) -> crate::Result<WireResponse> {
        self.infer_budget_ms(key, image, 0)
    }

    /// Submits one image with a relative deadline budget. Sub-millisecond
    /// budgets round up to 1 ms (0 on the wire means "no deadline").
    pub fn infer_deadline(
        &mut self,
        key: &str,
        image: &[f32],
        budget: Duration,
    ) -> crate::Result<WireResponse> {
        let ms = budget.as_millis().clamp(1, u32::MAX as u128) as u32;
        self.infer_budget_ms(key, image, ms)
    }

    /// Submits one image with an explicit millisecond budget (0 = none).
    pub fn infer_budget_ms(
        &mut self,
        key: &str,
        image: &[f32],
        budget_ms: u32,
    ) -> crate::Result<WireResponse> {
        self.infer_traced(key, image, budget_ms, None)
    }

    /// [`WireClient::infer_budget_ms`] plus an optional trace context.
    /// Untraced calls stay on the v1 frame; a traced call rides a v2
    /// frame (v1 has no trace tail). The async tier accepts v2 frames
    /// on any connection; the legacy blocking tier is v1-only and
    /// answers a traced call with a typed `BadFrame`.
    pub fn infer_traced(
        &mut self,
        key: &str,
        image: &[f32],
        budget_ms: u32,
        trace: Option<TraceCtx>,
    ) -> crate::Result<WireResponse> {
        let (payload, corr) = match trace {
            None => (proto::encode_infer(key, budget_ms, image), None),
            Some(t) => (
                proto::encode_infer_v2_traced(1, key, budget_ms, image, t),
                Some(1),
            ),
        };
        match self.call_with(&payload, corr)? {
            Response::Logits {
                class,
                latency_us,
                occupancy,
                padded,
                logits,
            } => Ok(WireResponse::Infer(WireInfer {
                class: class as usize,
                latency_us,
                batch: (occupancy as usize, padded as usize),
                logits,
            })),
            Response::Error { code, detail } => Ok(WireResponse::Error { code, detail }),
            Response::MetricsJson(_) => {
                Err(anyhow::anyhow!("metrics response to an infer request"))
            }
        }
    }

    /// Fetches the engine's `MetricsSnapshot` as a JSON string.
    pub fn metrics(&mut self) -> crate::Result<String> {
        match self.call(&proto::encode_request(&Request::Metrics))? {
            Response::MetricsJson(json) => Ok(json),
            Response::Error { code, detail } => {
                Err(anyhow::anyhow!("metrics refused: {} ({})", code, detail))
            }
            Response::Logits { .. } => {
                Err(anyhow::anyhow!("logits response to a metrics request"))
            }
        }
    }
}

// --------------------------------------------------- pipelined client

/// Protocol-v2 client: many requests in flight on one connection,
/// replies matched by correlation id. Unlike [`WireClient`] this is
/// deliberately bare — no reconnect, no retry — because a pipelined
/// stream has no safe generic recovery (which of the in-flight
/// requests executed?); loadgen and tests own that policy.
pub struct PipelinedClient {
    stream: TcpStream,
    next_corr: u32,
}

impl PipelinedClient {
    /// Single eager dial (read-bounded by [`DEFAULT_READ_TIMEOUT`]).
    pub fn connect(addr: &str) -> crate::Result<PipelinedClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connect to {} failed: {}", addr, e))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(DEFAULT_READ_TIMEOUT));
        let _ = stream.set_write_timeout(Some(DEFAULT_READ_TIMEOUT));
        Ok(PipelinedClient {
            stream,
            next_corr: 1,
        })
    }

    pub fn with_read_timeout(self, timeout: Duration) -> PipelinedClient {
        let _ = self
            .stream
            .set_read_timeout(Some(timeout.max(Duration::from_millis(1))));
        self
    }

    fn fresh_corr(&mut self) -> u32 {
        let id = self.next_corr;
        self.next_corr = self.next_corr.wrapping_add(1).max(1);
        id
    }

    /// Fires one infer request without waiting; returns its correlation
    /// id. Replies arrive via [`recv`](PipelinedClient::recv) in
    /// whatever order the server finishes them.
    pub fn submit(&mut self, key: &str, image: &[f32], budget_ms: u32) -> crate::Result<u32> {
        self.submit_traced(key, image, budget_ms, None)
    }

    /// [`submit`](PipelinedClient::submit) with an optional trace tail
    /// on the frame.
    pub fn submit_traced(
        &mut self,
        key: &str,
        image: &[f32],
        budget_ms: u32,
        trace: Option<TraceCtx>,
    ) -> crate::Result<u32> {
        let corr = self.fresh_corr();
        let payload = match trace {
            None => proto::encode_infer_v2(corr, key, budget_ms, image),
            Some(t) => proto::encode_infer_v2_traced(corr, key, budget_ms, image, t),
        };
        proto::write_frame(&mut self.stream, &payload)?;
        Ok(corr)
    }

    /// Fires a metrics request without waiting.
    pub fn submit_metrics(&mut self) -> crate::Result<u32> {
        let corr = self.fresh_corr();
        proto::write_frame(&mut self.stream, &proto::encode_metrics_v2(corr))?;
        Ok(corr)
    }

    /// Fires one streaming batch (`images.len() / px` images in one
    /// frame); the reply is a single `V2Batch` with one row per image
    /// in submission order.
    pub fn submit_batch(
        &mut self,
        key: &str,
        budget_ms: u32,
        px: usize,
        images: &[f32],
    ) -> crate::Result<u32> {
        anyhow::ensure!(px > 0 && images.len() % px == 0, "images must be whole");
        let corr = self.fresh_corr();
        proto::write_frame(
            &mut self.stream,
            &proto::encode_infer_batch(corr, key, budget_ms, images.len() / px, px, images),
        )?;
        Ok(corr)
    }

    /// Blocks for the next reply frame, whichever request it answers.
    pub fn recv(&mut self) -> crate::Result<proto::FramedResponse> {
        let frame = proto::read_frame(&mut self.stream)
            .map_err(|e| anyhow::anyhow!("pipelined read failed: {}", e))?
            .ok_or_else(|| anyhow::anyhow!("server closed mid-pipeline"))?;
        Ok(proto::decode_response_framed(&frame)?)
    }

    /// [`recv`](PipelinedClient::recv) narrowed to a single v2 infer
    /// reply: `(corr_id, outcome)`.
    pub fn recv_infer(&mut self) -> crate::Result<(u32, WireResponse)> {
        match self.recv()? {
            proto::FramedResponse::V2 {
                corr_id,
                resp: Response::Logits {
                    class,
                    latency_us,
                    occupancy,
                    padded,
                    logits,
                },
            } => Ok((
                corr_id,
                WireResponse::Infer(WireInfer {
                    class: class as usize,
                    latency_us,
                    batch: (occupancy as usize, padded as usize),
                    logits,
                }),
            )),
            proto::FramedResponse::V2 {
                corr_id,
                resp: Response::Error { code, detail },
            } => Ok((corr_id, WireResponse::Error { code, detail })),
            other => Err(anyhow::anyhow!(
                "expected a v2 infer reply, got {:?}",
                response_kind(&other)
            )),
        }
    }
}

fn response_kind(r: &proto::FramedResponse) -> &'static str {
    match r {
        proto::FramedResponse::V1(_) => "v1",
        proto::FramedResponse::V2 { .. } => "v2",
        proto::FramedResponse::V2Batch { .. } => "v2 batch",
    }
}

// -------------------------------------------------------- http client

/// Minimal keep-alive HTTP/1.1 caller for the async tier's JSON
/// endpoints — enough for loadgen and tests (real consumers use curl
/// or any HTTP library; the server speaks plain HTTP/1.1).
///
/// One cached connection, `Content-Length`-framed responses, and the
/// same one-retry-on-stale-connection policy as [`WireClient`].
pub struct HttpClient {
    addr: String,
    stream: Option<TcpStream>,
    read_timeout: Duration,
    dials: u64,
}

impl HttpClient {
    pub fn new(addr: impl Into<String>) -> HttpClient {
        HttpClient {
            addr: addr.into(),
            stream: None,
            read_timeout: DEFAULT_READ_TIMEOUT,
            dials: 0,
        }
    }

    pub fn with_read_timeout(mut self, timeout: Duration) -> HttpClient {
        self.read_timeout = timeout.max(Duration::from_millis(1));
        self
    }

    /// TCP dials performed so far — 1 across many requests proves
    /// keep-alive reuse.
    pub fn dials(&self) -> u64 {
        self.dials
    }

    /// `POST /v1/infer`; returns `(status, body)`.
    pub fn infer(
        &mut self,
        key: &str,
        image: &[f32],
        deadline_ms: u32,
    ) -> crate::Result<(u16, String)> {
        self.infer_traced(key, image, deadline_ms, None)
    }

    /// `POST /v1/infer` carrying an `X-Strum-Trace` header when `trace`
    /// is set, so the gateway/server stamps the request's spans with
    /// the caller's trace id instead of minting one.
    pub fn infer_traced(
        &mut self,
        key: &str,
        image: &[f32],
        deadline_ms: u32,
        trace: Option<u64>,
    ) -> crate::Result<(u16, String)> {
        use crate::util::json::Json;
        let body = Json::obj(vec![
            ("variant", Json::str(key)),
            ("deadline_ms", Json::Num(deadline_ms as f64)),
            (
                "image",
                Json::Arr(image.iter().map(|&x| Json::Num(x as f64)).collect()),
            ),
        ])
        .to_string();
        let extra: Vec<(String, String)> = trace
            .map(|t| {
                vec![(
                    "X-Strum-Trace".to_string(),
                    crate::telemetry::fmt_trace(t),
                )]
            })
            .unwrap_or_default();
        self.request_ext("POST", "/v1/infer", Some(&body), &extra)
    }

    /// Any request against the cached connection; returns
    /// `(status, body)`. Retries once on a fresh connection if a
    /// *reused* one failed (idled out between calls).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> crate::Result<(u16, String)> {
        self.request_ext(method, path, body, &[])
    }

    /// [`Self::request`] with extra headers appended to the fixed set.
    pub fn request_ext(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra_headers: &[(String, String)],
    ) -> crate::Result<(u16, String)> {
        for attempt in 0..2u8 {
            let reused = self.stream.is_some();
            match self.request_once(method, path, body, extra_headers) {
                Ok(out) => return Ok(out),
                Err(e) => {
                    self.stream = None;
                    if attempt == 0 && reused {
                        continue;
                    }
                    return Err(anyhow::anyhow!("http {} {} failed: {}", method, path, e));
                }
            }
        }
        unreachable!("retry loop returns on the second attempt");
    }

    fn request_once(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra_headers: &[(String, String)],
    ) -> io::Result<(u16, String)> {
        if self.stream.is_none() {
            let s = TcpStream::connect(&self.addr)?;
            let _ = s.set_nodelay(true);
            let _ = s.set_read_timeout(Some(self.read_timeout));
            let _ = s.set_write_timeout(Some(self.read_timeout));
            self.dials += 1;
            self.stream = Some(s);
        }
        let stream = self.stream.as_mut().expect("just connected");
        let body = body.unwrap_or("");
        let mut head = format!(
            "{} {} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
            method,
            path,
            self.addr,
            body.len(),
        );
        for (k, v) in extra_headers {
            head.push_str(&format!("{}: {}\r\n", k, v));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(body.as_bytes())?;
        stream.flush()?;

        // Read the response: headers to the terminator, then exactly
        // Content-Length body bytes.
        let mut buf = Vec::new();
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break i;
            }
            if buf.len() > 64 * 1024 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "response headers exceed 64 KiB",
                ));
            }
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-response",
                ));
            }
            buf.extend_from_slice(&chunk[..n]);
        };
        let head_text = String::from_utf8_lossy(&buf[..head_end]).to_string();
        let mut lines = head_text.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("bad status line {:?}", status_line),
                )
            })?;
        let mut content_length = 0usize;
        let mut keep_alive = true;
        for line in lines {
            let Some((name, value)) = line.split_once(':') else { continue };
            match name.trim().to_ascii_lowercase().as_str() {
                "content-length" => {
                    content_length = value.trim().parse().map_err(|_| {
                        io::Error::new(io::ErrorKind::InvalidData, "bad content-length")
                    })?;
                }
                "connection" => {
                    keep_alive = !value.trim().eq_ignore_ascii_case("close");
                }
                _ => {}
            }
        }
        let body_start = head_end + 4;
        let mut body_bytes = buf[body_start..].to_vec();
        while body_bytes.len() < content_length {
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-body",
                ));
            }
            body_bytes.extend_from_slice(&chunk[..n]);
        }
        body_bytes.truncate(content_length);
        if !keep_alive {
            self.stream = None;
        }
        let text = String::from_utf8(body_bytes)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "body is not utf-8"))?;
        Ok((status, text))
    }
}
