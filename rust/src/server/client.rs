//! Wire client: typed request/response calls over one cached TCP
//! connection, with lazy connect and one transparent reconnect retry.
//!
//! Server-side refusals (queue full, deadline shed, unknown variant, …)
//! are *data*, not errors: they come back as
//! [`WireResponse::Error`] with a typed [`ErrorCode`], so a load
//! generator can count sheds without string-matching. Transport and
//! protocol failures are `anyhow` errors.
//!
//! Retry semantics: a call that fails on a *reused* connection is
//! retried once on a fresh one (the cached socket may have idled out);
//! a call that fails on a fresh connection is reported. Inference is
//! idempotent, so the rare double-execute a retry can cause is safe.

use super::proto::{self, ErrorCode, ProtoError, Request, Response};
use std::io;
use std::net::TcpStream;
use std::time::Duration;

/// Default per-call read timeout. Every call is bounded — a server whose
/// connection workers are all occupied (excess connections queue behind
/// the pool) produces a typed transport error here, never an indefinite
/// hang, honoring the "shed or fail, never hang" contract end to end.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// One successful wire inference.
#[derive(Debug, Clone)]
pub struct WireInfer {
    pub class: usize,
    /// Queue→reply latency measured by the engine, microseconds.
    pub latency_us: u64,
    /// Batch the request rode in (occupancy, padded size).
    pub batch: (usize, usize),
    pub logits: Vec<f32>,
}

/// Outcome of one wire call: the server answered with logits or with a
/// typed refusal.
#[derive(Debug, Clone)]
pub enum WireResponse {
    Infer(WireInfer),
    Error { code: ErrorCode, detail: String },
}

impl WireResponse {
    /// Unwraps the inference, turning a typed refusal into an error.
    pub fn into_infer(self) -> crate::Result<WireInfer> {
        match self {
            WireResponse::Infer(r) => Ok(r),
            WireResponse::Error { code, detail } => {
                Err(anyhow::anyhow!("server refused: {} ({})", code, detail))
            }
        }
    }

    pub fn error_code(&self) -> Option<ErrorCode> {
        match self {
            WireResponse::Infer(_) => None,
            WireResponse::Error { code, .. } => Some(*code),
        }
    }
}

/// Client for the `strum` wire protocol.
pub struct WireClient {
    addr: String,
    stream: Option<TcpStream>,
    read_timeout: Duration,
}

impl WireClient {
    /// Lazy client: connects on first call.
    pub fn new(addr: impl Into<String>) -> WireClient {
        WireClient {
            addr: addr.into(),
            stream: None,
            read_timeout: DEFAULT_READ_TIMEOUT,
        }
    }

    /// Overrides the per-call read timeout (floored at 1 ms — a zero
    /// timeout would mean "no timeout" to the OS and reintroduce the
    /// unbounded hang this exists to prevent).
    pub fn with_read_timeout(mut self, timeout: Duration) -> WireClient {
        self.read_timeout = timeout.max(Duration::from_millis(1));
        self
    }

    /// Eager client: fails fast if the server is unreachable.
    pub fn connect(addr: impl Into<String>) -> crate::Result<WireClient> {
        let mut c = WireClient::new(addr);
        c.ensure()
            .map_err(|e| anyhow::anyhow!("connect to {} failed: {}", c.addr, e))?;
        Ok(c)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Drops the cached connection; the next call reconnects.
    pub fn disconnect(&mut self) {
        self.stream = None;
    }

    fn ensure(&mut self) -> io::Result<()> {
        if self.stream.is_none() {
            let s = TcpStream::connect(&self.addr)?;
            let _ = s.set_nodelay(true);
            let _ = s.set_read_timeout(Some(self.read_timeout));
            let _ = s.set_write_timeout(Some(self.read_timeout));
            self.stream = Some(s);
        }
        Ok(())
    }

    fn call(&mut self, payload: &[u8]) -> crate::Result<Response> {
        for attempt in 0..2u8 {
            let reused = self.stream.is_some();
            let mut timed_out = false;
            let result = (|| -> Result<Response, ProtoError> {
                self.ensure()?;
                let s = self.stream.as_mut().expect("ensure just connected");
                proto::write_frame(s, payload)?;
                let frame = proto::read_frame_poll(s, || {
                    timed_out = true;
                    true
                })?;
                match frame {
                    Some(p) => proto::decode_response(&p),
                    None => Err(ProtoError::Truncated { what: "response" }),
                }
            })();
            match result {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    self.disconnect();
                    // A timeout is terminal, never retried: the server
                    // may still be executing the request, and silently
                    // re-submitting would double the offered load
                    // exactly when the server is saturated.
                    if timed_out {
                        return Err(anyhow::anyhow!(
                            "wire call to {} timed out after {:?} (server saturated, \
                             stalled, or unreachable mid-call)",
                            self.addr,
                            self.read_timeout
                        ));
                    }
                    // Retry once only for a stale cached connection
                    // (idled out / server-side drop between calls).
                    let retryable =
                        matches!(e, ProtoError::Io(_) | ProtoError::Truncated { .. });
                    if attempt == 0 && reused && retryable {
                        continue;
                    }
                    return Err(anyhow::anyhow!("wire call to {} failed: {}", self.addr, e));
                }
            }
        }
        unreachable!("retry loop returns on the second attempt");
    }

    /// Submits one image with no deadline.
    pub fn infer(&mut self, key: &str, image: &[f32]) -> crate::Result<WireResponse> {
        self.infer_budget_ms(key, image, 0)
    }

    /// Submits one image with a relative deadline budget. Sub-millisecond
    /// budgets round up to 1 ms (0 on the wire means "no deadline").
    pub fn infer_deadline(
        &mut self,
        key: &str,
        image: &[f32],
        budget: Duration,
    ) -> crate::Result<WireResponse> {
        let ms = budget.as_millis().clamp(1, u32::MAX as u128) as u32;
        self.infer_budget_ms(key, image, ms)
    }

    /// Submits one image with an explicit millisecond budget (0 = none).
    pub fn infer_budget_ms(
        &mut self,
        key: &str,
        image: &[f32],
        budget_ms: u32,
    ) -> crate::Result<WireResponse> {
        let payload = proto::encode_infer(key, budget_ms, image);
        match self.call(&payload)? {
            Response::Logits {
                class,
                latency_us,
                occupancy,
                padded,
                logits,
            } => Ok(WireResponse::Infer(WireInfer {
                class: class as usize,
                latency_us,
                batch: (occupancy as usize, padded as usize),
                logits,
            })),
            Response::Error { code, detail } => Ok(WireResponse::Error { code, detail }),
            Response::MetricsJson(_) => {
                Err(anyhow::anyhow!("metrics response to an infer request"))
            }
        }
    }

    /// Fetches the engine's `MetricsSnapshot` as a JSON string.
    pub fn metrics(&mut self) -> crate::Result<String> {
        match self.call(&proto::encode_request(&Request::Metrics))? {
            Response::MetricsJson(json) => Ok(json),
            Response::Error { code, detail } => {
                Err(anyhow::anyhow!("metrics refused: {} ({})", code, detail))
            }
            Response::Logits { .. } => {
                Err(anyhow::anyhow!("logits response to a metrics request"))
            }
        }
    }
}
