//! Minimal HTTP/1.1 support for the async tier: an incremental
//! request parser, response builders, the `/v1/infer` JSON binding,
//! and the Prometheus text exposition of `MetricsSnapshot`.
//!
//! This is deliberately a *subset* of HTTP/1.1 — exactly what serving
//! JSON over keep-alive connections needs, with nothing speculative:
//!
//! - Request bodies are framed by `Content-Length` only
//!   (`Transfer-Encoding: chunked` is refused with `400`, never
//!   misparsed as an empty body).
//! - Connections are keep-alive by default (HTTP/1.1 semantics);
//!   `Connection: close` is honored, and HTTP/1.0 peers default to
//!   close unless they ask for keep-alive.
//! - Headers are capped at [`MAX_HEADER_BYTES`] and bodies at
//!   [`MAX_BODY_BYTES`]; a peer exceeding either gets a typed `400`
//!   and the connection closes — never an unbounded buffer.
//! - Responses always carry `Content-Length`, so the peer can reuse
//!   the connection without sniffing for EOF.
//!
//! The route table lives in [`aio`](super::aio) (the parser does not
//! know what paths exist); this module only converts bytes ↔ typed
//! requests/responses. HTTP/2 and TLS are explicit non-goals for now
//! (see ROADMAP follow-ups).

use super::proto::{ErrorCode, Response};
use crate::coordinator::{bucket_le_us, HistogramSnapshot};
use crate::telemetry::parse_trace;
use crate::util::json::Json;

/// Cap on the request line + headers (terminator included).
pub const MAX_HEADER_BYTES: usize = 64 * 1024;

/// Cap on a request body (matches the binary protocol's frame cap, so
/// the same image payloads fit through either front door).
pub const MAX_BODY_BYTES: usize = super::proto::MAX_FRAME;

/// One parsed HTTP request.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpRequest {
    pub method: String,
    /// Path only — a query string, if any, is split off and discarded
    /// (no endpoint takes query parameters today).
    pub path: String,
    /// Whether the connection should stay open after the response.
    pub keep_alive: bool,
    /// Trace id from an `X-Strum-Trace` header (hex, as printed by
    /// [`crate::telemetry::fmt_trace`]); `None` = untraced. A present
    /// but unparseable value is a `400`, never a silently dropped
    /// trace.
    pub trace: Option<u64>,
    pub body: Vec<u8>,
}

/// Incremental parse result over a connection's read buffer.
#[derive(Debug)]
pub enum HttpParse {
    /// A complete request; `consumed` bytes should be drained from the
    /// front of the buffer.
    Ready { req: HttpRequest, consumed: usize },
    /// The buffer does not hold a complete request yet.
    Partial,
    /// Irrecoverably malformed: answer `400` with this detail and
    /// close (the stream is no longer request-aligned).
    Bad(String),
}

/// Attempts to parse one request from the front of `buf`. Never
/// panics on hostile input; every length is checked against the caps
/// before any allocation sized from peer data.
pub fn try_parse(buf: &[u8]) -> HttpParse {
    let head_end = match find_terminator(buf) {
        Some(i) => i,
        None if buf.len() > MAX_HEADER_BYTES => {
            return HttpParse::Bad(format!("headers exceed the {} byte cap", MAX_HEADER_BYTES));
        }
        None => return HttpParse::Partial,
    };
    if head_end > MAX_HEADER_BYTES {
        return HttpParse::Bad(format!("headers exceed the {} byte cap", MAX_HEADER_BYTES));
    }
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(s) => s,
        Err(_) => return HttpParse::Bad("headers are not valid utf-8".into()),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("");
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() || parts.next().is_some() {
        return HttpParse::Bad(format!("malformed request line {:?}", request_line));
    }
    if !version.starts_with("HTTP/1.") {
        return HttpParse::Bad(format!("unsupported version {:?}", version));
    }
    // HTTP/1.1 defaults to keep-alive; HTTP/1.0 to close.
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_length = 0usize;
    let mut trace = None;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return HttpParse::Bad(format!("malformed header line {:?}", line));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => match value.parse::<usize>() {
                Ok(n) if n <= MAX_BODY_BYTES => content_length = n,
                Ok(n) => {
                    return HttpParse::Bad(format!(
                        "body of {} bytes exceeds the {} byte cap",
                        n, MAX_BODY_BYTES
                    ));
                }
                Err(_) => return HttpParse::Bad(format!("bad content-length {:?}", value)),
            },
            "transfer-encoding" => {
                return HttpParse::Bad("transfer-encoding is not supported; use content-length".into());
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "x-strum-trace" => match parse_trace(value) {
                Some(t) => trace = Some(t),
                None => {
                    return HttpParse::Bad(format!("bad x-strum-trace value {:?}", value));
                }
            },
            _ => {}
        }
    }
    let body_start = head_end + 4;
    let total = body_start + content_length;
    if buf.len() < total {
        return HttpParse::Partial;
    }
    let path = target.split('?').next().unwrap_or(target).to_string();
    HttpParse::Ready {
        req: HttpRequest {
            method,
            path,
            keep_alive,
            trace,
            body: buf[body_start..total].to_vec(),
        },
        consumed: total,
    }
}

/// Position of the `\r\n\r\n` header terminator, bounded by the header
/// cap (a hostile peer cannot make this scan unbounded memory: the
/// caller stops feeding bytes once `Bad` is returned).
fn find_terminator(buf: &[u8]) -> Option<usize> {
    let scan = buf.len().min(MAX_HEADER_BYTES + 4);
    buf[..scan].windows(4).position(|w| w == b"\r\n\r\n")
}

// ------------------------------------------------------------ responses

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        410 => "Gone",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Builds one complete response with `Content-Length` framing.
pub fn response(status: u16, content_type: &str, body: &[u8], keep_alive: bool) -> Vec<u8> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    let mut out = Vec::with_capacity(head.len() + body.len());
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body);
    out
}

/// A JSON `{"error": ..., "detail": ...}` response.
pub fn error_response(status: u16, error: &str, detail: &str, keep_alive: bool) -> Vec<u8> {
    let body = Json::obj(vec![
        ("error", Json::str(error)),
        ("detail", Json::str(detail)),
    ])
    .to_string();
    response(status, "application/json", body.as_bytes(), keep_alive)
}

/// HTTP status for a typed wire error (the JSON body still carries the
/// exact [`ErrorCode`] name — the status is for curl/monitors, the code
/// for programs).
pub fn status_for(code: ErrorCode) -> u16 {
    match code {
        ErrorCode::BadImage | ErrorCode::BadFrame => 400,
        ErrorCode::UnknownVariant => 404,
        ErrorCode::Retired => 410,
        ErrorCode::Batch => 500,
        ErrorCode::Upstream => 502,
        ErrorCode::QueueFull | ErrorCode::ShuttingDown | ErrorCode::Expired | ErrorCode::Shed => {
            503
        }
        ErrorCode::DeadlineExpired => 504,
    }
}

/// Parses a `POST /v1/infer` body:
/// `{"variant": "...", "deadline_ms": N, "image": [f, ...]}`
/// (`"key"` is accepted as an alias for `"variant"`; `deadline_ms`
/// defaults to 0 = no deadline). Returns `(key, deadline_ms, image)`.
pub fn parse_infer_body(body: &[u8]) -> Result<(String, u32, Vec<f32>), String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    let json = Json::parse(text).map_err(|e| format!("body is not valid json: {}", e))?;
    let key = json
        .get("variant")
        .or_else(|| json.get("key"))
        .and_then(|v| v.as_str())
        .ok_or_else(|| "missing string field \"variant\"".to_string())?
        .to_string();
    let deadline_ms = match json.get("deadline_ms") {
        None | Some(Json::Null) => 0,
        Some(v) => {
            let n = v
                .as_f64()
                .ok_or_else(|| "\"deadline_ms\" must be a number".to_string())?;
            if !(0.0..=u32::MAX as f64).contains(&n) {
                return Err(format!("\"deadline_ms\" {} out of range", n));
            }
            n as u32
        }
    };
    let image = json
        .get("image")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| "missing array field \"image\"".to_string())?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| "\"image\" must be an array of numbers".to_string())?;
    Ok((key, deadline_ms, image))
}

/// Renders an infer/metrics [`Response`] as one HTTP reply.
/// `prometheus` switches a `MetricsJson` payload to text exposition
/// (the `GET /metrics` route); logits serialize through f64, which is
/// exact for every finite f32, so JSON logits are bit-identical to the
/// binary protocol's.
pub fn render_response(resp: &Response, keep_alive: bool, prometheus: bool) -> Vec<u8> {
    match resp {
        Response::Logits {
            class,
            latency_us,
            occupancy,
            padded,
            logits,
        } => {
            let body = Json::obj(vec![
                ("class", Json::Num(*class as f64)),
                ("latency_us", Json::Num(*latency_us as f64)),
                (
                    "batch",
                    Json::obj(vec![
                        ("occupancy", Json::Num(*occupancy as f64)),
                        ("padded", Json::Num(*padded as f64)),
                    ]),
                ),
                (
                    "logits",
                    Json::Arr(logits.iter().map(|&x| Json::Num(x as f64)).collect()),
                ),
            ])
            .to_string();
            response(200, "application/json", body.as_bytes(), keep_alive)
        }
        Response::Error { code, detail } => {
            error_response(status_for(*code), code.name(), detail, keep_alive)
        }
        Response::MetricsJson(json) => {
            if prometheus {
                let body = prometheus_text(json);
                response(
                    200,
                    "text/plain; version=0.0.4; charset=utf-8",
                    body.as_bytes(),
                    keep_alive,
                )
            } else {
                response(200, "application/json", json.as_bytes(), keep_alive)
            }
        }
    }
}

// --------------------------------------------------- prometheus export

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"")
}

fn num(v: Option<&Json>) -> f64 {
    v.and_then(|j| j.as_f64()).unwrap_or(0.0)
}

/// Emits one histogram series: cumulative `_bucket{le=...}` lines (the
/// snapshot stores raw per-bucket counts), then `_sum` (seconds) and
/// `_count`. `labels` is either empty or `key="v",` — the trailing
/// comma composes with the `le` label.
fn push_histogram(out: &mut String, name: &str, labels: &str, h: &HistogramSnapshot) {
    let mut cum = 0u64;
    for (i, &n) in h.buckets.iter().enumerate() {
        cum += n;
        let le = match bucket_le_us(i) {
            Some(us) => format!("{}", us as f64 / 1e6),
            None => "+Inf".to_string(),
        };
        out.push_str(&format!(
            "{}_bucket{{{}le=\"{}\"}} {}\n",
            name, labels, le, cum
        ));
    }
    let plain = labels.trim_end_matches(',');
    let wrap = |s: &str| {
        if plain.is_empty() {
            s.to_string()
        } else {
            format!("{}{{{}}}", s, plain)
        }
    };
    out.push_str(&format!(
        "{} {}\n",
        wrap(&format!("{}_sum", name)),
        h.sum_us as f64 / 1e6
    ));
    out.push_str(&format!("{} {}\n", wrap(&format!("{}_count", name)), h.count));
}

/// Renders a `MetricsSnapshot` JSON document as Prometheus text
/// exposition (format 0.0.4). Unknown/missing fields render as 0 —
/// a scrape must never fail because a field moved.
pub fn prometheus_text(metrics_json: &str) -> String {
    let root = Json::parse(metrics_json).unwrap_or(Json::Null);
    let mut text = String::new();
    text.push_str(
        "# HELP strum_uptime_seconds Seconds since the engine started.\n# TYPE strum_uptime_seconds gauge\n",
    );
    text.push_str(&format!(
        "strum_uptime_seconds {}\n",
        num(root.get("uptime_s"))
    ));

    let fleet = [
        ("requests", "strum_requests_total", "Requests accepted for submit."),
        ("completed", "strum_requests_completed_total", "Requests answered with logits."),
        ("rejected", "strum_requests_rejected_total", "Requests refused at submit."),
        ("shed", "strum_requests_shed_total", "Requests shed by deadline pressure."),
        ("batches", "strum_batches_total", "Batches executed."),
    ];
    for (key, name, help) in fleet {
        text.push_str(&format!(
            "# HELP {} {}\n# TYPE {} counter\n",
            name, help, name
        ));
        text.push_str(&format!(
            "{} {}\n",
            name,
            num(root.get("fleet").and_then(|f| f.get(key)))
        ));
        if let Some(variants) = root.get("variants").and_then(|v| v.as_arr()) {
            for row in variants {
                let label = escape_label(row.get("key").and_then(|k| k.as_str()).unwrap_or("?"));
                text.push_str(&format!(
                    "{}{{variant=\"{}\"}} {}\n",
                    name,
                    label,
                    num(row.get(key))
                ));
            }
        }
    }

    text.push_str(
        "# HELP strum_queue_depth Requests waiting in a variant's queue.\n# TYPE strum_queue_depth gauge\n",
    );
    if let Some(variants) = root.get("variants").and_then(|v| v.as_arr()) {
        for row in variants {
            let label = escape_label(row.get("key").and_then(|k| k.as_str()).unwrap_or("?"));
            text.push_str(&format!(
                "strum_queue_depth{{variant=\"{}\"}} {}\n",
                label,
                num(row.get("queued"))
            ));
        }
    }

    // Native histogram exposition (replaces the old since-boot summary
    // quantiles): per-variant series from each row's `hist` block, plus
    // an unlabeled fleet series merged across variants. Raw per-bucket
    // counts accumulate into cumulative `le` form here.
    text.push_str(
        "# HELP strum_request_latency_seconds Completed-request latency histogram.\n# TYPE strum_request_latency_seconds histogram\n",
    );
    let mut fleet_hist = HistogramSnapshot::default();
    if let Some(variants) = root.get("variants").and_then(|v| v.as_arr()) {
        for row in variants {
            let label = escape_label(row.get("key").and_then(|k| k.as_str()).unwrap_or("?"));
            let h = row
                .get("hist")
                .map(HistogramSnapshot::from_json)
                .unwrap_or_default();
            fleet_hist.merge(&h);
            push_histogram(
                &mut text,
                "strum_request_latency_seconds",
                &format!("variant=\"{}\",", label),
                &h,
            );
        }
    }
    push_histogram(&mut text, "strum_request_latency_seconds", "", &fleet_hist);

    // Interval-delta block: what the engine observed since the previous
    // snapshot (scrape-to-scrape when Prometheus is the only caller).
    if let Some(w) = root.get("window") {
        text.push_str(
            "# HELP strum_window_seconds Length of the last metrics window.\n# TYPE strum_window_seconds gauge\n",
        );
        text.push_str(&format!(
            "strum_window_seconds {}\n",
            num(w.get("window_s"))
        ));
        text.push_str(
            "# HELP strum_window_requests Requests in the last window by outcome.\n# TYPE strum_window_requests gauge\n",
        );
        for key in ["completed", "shed", "rejected"] {
            text.push_str(&format!(
                "strum_window_requests{{outcome=\"{}\"}} {}\n",
                key,
                num(w.get(key))
            ));
        }
        text.push_str(
            "# HELP strum_window_latency_seconds Latency quantiles over the last window.\n# TYPE strum_window_latency_seconds gauge\n",
        );
        for (q, key) in [("0.5", "p50_us"), ("0.95", "p95_us"), ("0.99", "p99_us")] {
            text.push_str(&format!(
                "strum_window_latency_seconds{{quantile=\"{}\"}} {}\n",
                q,
                num(w.get(key)) / 1e6
            ));
        }
    }
    text.push_str(&format!(
        "# HELP strum_telemetry_dropped_total Telemetry events dropped by the bounded sink.\n# TYPE strum_telemetry_dropped_total counter\nstrum_telemetry_dropped_total {}\n",
        num(root.get("telemetry_dropped"))
    ));
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pipelined_keep_alive_requests() {
        let wire = b"POST /v1/infer HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: 2\r\n\r\n{}GET /v1/metrics HTTP/1.1\r\n\r\n";
        let HttpParse::Ready { req, consumed } = try_parse(wire) else {
            panic!("first request should parse");
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer");
        assert!(req.keep_alive);
        assert_eq!(req.body, b"{}");
        let HttpParse::Ready { req, consumed: c2 } = try_parse(&wire[consumed..]) else {
            panic!("second request should parse");
        };
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/v1/metrics");
        assert!(req.body.is_empty());
        assert_eq!(consumed + c2, wire.len());
    }

    #[test]
    fn partial_and_malformed_are_distinguished() {
        assert!(matches!(try_parse(b"GET /metr"), HttpParse::Partial));
        assert!(matches!(
            try_parse(b"GET /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nab"),
            HttpParse::Partial
        ));
        assert!(matches!(try_parse(b"NONSENSE\r\n\r\n"), HttpParse::Bad(_)));
        assert!(matches!(
            try_parse(b"GET /x HTTP/2.0\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        assert!(matches!(
            try_parse(b"GET /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        assert!(matches!(
            try_parse(b"GET /x HTTP/1.1\r\nContent-Length: zebra\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        // A header flood is refused once it passes the cap, not buffered
        // forever.
        let mut flood = b"GET /x HTTP/1.1\r\n".to_vec();
        flood.extend(std::iter::repeat(b'h').take(MAX_HEADER_BYTES + 8));
        assert!(matches!(try_parse(&flood), HttpParse::Bad(_)));
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let close = b"GET /m HTTP/1.1\r\nConnection: close\r\n\r\n";
        let HttpParse::Ready { req, .. } = try_parse(close) else {
            panic!()
        };
        assert!(!req.keep_alive);
        let old = b"GET /m HTTP/1.0\r\n\r\n";
        let HttpParse::Ready { req, .. } = try_parse(old) else {
            panic!()
        };
        assert!(!req.keep_alive);
        let old_ka = b"GET /m HTTP/1.0\r\nConnection: keep-alive\r\n\r\n";
        let HttpParse::Ready { req, .. } = try_parse(old_ka) else {
            panic!()
        };
        assert!(req.keep_alive);
    }

    #[test]
    fn infer_body_roundtrip_is_bit_exact() {
        // Every finite f32 survives the f32→f64→decimal→f64→f32 trip
        // exactly (f64 shortest-roundtrip printing); spot-check values
        // with awkward binary fractions.
        let vals: Vec<f32> = vec![0.1, -2.7182817, 3.4e38, f32::MIN_POSITIVE, 0.0];
        let body = format!(
            "{{\"variant\": \"k\", \"deadline_ms\": 7, \"image\": {}}}",
            Json::Arr(vals.iter().map(|&v| Json::Num(v as f64)).collect()).to_string()
        );
        let (key, dl, image) = parse_infer_body(body.as_bytes()).unwrap();
        assert_eq!(key, "k");
        assert_eq!(dl, 7);
        let got: Vec<u32> = image.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
        // Alias + defaults.
        let (key, dl, image) = parse_infer_body(b"{\"key\": \"x\", \"image\": []}").unwrap();
        assert_eq!((key.as_str(), dl, image.len()), ("x", 0, 0));
        // Typed refusals, not panics.
        assert!(parse_infer_body(b"{").is_err());
        assert!(parse_infer_body(b"{\"image\": [1]}").is_err());
        assert!(parse_infer_body(b"{\"variant\": \"k\", \"image\": [\"a\"]}").is_err());
        assert!(parse_infer_body(b"{\"variant\": \"k\", \"image\": [1], \"deadline_ms\": -4}").is_err());
    }

    #[test]
    fn responses_are_content_length_framed() {
        let bytes = render_response(
            &Response::Logits {
                class: 2,
                latency_us: 10,
                occupancy: 1,
                padded: 2,
                logits: vec![0.5, -1.5],
            },
            true,
            false,
        );
        let text = String::from_utf8(bytes).unwrap();
        let (head, body) = text.split_once("\r\n\r\n").unwrap();
        assert!(head.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(head.contains(&format!("Content-Length: {}", body.len())));
        assert!(head.contains("Connection: keep-alive"));
        assert!(body.contains("\"logits\":[0.5,-1.5]"));
        // Error statuses map per code; body keeps the typed name.
        let bytes = render_response(
            &Response::Error {
                code: ErrorCode::UnknownVariant,
                detail: "no variant \"z\"".into(),
            },
            false,
            false,
        );
        let text = String::from_utf8(bytes).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(text.contains("\"error\":\"unknown_variant\""));
        assert!(text.contains("Connection: close"));
    }

    #[test]
    fn prometheus_text_exposes_known_families() {
        let json = r#"{
            "uptime_s": 2.5, "telemetry_dropped": 1,
            "fleet": {"requests": 10, "completed": 8, "rejected": 1, "shed": 1, "batches": 4},
            "window": {"window_s": 1.5, "completed": 3, "shed": 1, "rejected": 0,
                       "p50_us": 1000, "p95_us": 2000, "p99_us": 3000},
            "variants": [{
                "key": "net:base:p0:native", "requests": 10, "completed": 8,
                "rejected": 1, "shed": 1, "batches": 4, "queued": 2,
                "latency": {"p50_us": 1000, "p95_us": 2000, "p99_us": 3000},
                "hist": {"buckets": [1, 2], "sum_us": 500, "count": 3}
            }]
        }"#;
        let text = prometheus_text(json);
        assert!(text.contains("# TYPE strum_requests_completed_total counter\n"));
        assert!(text.contains("strum_requests_completed_total 8\n"));
        assert!(text
            .contains("strum_requests_completed_total{variant=\"net:base:p0:native\"} 8\n"));
        assert!(text.contains("strum_uptime_seconds 2.5\n"));
        assert!(text.contains("strum_queue_depth{variant=\"net:base:p0:native\"} 2\n"));
        // Histogram exposition: cumulative le-form buckets per variant
        // plus an unlabeled fleet rollup.
        assert!(text.contains("# TYPE strum_request_latency_seconds histogram\n"));
        assert!(text.contains(
            "strum_request_latency_seconds_bucket{variant=\"net:base:p0:native\",le=\"0\"} 1\n"
        ));
        assert!(text.contains(
            "strum_request_latency_seconds_bucket{variant=\"net:base:p0:native\",le=\"+Inf\"} 3\n"
        ));
        assert!(text.contains("strum_request_latency_seconds_bucket{le=\"+Inf\"} 3\n"));
        assert!(text
            .contains("strum_request_latency_seconds_sum{variant=\"net:base:p0:native\"} 0.0005\n"));
        assert!(text
            .contains("strum_request_latency_seconds_count{variant=\"net:base:p0:native\"} 3\n"));
        assert!(text.contains("strum_request_latency_seconds_count 3\n"));
        // The old since-boot summary family is gone.
        assert!(!text.contains("strum_latency_seconds{"));
        // Window gauges.
        assert!(text.contains("strum_window_seconds 1.5\n"));
        assert!(text.contains("strum_window_requests{outcome=\"completed\"} 3\n"));
        assert!(text.contains("strum_window_latency_seconds{quantile=\"0.5\"} 0.001\n"));
        // Garbage input degrades to zeros, never a scrape failure.
        let fallback = prometheus_text("not json");
        assert!(fallback.contains("strum_requests_total 0\n"));
        assert!(fallback.contains("strum_request_latency_seconds_count 0\n"));
    }

    #[test]
    fn trace_header_parses_and_rejects_garbage() {
        let wire = b"GET /v1/metrics HTTP/1.1\r\nX-Strum-Trace: 00c0ffee00c0ffee\r\n\r\n";
        let HttpParse::Ready { req, .. } = try_parse(wire) else {
            panic!("traced request should parse");
        };
        assert_eq!(req.trace, Some(0x00c0_ffee_00c0_ffee));
        // Absent header = untraced.
        let HttpParse::Ready { req, .. } = try_parse(b"GET /v1/metrics HTTP/1.1\r\n\r\n") else {
            panic!()
        };
        assert_eq!(req.trace, None);
        // A malformed value is a typed 400, not a silently dropped trace.
        assert!(matches!(
            try_parse(b"GET /v1/metrics HTTP/1.1\r\nX-Strum-Trace: zebra\r\n\r\n"),
            HttpParse::Bad(_)
        ));
    }
}
