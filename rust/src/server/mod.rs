//! Wire serving front-end: a TCP server, protocol, and client library
//! in front of the multi-variant [`Engine`].
//!
//! This converts the crate from a library-with-a-CLI into a network
//! service: remote clients submit row-major image batches to any
//! registered (net, method, p) variant over a versioned, length-prefixed
//! binary protocol ([`proto`] documents the frame layout), with
//! per-request deadline budgets that are enforced server-side at three
//! stages (door / queue / wait — see [`proto::ErrorCode`]).
//!
//! Architecture — two tiers share the protocol and handler contract:
//!
//! - **Async tier ([`aio`], the default).** One poller thread over
//!   non-blocking sockets (readiness via a tiny `poll(2)` FFI shim —
//!   tokio is not in the vendored closure) owns accept, framing, and
//!   writes for *every* connection; a small dispatch pool hands
//!   requests to the handler, and the engine answers through
//!   completion callbacks instead of parking threads. One process
//!   holds 10k+ idle connections. The same poller serves the binary
//!   protocol (v1 in-order, v2 pipelined with correlation ids and
//!   streaming batches) and an HTTP/1.1 + JSON surface ([`http`]).
//! - **Legacy blocking tier ([`WireServer`], deprecated fallback
//!   behind `strum serve --legacy-threads`).** One acceptor thread
//!   plus a fixed pool of connection workers; each worker owns one
//!   connection and runs the strict request→response loop in [`conn`],
//!   polling the stop flag on a 200 ms read timeout. Fine for small
//!   fleets; a wall at production connection counts — prefer the async
//!   tier.
//!
//! [`WireClient`] is the matching client (lazy connect, one transparent
//! reconnect retry), [`PipelinedClient`] its v2 many-in-flight sibling,
//! and [`HttpClient`] a minimal keep-alive HTTP/1.1 caller; `strum
//! loadgen` drives all three as an open-loop load generator. `strum
//! serve --listen ADDR [--http-listen ADDR]` binds the server in front
//! of the engine the CLI builds.
//!
//! ## curl quickstart
//!
//! ```text
//! $ strum serve --compiled zoo.strumc --http-listen 127.0.0.1:8080
//! http listening on 127.0.0.1:8080
//!
//! # Inference (logits are bit-identical to the binary protocol):
//! $ curl -s -X POST http://127.0.0.1:8080/v1/infer \
//!     -H 'Content-Type: application/json' \
//!     -d '{"variant": "mini_cnn_s:base:p0:native",
//!          "deadline_ms": 250,
//!          "image": [0.1, 0.2, ...]}'
//! {"batch":{"occupancy":1,"padded":1},"class":3,"latency_us":412,"logits":[...]}
//!
//! # Engine metrics as JSON, or Prometheus text exposition:
//! $ curl -s http://127.0.0.1:8080/v1/metrics
//! $ curl -s http://127.0.0.1:8080/metrics | grep strum_requests_completed_total
//! ```
//!
//! ## Observability
//!
//! When a [`crate::telemetry::TelemetrySink`] is supplied via
//! [`WireServerOptions::telemetry`] (the CLI threads the engine's sink
//! through `strum serve --telemetry-out DIR`), the server emits
//! connection-lifecycle events into the same JSONL stream as the
//! engine: `conn_opened`/`conn_closed` (with the per-connection served
//! request count) around each connection, and one `server_drain` event
//! carrying the final connection/request totals when the graceful
//! shutdown begins. Engine-level request events (done/shed/rejected,
//! batches, gauges) come from the engine's own instrumentation — the
//! two layers share one `run_id` because they share one sink.
//!
//! **Request tracing.** A v2 request frame may carry an optional 9-byte
//! trace tail — a little-endian `u64` trace id plus a `u8` gateway
//! attempt ordinal ([`proto`] documents the exact layout). An absent
//! tail means an untraced request, so untraced v2 traffic is
//! byte-identical to before; v1 frames never carry traces, and the
//! legacy blocking tier refuses traced frames as `BadFrame` rather
//! than silently dropping the id. On HTTP the same context travels as
//! an `X-Strum-Trace` header (16 hex digits). The async tier decodes
//! the tail once at framing and hands a
//! [`crate::telemetry::TraceCtx`] to the handler, which threads it
//! into the engine so stage spans (and 1-in-N sampled per-layer
//! profiles) land in telemetry under that id. `WireClient::
//! infer_traced(.., None)` degrades to a plain v1 frame, so tracing is
//! strictly opt-in per request.
//!
//! ## Failure model
//!
//! What a peer can observe from this server, and what each observation
//! licenses it to do:
//!
//! - **Logits frame** — the request executed exactly once. Terminal.
//! - **Typed refusal frame** ([`ErrorCode`]) — the request was *not*
//!   executed (shed family, `QueueFull`, `ShuttingDown`, `Expired`) or
//!   failed in a way retrying elsewhere can help
//!   (`Shed`/`DeadlineExpired` on another, less-loaded replica).
//!   Application errors (`BadImage`, `UnknownVariant`, `BadFrame`,
//!   `Batch`) are deterministic: retrying them anywhere yields the same
//!   answer, so upstream routers must *not* retry those.
//! - **Connection error before any response byte** — the request may or
//!   may not have been read, but no reply was committed; inference is
//!   idempotent, so one bounded retry is safe.
//! - **Read timeout mid-call** — the server may still be executing;
//!   blind retry doubles offered load exactly when the server is
//!   saturated. [`WireClient`] treats this as terminal.
//!
//! Graceful drain strengthens the first two: every connection accepted
//! before `shutdown()` gets either a real answer or a typed
//! `ShuttingDown` refusal — including connections that race the stop
//! flag in the acceptor or sit unread in a worker's queue. Sockets
//! still in the kernel backlog when the listener closes are reset,
//! which peers see as a connection error (retriable, nothing
//! processed). A [`fault::FaultPlan`] can inject crashes, drops,
//! delays, and corrupt frames to prove supervisors survive each case.

pub mod aio;
pub mod client;
mod conn;
pub mod fault;
pub mod http;
pub mod proto;

pub use aio::{AioServer, AsyncWireHandler};
pub use client::{HttpClient, PipelinedClient, WireCallError, WireClient, WireInfer, WireResponse};
pub use fault::{FaultPlan, FaultState};
pub use proto::{ErrorCode, ProtoError};

use crate::coordinator::Engine;
use crate::telemetry::{Event, TelemetrySink};
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Answers decoded wire requests. The [`Engine`] is the canonical
/// implementation (local inference); the gateway implements it to
/// route requests across a replica fleet — both reuse the same
/// acceptor/worker/drain/fault machinery by construction.
pub trait WireHandler: Send + Sync + 'static {
    /// Answers one request. `arrived` is the instant the request frame
    /// finished reading — deadline budgets count down from it. `trace`
    /// is the request's trace context, if the peer supplied one (v2
    /// trace tail or `X-Strum-Trace`); handlers forward it into the
    /// engine so stage spans land in telemetry under that id.
    fn handle(
        &self,
        req: proto::Request,
        arrived: Instant,
        stats: &ServerStats,
        trace: Option<crate::telemetry::TraceCtx>,
    ) -> proto::Response;
}

/// Server tunables.
#[derive(Debug, Clone)]
pub struct WireServerOptions {
    /// Connection-worker threads (concurrent connections served; more
    /// connections queue behind them).
    pub conn_workers: usize,
    /// Structured-event sink for connection lifecycle events; share the
    /// engine's sink so both layers log under one `run_id`.
    pub telemetry: TelemetrySink,
    /// Deliberate misbehaviour for chaos tests ([`fault`]); `None` (the
    /// default) injects nothing.
    pub fault: Option<FaultPlan>,
}

impl Default for WireServerOptions {
    fn default() -> Self {
        WireServerOptions {
            conn_workers: 4,
            telemetry: TelemetrySink::disabled(),
            fault: None,
        }
    }
}

/// Server-level counters (engine-level serving metrics live in
/// [`Engine::metrics`]; these cover what happens before a request
/// reaches the engine).
#[derive(Debug, Default)]
pub struct ServerStats {
    connections: AtomicU64,
    requests: AtomicU64,
    shed_presubmit: AtomicU64,
    protocol_errors: AtomicU64,
    http_requests: AtomicU64,
    pipelined_conns: AtomicU64,
}

impl ServerStats {
    pub(crate) fn record_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn record_shed_presubmit(&self) {
        self.shed_presubmit.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn record_http_request(&self) {
        self.http_requests.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn record_pipelined_conn(&self) {
        self.pipelined_conns.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ServerStatsSnapshot {
        ServerStatsSnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            shed_presubmit: self.shed_presubmit.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            http_requests: self.http_requests.load(Ordering::Relaxed),
            pipelined_conns: self.pipelined_conns.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`ServerStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerStatsSnapshot {
    pub connections: u64,
    pub requests: u64,
    /// Requests shed by the connection handler before submit (budget
    /// already elapsed at dequeue).
    pub shed_presubmit: u64,
    pub protocol_errors: u64,
    /// HTTP responses written (async tier only; every routed or refused
    /// HTTP request counts exactly once, matching its `http_request`
    /// telemetry event).
    pub http_requests: u64,
    /// Connections that had ≥ 2 requests outstanding at least once
    /// (async tier only; matches `conn_pipelined` telemetry 1:1).
    pub pipelined_conns: u64,
}

struct ServerShared {
    handler: Arc<dyn WireHandler>,
    queue: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    stopping: AtomicBool,
    stats: ServerStats,
    telemetry: TelemetrySink,
    fault: Option<FaultState>,
}

/// Blocking TCP front-end over a [`WireHandler`] (usually an
/// [`Engine`]) — the **legacy tier**, kept as a fallback behind
/// `strum serve --legacy-threads`. Prefer [`AioServer`]: one poller
/// holds thousands of connections where this tier needs a thread each,
/// and its shutdown rides a wake fd instead of this tier's 100 ms
/// stop-flag read polling.
pub struct WireServer {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl WireServer {
    /// Binds `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and starts
    /// the acceptor + connection workers. The engine keeps serving any
    /// in-process handles concurrently — the wire front-end is just
    /// another submitter.
    pub fn bind(
        addr: &str,
        engine: Arc<Engine>,
        opts: WireServerOptions,
    ) -> crate::Result<WireServer> {
        WireServer::bind_handler(addr, engine, opts)
    }

    /// [`bind`](WireServer::bind) for any [`WireHandler`] — the gateway
    /// front-end mounts its router here.
    pub fn bind_handler(
        addr: &str,
        handler: Arc<impl WireHandler>,
        opts: WireServerOptions,
    ) -> crate::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shared = Arc::new(ServerShared {
            handler,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stopping: AtomicBool::new(false),
            stats: ServerStats::default(),
            telemetry: opts.telemetry.clone(),
            fault: opts.fault.filter(|p| !p.is_empty()).map(FaultState::new),
        });
        let workers = opts.conn_workers.max(1);
        let mut threads = Vec::with_capacity(workers + 1);
        {
            let sh = shared.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("wire-accept".into())
                    .spawn(move || accept_loop(&listener, &sh))?,
            );
        }
        for i in 0..workers {
            let sh = shared.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("wire-conn-{}", i))
                    .spawn(move || conn_worker(&sh))?,
            );
        }
        Ok(WireServer {
            addr: local,
            shared,
            threads,
        })
    }

    /// The bound address (resolves the ephemeral port of `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> ServerStatsSnapshot {
        self.shared.stats.snapshot()
    }

    /// Stops accepting, lets in-flight requests finish, joins every
    /// thread. Idle connections close within one read-poll interval.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.threads.is_empty() {
            return;
        }
        let s = self.shared.stats.snapshot();
        self.shared.telemetry.emit(Event::ServerDrain {
            connections: s.connections,
            requests: s.requests,
        });
        self.shared.stopping.store(true, Ordering::Release);
        // Unblock the acceptor with a throwaway loopback connection (the
        // accept call has no timeout of its own). A wildcard bind
        // address (0.0.0.0 / ::) is not connectable everywhere, so dial
        // localhost on the bound port instead, with a bounded timeout.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        self.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(listener: &TcpListener, sh: &ServerShared) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if sh.stopping.load(Ordering::Acquire) {
                    // Raced the stop flag: this is the shutdown wake-up
                    // or a real straggler that connected in the same
                    // tick. A straggler must get a typed `ShuttingDown`
                    // frame, not a silently dropped socket — and so
                    // must anything already sitting in the kernel
                    // accept backlog behind it.
                    refuse_conn(stream);
                    drain_backlog(listener);
                    return;
                }
                sh.stats.record_connection();
                sh.queue.lock().unwrap().push_back(stream);
                sh.cv.notify_one();
            }
            Err(_) => {
                if sh.stopping.load(Ordering::Acquire) {
                    return;
                }
                // Transient accept failure (EMFILE, aborted handshake):
                // back off briefly and keep serving.
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Answers a connection the server can no longer serve with one typed
/// `ShuttingDown` frame (best-effort, bounded) and closes it.
fn refuse_conn(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let _ = conn::write_refusal(&mut stream);
}

/// Empties the kernel accept backlog at shutdown, refusing each pending
/// connection with a typed frame instead of leaving it to be reset when
/// the listener closes.
fn drain_backlog(listener: &TcpListener) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    while let Ok((stream, _)) = listener.accept() {
        refuse_conn(stream);
    }
}

fn conn_worker(sh: &ServerShared) {
    loop {
        let stream = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                if sh.stopping.load(Ordering::Acquire) {
                    break None;
                }
                q = sh.cv.wait_timeout(q, Duration::from_millis(100)).unwrap().0;
            }
        };
        let Some(stream) = stream else { return };
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "unknown".to_string());
        sh.telemetry.emit(Event::ConnOpened { peer: peer.clone() });
        let served = conn::serve_conn(
            stream,
            &*sh.handler,
            &sh.stats,
            &sh.stopping,
            sh.fault.as_ref(),
        );
        sh.telemetry.emit(Event::ConnClosed {
            peer,
            requests: served,
        });
    }
}
