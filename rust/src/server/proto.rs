//! The `strum` wire protocol: a versioned, length-prefixed binary
//! framing for inference over TCP.
//!
//! # Frame layout (all little-endian)
//!
//! ```text
//! frame   := u32 len · payload            len = payload bytes (≤ MAX_FRAME)
//! payload := u8 version · u8 op · body              (version 1)
//!          | u8 version · u8 op · u32 corr_id · body (version 2)
//!
//! requests
//!   OP_INFER   u32 key_len · key bytes (UTF-8 variant key)
//!              u32 deadline_budget_ms   (0 = no deadline)
//!              u32 n · n × u32          (f32 bit patterns, row-major image)
//!   OP_METRICS (empty body)
//!   OP_INFER_BATCH (v2 only)
//!              u32 key_len · key bytes · u32 deadline_budget_ms
//!              u32 count · u32 px · count·px × u32 (f32 bit patterns)
//!
//! responses
//!   OP_LOGITS        u32 class · u64 latency_us
//!                    u16 batch_occupancy · u16 batch_padded
//!                    u32 n · n × u32    (f32 bit patterns, logit row)
//!   OP_ERROR         u8 code · u32 detail_len · detail bytes (UTF-8)
//!   OP_METRICS_JSON  u32 len · bytes    (MetricsSnapshot JSON)
//!   OP_LOGITS_BATCH  (v2 only) u32 count · count × row, where
//!                    row := u8 kind (0 = logits body, 1 = error body)
//! ```
//!
//! ## Version negotiation and pipelining
//!
//! Version 1 is the original strict request→response protocol: no
//! correlation ids, responses in request order. Version 2 prefixes every
//! payload with a client-chosen `u32 corr_id` echoed verbatim on the
//! response, which licenses the server to answer **out of order** — a
//! v2 client can pipeline many requests on one connection and match
//! replies by id. The version byte travels per frame, and the async
//! server decides per connection from the FIRST frame: a connection that
//! opens with v1 is served strictly in order end-to-end (old clients
//! keep working unchanged against the new tier); one that opens with v2
//! may see out-of-order completion. `OP_INFER_BATCH` amortizes framing:
//! `count` images ride one frame, fan out to the engine's batcher
//! individually, and come back as one `OP_LOGITS_BATCH` frame whose rows
//! (logits or typed per-image error) are in submission order.
//!
//! The deadline travels as a *budget* (relative milliseconds), not an
//! absolute timestamp — the server stamps the frame's arrival and
//! derives the absolute deadline locally, so client and server clocks
//! never need to agree. A request whose budget has already elapsed when
//! the server gets to it is shed before submit ([`ErrorCode::Expired`]);
//! one shed from the engine queue reports [`ErrorCode::Shed`]; one whose
//! reply misses the budget reports [`ErrorCode::DeadlineExpired`]. The
//! remaining codes mirror [`SubmitError`] arm for arm.
//!
//! ## Trace tail (v2 requests only)
//!
//! Any v2 **request** payload may carry an optional 9-byte trailing
//! field after its body: `u64 trace_id · u8 attempt` (little-endian).
//! Absent means untraced; v1 payloads never carry it. The decoder
//! distinguishes the two by exact size arithmetic — after the body,
//! exactly 0 bytes remaining is untraced, exactly 9 is traced, anything
//! else is corrupt. One deliberate consequence: a traced frame truncated
//! at exactly its 9 tail bytes decodes as a valid *untraced* request.
//! That is trace loss, not data corruption — the tail is observability
//! metadata, never payload — and it keeps the format backward-compatible
//! with v2 peers that predate tracing. Responses carry no tail: the
//! trace id lives in the server's telemetry spans, and v2 responses are
//! already matched to their request by `corr_id`.
//!
//! Decoding is defensive: a hostile peer can produce a typed
//! [`ProtoError`], never a panic or an unbounded allocation (frames are
//! capped at [`MAX_FRAME`]; every length field is bounds-checked against
//! the remaining payload).

use crate::coordinator::SubmitError;
use std::fmt;
use std::io::{self, Read, Write};

/// Original wire protocol version: strict request→response ordering,
/// no correlation ids. Still fully served (in order) by every tier.
pub const PROTO_VERSION: u8 = 1;

/// Protocol minor version 2: every payload carries a `u32 corr_id`
/// after the op byte, responses may return out of order, and the batch
/// ops ([`OP_INFER_BATCH`]/[`OP_LOGITS_BATCH`]) become available.
pub const PROTO_V2: u8 = 2;

/// Hard cap on one frame's payload (16 MiB — a 1024×1024×3 image batch
/// of one still fits with room to spare).
pub const MAX_FRAME: usize = 1 << 24;

/// Cap on images per `OP_INFER_BATCH` frame (the per-frame byte cap
/// usually binds first; this bounds decoded allocations for tiny px).
pub const MAX_BATCH_IMAGES: usize = 4096;

/// Size of the optional v2 request trace tail: `u64 trace_id · u8
/// attempt`, appended after the body (see the module docs).
pub const TRACE_TAIL_BYTES: usize = 9;

pub use crate::telemetry::TraceCtx;

/// Request ops.
pub const OP_INFER: u8 = 0x01;
pub const OP_METRICS: u8 = 0x02;
/// Streaming batch submission: many images in one frame (v2 only).
pub const OP_INFER_BATCH: u8 = 0x03;
/// Response ops (high bit set).
pub const OP_LOGITS: u8 = 0x81;
pub const OP_ERROR: u8 = 0x82;
pub const OP_METRICS_JSON: u8 = 0x83;
/// One row per batched image, submission order (v2 only).
pub const OP_LOGITS_BATCH: u8 = 0x84;

/// Typed wire error codes. `1..=5` mirror [`SubmitError`]; `6..=8` are
/// the three deadline-shed stages (door / queue / wait); `9` is a
/// backend execution failure; `10` a malformed frame; `11` is a
/// gateway-level refusal (no healthy upstream replica, or the bounded
/// retry budget was exhausted without a definitive answer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    QueueFull = 1,
    BadImage = 2,
    UnknownVariant = 3,
    Retired = 4,
    ShuttingDown = 5,
    /// Budget elapsed before submit — shed at the door.
    Expired = 6,
    /// Deadline passed while queued — shed before execution.
    Shed = 7,
    /// The reply did not arrive within the budget.
    DeadlineExpired = 8,
    /// The backend failed the batch.
    Batch = 9,
    /// The request frame could not be decoded.
    BadFrame = 10,
    /// The gateway could not reach a healthy upstream replica.
    Upstream = 11,
}

impl ErrorCode {
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(code: u8) -> Option<ErrorCode> {
        Some(match code {
            1 => ErrorCode::QueueFull,
            2 => ErrorCode::BadImage,
            3 => ErrorCode::UnknownVariant,
            4 => ErrorCode::Retired,
            5 => ErrorCode::ShuttingDown,
            6 => ErrorCode::Expired,
            7 => ErrorCode::Shed,
            8 => ErrorCode::DeadlineExpired,
            9 => ErrorCode::Batch,
            10 => ErrorCode::BadFrame,
            11 => ErrorCode::Upstream,
            _ => return None,
        })
    }

    /// Deadline-shed family: the request was dropped (or its reply
    /// abandoned) because its budget ran out — expected behaviour under
    /// overload, not a fault.
    pub fn is_shed(self) -> bool {
        matches!(
            self,
            ErrorCode::Expired | ErrorCode::Shed | ErrorCode::DeadlineExpired
        )
    }

    pub fn from_submit(e: &SubmitError) -> ErrorCode {
        match e {
            SubmitError::QueueFull { .. } => ErrorCode::QueueFull,
            SubmitError::BadImage { .. } => ErrorCode::BadImage,
            SubmitError::UnknownVariant { .. } => ErrorCode::UnknownVariant,
            SubmitError::Retired { .. } => ErrorCode::Retired,
            SubmitError::ShuttingDown => ErrorCode::ShuttingDown,
            SubmitError::Expired { .. } => ErrorCode::Expired,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::BadImage => "bad_image",
            ErrorCode::UnknownVariant => "unknown_variant",
            ErrorCode::Retired => "retired",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Expired => "expired",
            ErrorCode::Shed => "shed",
            ErrorCode::DeadlineExpired => "deadline_expired",
            ErrorCode::Batch => "batch_failed",
            ErrorCode::BadFrame => "bad_frame",
            ErrorCode::Upstream => "upstream",
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Typed protocol failures (I/O and decode).
#[derive(Debug)]
pub enum ProtoError {
    Io(io::Error),
    /// Declared frame length exceeds [`MAX_FRAME`].
    FrameTooLarge { len: usize },
    /// The stream ended (or the payload ran out) mid-structure.
    Truncated { what: &'static str },
    /// Payload carries a protocol version this build does not speak.
    BadVersion { found: u8 },
    /// Unknown op byte for this direction.
    BadOp { op: u8 },
    /// Structurally invalid payload content.
    Corrupt(String),
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "wire io error: {}", e),
            ProtoError::FrameTooLarge { len } => {
                write!(f, "frame of {} bytes exceeds the {} byte cap", len, MAX_FRAME)
            }
            ProtoError::Truncated { what } => write!(f, "truncated {}", what),
            ProtoError::BadVersion { found } => write!(
                f,
                "protocol version {} not supported (this build speaks {} and {})",
                found, PROTO_VERSION, PROTO_V2
            ),
            ProtoError::BadOp { op } => write!(f, "unknown op 0x{:02x}", op),
            ProtoError::Corrupt(why) => write!(f, "corrupt payload: {}", why),
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<io::Error> for ProtoError {
    fn from(e: io::Error) -> Self {
        ProtoError::Io(e)
    }
}

/// One client→server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Infer {
        /// Variant key the engine routes on.
        key: String,
        /// Relative deadline budget in milliseconds (0 = none).
        deadline_budget_ms: u32,
        /// Row-major `img·img·3` floats.
        image: Vec<f32>,
    },
    /// Ask for the engine's `MetricsSnapshot` as JSON.
    Metrics,
}

/// One server→client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Logits {
        class: u32,
        latency_us: u64,
        /// Batch the request rode in (occupancy, padded size).
        occupancy: u16,
        padded: u16,
        logits: Vec<f32>,
    },
    Error {
        code: ErrorCode,
        detail: String,
    },
    MetricsJson(String),
}

// ---------------------------------------------------------------- framing

/// Writes one length-prefixed frame and flushes.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME);
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Core frame reader shared by the blocking client path and the
/// server's timeout-polling path — ONE implementation of the header
/// loop, clean-EOF rule, [`MAX_FRAME`] cap, and truncation semantics.
/// `on_block` runs on every `WouldBlock`/`TimedOut` read (streams with
/// a read timeout configured): return `false` to keep waiting, `true`
/// to abort — a clean `Ok(None)` before any header byte, a typed
/// truncation once a frame has started.
pub fn read_frame_poll(
    r: &mut impl Read,
    mut on_block: impl FnMut() -> bool,
) -> Result<Option<Vec<u8>>, ProtoError> {
    let mut len4 = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(ProtoError::Truncated { what: "frame header" }),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                if on_block() {
                    return if got == 0 {
                        Ok(None)
                    } else {
                        Err(ProtoError::Truncated { what: "frame header" })
                    };
                }
            }
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME {
        return Err(ProtoError::FrameTooLarge { len });
    }
    let mut buf = vec![0u8; len];
    let mut filled = 0usize;
    while filled < len {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(ProtoError::Truncated { what: "frame body" }),
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                if on_block() {
                    return Err(ProtoError::Truncated { what: "frame body" });
                }
            }
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    Ok(Some(buf))
}

/// Reads one frame from a blocking stream. `Ok(None)` on a clean EOF
/// (peer closed between frames); EOF mid-frame is a typed
/// [`ProtoError::Truncated`]. A read-timeout wakeup (only possible when
/// the caller configured one on the stream) aborts immediately instead
/// of spinning.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, ProtoError> {
    read_frame_poll(r, || true)
}

// --------------------------------------------------------------- encoding

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(buf: &mut Vec<u8>, bytes: &[u8]) {
    put_u32(buf, bytes.len() as u32);
    buf.extend_from_slice(bytes);
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    put_u32(buf, xs.len() as u32);
    for &x in xs {
        put_u32(buf, x.to_bits());
    }
}

fn header(op: u8) -> Vec<u8> {
    vec![PROTO_VERSION, op]
}

/// Serializes an infer request payload straight from borrowed parts —
/// the client's hot path (no intermediate owned [`Request`], no image
/// copy).
pub fn encode_infer(key: &str, deadline_budget_ms: u32, image: &[f32]) -> Vec<u8> {
    let mut buf = header(OP_INFER);
    put_bytes(&mut buf, key.as_bytes());
    put_u32(&mut buf, deadline_budget_ms);
    put_f32s(&mut buf, image);
    buf
}

/// Serializes a request payload (frame it with [`write_frame`]).
pub fn encode_request(req: &Request) -> Vec<u8> {
    match req {
        Request::Infer {
            key,
            deadline_budget_ms,
            image,
        } => encode_infer(key, *deadline_budget_ms, image),
        Request::Metrics => header(OP_METRICS),
    }
}

/// Serializes a response payload (frame it with [`write_frame`]).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    match resp {
        Response::Logits {
            class,
            latency_us,
            occupancy,
            padded,
            logits,
        } => {
            let mut buf = header(OP_LOGITS);
            put_u32(&mut buf, *class);
            put_u64(&mut buf, *latency_us);
            buf.extend_from_slice(&occupancy.to_le_bytes());
            buf.extend_from_slice(&padded.to_le_bytes());
            put_f32s(&mut buf, logits);
            buf
        }
        Response::Error { code, detail } => {
            let mut buf = header(OP_ERROR);
            buf.push(code.as_u8());
            put_bytes(&mut buf, detail.as_bytes());
            buf
        }
        Response::MetricsJson(json) => {
            let mut buf = header(OP_METRICS_JSON);
            put_bytes(&mut buf, json.as_bytes());
            buf
        }
    }
}

// --------------------------------------------------------------- decoding

/// Bounds-checked little-endian reader over a payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], ProtoError> {
        if n > self.remaining() {
            return Err(ProtoError::Truncated { what });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, ProtoError> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, ProtoError> {
        Ok(u16::from_le_bytes(self.bytes(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.bytes(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.bytes(8, what)?.try_into().unwrap()))
    }

    fn string(&mut self, what: &'static str) -> Result<String, ProtoError> {
        let n = self.u32(what)? as usize;
        if n > self.remaining() {
            return Err(ProtoError::Truncated { what });
        }
        String::from_utf8(self.bytes(n, what)?.to_vec())
            .map_err(|_| ProtoError::Corrupt(format!("{} is not utf-8", what)))
    }

    fn f32_vec(&mut self, what: &'static str) -> Result<Vec<f32>, ProtoError> {
        let n = self.u32(what)? as usize;
        if n.checked_mul(4).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(ProtoError::Truncated { what });
        }
        let raw = self.bytes(n * 4, what)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn finish(self, what: &'static str) -> Result<(), ProtoError> {
        self.finish_ref(what)
    }

    /// Non-consuming [`Cursor::finish`] for decoders that still hold a
    /// borrow (the framed paths).
    fn finish_ref(&self, what: &'static str) -> Result<(), ProtoError> {
        if self.remaining() != 0 {
            return Err(ProtoError::Corrupt(format!(
                "{} trailing bytes after {}",
                self.remaining(),
                what
            )));
        }
        Ok(())
    }
}

fn check_version(c: &mut Cursor<'_>) -> Result<u8, ProtoError> {
    let version = c.u8("version byte")?;
    if version != PROTO_VERSION {
        return Err(ProtoError::BadVersion { found: version });
    }
    c.u8("op byte")
}

/// Parses a request payload.
pub fn decode_request(payload: &[u8]) -> Result<Request, ProtoError> {
    let mut c = Cursor::new(payload);
    let op = check_version(&mut c)?;
    match op {
        OP_INFER => {
            let key = c.string("variant key")?;
            let deadline_budget_ms = c.u32("deadline budget")?;
            let image = c.f32_vec("image")?;
            c.finish("infer request")?;
            Ok(Request::Infer {
                key,
                deadline_budget_ms,
                image,
            })
        }
        OP_METRICS => {
            c.finish("metrics request")?;
            Ok(Request::Metrics)
        }
        op => Err(ProtoError::BadOp { op }),
    }
}

/// Parses a response payload.
pub fn decode_response(payload: &[u8]) -> Result<Response, ProtoError> {
    let mut c = Cursor::new(payload);
    let op = check_version(&mut c)?;
    match op {
        OP_LOGITS => {
            let class = c.u32("class")?;
            let latency_us = c.u64("latency")?;
            let occupancy = c.u16("batch occupancy")?;
            let padded = c.u16("batch padded size")?;
            let logits = c.f32_vec("logits")?;
            c.finish("logits response")?;
            Ok(Response::Logits {
                class,
                latency_us,
                occupancy,
                padded,
                logits,
            })
        }
        OP_ERROR => {
            let raw = c.u8("error code")?;
            let code = ErrorCode::from_u8(raw)
                .ok_or_else(|| ProtoError::Corrupt(format!("error code {}", raw)))?;
            let detail = c.string("error detail")?;
            c.finish("error response")?;
            Ok(Response::Error { code, detail })
        }
        OP_METRICS_JSON => {
            let json = c.string("metrics json")?;
            c.finish("metrics response")?;
            Ok(Response::MetricsJson(json))
        }
        op => Err(ProtoError::BadOp { op }),
    }
}

// ------------------------------------------------- v2 framed envelope

/// One decoded request payload with its protocol envelope: the version
/// the client spoke and (for v2) the correlation id to echo back. The
/// async server tier decodes through this so one connection can mix
/// versions per the negotiation rules in the module docs.
#[derive(Debug, Clone, PartialEq)]
pub enum FramedRequest {
    /// Version-1 payload: answer in order, encode the reply as v1.
    V1(Request),
    /// Version-2 payload: echo `corr_id`, out-of-order replies allowed.
    V2 {
        corr_id: u32,
        req: Request,
        /// Optional trace tail (see the module docs); `None` = untraced.
        trace: Option<TraceCtx>,
    },
    /// Version-2 streaming batch: `count` images of `px` floats each,
    /// concatenated in `images`; answered by one `OP_LOGITS_BATCH`
    /// frame with `count` rows in submission order.
    V2Batch {
        corr_id: u32,
        key: String,
        deadline_budget_ms: u32,
        count: usize,
        px: usize,
        images: Vec<f32>,
        /// Optional trace tail shared by every image in the batch.
        trace: Option<TraceCtx>,
    },
}

/// One decoded response payload with its protocol envelope.
#[derive(Debug, Clone, PartialEq)]
pub enum FramedResponse {
    V1(Response),
    V2 { corr_id: u32, resp: Response },
    /// Rows are [`Response::Logits`] or [`Response::Error`], one per
    /// submitted image, in submission order.
    V2Batch { corr_id: u32, rows: Vec<Response> },
}

fn header_v2(op: u8, corr_id: u32) -> Vec<u8> {
    let mut buf = vec![PROTO_V2, op];
    put_u32(&mut buf, corr_id);
    buf
}

/// Serializes a v2 infer request payload from borrowed parts.
pub fn encode_infer_v2(corr_id: u32, key: &str, deadline_budget_ms: u32, image: &[f32]) -> Vec<u8> {
    let mut buf = header_v2(OP_INFER, corr_id);
    put_bytes(&mut buf, key.as_bytes());
    put_u32(&mut buf, deadline_budget_ms);
    put_f32s(&mut buf, image);
    buf
}

/// Serializes a v2 metrics request payload.
pub fn encode_metrics_v2(corr_id: u32) -> Vec<u8> {
    header_v2(OP_METRICS, corr_id)
}

fn put_trace_tail(buf: &mut Vec<u8>, trace: TraceCtx) {
    put_u64(buf, trace.trace_id);
    buf.push(trace.attempt);
}

/// [`encode_infer_v2`] plus the optional trace tail (see module docs).
pub fn encode_infer_v2_traced(
    corr_id: u32,
    key: &str,
    deadline_budget_ms: u32,
    image: &[f32],
    trace: TraceCtx,
) -> Vec<u8> {
    let mut buf = encode_infer_v2(corr_id, key, deadline_budget_ms, image);
    put_trace_tail(&mut buf, trace);
    buf
}

/// Serializes a v2 streaming-batch request: `images` must hold exactly
/// `count · px` floats (the images concatenated in submission order).
pub fn encode_infer_batch(
    corr_id: u32,
    key: &str,
    deadline_budget_ms: u32,
    count: usize,
    px: usize,
    images: &[f32],
) -> Vec<u8> {
    debug_assert_eq!(images.len(), count * px);
    let mut buf = header_v2(OP_INFER_BATCH, corr_id);
    put_bytes(&mut buf, key.as_bytes());
    put_u32(&mut buf, deadline_budget_ms);
    put_u32(&mut buf, count as u32);
    put_u32(&mut buf, px as u32);
    for &x in images {
        put_u32(&mut buf, x.to_bits());
    }
    buf
}

/// [`encode_infer_batch`] plus the optional trace tail (see module docs).
pub fn encode_infer_batch_traced(
    corr_id: u32,
    key: &str,
    deadline_budget_ms: u32,
    count: usize,
    px: usize,
    images: &[f32],
    trace: TraceCtx,
) -> Vec<u8> {
    let mut buf = encode_infer_batch(corr_id, key, deadline_budget_ms, count, px, images);
    put_trace_tail(&mut buf, trace);
    buf
}

/// Body of a single response, shared by the v1/v2 single encoders and
/// the batch-row encoder (which prefixes a row kind byte instead of a
/// payload header).
fn put_response_body(buf: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Logits {
            class,
            latency_us,
            occupancy,
            padded,
            logits,
        } => {
            put_u32(buf, *class);
            put_u64(buf, *latency_us);
            buf.extend_from_slice(&occupancy.to_le_bytes());
            buf.extend_from_slice(&padded.to_le_bytes());
            put_f32s(buf, logits);
        }
        Response::Error { code, detail } => {
            buf.push(code.as_u8());
            put_bytes(buf, detail.as_bytes());
        }
        Response::MetricsJson(json) => {
            put_bytes(buf, json.as_bytes());
        }
    }
}

/// Serializes a v2 response payload echoing the request's `corr_id`.
pub fn encode_response_v2(corr_id: u32, resp: &Response) -> Vec<u8> {
    let op = match resp {
        Response::Logits { .. } => OP_LOGITS,
        Response::Error { .. } => OP_ERROR,
        Response::MetricsJson(_) => OP_METRICS_JSON,
    };
    let mut buf = header_v2(op, corr_id);
    put_response_body(&mut buf, resp);
    buf
}

/// Serializes a v2 batch response: one row per image, submission order.
/// Rows must be `Logits` or `Error` (a `MetricsJson` row is a caller
/// bug and panics in debug builds; encoded as an error row otherwise).
pub fn encode_logits_batch(corr_id: u32, rows: &[Response]) -> Vec<u8> {
    let mut buf = header_v2(OP_LOGITS_BATCH, corr_id);
    put_u32(&mut buf, rows.len() as u32);
    for row in rows {
        match row {
            Response::Logits { .. } => {
                buf.push(0);
                put_response_body(&mut buf, row);
            }
            Response::Error { .. } => {
                buf.push(1);
                put_response_body(&mut buf, row);
            }
            Response::MetricsJson(_) => {
                debug_assert!(false, "a metrics row cannot ride a logits batch");
                buf.push(1);
                put_response_body(
                    &mut buf,
                    &Response::Error {
                        code: ErrorCode::Batch,
                        detail: "internal: metrics row in a logits batch".into(),
                    },
                );
            }
        }
    }
    buf
}

/// Decodes a request body WITHOUT asserting the payload is exhausted —
/// the v2 framed path reads an optional trace tail after the body.
fn decode_request_body_open(c: &mut Cursor<'_>, op: u8) -> Result<Request, ProtoError> {
    match op {
        OP_INFER => {
            let key = c.string("variant key")?;
            let deadline_budget_ms = c.u32("deadline budget")?;
            let image = c.f32_vec("image")?;
            Ok(Request::Infer {
                key,
                deadline_budget_ms,
                image,
            })
        }
        OP_METRICS => Ok(Request::Metrics),
        op => Err(ProtoError::BadOp { op }),
    }
}

fn decode_request_body(c: &mut Cursor<'_>, op: u8) -> Result<Request, ProtoError> {
    let req = decode_request_body_open(c, op)?;
    c.finish_ref(match req {
        Request::Infer { .. } => "infer request",
        Request::Metrics => "metrics request",
    })?;
    Ok(req)
}

/// Consumes the optional v2 trace tail: exactly 0 remaining bytes is
/// untraced, exactly [`TRACE_TAIL_BYTES`] is traced, anything else is
/// corrupt (same strictness as `finish_ref`, with one legal extra size).
fn read_trace_tail(c: &mut Cursor<'_>, what: &'static str) -> Result<Option<TraceCtx>, ProtoError> {
    match c.remaining() {
        0 => Ok(None),
        TRACE_TAIL_BYTES => {
            let trace_id = c.u64("trace id")?;
            let attempt = c.u8("trace attempt")?;
            Ok(Some(TraceCtx { trace_id, attempt }))
        }
        n => Err(ProtoError::Corrupt(format!(
            "{} trailing bytes after {}",
            n, what
        ))),
    }
}

/// Parses a request payload of either protocol version (the async
/// tier's decoder). V1 payloads decode exactly as [`decode_request`];
/// v2 payloads yield the correlation id and unlock the batch op.
pub fn decode_request_framed(payload: &[u8]) -> Result<FramedRequest, ProtoError> {
    let mut c = Cursor::new(payload);
    let version = c.u8("version byte")?;
    match version {
        PROTO_VERSION => {
            let op = c.u8("op byte")?;
            Ok(FramedRequest::V1(decode_request_body(&mut c, op)?))
        }
        PROTO_V2 => {
            let op = c.u8("op byte")?;
            let corr_id = c.u32("correlation id")?;
            if op == OP_INFER_BATCH {
                let key = c.string("variant key")?;
                let deadline_budget_ms = c.u32("deadline budget")?;
                let count = c.u32("batch count")? as usize;
                let px = c.u32("image length")? as usize;
                if count == 0 || count > MAX_BATCH_IMAGES {
                    return Err(ProtoError::Corrupt(format!(
                        "batch count {} outside 1..={}",
                        count, MAX_BATCH_IMAGES
                    )));
                }
                // px == 0 would pass the total-byte check with zero
                // image bytes and then fan out into nothing downstream
                // — a request that can never be answered.
                if px == 0 {
                    return Err(ProtoError::Corrupt(
                        "batch image length must be nonzero".into(),
                    ));
                }
                let total = count.checked_mul(px).and_then(|t| t.checked_mul(4));
                match total {
                    Some(bytes) if bytes == c.remaining() => {}
                    Some(bytes) if bytes + TRACE_TAIL_BYTES == c.remaining() => {}
                    _ => {
                        return Err(ProtoError::Truncated { what: "batch images" });
                    }
                }
                let raw = c.bytes(count * px * 4, "batch images")?;
                let images = raw
                    .chunks_exact(4)
                    .map(|b| f32::from_bits(u32::from_le_bytes(b.try_into().unwrap())))
                    .collect();
                let trace = read_trace_tail(&mut c, "batch request")?;
                Ok(FramedRequest::V2Batch {
                    corr_id,
                    key,
                    deadline_budget_ms,
                    count,
                    px,
                    images,
                    trace,
                })
            } else {
                let req = decode_request_body_open(&mut c, op)?;
                let trace = read_trace_tail(&mut c, "v2 request")?;
                Ok(FramedRequest::V2 {
                    corr_id,
                    req,
                    trace,
                })
            }
        }
        found => Err(ProtoError::BadVersion { found }),
    }
}

fn decode_response_body(c: &mut Cursor<'_>, op: u8) -> Result<Response, ProtoError> {
    match op {
        OP_LOGITS => {
            let class = c.u32("class")?;
            let latency_us = c.u64("latency")?;
            let occupancy = c.u16("batch occupancy")?;
            let padded = c.u16("batch padded size")?;
            let logits = c.f32_vec("logits")?;
            Ok(Response::Logits {
                class,
                latency_us,
                occupancy,
                padded,
                logits,
            })
        }
        OP_ERROR => {
            let raw = c.u8("error code")?;
            let code = ErrorCode::from_u8(raw)
                .ok_or_else(|| ProtoError::Corrupt(format!("error code {}", raw)))?;
            let detail = c.string("error detail")?;
            Ok(Response::Error { code, detail })
        }
        OP_METRICS_JSON => {
            let json = c.string("metrics json")?;
            Ok(Response::MetricsJson(json))
        }
        op => Err(ProtoError::BadOp { op }),
    }
}

/// Parses a response payload of either protocol version (the pipelined
/// client's decoder).
pub fn decode_response_framed(payload: &[u8]) -> Result<FramedResponse, ProtoError> {
    let mut c = Cursor::new(payload);
    let version = c.u8("version byte")?;
    match version {
        PROTO_VERSION => {
            let op = c.u8("op byte")?;
            let resp = decode_response_body(&mut c, op)?;
            c.finish_ref("response")?;
            Ok(FramedResponse::V1(resp))
        }
        PROTO_V2 => {
            let op = c.u8("op byte")?;
            let corr_id = c.u32("correlation id")?;
            if op == OP_LOGITS_BATCH {
                let count = c.u32("batch row count")? as usize;
                if count > MAX_BATCH_IMAGES {
                    return Err(ProtoError::Corrupt(format!(
                        "batch row count {} exceeds {}",
                        count, MAX_BATCH_IMAGES
                    )));
                }
                let mut rows = Vec::with_capacity(count.min(1024));
                for _ in 0..count {
                    let kind = c.u8("batch row kind")?;
                    let row_op = match kind {
                        0 => OP_LOGITS,
                        1 => OP_ERROR,
                        k => {
                            return Err(ProtoError::Corrupt(format!("batch row kind {}", k)));
                        }
                    };
                    rows.push(decode_response_body(&mut c, row_op)?);
                }
                c.finish_ref("batch response")?;
                Ok(FramedResponse::V2Batch { corr_id, rows })
            } else {
                let resp = decode_response_body(&mut c, op)?;
                c.finish_ref("response")?;
                Ok(FramedResponse::V2 { corr_id, resp })
            }
        }
        found => Err(ProtoError::BadVersion { found }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        for req in [
            Request::Infer {
                key: "net:base:p0:native".into(),
                deadline_budget_ms: 25,
                image: vec![0.0, 1.5, -2.25, f32::MIN_POSITIVE],
            },
            Request::Infer {
                key: String::new(),
                deadline_budget_ms: 0,
                image: Vec::new(),
            },
            Request::Metrics,
        ] {
            let payload = encode_request(&req);
            assert_eq!(decode_request(&payload).unwrap(), req);
        }
    }

    #[test]
    fn response_roundtrip() {
        for resp in [
            Response::Logits {
                class: 3,
                latency_us: 12_345,
                occupancy: 2,
                padded: 4,
                logits: vec![0.125, -7.5, 3.25],
            },
            Response::Error {
                code: ErrorCode::DeadlineExpired,
                detail: "no reply within the wait deadline".into(),
            },
            Response::MetricsJson("{\"fleet\": {}}".into()),
        ] {
            let payload = encode_response(&resp);
            assert_eq!(decode_response(&payload).unwrap(), resp);
        }
    }

    #[test]
    fn frame_roundtrip_and_clean_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncation_is_typed_never_panics() {
        // Every prefix of a valid payload decodes to a typed error.
        let payload = encode_request(&Request::Infer {
            key: "k".into(),
            deadline_budget_ms: 9,
            image: vec![1.0, 2.0],
        });
        for cut in 0..payload.len() {
            assert!(decode_request(&payload[..cut]).is_err(), "cut {}", cut);
        }
        // Truncated frame body.
        let mut framed = Vec::new();
        write_frame(&mut framed, &payload).unwrap();
        framed.truncate(framed.len() - 3);
        let mut r = std::io::Cursor::new(framed);
        assert!(matches!(
            read_frame(&mut r),
            Err(ProtoError::Truncated { .. })
        ));
    }

    #[test]
    fn zero_pixel_batch_is_refused_at_decode() {
        // count ≥ 1 with px == 0 satisfies the total-byte check with
        // zero image bytes, but fans out into nothing downstream — a
        // request no completion would ever answer. Must be corrupt.
        let payload = encode_infer_batch(7, "k", 0, 1, 0, &[]);
        assert!(matches!(
            decode_request_framed(&payload),
            Err(ProtoError::Corrupt(_))
        ));
        // The same shape with a nonzero px still decodes.
        let ok = encode_infer_batch(7, "k", 0, 1, 2, &[1.0, 2.0]);
        assert!(matches!(
            decode_request_framed(&ok),
            Ok(FramedRequest::V2Batch { count: 1, px: 2, .. })
        ));
    }

    #[test]
    fn hostile_lengths_are_bounded() {
        // Declared frame length beyond the cap is refused before any
        // allocation of that size.
        let mut buf = Vec::new();
        buf.extend_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut r),
            Err(ProtoError::FrameTooLarge { .. })
        ));
        // A declared image length far beyond the payload is a typed
        // truncation, not an allocation.
        let mut payload = vec![PROTO_VERSION, OP_INFER];
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.push(b'k');
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_request(&payload),
            Err(ProtoError::Truncated { .. })
        ));
    }

    #[test]
    fn version_and_op_are_gated() {
        let mut payload = encode_request(&Request::Metrics);
        payload[0] = PROTO_VERSION + 1;
        assert!(matches!(
            decode_request(&payload),
            Err(ProtoError::BadVersion { .. })
        ));
        let payload = vec![PROTO_VERSION, 0x7f];
        assert!(matches!(
            decode_request(&payload),
            Err(ProtoError::BadOp { op: 0x7f })
        ));
        // A response op is not a request.
        let payload = encode_response(&Response::MetricsJson("{}".into()));
        assert!(matches!(
            decode_request(&payload),
            Err(ProtoError::BadOp { .. })
        ));
    }

    #[test]
    fn framed_v1_matches_legacy_decoder() {
        let req = Request::Infer {
            key: "net:base:p0:native".into(),
            deadline_budget_ms: 25,
            image: vec![0.5, -1.25],
        };
        let payload = encode_request(&req);
        assert_eq!(
            decode_request_framed(&payload).unwrap(),
            FramedRequest::V1(req)
        );
        let resp = Response::MetricsJson("{}".into());
        let payload = encode_response(&resp);
        assert_eq!(
            decode_response_framed(&payload).unwrap(),
            FramedResponse::V1(resp)
        );
    }

    #[test]
    fn framed_v2_roundtrip_with_corr_ids() {
        let payload = encode_infer_v2(0xDEAD_BEEF, "k", 12, &[1.0, -2.5]);
        assert_eq!(
            decode_request_framed(&payload).unwrap(),
            FramedRequest::V2 {
                corr_id: 0xDEAD_BEEF,
                req: Request::Infer {
                    key: "k".into(),
                    deadline_budget_ms: 12,
                    image: vec![1.0, -2.5],
                },
                trace: None,
            }
        );
        let payload = encode_metrics_v2(7);
        assert_eq!(
            decode_request_framed(&payload).unwrap(),
            FramedRequest::V2 {
                corr_id: 7,
                req: Request::Metrics,
                trace: None,
            }
        );
        for resp in [
            Response::Logits {
                class: 1,
                latency_us: 99,
                occupancy: 1,
                padded: 2,
                logits: vec![0.25],
            },
            Response::Error {
                code: ErrorCode::Shed,
                detail: "late".into(),
            },
            Response::MetricsJson("{\"fleet\":{}}".into()),
        ] {
            let payload = encode_response_v2(42, &resp);
            assert_eq!(
                decode_response_framed(&payload).unwrap(),
                FramedResponse::V2 { corr_id: 42, resp }
            );
        }
        // v1 decoders must refuse v2 payloads (old servers/clients fail
        // typed, not silently misparse).
        let payload = encode_metrics_v2(7);
        assert!(matches!(
            decode_request(&payload),
            Err(ProtoError::BadVersion { found: 2 })
        ));
    }

    #[test]
    fn batch_roundtrip_and_validation() {
        let images: Vec<f32> = (0..6).map(|i| i as f32 * 0.5).collect();
        let payload = encode_infer_batch(9, "k", 50, 3, 2, &images);
        assert_eq!(
            decode_request_framed(&payload).unwrap(),
            FramedRequest::V2Batch {
                corr_id: 9,
                key: "k".into(),
                deadline_budget_ms: 50,
                count: 3,
                px: 2,
                images,
                trace: None,
            }
        );
        // Every truncation of the batch frame is a typed error.
        for cut in 0..payload.len() {
            assert!(
                decode_request_framed(&payload[..cut]).is_err(),
                "cut {}",
                cut
            );
        }
        // Zero images and an over-cap count are refused.
        let empty = encode_infer_batch(1, "k", 0, 0, 2, &[]);
        assert!(decode_request_framed(&empty).is_err());
        let mut hostile = header_v2(OP_INFER_BATCH, 1);
        put_bytes(&mut hostile, b"k");
        put_u32(&mut hostile, 0);
        put_u32(&mut hostile, (MAX_BATCH_IMAGES as u32) + 1);
        put_u32(&mut hostile, 4);
        assert!(decode_request_framed(&hostile).is_err());

        let rows = vec![
            Response::Logits {
                class: 0,
                latency_us: 10,
                occupancy: 3,
                padded: 4,
                logits: vec![1.0, 2.0],
            },
            Response::Error {
                code: ErrorCode::DeadlineExpired,
                detail: "row 1 missed".into(),
            },
        ];
        let payload = encode_logits_batch(9, &rows);
        assert_eq!(
            decode_response_framed(&payload).unwrap(),
            FramedResponse::V2Batch { corr_id: 9, rows }
        );
    }

    #[test]
    fn trace_tail_roundtrips_on_v2_requests() {
        let t = TraceCtx {
            trace_id: 0xFEED_FACE_CAFE_BEEF,
            attempt: 3,
        };
        // Single infer: the tail comes back bit-exact.
        let payload = encode_infer_v2_traced(11, "k", 25, &[0.5, -1.0], t);
        match decode_request_framed(&payload).unwrap() {
            FramedRequest::V2 {
                corr_id,
                req,
                trace,
            } => {
                assert_eq!(corr_id, 11);
                assert_eq!(trace, Some(t));
                assert_eq!(
                    req,
                    Request::Infer {
                        key: "k".into(),
                        deadline_budget_ms: 25,
                        image: vec![0.5, -1.0],
                    }
                );
            }
            other => panic!("unexpected decode: {:?}", other),
        }
        // Batch: one shared tail for every image.
        let images = [1.0f32, 2.0, 3.0, 4.0];
        let payload = encode_infer_batch_traced(12, "k", 0, 2, 2, &images, t);
        match decode_request_framed(&payload).unwrap() {
            FramedRequest::V2Batch { trace, count, .. } => {
                assert_eq!(trace, Some(t));
                assert_eq!(count, 2);
            }
            other => panic!("unexpected decode: {:?}", other),
        }
        // Documented ambiguity: cutting exactly the 9 tail bytes yields a
        // valid UNTRACED request (trace loss, not corruption)...
        let payload = encode_infer_v2_traced(13, "k", 0, &[1.0], t);
        let cut = &payload[..payload.len() - TRACE_TAIL_BYTES];
        assert!(matches!(
            decode_request_framed(cut).unwrap(),
            FramedRequest::V2 { trace: None, .. }
        ));
        // ...while any partial tail is refused as corrupt.
        for keep in 1..TRACE_TAIL_BYTES {
            let partial = &payload[..payload.len() - TRACE_TAIL_BYTES + keep];
            assert!(
                matches!(
                    decode_request_framed(partial),
                    Err(ProtoError::Corrupt(_))
                ),
                "partial tail of {} bytes decoded",
                keep
            );
        }
        // v1 never carries a tail: appending one is trailing garbage.
        let mut v1 = encode_infer("k", 0, &[1.0]);
        put_trace_tail(&mut v1, t);
        assert!(decode_request_framed(&v1).is_err());
    }

    #[test]
    fn error_codes_roundtrip_and_classify() {
        for code in [
            ErrorCode::QueueFull,
            ErrorCode::BadImage,
            ErrorCode::UnknownVariant,
            ErrorCode::Retired,
            ErrorCode::ShuttingDown,
            ErrorCode::Expired,
            ErrorCode::Shed,
            ErrorCode::DeadlineExpired,
            ErrorCode::Batch,
            ErrorCode::BadFrame,
            ErrorCode::Upstream,
        ] {
            assert_eq!(ErrorCode::from_u8(code.as_u8()), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(12), None);
        assert!(ErrorCode::Expired.is_shed());
        assert!(ErrorCode::Shed.is_shed());
        assert!(ErrorCode::DeadlineExpired.is_shed());
        assert!(!ErrorCode::QueueFull.is_shed());
        assert_eq!(
            ErrorCode::from_submit(&SubmitError::ShuttingDown),
            ErrorCode::ShuttingDown
        );
        assert_eq!(
            ErrorCode::from_submit(&SubmitError::Expired { key: "k".into() }),
            ErrorCode::Expired
        );
    }
}
