//! Fault-injection harness for the wire server.
//!
//! A [`FaultPlan`] describes deliberate misbehaviour a `strum serve`
//! process should exhibit — crash after N requests, drop connections,
//! delay or corrupt responses — so the gateway's supervision, retry,
//! and health-check paths can be exercised deterministically in tests
//! and CI instead of waiting for real infrastructure to fail.
//!
//! The plan is parsed from a compact `key=value` spec (CLI
//! `--fault-plan` or the `STRUM_FAULT_PLAN` environment variable, so a
//! gateway can arm exactly one replica of a fleet via the child's
//! environment):
//!
//! ```text
//! kill-after=200,drop-conn-every=50,delay-ms=5,corrupt-every=100
//! ```
//!
//! Faults apply to **infer** requests only. Metrics probes are never
//! faulted: the health checker must keep an accurate view of a replica
//! that is misbehaving at the request layer, and the kill-after counter
//! stays deterministic with respect to offered load.
//!
//! [`FaultState`] is the armed, shared form: one atomic request counter
//! across every connection worker, so "kill after 200 requests" means
//! the 200th request served by the *process*, not per connection.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Exit code a fault-plan kill terminates the process with. Distinct
/// from panic/abort codes so the supervisor's telemetry can attribute
/// the death, and tests can assert the crash was the injected one.
pub const FAULT_KILL_EXIT: i32 = 113;

/// A parsed fault specification. All fields optional; an empty plan
/// injects nothing.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Exit the whole process (with [`FAULT_KILL_EXIT`]) after serving
    /// this many infer requests.
    pub kill_after: Option<u64>,
    /// Drop the connection without replying on every Nth infer request.
    pub drop_conn_every: Option<u64>,
    /// Sleep this long before writing every infer response.
    pub delay_ms: Option<u64>,
    /// Replace every Nth infer response frame with garbage bytes.
    pub corrupt_every: Option<u64>,
}

impl FaultPlan {
    /// Parses a `key=value,key=value` spec. Unknown keys and malformed
    /// values are hard errors — a typo'd fault plan silently injecting
    /// nothing would pass the chaos test for the wrong reason.
    pub fn parse(spec: &str) -> crate::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault plan entry '{}' is not key=value", part))?;
            let n: u64 = value
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault plan value '{}' is not a number", value))?;
            if n == 0 {
                anyhow::bail!("fault plan value for '{}' must be > 0", key);
            }
            match key.trim() {
                "kill-after" => plan.kill_after = Some(n),
                "drop-conn-every" => plan.drop_conn_every = Some(n),
                "delay-ms" => plan.delay_ms = Some(n),
                "corrupt-every" => plan.corrupt_every = Some(n),
                other => anyhow::bail!("unknown fault plan key '{}'", other),
            }
        }
        Ok(plan)
    }

    /// Reads `STRUM_FAULT_PLAN` from the environment; `Ok(None)` when
    /// unset or empty.
    pub fn from_env() -> crate::Result<Option<FaultPlan>> {
        match std::env::var("STRUM_FAULT_PLAN") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlan::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    pub fn is_empty(&self) -> bool {
        *self == FaultPlan::default()
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut parts = Vec::new();
        if let Some(n) = self.kill_after {
            parts.push(format!("kill-after={}", n));
        }
        if let Some(n) = self.drop_conn_every {
            parts.push(format!("drop-conn-every={}", n));
        }
        if let Some(n) = self.delay_ms {
            parts.push(format!("delay-ms={}", n));
        }
        if let Some(n) = self.corrupt_every {
            parts.push(format!("corrupt-every={}", n));
        }
        write!(f, "{}", parts.join(","))
    }
}

/// What the connection loop should do to the current infer request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultAction {
    /// Exit the process (after any delay, before replying).
    pub kill: bool,
    /// Close the connection without a reply.
    pub drop_conn: bool,
    /// Sleep before replying.
    pub delay: Option<Duration>,
    /// Write a garbage frame instead of the real response.
    pub corrupt: bool,
}

/// An armed [`FaultPlan`]: one process-wide request counter shared by
/// every connection worker.
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    infers: AtomicU64,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        FaultState {
            plan,
            infers: AtomicU64::new(0),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Accounts one infer request and returns the faults due on it.
    /// The Nth request (1-based) triggers `kill_after=N` and every
    /// multiple of N triggers the `*-every=N` faults.
    pub fn next_action(&self) -> FaultAction {
        let seq = self.infers.fetch_add(1, Ordering::Relaxed) + 1;
        FaultAction {
            kill: self.plan.kill_after == Some(seq),
            drop_conn: self.plan.drop_conn_every.is_some_and(|n| seq % n == 0),
            delay: self.plan.delay_ms.map(Duration::from_millis),
            corrupt: self.plan.corrupt_every.is_some_and(|n| seq % n == 0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec_roundtrips() {
        let plan =
            FaultPlan::parse("kill-after=200, drop-conn-every=50,delay-ms=5,corrupt-every=100")
                .unwrap();
        assert_eq!(plan.kill_after, Some(200));
        assert_eq!(plan.drop_conn_every, Some(50));
        assert_eq!(plan.delay_ms, Some(5));
        assert_eq!(plan.corrupt_every, Some(100));
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("kill-after").is_err());
        assert!(FaultPlan::parse("kill-after=x").is_err());
        assert!(FaultPlan::parse("kill-after=0").is_err());
        assert!(FaultPlan::parse("explode=3").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn actions_fire_on_schedule() {
        let st = FaultState::new(FaultPlan::parse("kill-after=3,drop-conn-every=2").unwrap());
        let a1 = st.next_action();
        let a2 = st.next_action();
        let a3 = st.next_action();
        let a4 = st.next_action();
        assert!(!a1.kill && !a1.drop_conn);
        assert!(!a2.kill && a2.drop_conn);
        assert!(a3.kill && !a3.drop_conn);
        // kill-after fires exactly once (the process would be gone, but
        // the counter must not re-trigger in tests that outlive it).
        assert!(!a4.kill && a4.drop_conn);
    }

    #[test]
    fn empty_plan_is_inert() {
        let st = FaultState::new(FaultPlan::default());
        for _ in 0..10 {
            assert_eq!(st.next_action(), FaultAction::default());
        }
    }
}
