//! Versioned run manifests for benchmark artifacts.
//!
//! Every `BENCH_*.json` the harness or `strum loadgen` writes is now
//! wrapped by a manifest recording *where the numbers came from*: run
//! id, UTC timestamp, git commit + dirty flag, host identity (hostname,
//! CPU model, core count), the kernel-dispatch tier the process
//! resolved, and whether `STRUM_BENCH_QUICK` was set. Each wrapped
//! payload carries its byte size and FNV-1a 64 checksum, and the
//! manifest as a whole carries a checksum computed over its canonical
//! compact JSON with the `manifest_fnv1a64` field removed — so
//! `strum bench-diff` can refuse to compare tampered or truncated
//! artifacts.
//!
//! The `run_id` is the correlation key: a loadgen manifest and the
//! telemetry JSONL emitted by the server it drove share it when the
//! caller threads one id through both.

use crate::backend::kernels;
use crate::util::hash::fnv1a64;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// Bump when the manifest layout changes incompatibly.
pub const MANIFEST_FORMAT_VERSION: u32 = 1;

/// One wrapped benchmark artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct PayloadEntry {
    /// File name relative to the manifest's directory.
    pub path: String,
    pub bytes: u64,
    /// FNV-1a 64 of the file contents, lowercase hex.
    pub fnv1a64: String,
}

/// Provenance wrapper for a set of bench JSON files.
#[derive(Debug, Clone, PartialEq)]
pub struct RunManifest {
    pub format_version: u32,
    pub run_id: String,
    pub timestamp_utc: String,
    pub git_commit: String,
    pub git_dirty: bool,
    pub hostname: String,
    pub cpu: String,
    pub cores: usize,
    /// Kernel-dispatch tier resolved by this process
    /// (scalar/sse2/avx2/avx512).
    pub kernel_isa: String,
    pub bench_quick: bool,
    /// Bench name → wrapped artifact, sorted for canonical output.
    pub payloads: BTreeMap<String, PayloadEntry>,
}

impl RunManifest {
    /// Captures the current environment. Git state is best-effort
    /// (`"unknown"` outside a repo or without the git binary).
    pub fn capture(run_id: &str) -> RunManifest {
        let (git_commit, git_dirty) = git_state();
        RunManifest {
            format_version: MANIFEST_FORMAT_VERSION,
            run_id: run_id.to_string(),
            timestamp_utc: utc_now_rfc3339(),
            git_commit,
            git_dirty,
            hostname: hostname(),
            cpu: cpu_model(),
            cores: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            kernel_isa: kernels::active_isa().name().to_string(),
            bench_quick: std::env::var("STRUM_BENCH_QUICK").map(|v| v == "1").unwrap_or(false),
            payloads: BTreeMap::new(),
        }
    }

    /// Checksums `path` and records it under `name`. The stored path is
    /// the file name only — payloads are expected to sit next to the
    /// manifest.
    pub fn add_payload(&mut self, name: &str, path: &Path) -> crate::Result<()> {
        let data = fs::read(path)?;
        let file_name = path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| anyhow::anyhow!("payload has no file name: {:?}", path))?;
        self.payloads.insert(
            name.to_string(),
            PayloadEntry {
                path: file_name.to_string(),
                bytes: data.len() as u64,
                fnv1a64: format!("{:016x}", fnv1a64(&data)),
            },
        );
        Ok(())
    }

    /// Manifest body as JSON *without* the whole-manifest checksum.
    fn to_json_unchecksummed(&self) -> Json {
        let payloads = Json::Obj(
            self.payloads
                .iter()
                .map(|(name, p)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("path", Json::str(&p.path)),
                            ("bytes", Json::Num(p.bytes as f64)),
                            ("fnv1a64", Json::str(&p.fnv1a64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("format_version", Json::Num(self.format_version as f64)),
            ("run_id", Json::str(&self.run_id)),
            ("timestamp_utc", Json::str(&self.timestamp_utc)),
            ("git_commit", Json::str(&self.git_commit)),
            ("git_dirty", Json::Bool(self.git_dirty)),
            ("hostname", Json::str(&self.hostname)),
            ("cpu", Json::str(&self.cpu)),
            ("cores", Json::Num(self.cores as f64)),
            ("kernel_isa", Json::str(&self.kernel_isa)),
            ("bench_quick", Json::Bool(self.bench_quick)),
            ("payloads", payloads),
        ])
    }

    /// Whole-manifest checksum: FNV-1a 64 over the canonical compact
    /// serialization with the `manifest_fnv1a64` field absent. The
    /// BTreeMap-backed `Json` makes the serialization deterministic.
    pub fn manifest_checksum(&self) -> u64 {
        fnv1a64(self.to_json_unchecksummed().to_string().as_bytes())
    }

    pub fn to_json(&self) -> Json {
        let mut j = self.to_json_unchecksummed();
        if let Json::Obj(o) = &mut j {
            o.insert(
                "manifest_fnv1a64".to_string(),
                Json::str(format!("{:016x}", self.manifest_checksum())),
            );
        }
        j
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        fs::write(path, self.to_json().to_string_pretty() + "\n")?;
        Ok(())
    }

    pub fn load(path: &Path) -> crate::Result<RunManifest> {
        let text = fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {}", path.display(), e))?;
        Self::from_json(&j).map_err(|e| anyhow::anyhow!("{}: {}", path.display(), e))
    }

    pub fn from_json(j: &Json) -> Result<RunManifest, String> {
        let str_field = |k: &str| -> Result<String, String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("missing string field '{}'", k))
        };
        let version = j
            .get("format_version")
            .and_then(Json::as_f64)
            .ok_or("missing format_version")? as u32;
        if version != MANIFEST_FORMAT_VERSION {
            return Err(format!(
                "unsupported manifest format_version {} (expected {})",
                version, MANIFEST_FORMAT_VERSION
            ));
        }
        let mut payloads = BTreeMap::new();
        let obj = j
            .get("payloads")
            .and_then(Json::as_obj)
            .ok_or("missing payloads object")?;
        for (name, p) in obj {
            payloads.insert(
                name.clone(),
                PayloadEntry {
                    path: p
                        .get("path")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("payload '{}' missing path", name))?
                        .to_string(),
                    bytes: p
                        .get("bytes")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| format!("payload '{}' missing bytes", name))?
                        as u64,
                    fnv1a64: p
                        .get("fnv1a64")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("payload '{}' missing fnv1a64", name))?
                        .to_string(),
                },
            );
        }
        Ok(RunManifest {
            format_version: version,
            run_id: str_field("run_id")?,
            timestamp_utc: str_field("timestamp_utc")?,
            git_commit: str_field("git_commit")?,
            git_dirty: j.get("git_dirty").and_then(Json::as_bool).unwrap_or(false),
            hostname: str_field("hostname")?,
            cpu: str_field("cpu")?,
            cores: j.get("cores").and_then(Json::as_usize).unwrap_or(0),
            kernel_isa: str_field("kernel_isa")?,
            bench_quick: j.get("bench_quick").and_then(Json::as_bool).unwrap_or(false),
            payloads,
        })
    }

    /// Verifies the file at `path` against its embedded whole-manifest
    /// checksum. Returns the parsed manifest on success.
    pub fn load_verified(path: &Path) -> crate::Result<RunManifest> {
        let text = fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {}", path.display(), e))?;
        let stored = j
            .get("manifest_fnv1a64")
            .and_then(Json::as_str)
            .ok_or_else(|| {
                anyhow::anyhow!("{}: missing manifest_fnv1a64", path.display())
            })?
            .to_string();
        let m = Self::from_json(&j)
            .map_err(|e| anyhow::anyhow!("{}: {}", path.display(), e))?;
        let computed = format!("{:016x}", m.manifest_checksum());
        if stored != computed {
            return Err(anyhow::anyhow!(
                "{}: manifest checksum mismatch (stored {}, computed {})",
                path.display(),
                stored,
                computed
            ));
        }
        Ok(m)
    }

    /// Re-checksums every payload file relative to `base_dir`; returns
    /// the names that are missing or whose contents changed.
    pub fn verify_payloads(&self, base_dir: &Path) -> Vec<String> {
        let mut bad = Vec::new();
        for (name, p) in &self.payloads {
            match fs::read(base_dir.join(&p.path)) {
                Ok(data) => {
                    let got = format!("{:016x}", fnv1a64(&data));
                    if got != p.fnv1a64 || data.len() as u64 != p.bytes {
                        bad.push(name.clone());
                    }
                }
                Err(_) => bad.push(name.clone()),
            }
        }
        bad
    }
}

/// Resolves the directory bench artifacts should land in:
/// `STRUM_BENCH_DIR` if set, else `.`; created if needed.
pub fn bench_dir() -> PathBuf {
    let dir = std::env::var("STRUM_BENCH_DIR")
        .ok()
        .filter(|s| !s.is_empty())
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."));
    let _ = fs::create_dir_all(&dir);
    dir
}

fn git_state() -> (String, bool) {
    let run = |args: &[&str]| -> Option<String> {
        let out = std::process::Command::new("git").args(args).output().ok()?;
        if !out.status.success() {
            return None;
        }
        Some(String::from_utf8_lossy(&out.stdout).trim().to_string())
    };
    match run(&["rev-parse", "HEAD"]) {
        Some(commit) => {
            let dirty = run(&["status", "--porcelain"])
                .map(|s| !s.is_empty())
                .unwrap_or(false);
            (commit, dirty)
        }
        None => ("unknown".to_string(), false),
    }
}

fn hostname() -> String {
    fs::read_to_string("/proc/sys/kernel/hostname")
        .map(|s| s.trim().to_string())
        .ok()
        .filter(|s| !s.is_empty())
        .or_else(|| std::env::var("HOSTNAME").ok().filter(|s| !s.is_empty()))
        .unwrap_or_else(|| "unknown".to_string())
}

fn cpu_model() -> String {
    fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|text| {
            text.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split(':').nth(1))
                .map(|s| s.trim().to_string())
        })
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// RFC 3339 UTC timestamp from the system clock, no external crates:
/// civil-from-days (Howard Hinnant's algorithm) over the Unix epoch.
fn utc_now_rfc3339() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let days = (secs / 86_400) as i64;
    let (h, m, s) = {
        let rem = secs % 86_400;
        (rem / 3600, (rem % 3600) / 60, rem % 60)
    };
    let (y, mo, d) = civil_from_days(days);
    format!(
        "{:04}-{:02}-{:02}T{:02}:{:02}:{:02}Z",
        y, mo, d, h, m, s
    )
}

fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = (if mp < 10 { mp + 3 } else { mp - 9 }) as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("strum-manifest-{}-{}", tag, std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn capture_fills_environment() {
        let m = RunManifest::capture("r1");
        assert_eq!(m.format_version, MANIFEST_FORMAT_VERSION);
        assert_eq!(m.run_id, "r1");
        assert!(m.cores >= 1);
        assert!(["scalar", "sse2", "avx2", "avx512"].contains(&m.kernel_isa.as_str()));
        assert!(m.timestamp_utc.ends_with('Z'));
    }

    #[test]
    fn save_load_verify_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let payload = dir.join("BENCH_x.json");
        fs::write(&payload, b"{\"images_per_s\": 10}").unwrap();
        let mut m = RunManifest::capture("r2");
        m.add_payload("x", &payload).unwrap();
        let mpath = dir.join("MANIFEST_x.json");
        m.save(&mpath).unwrap();

        let loaded = RunManifest::load_verified(&mpath).unwrap();
        assert_eq!(loaded, m);
        assert!(loaded.verify_payloads(&dir).is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampering_is_detected() {
        let dir = tmp_dir("tamper");
        let payload = dir.join("BENCH_y.json");
        fs::write(&payload, b"{\"p99\": 5}").unwrap();
        let mut m = RunManifest::capture("r3");
        m.add_payload("y", &payload).unwrap();
        let mpath = dir.join("MANIFEST_y.json");
        m.save(&mpath).unwrap();

        // Payload edited after checksumming → verify_payloads flags it.
        fs::write(&payload, b"{\"p99\": 6}").unwrap();
        let loaded = RunManifest::load_verified(&mpath).unwrap();
        assert_eq!(loaded.verify_payloads(&dir), vec!["y".to_string()]);

        // Manifest field edited → whole-manifest checksum mismatch.
        let text = fs::read_to_string(&mpath).unwrap();
        let corrupted = text.replace("\"run_id\": \"r3\"", "\"run_id\": \"rX\"");
        assert_ne!(text, corrupted);
        fs::write(&mpath, corrupted).unwrap();
        assert!(RunManifest::load_verified(&mpath).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn civil_from_days_known_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1)); // 2024-01-01
        assert_eq!(civil_from_days(11_016), (2000, 2, 29)); // leap day
    }
}
