//! # Observability subsystem
//!
//! The crate's end-to-end observability layer, in three parts:
//!
//! * [`schema`] / [`writer`] — **structured runtime telemetry**: a
//!   schema-versioned tagged [`Event`] enum (request done/shed/rejected
//!   per variant, batch formation, variant lifecycle, server connection
//!   lifecycle, periodic engine gauges) written as JSONL by a
//!   non-blocking [`TelemetrySink`] — bounded channel into a dedicated
//!   flusher thread with size-based rotation and a retention cap. The
//!   hot path never serializes or touches disk; overload drops events
//!   and counts them (`telemetry_dropped` in the metrics snapshot).
//!   Enabled via `strum serve --telemetry-out DIR
//!   [--telemetry-interval-s N]`; a disabled sink is a no-op handle.
//! * [`manifest`] — **bench provenance**: every `BENCH_*.json` from the
//!   `hot_paths` harness and `strum loadgen` is wrapped by a
//!   [`RunManifest`] (format version, run id, UTC timestamp, git
//!   commit + dirty flag, host/CPU/cores, kernel-dispatch tier,
//!   `STRUM_BENCH_QUICK`) carrying FNV-1a checksums per payload plus a
//!   whole-manifest checksum computed with the field removed.
//! * [`diff`] — **the regression gate**: `strum bench-diff BASE NEW
//!   [--threshold-pct N]` verifies both manifests' checksums, pairs
//!   payloads by bench name, compares direction-classified metrics
//!   (throughput up, percentiles down, sheds gated only when the base
//!   run shed), and exits nonzero with a per-metric table on any
//!   regression past threshold. CI runs it against a fresh quick run.
//!   `strum bench-diff --history DIR...` extends the pairwise gate to a
//!   trajectory table across N verified runs.
//! * [`tail`] — **the query CLI**: `strum tail DIR [--run-id R]
//!   [--trace T] [--event E] [--variant K] [--rates --window-s N]`
//!   scans the JSONL segments back through [`validate_line`], filters,
//!   and reconstructs per-trace waterfalls (gateway attempt → queue
//!   wait → batch → execute → per-layer profile) or windowed request
//!   rates.
//!
//! Request tracing rides on the same log: a traced request (gateway
//! mint, client `X-Strum-Trace`, or `strum loadgen --trace`) carries a
//! 64-bit trace id on the v2 wire frames, and every pipeline stage
//! emits a schema-v2 `span` event tagged with the trace id, the gateway
//! attempt number, and (for hedge losers) an `abandoned` flag. Trace
//! ids print as 16 lowercase hex digits ([`fmt_trace`]/[`parse_trace`]).
//! Per-layer execute spans are sampled 1-in-N via `EngineOptions::
//! trace_sample` so the profiling hooks stay off the untraced hot path.
//!
//! The `run_id` threads through all of it: the sink stamps it on every
//! JSONL line, the manifest records it, and loadgen reuses one id for
//! both so a bench artifact can be joined to the event log it was
//! measured under.

pub mod diff;
pub mod manifest;
pub mod schema;
pub mod tail;
pub mod writer;

pub use diff::{
    diff_manifests, history_manifests, render_history, render_table, DiffReport, HistoryReport,
    MetricDelta,
};
pub use tail::{render_rates, render_waterfall, scan_dir, TailFilter, TailScan};
pub use manifest::{bench_dir, PayloadEntry, RunManifest, MANIFEST_FORMAT_VERSION};
pub use schema::{
    fmt_trace, parse_trace, validate_line, Event, GaugeRow, ParsedLine, ShedStage, TraceCtx,
    SCHEMA_VERSION, SPAN_STAGES,
};
pub use writer::{segment_files, TelemetryConfig, TelemetrySink};

/// Generates a process-unique run id: epoch millis + pid, hex. Unique
/// enough to correlate a run's manifest with its JSONL log; not a UUID.
pub fn fresh_run_id() -> String {
    let ms = std::time::SystemTime::now()
        .duration_since(std::time::SystemTime::UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0);
    format!("{:x}-{:x}", ms, std::process::id())
}
