//! `strum bench-diff`: compare two manifest-wrapped bench runs and gate
//! on regressions.
//!
//! Both sides are [`super::RunManifest`]s loaded with checksum
//! verification (whole-manifest and per-payload). Payloads are paired
//! by bench name; inside each pair, every numeric metric whose
//! direction is known (see [`metric_direction`]) is compared as a
//! relative delta, and a delta worse than the threshold becomes a
//! regression. Shed/drop counts are compared too, but only gate when
//! the base run actually shed — a 0→3 shed flip on a quick CI run is
//! noise, 100→300 is not.
//!
//! Metrics are extracted by a recursive walk over the payload JSON, so
//! the differ needs no per-bench schema: a metric's identity is its
//! path (`serve_multivariant/variants[mip2q]/p99_us`). Array elements
//! are labeled by their `name`/`key`/`variant` field when present,
//! else by index.

use super::manifest::RunManifest;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Which way "better" points for a metric leaf name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherIsBetter,
    LowerIsBetter,
    /// Informational only (configs, counts, sizes): never gates.
    Ignore,
}

/// Classifies a metric by its leaf field name.
pub fn metric_direction(leaf: &str) -> Direction {
    const HIGHER: &[&str] = &[
        "images_per_s",
        "gflop_equiv_per_s",
        "gib_per_s",
        "throughput_rps",
        "achieved_rps",
        "done_per_s",
    ];
    const LOWER: &[&str] = &[
        "p50_us",
        "p95_us",
        "p99_us",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "mean_us",
        "mean_ms",
        "latency_us",
        "cold_start_ms",
    ];
    if HIGHER.contains(&leaf) {
        Direction::HigherIsBetter
    } else if LOWER.contains(&leaf) {
        Direction::LowerIsBetter
    } else if leaf == "shed" || leaf == "rejected" || leaf.ends_with("_shed") {
        // Special-cased in compare(): gates only when base > 0.
        Direction::LowerIsBetter
    } else {
        Direction::Ignore
    }
}

fn is_shed_metric(leaf: &str) -> bool {
    leaf == "shed" || leaf == "rejected" || leaf.ends_with("_shed")
}

/// One compared metric.
#[derive(Debug, Clone)]
pub struct MetricDelta {
    /// `bench/path/to/metric` — stable across runs.
    pub path: String,
    pub base: f64,
    pub new: f64,
    /// Signed percent change, positive = worse (direction-adjusted).
    pub worse_pct: f64,
    pub regressed: bool,
}

/// Full diff outcome.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    pub deltas: Vec<MetricDelta>,
    /// Bench names present on only one side.
    pub unpaired: Vec<String>,
    /// Payloads whose checksum re-verification failed, per side.
    pub checksum_failures: Vec<String>,
}

impl DiffReport {
    pub fn regressions(&self) -> impl Iterator<Item = &MetricDelta> {
        self.deltas.iter().filter(|d| d.regressed)
    }

    pub fn failed(&self) -> bool {
        !self.checksum_failures.is_empty() || self.deltas.iter().any(|d| d.regressed)
    }
}

/// Extracts every numeric leaf from a payload JSON into `path → value`.
fn collect_metrics(prefix: &str, v: &Json, out: &mut BTreeMap<String, f64>) {
    match v {
        Json::Num(n) => {
            out.insert(prefix.to_string(), *n);
        }
        Json::Obj(o) => {
            for (k, child) in o {
                let p = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{}/{}", prefix, k)
                };
                collect_metrics(&p, child, out);
            }
        }
        Json::Arr(a) => {
            for (i, child) in a.iter().enumerate() {
                // Prefer a semantic label so reordering doesn't
                // misalign metric paths between runs.
                let label = child
                    .get("name")
                    .or_else(|| child.get("key"))
                    .or_else(|| child.get("variant"))
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .unwrap_or_else(|| i.to_string());
                collect_metrics(&format!("{}[{}]", prefix, label), child, out);
            }
        }
        _ => {}
    }
}

/// Loads, checksum-verifies, and diffs two manifests. `threshold_pct`
/// is the allowed direction-adjusted worsening before a metric gates.
pub fn diff_manifests(
    base_path: &Path,
    new_path: &Path,
    threshold_pct: f64,
) -> crate::Result<DiffReport> {
    let base = RunManifest::load_verified(base_path)?;
    let new = RunManifest::load_verified(new_path)?;
    let base_dir = base_path.parent().unwrap_or(Path::new("."));
    let new_dir = new_path.parent().unwrap_or(Path::new("."));

    let mut report = DiffReport::default();
    for name in base.verify_payloads(base_dir) {
        report.checksum_failures.push(format!("base:{}", name));
    }
    for name in new.verify_payloads(new_dir) {
        report.checksum_failures.push(format!("new:{}", name));
    }
    if !report.checksum_failures.is_empty() {
        // Numbers from tampered/missing payloads are meaningless;
        // report the integrity failure alone.
        return Ok(report);
    }

    for (name, bp) in &base.payloads {
        let Some(np) = new.payloads.get(name) else {
            report.unpaired.push(format!("base-only:{}", name));
            continue;
        };
        let bjson = read_payload(base_dir, &bp.path)?;
        let njson = read_payload(new_dir, &np.path)?;
        let mut bm = BTreeMap::new();
        let mut nm = BTreeMap::new();
        collect_metrics(name, &bjson, &mut bm);
        collect_metrics(name, &njson, &mut nm);
        for (path, bv) in &bm {
            let Some(nv) = nm.get(path) else { continue };
            let leaf = path.rsplit('/').next().unwrap_or(path);
            let dir = metric_direction(leaf);
            if dir == Direction::Ignore {
                continue;
            }
            report.deltas.push(compare(path, *bv, *nv, dir, leaf, threshold_pct));
        }
    }
    for name in new.payloads.keys() {
        if !base.payloads.contains_key(name) {
            report.unpaired.push(format!("new-only:{}", name));
        }
    }
    Ok(report)
}

fn compare(
    path: &str,
    base: f64,
    new: f64,
    dir: Direction,
    leaf: &str,
    threshold_pct: f64,
) -> MetricDelta {
    // Direction-adjusted "how much worse", in percent of base.
    let worse_pct = if base.abs() < 1e-12 {
        0.0
    } else {
        match dir {
            Direction::HigherIsBetter => (base - new) / base * 100.0,
            Direction::LowerIsBetter | Direction::Ignore => (new - base) / base * 100.0,
        }
    };
    // Shed/rejected counts only gate when the base run itself shed:
    // quick runs flipping 0→small are noise, sustained-shed growth is
    // a real serving regression.
    let gates = if is_shed_metric(leaf) { base > 0.0 } else { true };
    MetricDelta {
        path: path.to_string(),
        base,
        new,
        worse_pct,
        regressed: gates && worse_pct > threshold_pct,
    }
}

/// One verified run's column in a [`HistoryReport`] trajectory.
#[derive(Debug, Clone)]
pub struct HistoryRun {
    pub run_id: String,
    pub timestamp_utc: String,
    /// Gating-direction metrics only (`path → value`); informational
    /// leaves (configs, sizes) are dropped.
    pub metrics: BTreeMap<String, f64>,
}

/// `strum bench-diff --history`: N verified runs' metrics side by side,
/// oldest first. Unlike the pairwise diff this never gates — it answers
/// "how did p99 move across the last five runs", not "did it regress".
#[derive(Debug, Clone, Default)]
pub struct HistoryReport {
    /// Runs in manifest-timestamp order (RFC3339 sorts lexically).
    pub runs: Vec<HistoryRun>,
    /// `run_id:payload` for payloads whose checksum re-verification
    /// failed; the whole run is excluded from the table.
    pub checksum_failures: Vec<String>,
}

/// Loads and checksum-verifies N manifests, collects each run's
/// direction-classified metrics, and orders the runs by their manifest
/// timestamp (not argument order — shell globs don't sort by time).
pub fn history_manifests(paths: &[std::path::PathBuf]) -> crate::Result<HistoryReport> {
    anyhow::ensure!(
        paths.len() >= 2,
        "--history wants at least two manifests, got {}",
        paths.len()
    );
    let mut report = HistoryReport::default();
    for path in paths {
        let m = RunManifest::load_verified(path)?;
        let dir = path.parent().unwrap_or(Path::new("."));
        let failures = m.verify_payloads(dir);
        if !failures.is_empty() {
            for f in failures {
                report.checksum_failures.push(format!("{}:{}", m.run_id, f));
            }
            continue;
        }
        let mut metrics = BTreeMap::new();
        for (name, p) in &m.payloads {
            let json = read_payload(dir, &p.path)?;
            collect_metrics(name, &json, &mut metrics);
        }
        metrics.retain(|path, _| {
            let leaf = path.rsplit('/').next().unwrap_or(path);
            metric_direction(leaf) != Direction::Ignore
        });
        report.runs.push(HistoryRun {
            run_id: m.run_id.clone(),
            timestamp_utc: m.timestamp_utc.clone(),
            metrics,
        });
    }
    report
        .runs
        .sort_by(|a, b| (&a.timestamp_utc, &a.run_id).cmp(&(&b.timestamp_utc, &b.run_id)));
    Ok(report)
}

/// Renders the trajectory table: one row per metric, one column per
/// run, plus a direction-adjusted drift column (last vs first, positive
/// = got worse).
pub fn render_history(report: &HistoryReport) -> String {
    let mut out = String::new();
    if !report.checksum_failures.is_empty() {
        out.push_str("CHECKSUM FAILURES (runs excluded):\n");
        for f in &report.checksum_failures {
            out.push_str(&format!("  {}\n", f));
        }
    }
    if report.runs.is_empty() {
        out.push_str("no verified runs\n");
        return out;
    }
    out.push_str("runs (oldest first):\n");
    for (i, r) in report.runs.iter().enumerate() {
        out.push_str(&format!("  [{}] {}  {}\n", i, r.run_id, r.timestamp_utc));
    }
    let mut paths: Vec<&String> = report
        .runs
        .iter()
        .flat_map(|r| r.metrics.keys())
        .collect();
    paths.sort();
    paths.dedup();
    let width = paths.iter().map(|p| p.len()).max().unwrap_or(6).max(6);
    out.push_str(&format!("{:<w$}", "metric", w = width));
    for i in 0..report.runs.len() {
        out.push_str(&format!("  {:>12}", format!("[{}]", i)));
    }
    out.push_str("    drift%\n");
    for path in &paths {
        out.push_str(&format!("{:<w$}", path, w = width));
        for r in &report.runs {
            match r.metrics.get(*path) {
                Some(v) => out.push_str(&format!("  {:>12.3}", v)),
                None => out.push_str(&format!("  {:>12}", "-")),
            }
        }
        let present: Vec<f64> = report
            .runs
            .iter()
            .filter_map(|r| r.metrics.get(*path).copied())
            .collect();
        if present.len() >= 2 && present[0].abs() > 1e-12 {
            let leaf = path.rsplit('/').next().unwrap_or(path);
            let (first, last) = (present[0], present[present.len() - 1]);
            let drift = match metric_direction(leaf) {
                Direction::HigherIsBetter => (first - last) / first * 100.0,
                _ => (last - first) / first * 100.0,
            };
            out.push_str(&format!("  {:>+7.2}%\n", drift));
        } else {
            out.push_str(&format!("  {:>8}\n", "-"));
        }
    }
    out.push_str(&format!(
        "{} metrics across {} runs\n",
        paths.len(),
        report.runs.len()
    ));
    out
}

fn read_payload(dir: &Path, file: &str) -> crate::Result<Json> {
    let path = dir.join(file);
    let text = std::fs::read_to_string(&path)?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {}", path.display(), e))
}

/// Renders the per-metric table (regressions first, then the rest),
/// matching the repo's plain-text report style.
pub fn render_table(report: &DiffReport, threshold_pct: f64) -> String {
    let mut out = String::new();
    if !report.checksum_failures.is_empty() {
        out.push_str("CHECKSUM FAILURES:\n");
        for f in &report.checksum_failures {
            out.push_str(&format!("  {}\n", f));
        }
        return out;
    }
    let width = report
        .deltas
        .iter()
        .map(|d| d.path.len())
        .max()
        .unwrap_or(6)
        .max(6);
    out.push_str(&format!(
        "{:<w$}  {:>14}  {:>14}  {:>9}  status\n",
        "metric",
        "base",
        "new",
        "worse%",
        w = width
    ));
    let mut sorted: Vec<&MetricDelta> = report.deltas.iter().collect();
    sorted.sort_by(|a, b| {
        b.regressed
            .cmp(&a.regressed)
            .then(b.worse_pct.partial_cmp(&a.worse_pct).unwrap_or(std::cmp::Ordering::Equal))
    });
    for d in sorted {
        out.push_str(&format!(
            "{:<w$}  {:>14.3}  {:>14.3}  {:>+8.2}%  {}\n",
            d.path,
            d.base,
            d.new,
            d.worse_pct,
            if d.regressed { "REGRESSED" } else { "ok" },
            w = width
        ));
    }
    for u in &report.unpaired {
        out.push_str(&format!("unpaired: {}\n", u));
    }
    let n_reg = report.regressions().count();
    out.push_str(&format!(
        "{} metrics compared, {} regression(s) past {:.1}% threshold\n",
        report.deltas.len(),
        n_reg,
        threshold_pct
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("strum-diff-{}-{}", tag, std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_run(dir: &Path, name: &str, p99: f64, rps: f64, shed: f64) -> PathBuf {
        let payload = dir.join(format!("BENCH_{}.json", name));
        let body = Json::obj(vec![
            ("p99_us", Json::Num(p99)),
            ("throughput_rps", Json::Num(rps)),
            ("shed", Json::Num(shed)),
            ("config_batch", Json::Num(8.0)),
        ]);
        fs::write(&payload, body.to_string()).unwrap();
        let mut m = RunManifest::capture(&format!("run-{}", name));
        m.add_payload(name, &payload).unwrap();
        let mpath = dir.join(format!("MANIFEST_{}.json", name));
        m.save(&mpath).unwrap();
        mpath
    }

    #[test]
    fn identical_runs_pass() {
        let d1 = tmp_dir("same-a");
        let d2 = tmp_dir("same-b");
        let a = write_run(&d1, "serve", 900.0, 120.0, 0.0);
        let b = write_run(&d2, "serve", 900.0, 120.0, 0.0);
        let r = diff_manifests(&a, &b, 5.0).unwrap();
        assert!(!r.failed(), "{:?}", r);
        assert!(!r.deltas.is_empty());
        let _ = fs::remove_dir_all(&d1);
        let _ = fs::remove_dir_all(&d2);
    }

    #[test]
    fn p99_regression_gates_and_renders() {
        let d1 = tmp_dir("reg-a");
        let d2 = tmp_dir("reg-b");
        let a = write_run(&d1, "serve", 900.0, 120.0, 0.0);
        let b = write_run(&d2, "serve", 1400.0, 119.0, 0.0); // +55% p99
        let r = diff_manifests(&a, &b, 25.0).unwrap();
        assert!(r.failed());
        let regressed: Vec<_> = r.regressions().map(|d| d.path.as_str()).collect();
        assert_eq!(regressed, vec!["serve/p99_us"]);
        let table = render_table(&r, 25.0);
        assert!(table.contains("REGRESSED"));
        assert!(table.contains("serve/p99_us"));
        let _ = fs::remove_dir_all(&d1);
        let _ = fs::remove_dir_all(&d2);
    }

    #[test]
    fn throughput_drop_gates_but_config_never_does() {
        let d1 = tmp_dir("tp-a");
        let d2 = tmp_dir("tp-b");
        let a = write_run(&d1, "serve", 900.0, 120.0, 0.0);
        let b = write_run(&d2, "serve", 900.0, 60.0, 0.0); // -50% rps
        let r = diff_manifests(&a, &b, 10.0).unwrap();
        let regressed: Vec<_> = r.regressions().map(|d| d.path.as_str()).collect();
        assert_eq!(regressed, vec!["serve/throughput_rps"]);
        // config_batch is Ignore: never even compared.
        assert!(r.deltas.iter().all(|d| !d.path.contains("config_batch")));
        let _ = fs::remove_dir_all(&d1);
        let _ = fs::remove_dir_all(&d2);
    }

    #[test]
    fn shed_gates_only_with_nonzero_base() {
        let d1 = tmp_dir("shed-a");
        let d2 = tmp_dir("shed-b");
        // base shed 0 → new shed 5: noise, must not gate.
        let a = write_run(&d1, "serve", 900.0, 120.0, 0.0);
        let b = write_run(&d2, "serve", 900.0, 120.0, 5.0);
        assert!(!diff_manifests(&a, &b, 5.0).unwrap().failed());
        let _ = fs::remove_dir_all(&d1);
        let _ = fs::remove_dir_all(&d2);

        let d3 = tmp_dir("shed-c");
        let d4 = tmp_dir("shed-d");
        // base shed 100 → new shed 300: gates.
        let c = write_run(&d3, "serve", 900.0, 120.0, 100.0);
        let e = write_run(&d4, "serve", 900.0, 120.0, 300.0);
        assert!(diff_manifests(&c, &e, 5.0).unwrap().failed());
        let _ = fs::remove_dir_all(&d3);
        let _ = fs::remove_dir_all(&d4);
    }

    #[test]
    fn corrupted_payload_fails_integrity() {
        let d1 = tmp_dir("cor-a");
        let d2 = tmp_dir("cor-b");
        let a = write_run(&d1, "serve", 900.0, 120.0, 0.0);
        let b = write_run(&d2, "serve", 900.0, 120.0, 0.0);
        // Flip a byte in the new side's payload after manifesting.
        let payload = d2.join("BENCH_serve.json");
        let mut text = fs::read_to_string(&payload).unwrap();
        text = text.replace("900", "901");
        fs::write(&payload, text).unwrap();
        let r = diff_manifests(&a, &b, 5.0).unwrap();
        assert!(r.failed());
        assert_eq!(r.checksum_failures, vec!["new:serve".to_string()]);
        assert!(render_table(&r, 5.0).contains("CHECKSUM FAILURES"));
        let _ = fs::remove_dir_all(&d1);
        let _ = fs::remove_dir_all(&d2);
    }

    #[test]
    fn corrupted_manifest_is_an_error() {
        let d1 = tmp_dir("man-a");
        let d2 = tmp_dir("man-b");
        let a = write_run(&d1, "serve", 900.0, 120.0, 0.0);
        let b = write_run(&d2, "serve", 900.0, 120.0, 0.0);
        let text = fs::read_to_string(&b).unwrap();
        fs::write(&b, text.replace("\"kernel_isa\"", "\"kernel_lsa\"")).unwrap();
        assert!(diff_manifests(&a, &b, 5.0).is_err());
        let _ = fs::remove_dir_all(&d1);
        let _ = fs::remove_dir_all(&d2);
    }

    fn write_run_at(dir: &Path, run_id: &str, ts: &str, p99: f64) -> PathBuf {
        let payload = dir.join("BENCH_serve.json");
        let body = Json::obj(vec![
            ("p99_us", Json::Num(p99)),
            ("throughput_rps", Json::Num(100.0)),
        ]);
        fs::write(&payload, body.to_string()).unwrap();
        let mut m = RunManifest::capture(run_id);
        m.timestamp_utc = ts.to_string();
        m.add_payload("serve", &payload).unwrap();
        let mpath = dir.join("MANIFEST_serve.json");
        m.save(&mpath).unwrap();
        mpath
    }

    #[test]
    fn history_sorts_by_timestamp_and_reports_drift() {
        let d1 = tmp_dir("hist-a");
        let d2 = tmp_dir("hist-b");
        let d3 = tmp_dir("hist-c");
        // Passed newest-first on purpose: the sort must go by manifest
        // timestamp, not argument order.
        let newest = write_run_at(&d3, "run-c", "2026-08-03T00:00:00Z", 1200.0);
        let oldest = write_run_at(&d1, "run-a", "2026-08-01T00:00:00Z", 1000.0);
        let middle = write_run_at(&d2, "run-b", "2026-08-02T00:00:00Z", 1100.0);
        let r = history_manifests(&[newest, oldest, middle]).unwrap();
        assert!(r.checksum_failures.is_empty());
        let ids: Vec<&str> = r.runs.iter().map(|x| x.run_id.as_str()).collect();
        assert_eq!(ids, vec!["run-a", "run-b", "run-c"]);
        let table = render_history(&r);
        // p99 went 1000 → 1200: +20% drift (lower-is-better, so worse).
        assert!(table.contains("serve/p99_us"), "{}", table);
        assert!(table.contains("+20.00%"), "{}", table);
        // Flat throughput drifts 0%.
        assert!(table.contains("+0.00%") || table.contains("-0.00%"), "{}", table);
        for d in [&d1, &d2, &d3] {
            let _ = fs::remove_dir_all(d);
        }
    }

    #[test]
    fn history_excludes_tampered_runs() {
        let d1 = tmp_dir("histcor-a");
        let d2 = tmp_dir("histcor-b");
        let a = write_run_at(&d1, "run-a", "2026-08-01T00:00:00Z", 1000.0);
        let b = write_run_at(&d2, "run-b", "2026-08-02T00:00:00Z", 1100.0);
        let payload = d2.join("BENCH_serve.json");
        let text = fs::read_to_string(&payload).unwrap().replace("1100", "900");
        fs::write(&payload, text).unwrap();
        let r = history_manifests(&[a, b]).unwrap();
        assert_eq!(r.checksum_failures, vec!["run-b:serve".to_string()]);
        assert_eq!(r.runs.len(), 1);
        assert!(render_history(&r).contains("CHECKSUM FAILURES"));
        let _ = fs::remove_dir_all(&d1);
        let _ = fs::remove_dir_all(&d2);
    }

    #[test]
    fn history_wants_two_runs() {
        let d = tmp_dir("hist-one");
        let a = write_run_at(&d, "run-a", "2026-08-01T00:00:00Z", 1000.0);
        assert!(history_manifests(&[a]).is_err());
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn array_rows_pair_by_key_label() {
        let d1 = tmp_dir("arr-a");
        let d2 = tmp_dir("arr-b");
        let mk = |dir: &Path, p99_b: f64, p99_m: f64| -> PathBuf {
            let payload = dir.join("BENCH_multi.json");
            let body = Json::obj(vec![(
                "variants",
                Json::Arr(vec![
                    Json::obj(vec![("key", Json::str("base")), ("p99_us", Json::Num(p99_b))]),
                    Json::obj(vec![("key", Json::str("mip2q")), ("p99_us", Json::Num(p99_m))]),
                ]),
            )]);
            fs::write(&payload, body.to_string()).unwrap();
            let mut m = RunManifest::capture("run-arr");
            m.add_payload("multi", &payload).unwrap();
            let mpath = dir.join("MANIFEST_multi.json");
            m.save(&mpath).unwrap();
            mpath
        };
        let a = mk(&d1, 500.0, 800.0);
        let b = mk(&d2, 500.0, 1600.0); // only mip2q regressed
        let r = diff_manifests(&a, &b, 20.0).unwrap();
        let regressed: Vec<_> = r.regressions().map(|d| d.path.as_str()).collect();
        assert_eq!(regressed, vec!["multi/variants[mip2q]/p99_us"]);
        let _ = fs::remove_dir_all(&d1);
        let _ = fs::remove_dir_all(&d2);
    }
}
