//! Non-blocking JSONL event writer: a bounded channel in front of one
//! dedicated flusher thread.
//!
//! The contract the serving tier relies on: **zero writes on the
//! request hot path**. [`TelemetrySink::emit`] constructs nothing but
//! the event value and `try_send`s it into a bounded channel — no
//! serialization, no allocation beyond the event itself, no blocking.
//! When the channel is full the event is *dropped* and a counter
//! incremented (surfaced through the engine's metrics snapshot as
//! `telemetry_dropped`): backpressure from a slow disk can never stall
//! a worker. The flusher thread owns the receiver, serializes lines,
//! and handles size-based rotation plus a retention cap on rotated
//! files.
//!
//! A disabled sink ([`TelemetrySink::disabled`], the default) is a
//! no-op handle: `emit` is a branch on an `Option` and nothing else, so
//! instrumented code paths cost nothing when telemetry is off.

use super::schema::Event;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, SystemTime};

/// Writer tunables. `Default` gives 4 MiB rotation, 8 retained files,
/// and an 8192-event channel.
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Directory the JSONL files are written into (created if needed).
    pub dir: PathBuf,
    /// Rotate to a new file once the current one reaches this size.
    pub rotate_bytes: u64,
    /// Keep at most this many files (oldest deleted first). Never
    /// drops below 1.
    pub retain_files: usize,
    /// Bounded channel capacity; events beyond it are dropped+counted.
    pub capacity: usize,
}

impl TelemetryConfig {
    pub fn under(dir: impl Into<PathBuf>) -> TelemetryConfig {
        TelemetryConfig {
            dir: dir.into(),
            rotate_bytes: 4 * 1024 * 1024,
            retain_files: 8,
            capacity: 8192,
        }
    }
}

enum Msg {
    Event(Event, u64),
    /// Flush buffered lines to disk and ack.
    Flush(SyncSender<()>),
}

struct SinkInner {
    /// `None` only during drop (taken to disconnect the flusher).
    tx: Option<SyncSender<Msg>>,
    run_id: String,
    emitted: AtomicU64,
    dropped: AtomicU64,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl Drop for SinkInner {
    fn drop(&mut self) {
        // Disconnect first so the flusher drains the channel and exits,
        // then join it — every accepted event reaches disk.
        self.tx = None;
        if let Some(t) = self.flusher.take() {
            let _ = t.join();
        }
    }
}

/// Cheap cloneable telemetry handle. All clones share one run
/// (`run_id`), one channel, and one flusher thread; the last clone's
/// drop joins the flusher after draining.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySink {
    inner: Option<Arc<SinkInner>>,
}

impl TelemetrySink {
    /// The no-op sink: `emit` does nothing, `dropped()` is 0.
    pub fn disabled() -> TelemetrySink {
        TelemetrySink { inner: None }
    }

    /// Opens a sink writing JSONL under `cfg.dir`, generating a fresh
    /// run id. Fails only if the directory cannot be created.
    pub fn open(cfg: TelemetryConfig) -> crate::Result<TelemetrySink> {
        Self::open_with_run_id(cfg, super::fresh_run_id())
    }

    /// Opens a sink under an externally-chosen run id (so a caller can
    /// correlate the log with a manifest it writes itself).
    pub fn open_with_run_id(cfg: TelemetryConfig, run_id: String) -> crate::Result<TelemetrySink> {
        fs::create_dir_all(&cfg.dir)?;
        let (tx, rx) = mpsc::sync_channel(cfg.capacity.max(1));
        let id = run_id.clone();
        let flusher = std::thread::Builder::new()
            .name("telemetry-flush".into())
            .spawn(move || flusher_loop(rx, &cfg, &id))?;
        Ok(TelemetrySink {
            inner: Some(Arc::new(SinkInner {
                tx: Some(tx),
                run_id,
                emitted: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                flusher: Some(flusher),
            })),
        })
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// This sink's run id (empty for a disabled sink).
    pub fn run_id(&self) -> &str {
        self.inner.as_ref().map(|i| i.run_id.as_str()).unwrap_or("")
    }

    /// Events dropped because the channel was full.
    pub fn dropped(&self) -> u64 {
        self.inner
            .as_ref()
            .map(|i| i.dropped.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Events accepted into the channel (written or still buffered).
    pub fn emitted(&self) -> u64 {
        self.inner
            .as_ref()
            .map(|i| i.emitted.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Hot-path event submission: a branch, a timestamp, and a
    /// `try_send`. Never blocks, never writes; a full channel drops the
    /// event and bumps the drop counter.
    pub fn emit(&self, event: Event) {
        let Some(inner) = &self.inner else { return };
        let Some(tx) = &inner.tx else { return };
        let ts_ms = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        match tx.try_send(Msg::Event(event, ts_ms)) {
            Ok(()) => {
                inner.emitted.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                inner.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Blocks until every event accepted so far is on disk. Used at
    /// orderly shutdown and by tests before reading the log back; the
    /// request path never calls this.
    pub fn flush(&self) {
        let Some(inner) = &self.inner else { return };
        let Some(tx) = &inner.tx else { return };
        let (ack_tx, ack_rx) = mpsc::sync_channel(1);
        if tx.send(Msg::Flush(ack_tx)).is_ok() {
            let _ = ack_rx.recv();
        }
    }
}

/// The flusher: drains the channel, serializes lines, rotates files.
fn flusher_loop(rx: Receiver<Msg>, cfg: &TelemetryConfig, run_id: &str) {
    let mut seq = 0usize;
    let mut written = 0u64;
    let mut file = open_segment(&cfg.dir, run_id, seq);
    let mut buf = String::new();
    loop {
        // Block briefly so an idle stream still gets its lines flushed
        // out of the userspace buffer within ~200 ms.
        let msg = match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(m) => Some(m),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        match msg {
            Some(Msg::Event(event, ts_ms)) => {
                buf.clear();
                buf.push_str(&event.to_json(run_id, ts_ms).to_string());
                buf.push('\n');
                if let Some(f) = file.as_mut() {
                    if f.write_all(buf.as_bytes()).is_ok() {
                        written += buf.len() as u64;
                    }
                }
                if written >= cfg.rotate_bytes {
                    // Size-based rotation + retention sweep.
                    if let Some(f) = file.as_mut() {
                        let _ = f.flush();
                    }
                    seq += 1;
                    written = 0;
                    file = open_segment(&cfg.dir, run_id, seq);
                    enforce_retention(&cfg.dir, run_id, cfg.retain_files.max(1));
                }
            }
            Some(Msg::Flush(ack)) => {
                if let Some(f) = file.as_mut() {
                    let _ = f.flush();
                }
                let _ = ack.send(());
            }
            None => {
                if let Some(f) = file.as_mut() {
                    let _ = f.flush();
                }
            }
        }
    }
    if let Some(f) = file.as_mut() {
        let _ = f.flush();
    }
}

/// `telemetry-<run_id>.<seq>.jsonl`, buffered. An unopenable segment
/// degrades to discarding lines rather than crashing the flusher (the
/// drop counter does not cover disk failure; serving keeps going).
fn open_segment(dir: &Path, run_id: &str, seq: usize) -> Option<std::io::BufWriter<fs::File>> {
    let path = segment_path(dir, run_id, seq);
    fs::File::create(&path).ok().map(std::io::BufWriter::new)
}

pub(crate) fn segment_path(dir: &Path, run_id: &str, seq: usize) -> PathBuf {
    dir.join(format!("telemetry-{}.{:04}.jsonl", run_id, seq))
}

/// Lists this run's segment files, oldest (lowest seq) first.
pub fn segment_files(dir: &Path, run_id: &str) -> Vec<PathBuf> {
    let prefix = format!("telemetry-{}.", run_id);
    let mut files: Vec<PathBuf> = fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .map(|n| n.starts_with(&prefix) && n.ends_with(".jsonl"))
                        .unwrap_or(false)
                })
                .collect()
        })
        .unwrap_or_default();
    files.sort();
    files
}

fn enforce_retention(dir: &Path, run_id: &str, retain: usize) {
    let files = segment_files(dir, run_id);
    if files.len() > retain {
        for old in &files[..files.len() - retain] {
            let _ = fs::remove_file(old);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::schema::{validate_line, ShedStage};
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "strum-telemetry-{}-{}",
            tag,
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn shed_event() -> Event {
        Event::RequestShed {
            key: Arc::from("k"),
            stage: ShedStage::Queue,
        }
    }

    fn read_lines(dir: &Path, run_id: &str) -> Vec<String> {
        segment_files(dir, run_id)
            .iter()
            .flat_map(|p| {
                fs::read_to_string(p)
                    .unwrap_or_default()
                    .lines()
                    .map(str::to_string)
                    .collect::<Vec<_>>()
            })
            .collect()
    }

    #[test]
    fn accepted_events_all_reach_disk() {
        let dir = tmp_dir("basic");
        let sink = TelemetrySink::open(TelemetryConfig::under(&dir)).unwrap();
        let n = 500usize;
        for _ in 0..n {
            sink.emit(shed_event());
        }
        sink.flush();
        let lines = read_lines(&dir, sink.run_id());
        assert_eq!(lines.len() as u64, sink.emitted());
        assert_eq!(sink.emitted() + sink.dropped(), n as u64);
        for l in &lines {
            let p = validate_line(l).unwrap();
            assert_eq!(p.run_id, sink.run_id());
            assert_eq!(p.tag, "request_shed");
        }
        drop(sink);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn overflow_drops_are_counted_never_blocking() {
        let dir = tmp_dir("overflow");
        // Capacity 2: a burst far beyond it must never block the
        // emitter; the invariant is exact accounting, not a specific
        // drop count (the flusher races the burst).
        let sink = TelemetrySink::open(TelemetryConfig {
            capacity: 2,
            ..TelemetryConfig::under(&dir)
        })
        .unwrap();
        let n = 20_000u64;
        for _ in 0..n {
            sink.emit(shed_event());
        }
        sink.flush();
        assert_eq!(sink.emitted() + sink.dropped(), n);
        let lines = read_lines(&dir, sink.run_id());
        assert_eq!(lines.len() as u64, sink.emitted());
        drop(sink);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_and_retention_cap_file_count() {
        let dir = tmp_dir("rotate");
        let sink = TelemetrySink::open(TelemetryConfig {
            rotate_bytes: 2048,
            retain_files: 3,
            ..TelemetryConfig::under(&dir)
        })
        .unwrap();
        // Each line is ~90 bytes; thousands of events force many
        // rotations. Events are channel-paced (capacity 8192 default
        // far exceeds 4000, so nothing is dropped).
        for _ in 0..4000 {
            sink.emit(shed_event());
            // Pace the emitter so the flusher keeps up and every event
            // lands (drops would make the file-count assertion vacuous).
            if sink.emitted() % 512 == 0 {
                sink.flush();
            }
        }
        sink.flush();
        assert_eq!(sink.dropped(), 0);
        let run_id = sink.run_id().to_string();
        drop(sink);
        let files = segment_files(&dir, &run_id);
        assert!(
            files.len() <= 3,
            "retention cap violated: {} files",
            files.len()
        );
        assert!(!files.is_empty());
        // Every retained line still validates.
        for l in read_lines(&dir, &run_id) {
            validate_line(&l).unwrap();
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_sink_is_inert() {
        let sink = TelemetrySink::disabled();
        assert!(!sink.is_enabled());
        sink.emit(shed_event());
        sink.flush();
        assert_eq!(sink.emitted(), 0);
        assert_eq!(sink.dropped(), 0);
        assert_eq!(sink.run_id(), "");
    }

    #[test]
    fn drop_drains_the_channel() {
        let dir = tmp_dir("drain");
        let sink = TelemetrySink::open(TelemetryConfig::under(&dir)).unwrap();
        let run_id = sink.run_id().to_string();
        for _ in 0..200 {
            sink.emit(shed_event());
        }
        let emitted = sink.emitted();
        // No explicit flush: dropping the last handle must still land
        // every accepted event before the flusher exits.
        drop(sink);
        let lines = read_lines(&dir, &run_id);
        assert_eq!(lines.len() as u64, emitted);
        let _ = fs::remove_dir_all(&dir);
    }
}
