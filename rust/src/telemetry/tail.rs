//! `strum tail` — the telemetry query CLI's engine.
//!
//! Scans a directory of JSONL telemetry segments (as written by
//! [`TelemetrySink`](super::TelemetrySink)), validating every line with
//! [`validate_line`] and applying a [`TailFilter`]. Two renderers sit
//! on top of the scan:
//!
//! * [`render_waterfall`] — reconstructs one traced request end to end:
//!   gateway attempts (winner + abandoned hedges/retries), queue wait,
//!   batch formation, execute, per-layer profile, reply write — ordered
//!   by attempt then pipeline stage, with a layer-total vs execute
//!   cross-check.
//! * [`render_rates`] — windowed request rates: buckets
//!   `request_done`/`request_shed`/`request_rejected` events into
//!   fixed-width time windows and prints per-window counts and
//!   throughput.
//!
//! Invalid lines are counted and skipped, never fatal: a segment cut
//! mid-write by a crash ends in a torn line, and the reader must still
//! serve the 10k lines before it.

use super::schema::{fmt_trace, validate_line, ParsedLine, SPAN_STAGES};
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

/// Line predicate for [`scan_dir`]. Empty filter matches everything;
/// set fields AND together.
#[derive(Debug, Clone, Default)]
pub struct TailFilter {
    /// Keep only lines stamped with this run id.
    pub run_id: Option<String>,
    /// Keep only `span` lines carrying this trace id.
    pub trace: Option<u64>,
    /// Keep only lines with this event tag.
    pub event: Option<String>,
    /// Keep only lines whose variant key matches.
    pub variant: Option<String>,
}

impl TailFilter {
    pub fn matches(&self, line: &ParsedLine) -> bool {
        if let Some(r) = &self.run_id {
            if &line.run_id != r {
                return false;
            }
        }
        if let Some(t) = self.trace {
            if line.trace != Some(t) {
                return false;
            }
        }
        if let Some(e) = &self.event {
            if &line.tag != e {
                return false;
            }
        }
        if let Some(v) = &self.variant {
            if line.key.as_deref() != Some(v.as_str()) {
                return false;
            }
        }
        true
    }
}

/// Result of scanning a telemetry directory: the matching lines in
/// timestamp order, plus scan bookkeeping for the summary footer.
#[derive(Debug, Default)]
pub struct TailScan {
    /// Lines that validated and passed the filter, sorted by `ts_ms`
    /// (stable, so same-millisecond lines keep file order).
    pub lines: Vec<ParsedLine>,
    /// Segment files visited.
    pub files: usize,
    /// Non-empty lines read across all segments.
    pub total_lines: usize,
    /// Lines that failed schema validation (counted, skipped).
    pub invalid_lines: usize,
}

/// Scans every `telemetry-*.jsonl` segment under `dir` (all runs —
/// narrow with [`TailFilter::run_id`]), in filename order so rotation
/// sequence numbers read chronologically within a run.
pub fn scan_dir(dir: &Path, filter: &TailFilter) -> crate::Result<TailScan> {
    let mut names: Vec<std::path::PathBuf> = Vec::new();
    for entry in std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {}", dir.display(), e))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("telemetry-") && name.ends_with(".jsonl") {
            names.push(entry.path());
        }
    }
    names.sort();
    let mut scan = TailScan::default();
    for path in names {
        scan.files += 1;
        let file = File::open(&path)
            .map_err(|e| anyhow::anyhow!("cannot open {}: {}", path.display(), e))?;
        for line in BufReader::new(file).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            scan.total_lines += 1;
            match validate_line(&line) {
                Ok(parsed) => {
                    if filter.matches(&parsed) {
                        scan.lines.push(parsed);
                    }
                }
                Err(_) => scan.invalid_lines += 1,
            }
        }
    }
    scan.lines.sort_by_key(|l| l.ts_ms);
    Ok(scan)
}

/// Pipeline position of a span stage, for ordering a waterfall. Stages
/// outside [`SPAN_STAGES`] (a newer writer) sort last.
fn stage_rank(stage: &str) -> usize {
    SPAN_STAGES
        .iter()
        .position(|s| *s == stage)
        .unwrap_or(SPAN_STAGES.len())
}

/// Renders the waterfall for one trace id from a scan's lines: spans
/// grouped by attempt (gateway retries/hedges), each attempt's stages
/// in pipeline order, abandoned attempts tagged. The footer
/// cross-checks summed per-layer time against the execute span.
pub fn render_waterfall(lines: &[ParsedLine], trace: u64) -> String {
    let mut spans: Vec<&ParsedLine> = lines
        .iter()
        .filter(|l| l.tag == "span" && l.trace == Some(trace))
        .collect();
    if spans.is_empty() {
        return format!("trace {}: no spans found\n", fmt_trace(trace));
    }
    spans.sort_by(|a, b| {
        (a.attempt, stage_rank(a.stage.as_deref().unwrap_or("")), a.ts_ms).cmp(&(
            b.attempt,
            stage_rank(b.stage.as_deref().unwrap_or("")),
            b.ts_ms,
        ))
    });
    let mut out = format!("trace {} — {} spans\n", fmt_trace(trace), spans.len());
    let mut cur_attempt: Option<u32> = None;
    let mut layer_total: u64 = 0;
    let mut execute_us: Option<u64> = None;
    for s in &spans {
        if cur_attempt != Some(s.attempt) {
            cur_attempt = Some(s.attempt);
            let abandoned = spans
                .iter()
                .filter(|x| x.attempt == s.attempt)
                .all(|x| x.abandoned);
            out.push_str(&format!(
                "attempt {}{}\n",
                s.attempt,
                if abandoned { "  [abandoned]" } else { "" }
            ));
        }
        let stage = s.stage.as_deref().unwrap_or("?");
        let label = match (stage, &s.detail) {
            ("layer", Some(name)) => format!("layer {}", name),
            _ => stage.to_string(),
        };
        let key = s.key.as_deref().map(|k| format!("  [{}]", k)).unwrap_or_default();
        out.push_str(&format!("  {:<24} {:>10} us{}\n", label, s.dur_us, key));
        if !s.abandoned {
            match stage {
                "layer" => layer_total += s.dur_us,
                "execute" => execute_us = Some(s.dur_us),
                _ => {}
            }
        }
    }
    if let Some(exec) = execute_us {
        if layer_total > 0 {
            out.push_str(&format!(
                "layers sum {} us / execute {} us{}\n",
                layer_total,
                exec,
                if layer_total > exec {
                    "  (layers exceed execute: clock skew?)"
                } else {
                    ""
                }
            ));
        }
    }
    out
}

/// Renders windowed request rates from a scan's lines: buckets the
/// request-outcome events into `window_s`-second windows anchored at
/// the earliest event and prints per-window done/shed/rejected counts
/// plus completed-per-second.
pub fn render_rates(lines: &[ParsedLine], window_s: u64) -> String {
    let window_s = window_s.max(1);
    let outcomes: Vec<&ParsedLine> = lines
        .iter()
        .filter(|l| {
            matches!(
                l.tag.as_str(),
                "request_done" | "request_shed" | "request_rejected"
            )
        })
        .collect();
    if outcomes.is_empty() {
        return "no request events in range\n".to_string();
    }
    let t0 = outcomes.iter().map(|l| l.ts_ms).min().unwrap();
    let span_ms = window_s * 1000;
    let last = outcomes.iter().map(|l| l.ts_ms).max().unwrap();
    let windows = ((last - t0) / span_ms + 1) as usize;
    // (done, shed, rejected) per window.
    let mut counts = vec![(0u64, 0u64, 0u64); windows];
    for l in &outcomes {
        let idx = ((l.ts_ms - t0) / span_ms) as usize;
        let c = &mut counts[idx];
        match l.tag.as_str() {
            "request_done" => c.0 += 1,
            "request_shed" => c.1 += 1,
            _ => c.2 += 1,
        }
    }
    let mut out = format!(
        "{:>8}  {:>8}  {:>8}  {:>8}  {:>10}\n",
        "window_s", "done", "shed", "rejected", "done/s"
    );
    for (i, (done, shed, rejected)) in counts.iter().enumerate() {
        out.push_str(&format!(
            "{:>8}  {:>8}  {:>8}  {:>8}  {:>10.1}\n",
            i as u64 * window_s,
            done,
            shed,
            rejected,
            *done as f64 / window_s as f64
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Event, ShedStage};
    use std::io::Write;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "strum-tail-{}-{}-{}",
            tag,
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_segment(dir: &std::path::Path, name: &str, lines: &[String]) {
        let mut f = std::fs::File::create(dir.join(name)).unwrap();
        for l in lines {
            writeln!(f, "{}", l).unwrap();
        }
    }

    fn span(
        trace: u64,
        attempt: u32,
        stage: &'static str,
        dur_us: u64,
        abandoned: bool,
        detail: Option<&str>,
    ) -> Event {
        Event::Span {
            trace,
            attempt,
            stage,
            key: Some(Arc::from("cnn:w8a8")),
            dur_us,
            abandoned,
            detail: detail.map(String::from),
        }
    }

    fn line(ev: &Event, run_id: &str, ts_ms: u64) -> String {
        ev.to_json(run_id, ts_ms).to_string()
    }

    #[test]
    fn scan_filters_and_sorts_and_counts_invalid() {
        let dir = tmp_dir("scan");
        write_segment(
            &dir,
            "telemetry-runa.0000.jsonl",
            &[
                line(&span(7, 0, "execute", 100, false, None), "runa", 20),
                line(&span(7, 0, "queue_wait", 5, false, None), "runa", 10),
                "not json at all".to_string(),
            ],
        );
        write_segment(
            &dir,
            "telemetry-runb.0000.jsonl",
            &[line(&span(9, 0, "execute", 50, false, None), "runb", 15)],
        );
        // A non-telemetry file in the dir is ignored entirely.
        write_segment(&dir, "notes.txt", &["hello".to_string()]);

        let all = scan_dir(&dir, &TailFilter::default()).unwrap();
        assert_eq!(all.files, 2);
        assert_eq!(all.total_lines, 4);
        assert_eq!(all.invalid_lines, 1);
        assert_eq!(all.lines.len(), 3);
        // Sorted by ts_ms across files.
        let ts: Vec<u64> = all.lines.iter().map(|l| l.ts_ms).collect();
        assert_eq!(ts, vec![10, 15, 20]);

        let by_run = scan_dir(
            &dir,
            &TailFilter {
                run_id: Some("runb".into()),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(by_run.lines.len(), 1);
        assert_eq!(by_run.lines[0].trace, Some(9));

        let by_trace = scan_dir(
            &dir,
            &TailFilter {
                trace: Some(7),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(by_trace.lines.len(), 2);

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn filter_by_event_and_variant() {
        let done = Event::RequestDone {
            key: Arc::from("a"),
            latency_us: 10,
            deadline_budget_ms: None,
            batch_occupancy: 1,
            batch_padded: 1,
        };
        let shed = Event::RequestShed {
            key: Arc::from("b"),
            stage: ShedStage::Queue,
        };
        let dir = tmp_dir("filter");
        write_segment(
            &dir,
            "telemetry-r.0000.jsonl",
            &[line(&done, "r", 1), line(&shed, "r", 2)],
        );
        let sheds = scan_dir(
            &dir,
            &TailFilter {
                event: Some("request_shed".into()),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(sheds.lines.len(), 1);
        assert_eq!(sheds.lines[0].key.as_deref(), Some("b"));

        let var_a = scan_dir(
            &dir,
            &TailFilter {
                variant: Some("a".into()),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(var_a.lines.len(), 1);
        assert_eq!(var_a.lines[0].tag, "request_done");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn waterfall_orders_attempts_and_stages_and_flags_abandoned() {
        let t = 0xabcdu64;
        let dir = tmp_dir("wf");
        // Written out of order on purpose; hedge attempt 1 lost.
        write_segment(
            &dir,
            "telemetry-r.0000.jsonl",
            &[
                line(&span(t, 0, "execute", 900, false, None), "r", 30),
                line(&span(t, 0, "layer", 400, false, Some("conv1")), "r", 31),
                line(&span(t, 0, "layer", 300, false, Some("fc")), "r", 32),
                line(&span(t, 0, "queue_wait", 50, false, None), "r", 20),
                line(&span(t, 0, "gateway_attempt", 1200, false, None), "r", 40),
                line(&span(t, 1, "gateway_attempt", 800, true, None), "r", 41),
                line(&span(999, 0, "execute", 1, false, None), "r", 5),
            ],
        );
        let scan = scan_dir(&dir, &TailFilter::default()).unwrap();
        let out = render_waterfall(&scan.lines, t);
        // Both attempts present; the losing hedge is tagged.
        assert!(out.contains("attempt 0\n"), "{}", out);
        assert!(out.contains("attempt 1  [abandoned]"), "{}", out);
        // Stage order within attempt 0: gateway_attempt, queue_wait,
        // execute, then layers.
        let ga = out.find("gateway_attempt").unwrap();
        let qw = out.find("queue_wait").unwrap();
        let ex = out.find("execute").unwrap();
        let l1 = out.find("layer conv1").unwrap();
        let l2 = out.find("layer fc").unwrap();
        assert!(ga < qw && qw < ex && ex < l1 && l1 < l2, "{}", out);
        // Footer reconciles layer sum against execute.
        assert!(out.contains("layers sum 700 us / execute 900 us"), "{}", out);
        // The other trace's span stayed out.
        assert_eq!(out.matches("execute").count(), 2, "{}", out); // span line + footer

        let missing = render_waterfall(&scan.lines, 0xdead);
        assert!(missing.contains("no spans found"), "{}", missing);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rates_bucket_by_window() {
        let done = |ts: u64| {
            line(
                &Event::RequestDone {
                    key: Arc::from("k"),
                    latency_us: 5,
                    deadline_budget_ms: None,
                    batch_occupancy: 1,
                    batch_padded: 1,
                },
                "r",
                ts,
            )
        };
        let shed = |ts: u64| {
            line(
                &Event::RequestShed {
                    key: Arc::from("k"),
                    stage: ShedStage::Door,
                },
                "r",
                ts,
            )
        };
        let dir = tmp_dir("rates");
        write_segment(
            &dir,
            "telemetry-r.0000.jsonl",
            &[
                done(1000),
                done(1500),
                shed(1900),
                done(3100), // second 2s window
            ],
        );
        let scan = scan_dir(&dir, &TailFilter::default()).unwrap();
        let out = render_rates(&scan.lines, 2);
        let rows: Vec<&str> = out.lines().collect();
        assert_eq!(rows.len(), 3, "{}", out); // header + 2 windows
        assert!(rows[1].trim_start().starts_with('0'), "{}", out);
        // Window 0: 2 done, 1 shed. Window 1: 1 done.
        assert!(rows[1].contains('2') && rows[1].contains('1'), "{}", out);
        assert!(rows[2].contains('1'), "{}", out);

        let empty = render_rates(&[], 2);
        assert!(empty.contains("no request events"), "{}", empty);
        std::fs::remove_dir_all(&dir).ok();
    }
}
