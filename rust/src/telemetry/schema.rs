//! Telemetry event schema: the tagged event enum every JSONL line is
//! one serialization of, plus the line validator the tests and external
//! consumers use.
//!
//! Every line is a self-describing JSON object carrying three envelope
//! fields — `schema_version` (this file's [`SCHEMA_VERSION`]), `run_id`
//! (one per [`super::TelemetrySink`], correlating the log with the run
//! manifest written next to bench JSONs), and `ts_ms` (unix epoch
//! milliseconds) — plus `event` (the tag) and the tag's own fields.
//! Consumers MUST ignore unknown fields and unknown tags: minor schema
//! growth adds fields/tags, a major change bumps [`SCHEMA_VERSION`].
//!
//! Events are plain values: the hot path constructs one and hands it to
//! the sink's bounded channel; serialization happens on the flusher
//! thread ([`super::writer`]), never on the request path.

use crate::coordinator::MetricsSnapshot;
use crate::util::json::Json;
use std::sync::Arc;

/// Telemetry line schema version. Bump on breaking changes only;
/// additive fields keep the version. v2 added the `span` tag (request
/// tracing) and the interval-delta fields on `engine_gauges` rows.
/// [`validate_line`] accepts every version up to this one, so mixed
/// logs (a v1 segment next to a v2 segment) still parse.
pub const SCHEMA_VERSION: u32 = 2;

/// Formats a trace id the way it travels in JSON and on the CLI:
/// 16 lowercase hex digits. Trace ids are random u64s — serializing
/// them as JSON numbers would lose precision above 2^53, so they ride
/// as strings everywhere outside the binary wire protocol.
pub fn fmt_trace(trace: u64) -> String {
    format!("{:016x}", trace)
}

/// Parses a trace id as printed by [`fmt_trace`] (any-length hex,
/// leading `0x` tolerated).
pub fn parse_trace(s: &str) -> Option<u64> {
    let s = s.trim();
    let s = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")).unwrap_or(s);
    if s.is_empty() || s.len() > 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// Trace context attached to one request attempt: the gateway-minted
/// trace id plus the attempt ordinal (0 = primary; retries and hedges
/// count up while sharing the trace id). Rides v2 wire frames as the
/// optional 9-byte tail ([`crate::server::proto::TRACE_TAIL_BYTES`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub attempt: u8,
}

/// Stage names a [`Event::Span`] may carry, in causal order along the
/// serving path. `strum tail` sorts a trace's spans by this order when
/// timestamps tie.
pub const SPAN_STAGES: &[&str] = &[
    "gateway_attempt",
    "door",
    "queue_wait",
    "batch",
    "execute",
    "layer",
    "reply_write",
];

/// Where a deadline shed happened (mirrors the serving tier's three
/// shed stages; the wait-stage shed is client-side and not an engine
/// event).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedStage {
    /// Refused at submit: the deadline had already passed.
    Door,
    /// Dropped by a worker: the deadline passed while queued.
    Queue,
}

impl ShedStage {
    pub fn name(&self) -> &'static str {
        match self {
            ShedStage::Door => "door",
            ShedStage::Queue => "queue",
        }
    }
}

/// Per-variant gauge row inside an [`Event::EngineGauges`] snapshot.
/// `completed`/`shed`/`rejected` stay cumulative (since boot) for
/// compatibility; the `d_*` twins are the deltas over the ticker
/// interval that ended at this event (`window_s` seconds), so
/// dashboards read per-interval rates straight off the row instead of
/// differencing successive snapshots by hand.
#[derive(Debug, Clone)]
pub struct GaugeRow {
    pub key: String,
    pub queued: usize,
    pub completed: u64,
    pub shed: u64,
    pub rejected: u64,
    pub throughput_rps: f64,
    pub p99_us: f64,
    /// Requests completed in the interval ending at this event.
    pub d_completed: u64,
    /// Requests shed in the interval.
    pub d_shed: u64,
    /// Submits rejected in the interval.
    pub d_rejected: u64,
    /// Interval length in seconds (0 on the first emission).
    pub window_s: f64,
}

/// One telemetry event. Variant keys ride as `Arc<str>` so per-request
/// events clone a pointer, not a heap string, on the hot path.
#[derive(Debug, Clone)]
pub enum Event {
    /// A request completed; counts reconcile with the metrics snapshot's
    /// per-variant `completed`.
    RequestDone {
        key: Arc<str>,
        latency_us: u64,
        /// The request's total deadline budget (enqueue → deadline),
        /// absent for requests submitted without one.
        deadline_budget_ms: Option<u64>,
        batch_occupancy: u32,
        batch_padded: u32,
    },
    /// A request shed for a passed deadline (door or queue stage);
    /// counts reconcile with the snapshot's per-variant `shed`.
    RequestShed { key: Arc<str>, stage: ShedStage },
    /// A submit refused with QueueFull backpressure; counts reconcile
    /// with the snapshot's per-variant `rejected`.
    RequestRejected { key: Arc<str>, depth: usize },
    /// A worker cut a batch from a variant queue.
    BatchFormed {
        key: Arc<str>,
        occupancy: u32,
        padded: u32,
    },
    /// A variant was hot-added to the engine.
    VariantRegistered {
        key: Arc<str>,
        net: String,
        backend: String,
    },
    /// A variant finished draining and was removed.
    VariantRetired { key: Arc<str> },
    /// The wire server accepted a connection.
    ConnOpened { peer: String },
    /// A connection closed (EOF, error, or drain) after serving
    /// `requests` framed requests.
    ConnClosed { peer: String, requests: u64 },
    /// The wire server began its graceful drain.
    ServerDrain { connections: u64, requests: u64 },
    /// A connection went pipelined: a second request arrived while the
    /// first was still in flight (async tier only; emitted once per
    /// connection, at the first overlap). `depth` is the in-flight
    /// count at that moment. Counts reconcile with the server stats
    /// snapshot's `pipelined_conns`.
    ConnPipelined { peer: String, depth: u64 },
    /// The HTTP gateway answered one request (any endpoint, any
    /// status). Counts reconcile with the server stats snapshot's
    /// `http_requests`.
    HttpRequest {
        method: String,
        path: String,
        status: u16,
        latency_us: u64,
    },
    /// Periodic engine gauge snapshot (one row per live variant).
    EngineGauges {
        uptime_s: f64,
        workers: usize,
        variants: Vec<GaugeRow>,
    },
    /// The gateway supervisor spawned (or respawned) a replica process
    /// and scraped its listen address.
    ReplicaSpawned {
        id: u64,
        cohort: u64,
        addr: String,
        pid: u32,
    },
    /// A supervised replica process exited (crash, fault-plan kill, or
    /// drain); `exit_code` is absent when the process died to a signal.
    ReplicaDied {
        id: u64,
        cohort: u64,
        exit_code: Option<i64>,
        restarts: u64,
    },
    /// The supervisor scheduled a crashed replica's restart after a
    /// backoff pause.
    ReplicaRestarted {
        id: u64,
        cohort: u64,
        restarts: u64,
        backoff_ms: u64,
    },
    /// A rolling deploy began: a new artifact version was observed and
    /// a fresh cohort of replicas is coming up.
    DeployStarted { cohort: u64, version: String },
    /// The new cohort survived probation and owns the traffic.
    DeployCompleted { cohort: u64, version: String },
    /// The new cohort regressed (or never became healthy) and traffic
    /// returned to the previous cohort.
    DeployRolledBack {
        cohort: u64,
        version: String,
        reason: String,
    },
    /// The gateway router retried a request on another replica after a
    /// shed or connection failure.
    RouteRetry { key: Arc<str>, reason: String },
    /// The gateway fired a tail hedge; `win` marks whether the hedge's
    /// reply beat the primary's.
    HedgeFired { key: Arc<str>, win: bool },
    /// One timed stage of a traced request (see [`SPAN_STAGES`]).
    /// Emitted only for requests carrying a trace id, so the untraced
    /// hot path never constructs one. `attempt` distinguishes gateway
    /// retries/hedges sharing one trace id; `abandoned` tags the spans
    /// of a hedge attempt whose reply lost the race (or a retried
    /// primary). `detail` carries the layer name for `stage == "layer"`.
    Span {
        trace: u64,
        attempt: u32,
        stage: &'static str,
        key: Option<Arc<str>>,
        dur_us: u64,
        abandoned: bool,
        detail: Option<String>,
    },
}

impl Event {
    /// The line's `event` tag.
    pub fn tag(&self) -> &'static str {
        match self {
            Event::RequestDone { .. } => "request_done",
            Event::RequestShed { .. } => "request_shed",
            Event::RequestRejected { .. } => "request_rejected",
            Event::BatchFormed { .. } => "batch_formed",
            Event::VariantRegistered { .. } => "variant_registered",
            Event::VariantRetired { .. } => "variant_retired",
            Event::ConnOpened { .. } => "conn_opened",
            Event::ConnClosed { .. } => "conn_closed",
            Event::ServerDrain { .. } => "server_drain",
            Event::ConnPipelined { .. } => "conn_pipelined",
            Event::HttpRequest { .. } => "http_request",
            Event::EngineGauges { .. } => "engine_gauges",
            Event::ReplicaSpawned { .. } => "replica_spawned",
            Event::ReplicaDied { .. } => "replica_died",
            Event::ReplicaRestarted { .. } => "replica_restarted",
            Event::DeployStarted { .. } => "deploy_started",
            Event::DeployCompleted { .. } => "deploy_completed",
            Event::DeployRolledBack { .. } => "deploy_rolled_back",
            Event::RouteRetry { .. } => "route_retry",
            Event::HedgeFired { .. } => "hedge_fired",
            Event::Span { .. } => "span",
        }
    }

    /// Builds a periodic gauge event from a typed metrics snapshot.
    /// Interval deltas read zero (no earlier snapshot to difference
    /// against) — the ticker uses [`Event::gauges_delta`].
    pub fn gauges(snap: &MetricsSnapshot) -> Event {
        Self::gauges_delta(snap, None)
    }

    /// Builds a gauge event whose rows carry both cumulative counters
    /// and the deltas since `prev` (the previous ticker snapshot).
    /// Variants absent from `prev` (hot-added since) report their
    /// cumulative counts as the delta.
    pub fn gauges_delta(snap: &MetricsSnapshot, prev: Option<&MetricsSnapshot>) -> Event {
        let window_s = prev.map_or(0.0, |p| (snap.uptime_s - p.uptime_s).max(0.0));
        Event::EngineGauges {
            uptime_s: snap.uptime_s,
            workers: snap.workers,
            variants: snap
                .variants
                .iter()
                .map(|v| {
                    let old = prev.and_then(|p| p.variants.iter().find(|o| o.key == v.key));
                    let base = |f: fn(&crate::coordinator::VariantSnapshot) -> u64| {
                        old.map(f).unwrap_or(0)
                    };
                    GaugeRow {
                        key: v.key.clone(),
                        queued: v.queued,
                        completed: v.completed,
                        shed: v.shed,
                        rejected: v.rejected,
                        throughput_rps: v.throughput_rps,
                        p99_us: v.latency.p99_us,
                        d_completed: v.completed.saturating_sub(base(|o| o.completed)),
                        d_shed: v.shed.saturating_sub(base(|o| o.shed)),
                        d_rejected: v.rejected.saturating_sub(base(|o| o.rejected)),
                        window_s,
                    }
                })
                .collect(),
        }
    }

    /// Serializes one JSONL line body (envelope + tag fields). Runs on
    /// the flusher thread only.
    pub fn to_json(&self, run_id: &str, ts_ms: u64) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("run_id", Json::str(run_id)),
            ("ts_ms", Json::Num(ts_ms as f64)),
            ("event", Json::str(self.tag())),
        ];
        match self {
            Event::RequestDone {
                key,
                latency_us,
                deadline_budget_ms,
                batch_occupancy,
                batch_padded,
            } => {
                fields.push(("key", Json::str(&**key)));
                fields.push(("latency_us", Json::Num(*latency_us as f64)));
                fields.push((
                    "deadline_budget_ms",
                    match deadline_budget_ms {
                        Some(ms) => Json::Num(*ms as f64),
                        None => Json::Null,
                    },
                ));
                fields.push(("batch_occupancy", Json::Num(*batch_occupancy as f64)));
                fields.push(("batch_padded", Json::Num(*batch_padded as f64)));
            }
            Event::RequestShed { key, stage } => {
                fields.push(("key", Json::str(&**key)));
                fields.push(("stage", Json::str(stage.name())));
            }
            Event::RequestRejected { key, depth } => {
                fields.push(("key", Json::str(&**key)));
                fields.push(("depth", Json::Num(*depth as f64)));
            }
            Event::BatchFormed {
                key,
                occupancy,
                padded,
            } => {
                fields.push(("key", Json::str(&**key)));
                fields.push(("occupancy", Json::Num(*occupancy as f64)));
                fields.push(("padded", Json::Num(*padded as f64)));
            }
            Event::VariantRegistered { key, net, backend } => {
                fields.push(("key", Json::str(&**key)));
                fields.push(("net", Json::str(net.as_str())));
                fields.push(("backend", Json::str(backend.as_str())));
            }
            Event::VariantRetired { key } => {
                fields.push(("key", Json::str(&**key)));
            }
            Event::ConnOpened { peer } => {
                fields.push(("peer", Json::str(peer.as_str())));
            }
            Event::ConnClosed { peer, requests } => {
                fields.push(("peer", Json::str(peer.as_str())));
                fields.push(("requests", Json::Num(*requests as f64)));
            }
            Event::ServerDrain {
                connections,
                requests,
            } => {
                fields.push(("connections", Json::Num(*connections as f64)));
                fields.push(("requests", Json::Num(*requests as f64)));
            }
            Event::ConnPipelined { peer, depth } => {
                fields.push(("peer", Json::str(peer.as_str())));
                fields.push(("depth", Json::Num(*depth as f64)));
            }
            Event::HttpRequest {
                method,
                path,
                status,
                latency_us,
            } => {
                fields.push(("method", Json::str(method.as_str())));
                fields.push(("path", Json::str(path.as_str())));
                fields.push(("status", Json::Num(*status as f64)));
                fields.push(("latency_us", Json::Num(*latency_us as f64)));
            }
            Event::EngineGauges {
                uptime_s,
                workers,
                variants,
            } => {
                fields.push(("uptime_s", Json::Num(*uptime_s)));
                fields.push(("workers", Json::Num(*workers as f64)));
                fields.push((
                    "variants",
                    Json::Arr(
                        variants
                            .iter()
                            .map(|g| {
                                Json::obj(vec![
                                    ("key", Json::str(g.key.as_str())),
                                    ("queued", Json::Num(g.queued as f64)),
                                    ("completed", Json::Num(g.completed as f64)),
                                    ("shed", Json::Num(g.shed as f64)),
                                    ("rejected", Json::Num(g.rejected as f64)),
                                    ("throughput_rps", Json::Num(g.throughput_rps)),
                                    ("p99_us", Json::Num(g.p99_us)),
                                    ("d_completed", Json::Num(g.d_completed as f64)),
                                    ("d_shed", Json::Num(g.d_shed as f64)),
                                    ("d_rejected", Json::Num(g.d_rejected as f64)),
                                    ("window_s", Json::Num(g.window_s)),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
            Event::ReplicaSpawned {
                id,
                cohort,
                addr,
                pid,
            } => {
                fields.push(("id", Json::Num(*id as f64)));
                fields.push(("cohort", Json::Num(*cohort as f64)));
                fields.push(("addr", Json::str(addr.as_str())));
                fields.push(("pid", Json::Num(*pid as f64)));
            }
            Event::ReplicaDied {
                id,
                cohort,
                exit_code,
                restarts,
            } => {
                fields.push(("id", Json::Num(*id as f64)));
                fields.push(("cohort", Json::Num(*cohort as f64)));
                fields.push((
                    "exit_code",
                    match exit_code {
                        Some(c) => Json::Num(*c as f64),
                        None => Json::Null,
                    },
                ));
                fields.push(("restarts", Json::Num(*restarts as f64)));
            }
            Event::ReplicaRestarted {
                id,
                cohort,
                restarts,
                backoff_ms,
            } => {
                fields.push(("id", Json::Num(*id as f64)));
                fields.push(("cohort", Json::Num(*cohort as f64)));
                fields.push(("restarts", Json::Num(*restarts as f64)));
                fields.push(("backoff_ms", Json::Num(*backoff_ms as f64)));
            }
            Event::DeployStarted { cohort, version }
            | Event::DeployCompleted { cohort, version } => {
                fields.push(("cohort", Json::Num(*cohort as f64)));
                fields.push(("version", Json::str(version.as_str())));
            }
            Event::DeployRolledBack {
                cohort,
                version,
                reason,
            } => {
                fields.push(("cohort", Json::Num(*cohort as f64)));
                fields.push(("version", Json::str(version.as_str())));
                fields.push(("reason", Json::str(reason.as_str())));
            }
            Event::RouteRetry { key, reason } => {
                fields.push(("key", Json::str(&**key)));
                fields.push(("reason", Json::str(reason.as_str())));
            }
            Event::HedgeFired { key, win } => {
                fields.push(("key", Json::str(&**key)));
                fields.push(("win", Json::Bool(*win)));
            }
            Event::Span {
                trace,
                attempt,
                stage,
                key,
                dur_us,
                abandoned,
                detail,
            } => {
                fields.push(("trace", Json::Str(fmt_trace(*trace))));
                fields.push(("attempt", Json::Num(*attempt as f64)));
                fields.push(("stage", Json::str(stage)));
                if let Some(k) = key {
                    fields.push(("key", Json::str(&**k)));
                }
                fields.push(("dur_us", Json::Num(*dur_us as f64)));
                fields.push(("abandoned", Json::Bool(*abandoned)));
                if let Some(d) = detail {
                    fields.push(("detail", Json::str(d.as_str())));
                }
            }
        }
        Json::obj(fields)
    }
}

/// A validated, partially-decoded telemetry line: the envelope plus the
/// fields reconciliation cares about. Unknown tags are rejected by
/// [`validate_line`] (this crate emits only known tags; a consumer
/// tolerating foreign producers should skip them instead).
#[derive(Debug, Clone)]
pub struct ParsedLine {
    pub schema_version: u32,
    pub run_id: String,
    pub ts_ms: u64,
    pub tag: String,
    /// Variant key, for per-variant events.
    pub key: Option<String>,
    /// Trace id, for `span` lines (parsed from the hex string field).
    pub trace: Option<u64>,
    /// Span stage, for `span` lines.
    pub stage: Option<String>,
    /// Attempt number, for `span` lines (0 otherwise).
    pub attempt: u32,
    /// Span duration in microseconds (0 for non-span lines).
    pub dur_us: u64,
    /// Whether a span belonged to an abandoned (losing) attempt.
    pub abandoned: bool,
    /// Span detail (layer name for `stage == "layer"`).
    pub detail: Option<String>,
}

/// Known event tags, for validation.
const KNOWN_TAGS: &[&str] = &[
    "request_done",
    "request_shed",
    "request_rejected",
    "batch_formed",
    "variant_registered",
    "variant_retired",
    "conn_opened",
    "conn_closed",
    "server_drain",
    "conn_pipelined",
    "http_request",
    "engine_gauges",
    "replica_spawned",
    "replica_died",
    "replica_restarted",
    "deploy_started",
    "deploy_completed",
    "deploy_rolled_back",
    "route_retry",
    "hedge_fired",
    "span",
];

/// Parses and validates one JSONL line against the schema: well-formed
/// JSON object, complete envelope, supported `schema_version`, known
/// tag, and the tag's required fields present with the right types.
pub fn validate_line(line: &str) -> crate::Result<ParsedLine> {
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("unparseable line: {}", e))?;
    anyhow::ensure!(v.as_obj().is_some(), "line is not a JSON object");
    let version = v
        .get("schema_version")
        .and_then(|x| x.as_f64())
        .ok_or_else(|| anyhow::anyhow!("missing schema_version"))? as u32;
    anyhow::ensure!(
        (1..=SCHEMA_VERSION).contains(&version),
        "unsupported schema_version {} (supported: 1..={})",
        version,
        SCHEMA_VERSION
    );
    let run_id = v
        .get("run_id")
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing run_id"))?
        .to_string();
    let ts_ms = v
        .get("ts_ms")
        .and_then(|x| x.as_f64())
        .ok_or_else(|| anyhow::anyhow!("missing ts_ms"))? as u64;
    let tag = v
        .get("event")
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing event tag"))?
        .to_string();
    anyhow::ensure!(KNOWN_TAGS.contains(&tag.as_str()), "unknown event tag '{}'", tag);
    let require_str = |field: &str| -> crate::Result<String> {
        v.get(field)
            .and_then(|x| x.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("{}: missing string field '{}'", tag, field))
    };
    let require_num = |field: &str| -> crate::Result<f64> {
        v.get(field)
            .and_then(|x| x.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{}: missing numeric field '{}'", tag, field))
    };
    let mut trace = None;
    let mut stage_field = None;
    let mut attempt = 0u32;
    let mut dur_us = 0u64;
    let mut abandoned = false;
    let mut detail = None;
    let key = match tag.as_str() {
        "span" => {
            let t = require_str("trace")?;
            trace = Some(
                parse_trace(&t)
                    .ok_or_else(|| anyhow::anyhow!("span: bad trace id '{}'", t))?,
            );
            let stage = require_str("stage")?;
            anyhow::ensure!(
                SPAN_STAGES.contains(&stage.as_str()),
                "span: unknown stage '{}'",
                stage
            );
            stage_field = Some(stage);
            attempt = require_num("attempt")? as u32;
            dur_us = require_num("dur_us")? as u64;
            abandoned = v.get("abandoned").and_then(|x| x.as_bool()).unwrap_or(false);
            detail = v.get("detail").and_then(|x| x.as_str()).map(str::to_string);
            v.get("key").and_then(|x| x.as_str()).map(str::to_string)
        }
        "request_done" => {
            require_num("latency_us")?;
            require_num("batch_occupancy")?;
            Some(require_str("key")?)
        }
        "request_shed" => {
            let stage = require_str("stage")?;
            anyhow::ensure!(
                stage == "door" || stage == "queue",
                "request_shed: bad stage '{}'",
                stage
            );
            Some(require_str("key")?)
        }
        "request_rejected" => {
            require_num("depth")?;
            Some(require_str("key")?)
        }
        "batch_formed" => {
            require_num("occupancy")?;
            require_num("padded")?;
            Some(require_str("key")?)
        }
        "variant_registered" => {
            require_str("net")?;
            require_str("backend")?;
            Some(require_str("key")?)
        }
        "variant_retired" => Some(require_str("key")?),
        "conn_opened" => {
            require_str("peer")?;
            None
        }
        "conn_closed" => {
            require_str("peer")?;
            require_num("requests")?;
            None
        }
        "server_drain" => {
            require_num("connections")?;
            require_num("requests")?;
            None
        }
        "conn_pipelined" => {
            require_str("peer")?;
            require_num("depth")?;
            None
        }
        "http_request" => {
            require_str("method")?;
            require_str("path")?;
            require_num("status")?;
            require_num("latency_us")?;
            None
        }
        "engine_gauges" => {
            require_num("uptime_s")?;
            anyhow::ensure!(
                v.get("variants").and_then(|x| x.as_arr()).is_some(),
                "engine_gauges: missing variants array"
            );
            None
        }
        "replica_spawned" => {
            require_num("id")?;
            require_num("cohort")?;
            require_str("addr")?;
            require_num("pid")?;
            None
        }
        "replica_died" => {
            require_num("id")?;
            require_num("cohort")?;
            require_num("restarts")?;
            // exit_code may be null (killed by signal); when present it
            // must be numeric.
            if let Some(code) = v.get("exit_code") {
                anyhow::ensure!(
                    matches!(code, Json::Null | Json::Num(_)),
                    "replica_died: exit_code must be null or numeric"
                );
            }
            None
        }
        "replica_restarted" => {
            require_num("id")?;
            require_num("cohort")?;
            require_num("restarts")?;
            require_num("backoff_ms")?;
            None
        }
        "deploy_started" | "deploy_completed" => {
            require_num("cohort")?;
            require_str("version")?;
            None
        }
        "deploy_rolled_back" => {
            require_num("cohort")?;
            require_str("version")?;
            require_str("reason")?;
            None
        }
        "route_retry" => {
            require_str("reason")?;
            Some(require_str("key")?)
        }
        "hedge_fired" => {
            anyhow::ensure!(
                v.get("win").and_then(|x| x.as_bool()).is_some(),
                "hedge_fired: missing bool field 'win'"
            );
            Some(require_str("key")?)
        }
        _ => unreachable!("tag checked against KNOWN_TAGS"),
    };
    Ok(ParsedLine {
        schema_version: version,
        run_id,
        ts_ms,
        tag,
        key,
        trace,
        stage: stage_field,
        attempt,
        dur_us,
        abandoned,
        detail,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> Arc<str> {
        Arc::from("mini_cnn_s:base")
    }

    #[test]
    fn every_event_serializes_and_validates() {
        let events = vec![
            Event::RequestDone {
                key: key(),
                latency_us: 420,
                deadline_budget_ms: Some(25),
                batch_occupancy: 3,
                batch_padded: 4,
            },
            Event::RequestShed {
                key: key(),
                stage: ShedStage::Door,
            },
            Event::RequestShed {
                key: key(),
                stage: ShedStage::Queue,
            },
            Event::RequestRejected {
                key: key(),
                depth: 1024,
            },
            Event::BatchFormed {
                key: key(),
                occupancy: 7,
                padded: 8,
            },
            Event::VariantRegistered {
                key: key(),
                net: "mini_cnn_s".into(),
                backend: "native".into(),
            },
            Event::VariantRetired { key: key() },
            Event::ConnOpened {
                peer: "127.0.0.1:5000".into(),
            },
            Event::ConnClosed {
                peer: "127.0.0.1:5000".into(),
                requests: 12,
            },
            Event::ServerDrain {
                connections: 3,
                requests: 36,
            },
            Event::ConnPipelined {
                peer: "127.0.0.1:5000".into(),
                depth: 2,
            },
            Event::HttpRequest {
                method: "POST".into(),
                path: "/v1/infer".into(),
                status: 200,
                latency_us: 850,
            },
            Event::EngineGauges {
                uptime_s: 1.5,
                workers: 2,
                variants: vec![GaugeRow {
                    key: "k".into(),
                    queued: 0,
                    completed: 10,
                    shed: 1,
                    rejected: 0,
                    throughput_rps: 6.7,
                    p99_us: 900.0,
                    d_completed: 4,
                    d_shed: 0,
                    d_rejected: 0,
                    window_s: 0.5,
                }],
            },
            Event::Span {
                trace: 0xDEAD_BEEF_0102_0304,
                attempt: 0,
                stage: "queue_wait",
                key: Some(key()),
                dur_us: 314,
                abandoned: false,
                detail: None,
            },
            Event::Span {
                trace: 1,
                attempt: 2,
                stage: "layer",
                key: Some(key()),
                dur_us: 42,
                abandoned: true,
                detail: Some("conv1".into()),
            },
            Event::Span {
                trace: u64::MAX,
                attempt: 1,
                stage: "gateway_attempt",
                key: None,
                dur_us: 9000,
                abandoned: true,
                detail: None,
            },
            Event::ReplicaSpawned {
                id: 1,
                cohort: 0,
                addr: "127.0.0.1:41234".into(),
                pid: 4242,
            },
            Event::ReplicaDied {
                id: 1,
                cohort: 0,
                exit_code: Some(113),
                restarts: 2,
            },
            Event::ReplicaDied {
                id: 2,
                cohort: 0,
                exit_code: None,
                restarts: 0,
            },
            Event::ReplicaRestarted {
                id: 1,
                cohort: 0,
                restarts: 3,
                backoff_ms: 160,
            },
            Event::DeployStarted {
                cohort: 1,
                version: "mini_cnn_s/fp:deadbeef/enc:1".into(),
            },
            Event::DeployCompleted {
                cohort: 1,
                version: "mini_cnn_s/fp:deadbeef/enc:1".into(),
            },
            Event::DeployRolledBack {
                cohort: 1,
                version: "mini_cnn_s/fp:deadbeef/enc:1".into(),
                reason: "cohort never became healthy".into(),
            },
            Event::RouteRetry {
                key: key(),
                reason: "shed".into(),
            },
            Event::HedgeFired {
                key: key(),
                win: true,
            },
        ];
        for e in events {
            let line = e.to_json("run-abc", 1234).to_string();
            let parsed = validate_line(&line).unwrap_or_else(|err| {
                panic!("event {} failed validation: {} ({})", e.tag(), err, line)
            });
            assert_eq!(parsed.schema_version, SCHEMA_VERSION);
            assert_eq!(parsed.run_id, "run-abc");
            assert_eq!(parsed.ts_ms, 1234);
            assert_eq!(parsed.tag, e.tag());
        }
    }

    #[test]
    fn validate_rejects_malformed_lines() {
        // Not JSON at all.
        assert!(validate_line("not json").is_err());
        // Not an object.
        assert!(validate_line("[1,2]").is_err());
        // Missing envelope fields.
        assert!(validate_line(r#"{"event":"request_done"}"#).is_err());
        // Unknown tag.
        assert!(validate_line(
            r#"{"schema_version":1,"run_id":"r","ts_ms":1,"event":"nonsense"}"#
        )
        .is_err());
        // Future schema version.
        assert!(validate_line(
            r#"{"schema_version":99,"run_id":"r","ts_ms":1,"event":"server_drain","connections":0,"requests":0}"#
        )
        .is_err());
        // Known tag with a missing required field.
        assert!(validate_line(
            r#"{"schema_version":1,"run_id":"r","ts_ms":1,"event":"request_done","key":"k"}"#
        )
        .is_err());
        // Bad shed stage.
        assert!(validate_line(
            r#"{"schema_version":1,"run_id":"r","ts_ms":1,"event":"request_shed","key":"k","stage":"wait"}"#
        )
        .is_err());
        // Gateway events with missing required fields.
        assert!(validate_line(
            r#"{"schema_version":1,"run_id":"r","ts_ms":1,"event":"replica_spawned","id":0}"#
        )
        .is_err());
        assert!(validate_line(
            r#"{"schema_version":1,"run_id":"r","ts_ms":1,"event":"replica_died","id":0,"cohort":0,"restarts":0,"exit_code":"boom"}"#
        )
        .is_err());
        assert!(validate_line(
            r#"{"schema_version":1,"run_id":"r","ts_ms":1,"event":"deploy_rolled_back","cohort":1,"version":"v"}"#
        )
        .is_err());
        assert!(validate_line(
            r#"{"schema_version":1,"run_id":"r","ts_ms":1,"event":"hedge_fired","key":"k","win":"yes"}"#
        )
        .is_err());
    }

    #[test]
    fn trace_ids_roundtrip_as_hex_strings() {
        for t in [0u64, 1, 0xDEAD_BEEF, u64::MAX, 1 << 63] {
            assert_eq!(parse_trace(&fmt_trace(t)), Some(t));
        }
        assert_eq!(parse_trace("0xAb"), Some(0xab));
        assert_eq!(parse_trace(" ff "), Some(0xff));
        assert_eq!(parse_trace(""), None);
        assert_eq!(parse_trace("zz"), None);
        assert_eq!(parse_trace("00000000000000000f"), None); // > 16 digits
        // Full-width ids (the reason trace rides as a string): a JSON
        // f64 number could not hold this value exactly.
        let e = Event::Span {
            trace: u64::MAX - 1,
            attempt: 0,
            stage: "execute",
            key: None,
            dur_us: 1,
            abandoned: false,
            detail: None,
        };
        let parsed = validate_line(&e.to_json("r", 0).to_string()).unwrap();
        assert_eq!(parsed.trace, Some(u64::MAX - 1));
        assert_eq!(parsed.stage.as_deref(), Some("execute"));
    }

    #[test]
    fn v1_lines_still_validate_under_v2() {
        // A pre-bump segment line (schema_version 1) must keep parsing
        // so mixed telemetry directories remain queryable.
        let parsed = validate_line(
            r#"{"schema_version":1,"run_id":"r","ts_ms":1,"event":"server_drain","connections":0,"requests":0}"#,
        )
        .unwrap();
        assert_eq!(parsed.schema_version, 1);
        // Future versions are still refused.
        assert!(validate_line(
            r#"{"schema_version":3,"run_id":"r","ts_ms":1,"event":"server_drain","connections":0,"requests":0}"#
        )
        .is_err());
        // Span lines with a bad stage or unparseable trace are refused.
        assert!(validate_line(
            r#"{"schema_version":2,"run_id":"r","ts_ms":1,"event":"span","trace":"ff","stage":"warp","attempt":0,"dur_us":1}"#
        )
        .is_err());
        assert!(validate_line(
            r#"{"schema_version":2,"run_id":"r","ts_ms":1,"event":"span","trace":"not-hex","stage":"execute","attempt":0,"dur_us":1}"#
        )
        .is_err());
    }

    #[test]
    fn gauge_deltas_difference_successive_snapshots() {
        use crate::coordinator::{
            FleetSnapshot, LatencyStats, MetricsSnapshot, VariantSnapshot,
            METRICS_SCHEMA_VERSION,
        };
        use crate::util::stats::Summary;
        use std::time::Duration;
        let mk = |completed: u64, shed: u64, uptime: f64| {
            let v = VariantSnapshot {
                key: "k".into(),
                net: "n".into(),
                backend: "native".into(),
                img: 8,
                classes: 4,
                requests: completed,
                completed,
                rejected: 0,
                shed,
                batches: 1,
                padded_slots: 0,
                mean_batch: 1.0,
                queued: 0,
                throughput_rps: 0.0,
                latency: LatencyStats::from_summary(&Summary::new()),
                hist: Default::default(),
            };
            MetricsSnapshot {
                schema_version: METRICS_SCHEMA_VERSION,
                wall_s: uptime,
                uptime_s: uptime,
                workers: 1,
                telemetry_dropped: 0,
                kernel_isa: "scalar".into(),
                fleet: FleetSnapshot::rollup(std::slice::from_ref(&v), Duration::from_secs(1), &[]),
                window: Default::default(),
                variants: vec![v],
            }
        };
        let prev = mk(10, 2, 1.0);
        let cur = mk(25, 3, 3.0);
        let Event::EngineGauges { variants, .. } = Event::gauges_delta(&cur, Some(&prev)) else {
            panic!("wrong event type");
        };
        assert_eq!(variants[0].d_completed, 15);
        assert_eq!(variants[0].d_shed, 1);
        assert_eq!(variants[0].completed, 25); // cumulative kept
        assert!((variants[0].window_s - 2.0).abs() < 1e-9);
        // No prev → deltas read zero-based cumulative, window 0.
        let Event::EngineGauges { variants, .. } = Event::gauges(&cur) else {
            panic!("wrong event type");
        };
        assert_eq!(variants[0].d_completed, 25);
        assert_eq!(variants[0].window_s, 0.0);
    }

    #[test]
    fn null_deadline_budget_is_valid() {
        let e = Event::RequestDone {
            key: key(),
            latency_us: 1,
            deadline_budget_ms: None,
            batch_occupancy: 1,
            batch_padded: 1,
        };
        let line = e.to_json("r", 0).to_string();
        assert!(line.contains("\"deadline_budget_ms\":null"));
        validate_line(&line).unwrap();
    }
}
