//! Adder-tree and accumulator cost models (the PE's reduction datapath,
//! Fig. 7/8: 8 products → adder tree → OF accumulator).

use super::gates::{activity, cell, Cost};

/// Ripple/carry-select hybrid n-bit adder (area ≈ n FAs; the speed
//  technique changes timing, not first-order area).
pub fn adder(n_bits: u32) -> Cost {
    Cost::uniform(n_bits as f64 * cell::FA, activity::ADDER)
}

/// Binary adder tree summing `inputs` operands of `in_bits` bits.
/// Width grows one bit per level (full-precision accumulation, no
/// truncation — matching the INT32 accumulators of the datapath).
pub fn adder_tree(inputs: u32, in_bits: u32) -> Cost {
    assert!(inputs.is_power_of_two() && inputs >= 2);
    let mut total = Cost::ZERO;
    let mut n = inputs;
    let mut bits = in_bits;
    while n > 1 {
        total += adder(bits + 1) * (n / 2) as f64;
        n /= 2;
        bits += 1;
    }
    total
}

/// Output-feature accumulator: n-bit adder + n-bit register.
pub fn accumulator(n_bits: u32) -> Cost {
    let add = adder(n_bits);
    let reg = Cost::uniform(n_bits as f64 * cell::DFF, activity::REGFILE);
    add + reg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_has_n_minus_one_adders() {
        // 8 inputs → 4+2+1 = 7 adders of growing width.
        let t = adder_tree(8, 16);
        let manual = adder(17) * 4.0 + adder(18) * 2.0 + adder(19) * 1.0;
        assert!((t.area - manual.area).abs() < 1e-9);
    }

    #[test]
    fn tree_monotone_in_inputs_and_width() {
        assert!(adder_tree(8, 16).area > adder_tree(4, 16).area);
        assert!(adder_tree(8, 20).area > adder_tree(8, 16).area);
    }

    #[test]
    fn accumulator_includes_register() {
        let a = accumulator(32);
        assert!(a.area > adder(32).area);
    }
}
