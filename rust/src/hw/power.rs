//! Activity-driven power estimation (the PTPX-with-SAIF substitute, §VI).
//!
//! Two sources of activity:
//! * **Analytic dense workload** ([`Activity::dense`]) — every MAC lane
//!   busy every cycle, RFs feeding at full rate: the steady-state inner
//!   loop of a compute-bound conv layer. Used for Fig. 13's power columns.
//! * **Simulator trace** ([`Activity`] built by `sim::driver`) — per-
//!   component op counts from the cycle-level FlexNN simulation of a real
//!   layer (the SAIF-equivalent path).
//!
//! Energy bookkeeping is in NAND2-toggle equivalents; reported *power* is
//! energy/cycle, and all paper comparisons are ratios, so units cancel.

use super::dpu::{dpu_cost, DpuConfig, DpuCost};
use super::gates::activity::LEAKAGE_PER_GATE;
use super::pe::{pe_cost, PeVariant};

/// Per-byte access energies (NAND2-toggle equivalents), Eyeriss-class
/// relative magnitudes: SRAM ≫ RF per byte; both comparable in aggregate
/// to MAC energy in a dense accelerator.
pub const RF_ACCESS_PER_BYTE: f64 = 40.0;
pub const SRAM_ACCESS_PER_BYTE: f64 = 110.0;

/// Component-level activity counts over a simulated window.
#[derive(Debug, Clone, Default)]
pub struct Activity {
    /// Total cycles in the window.
    pub cycles: u64,
    /// High-precision multiplier ops (lane-cycles).
    pub mult_ops: u64,
    /// Low-precision lane ops (shifter or narrow-mult lane-cycles).
    pub low_ops: u64,
    /// Adder-tree reduction cycles (PE-cycles with any active lane).
    pub tree_cycles: u64,
    /// Accumulator updates.
    pub accum_ops: u64,
    /// RF bytes read + written (data + bitmap RFs).
    pub rf_bytes: u64,
    /// SRAM bytes read + written.
    pub sram_bytes: u64,
    /// PE-cycles where the PE was clocked (not clock-gated idle).
    pub pe_active_cycles: u64,
}

impl Activity {
    /// Dense steady-state activity for `pes` PEs over `cycles` cycles with
    /// a `p_low` fraction of lanes running at low precision.
    pub fn dense(pes: u64, cycles: u64, p_low: f64) -> Activity {
        let lane_cycles = pes * cycles * 8;
        let low = (lane_cycles as f64 * p_low) as u64;
        Activity {
            cycles,
            mult_ops: lane_cycles - low,
            low_ops: low,
            tree_cycles: pes * cycles,
            accum_ops: pes * cycles,
            // IF 8 B + FL 8 B reads + 4 B OF r/w + 2 B bitmap per PE-cycle.
            rf_bytes: pes * cycles * (8 + 8 + 8 + 2),
            // 32 B/cycle load port + 16 B drain, amortized over the array.
            sram_bytes: cycles * 48,
            pe_active_cycles: pes * cycles,
        }
    }
}

/// Itemized power report (energy per cycle).
#[derive(Debug, Clone)]
pub struct PowerReport {
    pub variant: PeVariant,
    pub mac_datapath: f64,
    pub regfiles: f64,
    pub clock: f64,
    pub sram: f64,
    pub load_drain: f64,
    pub leakage: f64,
}

impl PowerReport {
    /// PE-level scope (datapath only), matching the paper's
    /// PE-in-isolation numbers.
    pub fn pe_level(&self) -> f64 {
        self.mac_datapath
    }
    /// PE-array scope: datapath + RFs + clocking.
    pub fn array_level(&self) -> f64 {
        self.mac_datapath + self.regfiles + self.clock
    }
    /// Full DPU.
    pub fn dpu_level(&self) -> f64 {
        self.array_level() + self.sram + self.load_drain + self.leakage
    }
}

/// Computes the power report for a variant from activity counts.
pub fn power(variant: PeVariant, act: &Activity, cfg: &DpuConfig) -> PowerReport {
    let cycles = act.cycles.max(1) as f64;
    let pc = pe_cost(variant);
    let dc: DpuCost = dpu_cost(variant, cfg);

    // Lane energies: per-op energy of one lane = component energy / lanes.
    let mult_lane = if matches!(variant, PeVariant::BaselineInt8 | PeVariant::DynamicMip2q { .. })
    {
        pc.multipliers.energy / 8.0
    } else {
        pc.multipliers.energy / 4.0
    };
    let low_lane = if pc.low_lanes.energy > 0.0 {
        pc.low_lanes.energy / 4.0
    } else {
        // Baseline has no low lanes; low ops (if any) run on multipliers.
        mult_lane
    };

    let mac = act.mult_ops as f64 * mult_lane
        + act.low_ops as f64 * low_lane
        + act.tree_cycles as f64 * pc.tree.energy
        + act.accum_ops as f64 * pc.accum.energy
        + act.pe_active_cycles as f64 * (pc.routing.energy + pc.control.energy + pc.gating.energy);

    let rf = act.rf_bytes as f64 * RF_ACCESS_PER_BYTE;
    let clock = act.pe_active_cycles as f64 * dc.pe_clock.energy;
    let sram = act.sram_bytes as f64 * SRAM_ACCESS_PER_BYTE;
    let load_drain = act.cycles as f64 * dc.load_drain.energy * 0.25;
    let leakage = dc.total.area * LEAKAGE_PER_GATE * act.cycles as f64;

    PowerReport {
        variant,
        mac_datapath: mac / cycles,
        regfiles: rf / cycles,
        clock: clock / cycles,
        sram: sram / cycles,
        load_drain: load_drain / cycles,
        leakage: leakage / cycles,
    }
}

/// TOPS/W proxy: MAC ops per unit energy at DPU scope.
pub fn tops_per_watt(variant: PeVariant, act: &Activity, cfg: &DpuConfig) -> f64 {
    let rep = power(variant, act, cfg);
    let macs_per_cycle = (act.mult_ops + act.low_ops) as f64 / act.cycles.max(1) as f64;
    macs_per_cycle / rep.dpu_level()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::pe::pe_dense_cycle_energy;
    use crate::hw::regfile::pe_regfiles;

    fn dense_act() -> Activity {
        Activity::dense(256, 10_000, 0.5)
    }

    #[test]
    fn pe_power_savings_band() {
        // Paper: 31–34% PE-level power savings (§VII-B).
        let cfg = DpuConfig::flexnn_16x16();
        let act = dense_act();
        let base = power(PeVariant::BaselineInt8, &act, &cfg).pe_level();
        for l in [7u8, 5] {
            let e = power(PeVariant::StaticMip2q { l_max: l }, &act, &cfg).pe_level();
            let save = 1.0 - e / base;
            assert!((0.25..=0.42).contains(&save), "L={} saving {}", l, save);
        }
    }

    #[test]
    fn dpu_power_savings_band() {
        // Paper: 10–12% power savings at PE-array/DPU scope.
        let cfg = DpuConfig::flexnn_16x16();
        let act = dense_act();
        let base = power(PeVariant::BaselineInt8, &act, &cfg).dpu_level();
        let e = power(PeVariant::StaticMip2q { l_max: 7 }, &act, &cfg).dpu_level();
        let save = 1.0 - e / base;
        assert!((0.06..=0.18).contains(&save), "dpu saving {}", save);
    }

    #[test]
    fn l5_saves_at_least_as_much_as_l7() {
        let cfg = DpuConfig::flexnn_16x16();
        let act = dense_act();
        let e7 = power(PeVariant::StaticMip2q { l_max: 7 }, &act, &cfg).pe_level();
        let e5 = power(PeVariant::StaticMip2q { l_max: 5 }, &act, &cfg).pe_level();
        assert!(e5 <= e7);
    }

    #[test]
    fn consistency_dense_matches_pe_dense_energy() {
        // The analytic dense path and pe_dense_cycle_energy agree on the
        // ordering of variants.
        let base = pe_dense_cycle_energy(PeVariant::BaselineInt8);
        let stat = pe_dense_cycle_energy(PeVariant::StaticMip2q { l_max: 7 });
        assert!(stat < base);
    }

    #[test]
    fn tops_per_watt_improves() {
        let cfg = DpuConfig::flexnn_16x16();
        let act = dense_act();
        assert!(
            tops_per_watt(PeVariant::StaticMip2q { l_max: 5 }, &act, &cfg)
                > tops_per_watt(PeVariant::BaselineInt8, &act, &cfg)
        );
    }

    #[test]
    fn regfiles_used() {
        // Silence dead-code: pe_regfiles is part of the public surface.
        assert!(pe_regfiles().area > 0.0);
    }
}
