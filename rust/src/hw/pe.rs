//! Processing-element cost models (§V-B, Fig. 8/9).
//!
//! The "PE level" metric matches the paper's PE-in-isolation numbers: the
//! MAC datapath (multiplier/shifter lanes, adder tree, accumulator) plus
//! the operand-routing (find-first + mask decode) and local control. The
//! register files and clocking are accounted one level up (PE-array), as
//! in the paper ("significant overhead (such as the register file) imposes
//! limitations on the relative area savings" beyond PE level).
//!
//! Variants:
//! * [`PeVariant::BaselineInt8`] — FlexNN PE: 8 INT8×INT8 multiplier lanes.
//! * [`PeVariant::StaticMip2q`] — N=4 lanes permanently replaced with
//!   barrel shifters (Fig. 8c); INT8-only layers fall back to a 2-cycle
//!   mode on the remaining 4 multipliers (§V-B).
//! * [`PeVariant::DynamicMip2q`] — shifters instantiated *alongside* 4 of
//!   the 8 multipliers with clock-gating + a config register (Fig. 9);
//!   area overhead in exchange for runtime quality configurability.
//! * [`PeVariant::StaticDliq`] — extension: 4 lanes as INT-q×INT8
//!   multipliers (the DLIQ datapath the paper describes but does not
//!   synthesize; kept for the ablation benches).

use super::adder::{accumulator, adder_tree};
use super::gates::{activity, cell, Cost};
use super::multiplier::{int8x8, intqx8};
use super::shifter::barrel_shifter;

/// Lanes per PE (8 MACs, §VI).
pub const LANES: u32 = 8;
/// Low-precision lanes in StruM variants (N = 4, §V-B).
pub const STRUM_LANES: u32 = 4;

/// PE microarchitecture variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeVariant {
    BaselineInt8,
    StaticMip2q { l_max: u8 },
    DynamicMip2q { l_max: u8 },
    StaticDliq { q: u8 },
}

impl PeVariant {
    pub fn name(&self) -> String {
        match *self {
            PeVariant::BaselineInt8 => "baseline".into(),
            PeVariant::StaticMip2q { l_max } => format!("static-mip2q-L{}", l_max),
            PeVariant::DynamicMip2q { l_max } => format!("dynamic-mip2q-L{}", l_max),
            PeVariant::StaticDliq { q } => format!("static-dliq-q{}", q),
        }
    }
}

/// Itemized PE cost (areas in NAND2-equivalents; energies per op).
#[derive(Debug, Clone, Copy, Default)]
pub struct PeCost {
    /// High-precision INT8×INT8 multiplier lanes.
    pub multipliers: Cost,
    /// Low-precision lanes (shifters / narrow multipliers), if any.
    pub low_lanes: Cost,
    /// Clock-gating cells + config register (dynamic variant only).
    pub gating: Cost,
    /// Product adder tree.
    pub tree: Cost,
    /// Output accumulator (INT32 + OF register).
    pub accum: Cost,
    /// Find-first sparsity logic + StruM mask-decode operand routing.
    pub routing: Cost,
    /// Local control FSM.
    pub control: Cost,
}

impl PeCost {
    pub fn total(&self) -> Cost {
        self.multipliers + self.low_lanes + self.gating + self.tree + self.accum
            + self.routing + self.control
    }
    pub fn area(&self) -> f64 {
        self.total().area
    }
}

/// Find-first + operand-routing logic. FlexNN's two-sided sparsity
/// acceleration already carries a find-first network over the 16-lane
/// bitmap RFs (Fig. 7); StruM reuses it as the precision router (§VI), so
/// the baseline and StruM variants share this cost, with a small extra
/// decode for the mixed-precision steering in StruM PEs.
fn routing_cost(strum: bool) -> Cost {
    // Priority-encode over a 16-bit bitmap, twice (IF and FL sides).
    let find_first = 2.0 * 16.0 * 4.0 * cell::NAND2;
    // Operand crossbar: 8 destination lanes × 8-bit operands × 2-deep mux.
    let xbar = 8.0 * 8.0 * 2.0 * cell::MUX2;
    let strum_decode = if strum {
        // Mask-bit steering into the hi/lo lane groups.
        8.0 * 2.0 * cell::AND2 + 16.0 * cell::NAND2
    } else {
        0.0
    };
    Cost::uniform(find_first + xbar + strum_decode, activity::CONTROL)
}

fn control_cost() -> Cost {
    Cost::uniform(200.0, activity::CONTROL)
}

/// Builds the itemized cost of a PE variant.
pub fn pe_cost(variant: PeVariant) -> PeCost {
    let tree = adder_tree(LANES, 16);
    let accum = accumulator(32);
    let control = control_cost();
    match variant {
        PeVariant::BaselineInt8 => PeCost {
            multipliers: int8x8() * LANES as f64,
            low_lanes: Cost::ZERO,
            gating: Cost::ZERO,
            tree,
            accum,
            routing: routing_cost(false),
            control,
        },
        PeVariant::StaticMip2q { l_max } => PeCost {
            multipliers: int8x8() * (LANES - STRUM_LANES) as f64,
            low_lanes: barrel_shifter(8, l_max as u32) * STRUM_LANES as f64,
            gating: Cost::ZERO,
            tree,
            accum,
            routing: routing_cost(true),
            control,
        },
        PeVariant::DynamicMip2q { l_max } => {
            // Multipliers retained; shifters added beside 4 of them, with
            // ICG cells, a config register, and a product-select mux per
            // augmented lane (Fig. 9).
            let select_mux = Cost::uniform(16.0 * cell::MUX2, activity::CONTROL);
            let cfg_reg = Cost::uniform(8.0 * cell::DFF, activity::REGFILE);
            let icg = Cost::uniform(cell::ICG, activity::CONTROL);
            PeCost {
                multipliers: int8x8() * LANES as f64,
                low_lanes: barrel_shifter(8, l_max as u32) * STRUM_LANES as f64,
                gating: (icg + select_mux) * STRUM_LANES as f64 + cfg_reg,
                tree,
                accum,
                routing: routing_cost(true),
                control,
            }
        }
        PeVariant::StaticDliq { q } => PeCost {
            multipliers: int8x8() * (LANES - STRUM_LANES) as f64,
            low_lanes: intqx8(q as u32) * STRUM_LANES as f64,
            gating: Cost::ZERO,
            tree,
            accum,
            routing: routing_cost(true),
            control,
        },
    }
}

/// Per-cycle dynamic energy of the PE datapath in dense StruM mode (all
/// lanes busy): the analytic workload used for Fig. 13's power columns
/// when no simulator activity trace is supplied.
pub fn pe_dense_cycle_energy(variant: PeVariant) -> f64 {
    let c = pe_cost(variant);
    match variant {
        PeVariant::BaselineInt8 => {
            c.multipliers.energy + c.tree.energy + c.accum.energy + c.routing.energy
                + c.control.energy
        }
        PeVariant::StaticMip2q { .. } | PeVariant::StaticDliq { .. } => {
            c.multipliers.energy + c.low_lanes.energy + c.tree.energy + c.accum.energy
                + c.routing.energy + c.control.energy
        }
        PeVariant::DynamicMip2q { .. } => {
            // In StruM mode 4 multipliers are clock-gated: their dynamic
            // energy is out, shifters + gating overhead are in.
            c.multipliers.energy * 0.5
                + c.low_lanes.energy
                + c.gating.energy
                + c.tree.energy
                + c.accum.energy
                + c.routing.energy
                + c.control.energy
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_area_dominated_by_multipliers() {
        let c = pe_cost(PeVariant::BaselineInt8);
        assert!(c.multipliers.area / c.area() > 0.5);
    }

    #[test]
    fn static_variants_smaller_than_baseline() {
        let base = pe_cost(PeVariant::BaselineInt8).area();
        for v in [
            PeVariant::StaticMip2q { l_max: 7 },
            PeVariant::StaticMip2q { l_max: 5 },
            PeVariant::StaticDliq { q: 4 },
        ] {
            assert!(pe_cost(v).area() < base, "{:?}", v);
        }
    }

    #[test]
    fn dynamic_variant_larger_than_baseline() {
        let base = pe_cost(PeVariant::BaselineInt8).area();
        let dynm = pe_cost(PeVariant::DynamicMip2q { l_max: 7 }).area();
        assert!(dynm > base);
        // ...but only modestly (shifters are small).
        assert!(dynm / base < 1.25, "ratio {}", dynm / base);
    }

    #[test]
    fn pe_power_savings_in_paper_band() {
        // Paper §VII-B: 31–34% PE power savings; our structural model
        // should land in a band around that (see EXPERIMENTS.md).
        let base = pe_dense_cycle_energy(PeVariant::BaselineInt8);
        for (v, lo, hi) in [
            (PeVariant::StaticMip2q { l_max: 7 }, 0.27, 0.40),
            (PeVariant::StaticMip2q { l_max: 5 }, 0.28, 0.41),
        ] {
            let e = pe_dense_cycle_energy(v);
            let save = 1.0 - e / base;
            assert!((lo..=hi).contains(&save), "{:?} saving {}", v, save);
        }
    }

    #[test]
    fn dynamic_power_savings_match_static_shape() {
        // Paper: dynamic config has "the same power savings" as static.
        let base = pe_dense_cycle_energy(PeVariant::BaselineInt8);
        let stat = pe_dense_cycle_energy(PeVariant::StaticMip2q { l_max: 7 });
        let dynm = pe_dense_cycle_energy(PeVariant::DynamicMip2q { l_max: 7 });
        let ds = 1.0 - dynm / base;
        let ss = 1.0 - stat / base;
        assert!((ds - ss).abs() < 0.05, "static {} dynamic {}", ss, ds);
    }

    #[test]
    fn dliq_lanes_cost_more_than_mip2q_lanes() {
        // The paper chose MIP2Q for hardware because shifts beat INT4
        // multipliers (§IV-C.2).
        let dliq = pe_cost(PeVariant::StaticDliq { q: 4 });
        let mip = pe_cost(PeVariant::StaticMip2q { l_max: 7 });
        assert!(dliq.low_lanes.area > mip.low_lanes.area);
        assert!(dliq.low_lanes.energy > mip.low_lanes.energy);
    }
}
