//! Gate-level primitives and cost algebra.
//!
//! Areas are NAND2-equivalents — the standard-cell bookkeeping unit ASIC
//! flows report. The per-primitive numbers below are textbook CMOS
//! standard-cell equivalences (e.g. Weste & Harris): they fix the *ratios*
//! between components, which is what the paper's savings percentages
//! depend on; the absolute scale cancels out of every reported metric.
//!
//! Dynamic energy is modeled as `area × activity` per operation: switched
//! capacitance is first-order proportional to gate count, and the activity
//! factor captures how much of a component toggles per op (a multiplier's
//! array churns on data; a barrel shifter only re-routes). Activity factors
//! live in [`Activity`] — the single calibration surface of the model.

use std::ops::{Add, AddAssign, Mul};

/// NAND2-equivalent areas of standard cells.
pub mod cell {
    /// 2-input NAND — the unit.
    pub const NAND2: f64 = 1.0;
    /// Inverter.
    pub const INV: f64 = 0.6;
    /// 2-input AND (NAND + INV).
    pub const AND2: f64 = 1.5;
    /// 2-input XOR.
    pub const XOR2: f64 = 2.5;
    /// 2:1 mux.
    pub const MUX2: f64 = 2.5;
    /// Full adder (sum + carry).
    pub const FA: f64 = 6.0;
    /// Half adder.
    pub const HA: f64 = 3.0;
    /// D flip-flop with enable.
    pub const DFF: f64 = 7.0;
    /// Latch (used in latch-array register files).
    pub const LATCH: f64 = 3.5;
    /// 6T SRAM bit, NAND2-equivalent footprint (dense macro).
    pub const SRAM_BIT: f64 = 0.55;
    /// Integrated clock-gating cell.
    pub const ICG: f64 = 4.0;
}

/// Per-operation activity factors (fraction of a component's gates that
/// toggle per operation). These are the model's calibration constants; see
/// DESIGN.md §hw for the rationale and EXPERIMENTS.md for the resulting
/// Fig. 13 comparison.
pub mod activity {
    /// Array multiplier on random operand data.
    pub const MULTIPLIER: f64 = 0.50;
    /// Barrel shifter: mux network re-routes, little glitching.
    pub const SHIFTER: f64 = 0.22;
    /// Adder tree / accumulators.
    pub const ADDER: f64 = 0.40;
    /// Register-file read or write (per accessed bit's worth of array).
    pub const REGFILE: f64 = 0.08;
    /// SRAM access (per accessed bit, amortized periphery).
    pub const SRAM: f64 = 0.05;
    /// Control / routing logic.
    pub const CONTROL: f64 = 0.25;
    /// Leakage per gate per cycle, as a fraction of a NAND2 toggle. At a
    /// low-leakage 3nm-class node leakage is a small slice of total power
    /// for an always-active accelerator.
    pub const LEAKAGE_PER_GATE: f64 = 0.012;
}

/// Area + per-op dynamic energy of a hardware component.
///
/// `energy` is in NAND2-toggle equivalents *per operation* of that
/// component (one multiply, one RF read, ...). Power roll-ups multiply by
/// op counts per cycle (analytic) or simulator activity counts (measured).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cost {
    pub area: f64,
    pub energy: f64,
}

impl Cost {
    pub const ZERO: Cost = Cost { area: 0.0, energy: 0.0 };

    /// A component of `area` gates with uniform activity `act`.
    pub fn uniform(area: f64, act: f64) -> Cost {
        Cost { area, energy: area * act }
    }
}

impl Add for Cost {
    type Output = Cost;
    fn add(self, rhs: Cost) -> Cost {
        Cost { area: self.area + rhs.area, energy: self.energy + rhs.energy }
    }
}

impl AddAssign for Cost {
    fn add_assign(&mut self, rhs: Cost) {
        self.area += rhs.area;
        self.energy += rhs.energy;
    }
}

impl Mul<f64> for Cost {
    type Output = Cost;
    fn mul(self, k: f64) -> Cost {
        Cost { area: self.area * k, energy: self.energy * k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_algebra() {
        let a = Cost { area: 10.0, energy: 2.0 };
        let b = Cost { area: 5.0, energy: 1.0 };
        let c = a + b * 2.0;
        assert_eq!(c.area, 20.0);
        assert_eq!(c.energy, 4.0);
    }

    #[test]
    fn uniform_energy_scales_with_area() {
        let c = Cost::uniform(100.0, 0.5);
        assert_eq!(c.energy, 50.0);
    }

    #[test]
    fn shifter_cheaper_to_toggle_than_multiplier() {
        // The codesign premise: same area would still yield less energy.
        assert!(activity::SHIFTER < activity::MULTIPLIER);
    }
}
