//! PE-array and DPU roll-up (§VI configuration: a unified tile of 256 PEs
//! in a 16×16 grid, 8 MACs/PE = 2048 MACs, 1.5 MB SRAM with 32 B ports,
//! 208 B of RF per PE).

use super::gates::{activity, cell, Cost};
use super::pe::{pe_cost, PeVariant};
use super::regfile::{pe_regfiles, sram};

/// DPU structural configuration.
#[derive(Debug, Clone)]
pub struct DpuConfig {
    pub grid_cols: usize,
    pub grid_rows: usize,
    /// On-chip SRAM bytes.
    pub sram_bytes: u64,
    /// SRAM read/write port width in bytes.
    pub sram_port_bytes: u32,
}

impl DpuConfig {
    /// The paper's configuration (§VI).
    pub fn flexnn_16x16() -> DpuConfig {
        DpuConfig {
            grid_cols: 16,
            grid_rows: 16,
            sram_bytes: 3 * 512 * 1024 / 2 * 2, // 1.5 MB
            sram_port_bytes: 32,
        }
    }

    pub fn num_pes(&self) -> usize {
        self.grid_cols * self.grid_rows
    }
}

/// Itemized DPU cost.
#[derive(Debug, Clone)]
pub struct DpuCost {
    pub variant: PeVariant,
    /// One PE's datapath (PE-level scope).
    pub pe_core: Cost,
    /// One PE's register files (data + bitmap + OF).
    pub pe_rf: Cost,
    /// Per-PE clock-tree & pipeline overhead.
    pub pe_clock: Cost,
    /// PE-array total (cores + RFs + clock + column broadcast).
    pub array: Cost,
    /// Column broadcast / NoC wiring+drivers for the whole array.
    pub broadcast: Cost,
    /// SRAM macro.
    pub sram: Cost,
    /// Load + drain units (DMA engines, §V-A).
    pub load_drain: Cost,
    /// Full DPU.
    pub total: Cost,
}

/// Per-PE clock-tree + pipeline-register overhead. Clock distribution in a
/// dense MAC array is a significant, variant-independent slice of area and
/// (especially) power — this is what dilutes the PE-level savings at the
/// array/DPU level alongside the RFs (§VII-B).
fn pe_clock_overhead() -> Cost {
    // Operand/stage pipeline registers: 2 stages × 16 B + misc state.
    let pipeline_bits = 2.0 * 16.0 * 8.0 + 64.0;
    let area = pipeline_bits * cell::DFF * 1.2; // + local clock buffers
    // Clock toggles every cycle: high effective activity.
    Cost { area, energy: area * 0.9 }
}

/// Builds the itemized DPU cost for a PE variant.
pub fn dpu_cost(variant: PeVariant, cfg: &DpuConfig) -> DpuCost {
    let n = cfg.num_pes() as f64;
    let pe_core = pe_cost(variant).total();
    let pe_rf = pe_regfiles();
    let pe_clock = pe_clock_overhead();

    // Column broadcast: per column, weight/activation distribution bus
    // drivers + repeaters spanning the column.
    let per_col = Cost::uniform(
        (cfg.grid_rows as f64) * 16.0 * 8.0 * cell::INV * 0.5,
        activity::CONTROL,
    );
    let broadcast = per_col * cfg.grid_cols as f64;

    let array = (pe_core + pe_rf + pe_clock) * n + broadcast;

    let sram_c = sram(cfg.sram_bytes);
    // Load & drain units: address generators, rotators, the §IV-D weight
    // decoder (mask-header parse + payload align) per column.
    let decoder_per_col = Cost::uniform(
        16.0 * 8.0 * cell::MUX2 + 64.0 * cell::NAND2,
        activity::CONTROL,
    );
    let load_drain = Cost::uniform(40_000.0, activity::CONTROL)
        + decoder_per_col * cfg.grid_cols as f64;

    let total = array + sram_c + load_drain;
    DpuCost {
        variant,
        pe_core,
        pe_rf,
        pe_clock,
        array,
        broadcast,
        sram: sram_c,
        load_drain,
        total,
    }
}

/// TOPS/mm² proxy: MACs per cycle per unit area (relative — NAND2 units).
pub fn tops_per_area(variant: PeVariant, cfg: &DpuConfig) -> f64 {
    let macs_per_cycle = (cfg.num_pes() * 8) as f64;
    macs_per_cycle / dpu_cost(variant, cfg).total.area
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_is_a_large_share_of_dpu_area() {
        let cfg = DpuConfig::flexnn_16x16();
        let c = dpu_cost(PeVariant::BaselineInt8, &cfg);
        let share = c.sram.area / c.total.area;
        assert!((0.3..0.9).contains(&share), "sram share {}", share);
    }

    #[test]
    fn array_savings_diluted_vs_pe_savings() {
        let cfg = DpuConfig::flexnn_16x16();
        let b = dpu_cost(PeVariant::BaselineInt8, &cfg);
        let s = dpu_cost(PeVariant::StaticMip2q { l_max: 7 }, &cfg);
        let pe_save = 1.0 - s.pe_core.area / b.pe_core.area;
        let arr_save = 1.0 - s.array.area / b.array.area;
        let dpu_save = 1.0 - s.total.area / b.total.area;
        assert!(pe_save > arr_save && arr_save > dpu_save);
    }

    #[test]
    fn tops_per_area_improves_with_static_strum() {
        let cfg = DpuConfig::flexnn_16x16();
        assert!(
            tops_per_area(PeVariant::StaticMip2q { l_max: 5 }, &cfg)
                > tops_per_area(PeVariant::BaselineInt8, &cfg)
        );
    }

    #[test]
    fn config_macs() {
        let cfg = DpuConfig::flexnn_16x16();
        assert_eq!(cfg.num_pes(), 256);
        assert_eq!(cfg.num_pes() * 8, 2048); // §VI: 2048 MACs
        assert_eq!(cfg.sram_bytes, 1_572_864); // 1.5 MB
    }
}
