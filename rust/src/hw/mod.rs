//! Gate-level hardware cost model (§V microarchitecture, §VII-B results).
//!
//! The paper synthesizes the StruM-modified FlexNN DPU on a 3 nm process
//! with Synopsys Fusion Compiler and measures power with PrimeTime-PX. We
//! do not have a PDK, so area and power are modeled *structurally*, in
//! process-independent units:
//!
//! * **Area** — NAND2-equivalent gate counts, composed bottom-up from
//!   full-adder / mux / flop primitives ([`gates`]) into array multipliers
//!   ([`multiplier`]), barrel shifters ([`shifter`]), adder trees
//!   ([`adder`]), register files and SRAM ([`regfile`]), PE variants
//!   ([`pe`]) and the full DPU ([`dpu`]).
//! * **Dynamic energy** — per-operation switched capacitance proxied by
//!   `gate count × activity factor` ([`gates::Activity`] constants), and
//!   driven by either an analytic dense workload or per-component activity
//!   counts from the cycle simulator ([`power`], SAIF-equivalent).
//! * **Leakage** — proportional to area.
//!
//! The *ratios* the paper reports (PE-level 23–26 % area and 31–34 % power
//! savings, DPU-level 2–3 % area and 10–12 % power) are gate-count
//! properties of the design and largely process-independent, so they are
//! expected to — and do — reproduce; see `cargo bench --bench
//! fig13_area_power` and EXPERIMENTS.md.

pub mod adder;
pub mod dpu;
pub mod gates;
pub mod multiplier;
pub mod pe;
pub mod power;
pub mod regfile;
pub mod shifter;

pub use gates::Cost;
pub use pe::{pe_cost, PeVariant};

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline Fig. 13 shape: PE-level area savings of the static
    /// MIP2Q variants fall in the paper's 23–26 % band, with L=5 saving
    /// more than L=7.
    #[test]
    fn pe_area_savings_in_paper_band() {
        let base = pe_cost(PeVariant::BaselineInt8).area();
        let l7 = pe_cost(PeVariant::StaticMip2q { l_max: 7 }).area();
        let l5 = pe_cost(PeVariant::StaticMip2q { l_max: 5 }).area();
        let s7 = 1.0 - l7 / base;
        let s5 = 1.0 - l5 / base;
        assert!(s5 > s7, "L=5 must save more area than L=7");
        assert!((0.20..=0.30).contains(&s7), "L=7 area saving {}", s7);
        assert!((0.22..=0.32).contains(&s5), "L=5 area saving {}", s5);
    }

    /// DPU-level static area savings land in the paper's 2–3 % band and
    /// the dynamic variant costs ~3 % extra area.
    #[test]
    fn dpu_area_deltas_in_paper_band() {
        let cfg = dpu::DpuConfig::flexnn_16x16();
        let base = dpu::dpu_cost(PeVariant::BaselineInt8, &cfg).total.area;
        let stat = dpu::dpu_cost(PeVariant::StaticMip2q { l_max: 7 }, &cfg).total.area;
        let dynm = dpu::dpu_cost(PeVariant::DynamicMip2q { l_max: 7 }, &cfg).total.area;
        let save = 1.0 - stat / base;
        let over = dynm / base - 1.0;
        assert!((0.01..=0.05).contains(&save), "static DPU saving {}", save);
        assert!((0.005..=0.05).contains(&over), "dynamic DPU overhead {}", over);
    }
}
