//! Register-file and SRAM cost models (§VI: per-PE 4×16 B IF RF, 4×16 B FL
//! RF, 16×4 B OF RF, two 4×2 B sparsity-bitmap RFs = 208 B/PE; DPU-level
//! 1.5 MB SRAM with 32 B ports).

use super::gates::{activity, cell, Cost};

/// Latch-array register file: `bytes` of storage with `read_ports` +
/// `write_ports` access ports. Periphery (decoders, port muxes) scales
/// with port count.
pub fn regfile(bytes: u32, read_ports: u32, write_ports: u32) -> Cost {
    let bits = bytes as f64 * 8.0;
    let array = bits * cell::LATCH;
    // Per-port wordline/bitline mux + decode overhead, ~30% of array per
    // port pair (small RFs are periphery-dominated).
    let ports = (read_ports + write_ports) as f64;
    let periphery = array * 0.15 * ports;
    Cost::uniform(array + periphery, activity::REGFILE)
}

/// The full per-PE RF complement (§VI): data RFs + bitmap RFs + OF RF.
pub fn pe_regfiles() -> Cost {
    // 4×16B IF data RF, 4×16B FL data RF (1r1w each).
    let if_rf = regfile(64, 1, 1);
    let fl_rf = regfile(64, 1, 1);
    // 16×4B OF RF (accumulator state, 1r1w).
    let of_rf = regfile(64, 1, 1);
    // Sparsity/precision bitmap RFs: 4×2B each for IF and FL (one bit per
    // data byte — reused as the StruM precision bitmap, §VI).
    let bitmap = regfile(8, 1, 1) + regfile(8, 1, 1);
    if_rf + fl_rf + of_rf + bitmap
}

/// Dense SRAM macro: `bytes` with amortized periphery.
pub fn sram(bytes: u64) -> Cost {
    let bits = bytes as f64 * 8.0;
    let array = bits * cell::SRAM_BIT;
    let periphery = array * 0.12;
    Cost::uniform(array + periphery, activity::SRAM)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_rf_totals_208_bytes() {
        // 64+64+64+8+8 = 208 B (§VI).
        let total_bytes = 64 + 64 + 64 + 8 + 8;
        assert_eq!(total_bytes, 208);
    }

    #[test]
    fn sram_denser_than_regfile_per_byte() {
        let rf = regfile(64, 1, 1).area / 64.0;
        let sr = sram(65536).area / 65536.0;
        assert!(sr < rf / 3.0, "sram {} rf {}", sr, rf);
    }

    #[test]
    fn ports_add_area() {
        assert!(regfile(64, 2, 2).area > regfile(64, 1, 1).area);
    }

    #[test]
    fn regfile_scale_sanity() {
        // 208B of RF should be of the same order as the 8-MAC datapath
        // (a few thousand NAND2) — not 10x larger or smaller.
        let c = pe_regfiles();
        assert!((3_000.0..15_000.0).contains(&c.area), "area {}", c.area);
    }
}
