//! Barrel-shifter cost models (§V-B).
//!
//! A MIP2Q low-precision lane multiplies an 8-bit activation by `±2^k`,
//! `k ∈ [0, L]`: a left-shift by up to `L` positions plus a conditional
//! two's-complement negate. Structure:
//!
//! * `⌈log2(L+1)⌉` mux stages, each as wide as the (growing) datapath —
//!   output width is `8 + L` bits plus sign;
//! * a row of XORs + increment folded into the adder tree's carry-in for
//!   the negate (costed here as the XOR row).
//!
//! Reducing the shift range (L=7 → L=5) shrinks both the output datapath
//! and the stage width — the paper's "L=5 variant allows further hardware
//! complexity reduction" (§V-B).

use super::gates::{activity, cell, Cost};

/// Number of mux stages for shift range [0, L]: ⌈log2(L+1)⌉.
pub fn stages(l_max: u32) -> u32 {
    (l_max + 1).next_power_of_two().trailing_zeros().max(1)
}

/// Cost of a barrel shifter for `act_bits`-wide input and shift range
/// `[0, l_max]`, with sign-conditioned negation.
pub fn barrel_shifter(act_bits: u32, l_max: u32) -> Cost {
    assert!(l_max >= 1, "degenerate shifter");
    let out_bits = (act_bits + l_max) as f64;
    // Mux stages: stage s shifts by 2^s; each stage spans the output width.
    let n_stages = (l_max + 1).next_power_of_two().trailing_zeros().max(1) as f64;
    let mux_net = n_stages * out_bits * cell::MUX2;
    // Sign-conditioned inversion (XOR row); the +1 rides the adder carry-in.
    let negate = out_bits * cell::XOR2 * 0.5;
    // Shift-amount decode.
    let decode = n_stages * cell::AND2 * 2.0;
    Cost::uniform(mux_net + negate + decode, activity::SHIFTER)
}

/// The paper's full-range variant: L = 7 (4-bit payload).
pub fn mip2q_l7() -> Cost {
    barrel_shifter(8, 7)
}

/// The paper's reduced-range variant: L = 5.
pub fn mip2q_l5() -> Cost {
    barrel_shifter(8, 5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::multiplier::int8x8;

    #[test]
    fn shifter_much_smaller_than_multiplier() {
        // The strength-reduction premise: a shifter lane is a fraction of
        // a multiplier lane.
        let r = mip2q_l7().area / int8x8().area;
        assert!((0.15..0.45).contains(&r), "area ratio {}", r);
    }

    #[test]
    fn l5_smaller_than_l7() {
        assert!(mip2q_l5().area < mip2q_l7().area);
    }

    #[test]
    fn shifter_energy_fraction_far_below_multiplier() {
        let mul = int8x8();
        let shf = mip2q_l7();
        assert!(shf.energy < 0.15 * mul.energy, "shift {} vs mul {}", shf.energy, mul.energy);
    }

    #[test]
    fn stage_counts() {
        // L=1 → 1 stage, L=3 → 2, L=5..7 → 3.
        assert_eq!((1u32 + 1).next_power_of_two().trailing_zeros(), 1);
        assert_eq!((3u32 + 1).next_power_of_two().trailing_zeros(), 2);
        assert_eq!((5u32 + 1).next_power_of_two().trailing_zeros(), 3);
        assert_eq!((7u32 + 1).next_power_of_two().trailing_zeros(), 3);
    }
}
