//! Signed array-multiplier cost models.
//!
//! A Baugh-Wooley style n×m array multiplier: n·m partial-product AND
//! gates, (n−1) rows of m-bit carry-save adders, and a final (n+m)-bit
//! carry-propagate adder. This reproduces the classic ~O(n·m) area law, so
//! INT4×INT8 comes out at roughly half of INT8×INT8 — the ratio behind the
//! DLIQ PE variant (§IV-D.2) — and powers the Fig. 6 dot-product unit
//! accounting.

use super::gates::{activity, cell, Cost};

/// Cost of a signed n×m-bit array multiplier.
pub fn array_multiplier(n_bits: u32, m_bits: u32) -> Cost {
    assert!(n_bits >= 2 && m_bits >= 2);
    let n = n_bits as f64;
    let m = m_bits as f64;
    // Partial products (AND2s; Baugh-Wooley sign handling adds a row of
    // inverters + constant-bit adders, folded into a 5% factor).
    let pp = n * m * cell::AND2 * 1.05;
    // Carry-save reduction: (n-1) rows of m FAs.
    let csa = (n - 1.0) * m * cell::FA;
    // Final carry-propagate adder over n+m bits.
    let cpa = (n + m) * cell::FA;
    Cost::uniform(pp + csa + cpa, activity::MULTIPLIER)
}

/// The FlexNN baseline INT8×INT8 multiplier (weights × activations).
pub fn int8x8() -> Cost {
    array_multiplier(8, 8)
}

/// INT4×INT8 multiplier used by a DLIQ low-precision lane (§IV-C.1):
/// the 4-bit weight code is consumed directly; the fixed `<< (8-q)`
/// re-alignment is free (wiring into the adder tree).
pub fn int4x8() -> Cost {
    array_multiplier(4, 8)
}

/// A q-bit × 8-bit DLIQ lane multiplier for arbitrary q ≥ 2.
pub fn intqx8(q: u32) -> Cost {
    array_multiplier(q.max(2), 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_multiplier_in_expected_range() {
        // Classic 8×8 array multiplier ≈ 400–600 NAND2-equivalents.
        let c = int8x8();
        assert!((400.0..650.0).contains(&c.area), "area {}", c.area);
    }

    #[test]
    fn int4_roughly_half_of_int8() {
        let r = int4x8().area / int8x8().area;
        assert!((0.40..0.60).contains(&r), "ratio {}", r);
    }

    #[test]
    fn area_monotone_in_width() {
        for q in 2..8 {
            assert!(intqx8(q).area < intqx8(q + 1).area);
        }
    }

    #[test]
    fn energy_tracks_multiplier_activity() {
        let c = int8x8();
        assert!((c.energy / c.area - activity::MULTIPLIER).abs() < 1e-12);
    }
}
