//! LSB-first bitstream writer/reader.
//!
//! Bit order matches the hardware decoder's natural consumption order: the
//! first bit written occupies the least-significant bit of byte 0, so a
//! `w`-wide mask header reads back as an integer whose bit `i` is element
//! `i`'s precision flag — the same value the PE's find-first logic muxes on.

/// Append-only bit writer.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits already used in the last byte (0..8; 0 means byte-aligned).
    used: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total bits written.
    pub fn bit_len(&self) -> usize {
        if self.used == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.used as usize
        }
    }

    /// Writes the low `n` bits of `v` (n ≤ 64), LSB first.
    pub fn write(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        debug_assert!(n == 64 || v < (1u64 << n), "value {} does not fit {} bits", v, n);
        let mut v = v;
        let mut left = n;
        while left > 0 {
            if self.used == 0 {
                self.buf.push(0);
            }
            let space = 8 - self.used;
            let take = space.min(left);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            let bits = (v & mask) as u8;
            let last = self.buf.len() - 1;
            self.buf[last] |= bits << self.used;
            self.used = (self.used + take) % 8;
            v >>= take;
            left -= take;
        }
    }

    /// Writes one bit.
    pub fn write_bit(&mut self, b: bool) {
        self.write(b as u64, 1);
    }

    /// Writes a signed value in `n`-bit two's complement.
    pub fn write_signed(&mut self, v: i64, n: u32) {
        debug_assert!(n >= 1 && n <= 64);
        debug_assert!(
            n == 64 || (v >= -(1i64 << (n - 1)) && v < (1i64 << (n - 1))),
            "value {} does not fit signed {} bits",
            v,
            n
        );
        self.write((v as u64) & if n == 64 { u64::MAX } else { (1u64 << n) - 1 }, n);
    }

    /// Pads to a byte boundary and returns the buffer.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Sequential bit reader over a byte slice.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // in bits
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }

    /// Reads `n` bits (LSB-first), returning them as an unsigned value.
    pub fn read(&mut self, n: u32) -> Option<u64> {
        if n as usize > self.remaining_bits() {
            return None;
        }
        let mut out = 0u64;
        let mut got = 0u32;
        while got < n {
            let byte = self.buf[self.pos / 8];
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(n - got);
            let mask = ((1u16 << take) - 1) as u8;
            let bits = (byte >> off) & mask;
            out |= (bits as u64) << got;
            got += take;
            self.pos += take as usize;
        }
        Some(out)
    }

    pub fn read_bit(&mut self) -> Option<bool> {
        self.read(1).map(|b| b != 0)
    }

    /// Reads an `n`-bit two's-complement signed value.
    pub fn read_signed(&mut self, n: u32) -> Option<i64> {
        debug_assert!(n >= 1 && n <= 64);
        let raw = self.read(n)?;
        if n == 64 {
            return Some(raw as i64);
        }
        let sign_bit = 1u64 << (n - 1);
        if raw & sign_bit != 0 {
            Some((raw | !((1u64 << n) - 1)) as i64)
        } else {
            Some(raw as i64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), Some(b));
        }
    }

    #[test]
    fn multi_width_roundtrip() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0xABCD, 16);
        w.write(1, 1);
        w.write(0x3FFFFFFFF, 34);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), Some(0b101));
        assert_eq!(r.read(16), Some(0xABCD));
        assert_eq!(r.read(1), Some(1));
        assert_eq!(r.read(34), Some(0x3FFFFFFFF));
    }

    #[test]
    fn signed_roundtrip() {
        let mut w = BitWriter::new();
        for v in [-8i64, -1, 0, 7] {
            w.write_signed(v, 4);
        }
        w.write_signed(-128, 8);
        w.write_signed(127, 8);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_signed(4), Some(-8));
        assert_eq!(r.read_signed(4), Some(-1));
        assert_eq!(r.read_signed(4), Some(0));
        assert_eq!(r.read_signed(4), Some(7));
        assert_eq!(r.read_signed(8), Some(-128));
        assert_eq!(r.read_signed(8), Some(127));
    }

    #[test]
    fn lsb_first_layout() {
        let mut w = BitWriter::new();
        w.write(1, 1); // bit 0 of byte 0
        w.write(0, 1);
        w.write(1, 1); // bit 2
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b0000_0101]);
    }

    #[test]
    fn read_past_end_is_none() {
        let bytes = vec![0xFF];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(8), Some(0xFF));
        assert_eq!(r.read(1), None);
    }

    #[test]
    fn bit_len_tracks_partial_bytes() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write(0, 5);
        assert_eq!(w.bit_len(), 5);
        w.write(0, 3);
        assert_eq!(w.bit_len(), 8);
        w.write(0, 1);
        assert_eq!(w.bit_len(), 9);
    }
}
