//! Analytic compression ratios (§IV-D.1, Eq. 1 and Eq. 2).
//!
//! `r` = compressed / uncompressed weight memory, with 8-bit uncompressed
//! weights and a 1-bit-per-element mask header:
//!
//! * Eq. 1 (payload-carrying low set, q > 1):  `r = (p(q-8) + 9) / 8`
//! * Eq. 2 (no low payload: structured sparsity, or q = 1):  `r = (9-8p)/8`

use crate::quant::Method;

/// Eq. 1: ratio for a method whose low set stores `q`-bit payloads.
pub fn ratio_payload(p: f64, q: u32) -> f64 {
    (p * (q as f64 - 8.0) + 9.0) / 8.0
}

/// Eq. 2: ratio when the low set stores no payload (sparsity; q = 1).
pub fn ratio_sparsity(p: f64) -> f64 {
    (9.0 - 8.0 * p) / 8.0
}

/// Analytic ratio for any configured method at low fraction `p`.
pub fn ratio_for(method: Method, p: f64) -> f64 {
    let q = method.payload_bits();
    match method {
        Method::Baseline => ratio_payload(0.0, 8),
        Method::StructuredSparsity => ratio_sparsity(p),
        Method::Dliq { q: dq } if dq <= 1 => ratio_sparsity(p),
        _ => ratio_payload(p, q),
    }
}

/// Bits per element for a given method/p (8·r) — convenient for memory
/// bandwidth accounting in the simulator.
pub fn bits_per_element(method: Method, p: f64) -> f64 {
    8.0 * ratio_for(method, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Method;

    #[test]
    fn eq1_paper_points() {
        // DLIQ q=4, p=0.5: (0.5·(-4)+9)/8 = 7/8.
        assert!((ratio_payload(0.5, 4) - 0.875).abs() < 1e-12);
        // p=0: just the mask header overhead, 9/8.
        assert!((ratio_payload(0.0, 4) - 1.125).abs() < 1e-12);
        // p=1, q=4: 5/8.
        assert!((ratio_payload(1.0, 4) - 0.625).abs() < 1e-12);
    }

    #[test]
    fn eq2_paper_points() {
        assert!((ratio_sparsity(0.5) - 0.625).abs() < 1e-12);
        assert!((ratio_sparsity(0.25) - 0.875).abs() < 1e-12);
        assert!((ratio_sparsity(1.0) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn sparsity_always_at_least_as_small_as_payload_methods() {
        // For the same p, sparsity stores strictly less (paper §VII-A2).
        for p in [0.1, 0.25, 0.5, 0.75, 1.0] {
            for q in 2..=7u32 {
                assert!(ratio_sparsity(p) < ratio_payload(p, q));
            }
        }
    }

    #[test]
    fn ratio_for_dispatches() {
        assert_eq!(
            ratio_for(Method::StructuredSparsity, 0.5),
            ratio_sparsity(0.5)
        );
        assert_eq!(ratio_for(Method::Dliq { q: 4 }, 0.5), ratio_payload(0.5, 4));
        // MIP2Q L=7 → q=4 bits.
        assert_eq!(ratio_for(Method::Mip2q { l_max: 7 }, 0.5), ratio_payload(0.5, 4));
        // MIP2Q L=3 → q=3 bits.
        assert_eq!(ratio_for(Method::Mip2q { l_max: 3 }, 0.5), ratio_payload(0.5, 3));
        // DLIQ q=1 degenerates to Eq. 2.
        assert_eq!(ratio_for(Method::Dliq { q: 1 }, 0.5), ratio_sparsity(0.5));
    }

    #[test]
    fn monotone_in_p_and_q() {
        for q in 2..=7u32 {
            assert!(ratio_payload(0.75, q) < ratio_payload(0.25, q));
        }
        for p in [0.25, 0.5, 0.75] {
            assert!(ratio_payload(p, 3) < ratio_payload(p, 4));
        }
    }
}
