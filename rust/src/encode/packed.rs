//! Kernel-layout prepacked weight banks.
//!
//! [`PackedBanks`] is the execution layout the native GEMM consumes: the
//! dense int8 high bank `[oc][k]`, plus the method-dependent low bank
//! (dense DLIQ codes, MIP2Q shift-add CSR, or empty). It used to be built
//! inside `backend::StrumGemm::from_layer` on every registration; hoisting
//! it here lets `artifact::compile_net` run the packing ONCE offline and
//! serialize the result into the `.strumc` container, so serve-time bind
//! is a borrow (mmap) or memcpy (owned) instead of a decode + repack.
//!
//! The layout is deliberately byte-stable: `from_layer` is deterministic
//! (MIP2Q taps sorted by `(shift, sign, col)`), so recompiling the same
//! net always reproduces identical banks — the artifact byte-stability
//! tests depend on that.

use crate::quant::{Method, StrumLayer};
use crate::util::mmap::BankI8;
use crate::Result;
use anyhow::{anyhow, ensure};

/// Low-precision bank in execution form.
#[derive(Debug, Clone, PartialEq)]
pub enum LowBank {
    /// No low-bank work: structured sparsity, DLIQ q≤1, or baseline.
    Empty,
    /// DLIQ: dense `q`-bit codes per channel (zeros on high slots) plus
    /// the bank-level realign shift `8-q`.
    Dliq { shift: u32, codes: BankI8 },
    /// MIP2Q: per-channel CSR of (column, shift, negate) shift-add taps,
    /// sorted by `(shift, negate)` within each channel so the kernel can
    /// batch the adds of a group under a single barrel shift.
    Pow2 {
        row_ptr: Vec<u32>,
        col: Vec<u32>,
        shift: Vec<u8>,
        neg: Vec<bool>,
    },
}

/// Kernel-layout banks for one layer: `oc` output channels × `k` lanes.
/// Equality compares bank *contents*, not storage mode (owned vs mapped).
#[derive(Debug, Clone, PartialEq)]
pub struct PackedBanks {
    pub oc: usize,
    pub k: usize,
    /// Dense high bank `[oc][k]`: mask-selected INT8 values, 0 elsewhere.
    pub hi: BankI8,
    pub low: LowBank,
}

impl PackedBanks {
    /// Builds the execution banks from a StruM-transformed layer (codes +
    /// mask, the §IV-D payload semantics — not the precomputed `values`).
    /// Deterministic: identical layers always yield identical banks.
    pub fn from_layer(layer: &StrumLayer) -> Result<PackedBanks> {
        let oc = layer.oc;
        let k = layer.rows * layer.cols;
        ensure!(layer.codes.len() == oc * k, "layer {}: bad code count", layer.name);
        ensure!(layer.scales.len() == oc, "layer {}: bad scale count", layer.name);
        let mut hi = vec![0i8; oc * k];
        let low = match layer.params.method {
            Method::Baseline => {
                // Baseline keeps every element in the INT8 bank.
                hi.copy_from_slice(&layer.codes);
                LowBank::Empty
            }
            Method::StructuredSparsity => {
                fill_hi(&mut hi, layer);
                LowBank::Empty
            }
            Method::Dliq { q } => {
                fill_hi(&mut hi, layer);
                if q <= 1 {
                    LowBank::Empty
                } else {
                    let mut codes = vec![0i8; oc * k];
                    for i in 0..oc * k {
                        if !layer.mask[i] {
                            codes[i] = layer.codes[i];
                        }
                    }
                    LowBank::Dliq {
                        shift: (8 - q) as u32,
                        codes: codes.into(),
                    }
                }
            }
            Method::Mip2q { .. } => {
                fill_hi(&mut hi, layer);
                let mut row_ptr = Vec::with_capacity(oc + 1);
                let mut col = Vec::new();
                let mut shift = Vec::new();
                let mut neg = Vec::new();
                row_ptr.push(0u32);
                let mut taps: Vec<(u8, bool, u32)> = Vec::with_capacity(k);
                for c in 0..oc {
                    taps.clear();
                    for j in 0..k {
                        let i = c * k + j;
                        if layer.mask[i] {
                            continue;
                        }
                        let code = layer.codes[i];
                        if code == 0 {
                            return Err(anyhow!(
                                "layer {}: zero MIP2Q code at ({}, {})",
                                layer.name,
                                c,
                                j
                            ));
                        }
                        taps.push((code.unsigned_abs() - 1, code < 0, j as u32));
                    }
                    // Group by (shift, sign): one barrel shift per group
                    // at execution time instead of one per tap.
                    taps.sort_unstable();
                    for &(s, n, j) in &taps {
                        col.push(j);
                        shift.push(s);
                        neg.push(n);
                    }
                    row_ptr.push(col.len() as u32);
                }
                LowBank::Pow2 {
                    row_ptr,
                    col,
                    shift,
                    neg,
                }
            }
        };
        Ok(PackedBanks {
            oc,
            k,
            hi: hi.into(),
            low,
        })
    }

    /// Structural sanity checks, used after deserializing untrusted bank
    /// bytes (bounds the kernel indexes rather than trusting the file).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.hi.len() == self.oc * self.k, "hi bank length");
        match &self.low {
            LowBank::Empty => {}
            LowBank::Dliq { shift, codes } => {
                ensure!(*shift < 8, "dliq realign shift out of range");
                ensure!(codes.len() == self.oc * self.k, "dliq bank length");
            }
            LowBank::Pow2 { row_ptr, col, shift, neg } => {
                ensure!(row_ptr.len() == self.oc + 1, "pow2 row_ptr length");
                ensure!(row_ptr.first() == Some(&0), "pow2 row_ptr start");
                ensure!(
                    row_ptr.windows(2).all(|w| w[0] <= w[1]),
                    "pow2 row_ptr not monotonic"
                );
                let taps = *row_ptr.last().unwrap() as usize;
                ensure!(col.len() == taps, "pow2 col length");
                ensure!(shift.len() == taps, "pow2 shift length");
                ensure!(neg.len() == taps, "pow2 neg length");
                ensure!(col.iter().all(|&c| (c as usize) < self.k), "pow2 col bound");
                ensure!(shift.iter().all(|&s| s < 8), "pow2 shift bound");
            }
        }
        Ok(())
    }

    /// Number of low-bank taps (diagnostic / bench reporting).
    pub fn low_taps(&self) -> usize {
        match &self.low {
            LowBank::Empty => 0,
            LowBank::Dliq { codes, .. } => codes.iter().filter(|&&c| c != 0).count(),
            LowBank::Pow2 { col, .. } => col.len(),
        }
    }

    /// True when any bank borrows from a file mapping (zero-copy bind).
    pub fn is_mapped(&self) -> bool {
        self.hi.is_mapped()
            || matches!(&self.low, LowBank::Dliq { codes, .. } if codes.is_mapped())
    }
}

fn fill_hi(hi: &mut [i8], layer: &StrumLayer) {
    for i in 0..hi.len() {
        if layer.mask[i] {
            hi[i] = layer.codes[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::tensor::qlayer;
    use crate::quant::{apply_strum, StrumParams};
    use crate::util::prng::Rng;

    fn transformed(method: Method, seed: u64) -> StrumLayer {
        let mut rng = Rng::new(seed);
        let data: Vec<i8> = (0..4 * 3 * 16)
            .map(|_| (rng.gaussian() * 40.0).clamp(-127.0, 127.0) as i8)
            .collect();
        let layer = qlayer("p", 4, 3, 16, data, vec![0.02; 4]);
        apply_strum(&layer, &StrumParams::new(method, 1, 8, 0.5))
    }

    #[test]
    fn packing_is_deterministic_and_valid() {
        for method in [
            Method::Baseline,
            Method::StructuredSparsity,
            Method::Dliq { q: 4 },
            Method::Mip2q { l_max: 7 },
        ] {
            let s = transformed(method, 77);
            let a = PackedBanks::from_layer(&s).unwrap();
            let b = PackedBanks::from_layer(&s).unwrap();
            a.validate().unwrap();
            assert_eq!(&a.hi[..], &b.hi[..], "{:?}", method);
            assert_eq!(a.low_taps(), b.low_taps(), "{:?}", method);
            assert!(!a.is_mapped());
        }
    }

    #[test]
    fn validate_rejects_broken_csr() {
        let s = transformed(Method::Mip2q { l_max: 7 }, 5);
        let mut p = PackedBanks::from_layer(&s).unwrap();
        if let LowBank::Pow2 { col, .. } = &mut p.low {
            col[0] = u32::MAX; // out-of-bounds column
        }
        assert!(p.validate().is_err());
    }
}
