//! The §IV-D.1 compressed weight-block format.
//!
//! Per `[l, w]` block, in block-grid order:
//!
//! 1. **Mask header** — `l·w` bits, bit `i` = 1 ⇔ element `i` is high
//!    precision (element order: block row-major, padding lanes included —
//!    the hardware's RF lanes physically exist either way).
//! 2. **Payload** — for each element in the same order:
//!    * mask 1 → 8-bit INT8 value (two's complement);
//!    * mask 0 → method-dependent:
//!      - structured sparsity (and DLIQ q=1): nothing (value is 0);
//!      - DLIQ: `q`-bit two's-complement code (grid value = code·2^(8-q));
//!      - MIP2Q: `q`-bit sign+shift code (sign in the top bit, shift `k`
//!        in the low `q-1` bits; value = ±2^k).
//!
//! The decoder "reads the correct number of bits from the payload" exactly
//! as Fig. 5 describes: the mask bit selects 8 vs `q` bits per element.

use super::bitstream::{BitReader, BitWriter};
use crate::quant::{BlockLayout, Method, StrumLayer, StrumParams};

/// An encoded layer: the compressed bitstream plus everything needed to
/// decode it.
#[derive(Debug, Clone)]
pub struct EncodedLayer {
    pub name: String,
    pub params: StrumParams,
    pub oc: usize,
    pub rows: usize,
    pub cols: usize,
    pub scales: Vec<f32>,
    /// Compressed mask+payload bits (byte-padded at the very end only).
    pub bytes: Vec<u8>,
    /// Exact bit length (before byte padding).
    pub bits: usize,
}

impl EncodedLayer {
    /// Elements in the padded block grid (what the hardware stores).
    pub fn padded_elems(&self) -> usize {
        let layout = BlockLayout::new(self.oc, self.rows, self.cols, self.params.block);
        layout.num_blocks() * layout.block_elems()
    }

    /// Measured compression ratio r = compressed bits / (8 bits · padded
    /// elements) — directly comparable to Eq. 1 / Eq. 2.
    pub fn measured_ratio(&self) -> f64 {
        self.bits as f64 / (8.0 * self.padded_elems() as f64)
    }
}

thread_local! {
    /// Per-thread count of [`encode_layer`] invocations — with
    /// `transform_network_calls` this asserts the cached serve path does
    /// zero quantize/encode work (thread-local: no test cross-talk).
    static ENCODE_CALLS: std::cell::Cell<u64> = std::cell::Cell::new(0);
}

/// How many times THIS thread has run [`encode_layer`].
pub fn encode_layer_calls() -> u64 {
    ENCODE_CALLS.with(|c| c.get())
}

/// Encodes a StruM-transformed layer into the compressed format.
pub fn encode_layer(layer: &StrumLayer) -> EncodedLayer {
    ENCODE_CALLS.with(|c| c.set(c.get() + 1));
    let params = layer.params;
    let layout = BlockLayout::new(layer.oc, layer.rows, layer.cols, params.block);
    let q = params.method.payload_bits();
    let mut w = BitWriter::new();
    let be = layout.block_elems();
    let mut mask_bits: Vec<bool> = Vec::with_capacity(be);
    let mut elems: Vec<Option<usize>> = Vec::with_capacity(be);
    for blk in 0..layout.num_blocks() {
        mask_bits.clear();
        elems.clear();
        for idx in layout.block_indices(blk) {
            // Padding lanes are low-precision by construction.
            mask_bits.push(idx.map(|i| layer.mask[i]).unwrap_or(false));
            elems.push(idx);
        }
        // 1. Mask header (batched into ≤64-bit words — §Perf hot path).
        for chunk in mask_bits.chunks(64) {
            let mut word = 0u64;
            for (i, &m) in chunk.iter().enumerate() {
                word |= (m as u64) << i;
            }
            w.write(word, chunk.len() as u32);
        }
        // 2. Payload.
        for (slot, idx) in elems.iter().enumerate() {
            let high = mask_bits[slot];
            match (high, idx) {
                (true, Some(i)) => w.write_signed(layer.codes[*i] as i64, 8),
                (true, None) => unreachable!("padding is never high"),
                (false, Some(i)) => write_low_code(&mut w, layer.codes[*i], params.method, q),
                (false, None) => {
                    // Padding lane: canonical zero-ish code.
                    match params.method {
                        Method::Mip2q { .. } => {
                            // +2^0 encodes as sign=0, k=0.
                            if q > 0 {
                                w.write(0, q);
                            }
                        }
                        _ => {
                            if q > 0 {
                                w.write(0, q);
                            }
                        }
                    }
                }
            }
        }
    }
    let bits = w.bit_len();
    EncodedLayer {
        name: layer.name.clone(),
        params,
        oc: layer.oc,
        rows: layer.rows,
        cols: layer.cols,
        scales: layer.scales.clone(),
        bytes: w.finish(),
        bits,
    }
}

fn write_low_code(w: &mut BitWriter, code: i8, method: Method, q: u32) {
    match method {
        Method::Baseline => w.write_signed(code as i64, 8),
        Method::StructuredSparsity => {} // no payload
        Method::Dliq { q: dq } => {
            if dq <= 1 {
                return; // degenerate: value is 0, known from mask
            }
            w.write_signed(code as i64, q);
        }
        Method::Mip2q { .. } => {
            // code = ±(k+1) sign-magnitude → pack sign | k.
            debug_assert!(code != 0);
            let neg = code < 0;
            let k = (code.unsigned_abs() - 1) as u64;
            debug_assert!(q >= 1);
            let field = ((neg as u64) << (q - 1)) | k;
            w.write(field, q);
        }
    }
}

/// Decodes an [`EncodedLayer`] back into a [`StrumLayer`] (effective
/// values, codes and mask). Exact inverse of [`encode_layer`].
pub fn decode_layer(enc: &EncodedLayer) -> crate::Result<StrumLayer> {
    let params = enc.params;
    let layout = BlockLayout::new(enc.oc, enc.rows, enc.cols, params.block);
    let q = params.method.payload_bits();
    let n = enc.oc * enc.rows * enc.cols;
    let mut out = StrumLayer {
        name: enc.name.clone(),
        params,
        oc: enc.oc,
        rows: enc.rows,
        cols: enc.cols,
        values: vec![0; n],
        codes: vec![0; n],
        mask: vec![false; n],
        scales: enc.scales.clone(),
        grid_rmse: 0.0,
    };
    let mut r = BitReader::new(&enc.bytes);
    let be = layout.block_elems();
    let mut mask_bits: Vec<bool> = Vec::with_capacity(be);
    let mut elems: Vec<Option<usize>> = Vec::with_capacity(be);
    let fail = || anyhow::anyhow!("truncated bitstream in layer {}", enc.name);
    for blk in 0..layout.num_blocks() {
        mask_bits.clear();
        elems.clear();
        elems.extend(layout.block_indices(blk));
        // Mask header, batched reads mirroring the writer.
        let mut remaining = be;
        while remaining > 0 {
            let take = remaining.min(64);
            let word = r.read(take as u32).ok_or_else(fail)?;
            for i in 0..take {
                mask_bits.push((word >> i) & 1 == 1);
            }
            remaining -= take;
        }
        for (slot, idx) in elems.iter().enumerate() {
            let high = mask_bits[slot];
            if high {
                let v = r.read_signed(8).ok_or_else(fail)? as i8;
                if let Some(i) = idx {
                    out.mask[*i] = true;
                    out.codes[*i] = v;
                    out.values[*i] = v as i16;
                }
            } else {
                match params.method {
                    Method::Baseline => {
                        let v = r.read_signed(8).ok_or_else(fail)? as i8;
                        if let Some(i) = idx {
                            out.codes[*i] = v;
                            out.values[*i] = v as i16;
                        }
                    }
                    Method::StructuredSparsity => {
                        if let Some(i) = idx {
                            out.codes[*i] = 0;
                            out.values[*i] = 0;
                        }
                    }
                    Method::Dliq { q: dq } => {
                        let code = if dq <= 1 {
                            0
                        } else {
                            r.read_signed(q).ok_or_else(fail)? as i8
                        };
                        if let Some(i) = idx {
                            out.codes[*i] = code;
                            out.values[*i] = crate::quant::dliq::decode(code, dq);
                        }
                    }
                    Method::Mip2q { l_max } => {
                        let field = r.read(q).ok_or_else(fail)?;
                        let neg = (field >> (q - 1)) & 1 == 1;
                        let k = (field & ((1 << (q - 1)) - 1)) as u8;
                        let code = crate::quant::mip2q::encode_code(neg, k);
                        if let Some(i) = idx {
                            out.codes[*i] = code;
                            out.values[*i] = crate::quant::mip2q::decode(code, l_max);
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::tensor::qlayer;
    use crate::quant::{apply_strum, Method, StrumParams};
    use crate::util::prng::Rng;

    fn random_layer(oc: usize, rows: usize, cols: usize, seed: u64) -> crate::quant::QLayer {
        let mut rng = Rng::new(seed);
        let data: Vec<i8> = (0..oc * rows * cols)
            .map(|_| (rng.gaussian() * 40.0).clamp(-127.0, 127.0) as i8)
            .collect();
        qlayer("rnd", oc, rows, cols, data, vec![0.01; oc])
    }

    fn roundtrip(method: Method, oc: usize, rows: usize, cols: usize, l: usize, w: usize, p: f64) {
        let layer = random_layer(oc, rows, cols, 42);
        let s = apply_strum(&layer, &StrumParams::new(method, l, w, p));
        let enc = encode_layer(&s);
        let dec = decode_layer(&enc).unwrap();
        assert_eq!(dec.values, s.values, "{:?}", method);
        assert_eq!(dec.mask, s.mask, "{:?}", method);
        assert_eq!(dec.codes, s.codes, "{:?}", method);
    }

    #[test]
    fn roundtrip_all_methods_aligned() {
        for method in [
            Method::StructuredSparsity,
            Method::Dliq { q: 4 },
            Method::Dliq { q: 2 },
            Method::Mip2q { l_max: 7 },
            Method::Mip2q { l_max: 5 },
            Method::Mip2q { l_max: 3 },
        ] {
            roundtrip(method, 4, 1, 32, 1, 16, 0.5);
        }
    }

    #[test]
    fn roundtrip_with_padding_and_l_blocks() {
        for method in [
            Method::StructuredSparsity,
            Method::Dliq { q: 4 },
            Method::Mip2q { l_max: 7 },
        ] {
            roundtrip(method, 3, 3, 10, 2, 8, 0.5);
            roundtrip(method, 1, 1, 5, 1, 16, 0.25);
        }
    }

    #[test]
    fn measured_ratio_matches_eq1_when_aligned() {
        // DLIQ q=4, p=0.5, no padding: Eq.1 → r = (0.5·(4-8)+9)/8 = 7/8.
        let layer = random_layer(4, 1, 64, 7);
        let s = apply_strum(&layer, &StrumParams::paper(Method::Dliq { q: 4 }, 0.5));
        let enc = encode_layer(&s);
        assert!((enc.measured_ratio() - 7.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn measured_ratio_matches_eq2_for_sparsity() {
        // Sparsity p=0.5: Eq.2 → r = (9-8·0.5)/8 = 5/8.
        let layer = random_layer(2, 1, 48, 9);
        let s = apply_strum(&layer, &StrumParams::paper(Method::StructuredSparsity, 0.5));
        let enc = encode_layer(&s);
        assert!((enc.measured_ratio() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_encoding_is_9_8() {
        // Baseline still carries the mask header: r = 9/8 (Eq.1, p=0).
        let layer = random_layer(2, 1, 32, 3);
        let s = apply_strum(&layer, &StrumParams::paper(Method::Baseline, 0.0));
        let enc = encode_layer(&s);
        assert!((enc.measured_ratio() - 9.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn decode_detects_truncation() {
        let layer = random_layer(2, 1, 32, 5);
        let s = apply_strum(&layer, &StrumParams::paper(Method::Dliq { q: 4 }, 0.5));
        let mut enc = encode_layer(&s);
        enc.bytes.truncate(enc.bytes.len() / 2);
        assert!(decode_layer(&enc).is_err());
    }
}
