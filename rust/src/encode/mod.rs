//! Weight encoding (§IV-D.1): compressed blocks = mask header + payload.
//!
//! * [`bitstream`] — LSB-first bit-level writer/reader (the substrate).
//! * [`format`] — the block codec: one mask bit per element (1 = high
//!   precision / INT8 payload; 0 = low precision / `q`-bit payload or no
//!   payload for structured sparsity), followed by the payload bits in
//!   block order.
//! * [`compression`] — the paper's analytic compression ratios (Eq. 1 and
//!   Eq. 2) plus measured-size accounting to validate them.
//! * [`packed`] — kernel-layout execution banks ([`packed::PackedBanks`]):
//!   the dense int8 high bank + DLIQ/MIP2Q low bank the native GEMM
//!   consumes, built once at compile time and serialized into `.strumc`
//!   so serve-time bind never repacks.
//!
//! Encoded layers are also the payload of compiled `.strumc` artifacts
//! (`crate::artifact`): `strum compile` serializes them to disk once and
//! the serve path binds straight from the prepacked bank bytes —
//! [`format::encode_layer_calls`] counts invocations so tests can assert
//! the cached path never re-encodes.

pub mod bitstream;
pub mod compression;
pub mod format;
pub mod packed;

pub use bitstream::{BitReader, BitWriter};
pub use compression::{ratio_payload, ratio_sparsity};
pub use format::{decode_layer, encode_layer, encode_layer_calls, EncodedLayer};
pub use packed::{LowBank, PackedBanks};
