//! Replica-fleet gateway: a supervisor + routing tier that fronts N
//! `strum serve` replicas behind one wire endpoint.
//!
//! `strum gateway --replicas N` spawns N child `strum serve --listen
//! 127.0.0.1:0` processes, scrapes each child's ephemeral port from its
//! `listening on ADDR` stdout line, and mounts a [`GatewayHandler`] on
//! the same [`WireServer`](crate::server::WireServer) front-end the
//! replicas themselves use — clients speak the identical protocol to a
//! gateway and to a single replica. Four cooperating pieces:
//!
//! * [`supervisor`] — one slot thread per replica: spawn, scrape the
//!   address, poll for exit, restart with capped jittered exponential
//!   backoff. A replica marked [`ReplicaState::Draining`] is killed
//!   only after its in-flight requests drain.
//! * [`health`] — probes every replica's wire metrics op on an
//!   interval, differencing [`WireCounts`] snapshots into per-replica
//!   shed/reject rates and flipping `healthy` on consecutive failures.
//! * [`router`] — shed-aware forwarding: per-variant least-outstanding
//!   selection over healthy replicas of the active cohort, ONE bounded
//!   retry on another replica when a forward comes back retryable, and
//!   optional tail hedging after a p95-derived delay.
//! * [`deploy`] — rolling deploys: watch a `.strumc` artifact path for
//!   a new version (weights fingerprint + encoder version from the
//!   header), bring up a fresh cohort, shift traffic, hold probation,
//!   and either drain the old cohort or roll back.
//!
//! ## Failure model
//!
//! The gateway narrows what clients can observe compared to a raw
//! replica (see the [`server`](crate::server) failure model for the
//! per-replica contract):
//!
//! - A replica crash mid-request surfaces as a connection error to the
//!   *gateway*, never to the client: the router retries once on another
//!   healthy replica (inference is idempotent; the failed forward
//!   committed no response). Only when no healthy replica remains does
//!   the client see a typed [`ErrorCode::Upstream`] refusal.
//! - Retryable outcomes are the shed family plus `QueueFull` and
//!   `ShuttingDown` — states another replica may not share.
//!   Application errors (`BadImage`, `UnknownVariant`, `BadFrame`) are
//!   deterministic and forwarded verbatim, never retried.
//! - A forward **timeout** is terminal ([`WireClient`] semantics): the
//!   replica may still be executing, and re-submitting would double
//!   offered load exactly when the fleet is saturated.
//! - Deadline budgets shrink as they travel: the gateway forwards the
//!   *remaining* budget, so a retry never grants more time than the
//!   client asked for.
//!
//! [`WireCounts`]: crate::coordinator::WireCounts
//! [`ErrorCode::Upstream`]: crate::server::ErrorCode::Upstream
//! [`WireClient`]: crate::server::WireClient

pub mod deploy;
pub mod health;
pub mod router;
pub mod supervisor;

pub use router::GatewayHandler;

use crate::coordinator::WireCounts;
use crate::telemetry::TelemetrySink;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How to launch one supervised replica process. The command must print
/// `listening on ADDR` on stdout once its wire server is bound (the
/// supervisor scrapes the ephemeral port from that line).
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub binary: PathBuf,
    pub args: Vec<String>,
    /// Extra environment for the child (e.g. `STRUM_FAULT_PLAN` to arm
    /// exactly one replica of a fleet with a fault plan).
    pub env: Vec<(String, String)>,
}

/// Replica lifecycle. Only `Up` + healthy replicas are routable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Process spawned, address not yet scraped.
    Starting,
    /// Address known; serving (routable once the prober marks it healthy).
    Up,
    /// No new work; killed once in-flight requests drain.
    Draining,
    /// Process exited unexpectedly; the supervisor is backing off
    /// toward a restart.
    Dead,
    /// Permanently gone (drained out, or the gateway stopped).
    Retired,
}

impl ReplicaState {
    pub fn name(&self) -> &'static str {
        match self {
            ReplicaState::Starting => "starting",
            ReplicaState::Up => "up",
            ReplicaState::Draining => "draining",
            ReplicaState::Dead => "dead",
            ReplicaState::Retired => "retired",
        }
    }
}

/// One replica's live record in the fleet table. All mutation happens
/// under the fleet mutex; the supervisor, prober, router, and deploy
/// watcher each own disjoint transitions.
#[derive(Debug)]
pub struct Replica {
    pub id: u64,
    /// Deploy generation: 0 is the boot fleet, each rolling deploy
    /// allocates the next.
    pub cohort: u64,
    /// Spawned and restarted by a supervisor slot (false = attached to
    /// an externally managed address via `--attach`).
    pub supervised: bool,
    pub state: ReplicaState,
    pub addr: Option<String>,
    pub pid: Option<u32>,
    /// Routable: flipped true by a successful health probe, false by
    /// `fail_threshold` consecutive probe failures or a forward-level
    /// transport error.
    pub healthy: bool,
    /// Set when the replica is flagged unhealthy (probe threshold,
    /// forward transport error, death). While set, a successful probe
    /// alone does not re-admit: the prober requires one clean
    /// delta-based window (two comparable samples with no failure or
    /// restart in between) before flipping `healthy` back on. Fresh
    /// replicas (never flagged) admit on their first successful probe.
    pub probation: bool,
    pub consec_fail: u32,
    pub restarts: u64,
    /// In-flight forwards per variant key (least-outstanding routing).
    pub outstanding: HashMap<String, usize>,
    pub outstanding_total: usize,
    /// Successful forwards completed through the gateway.
    pub served: u64,
    /// Last health-probe counters (for differencing).
    pub last_counts: Option<WireCounts>,
    /// Shed+reject rate over the last probe interval.
    pub unhealthy_rate: f64,
}

impl Replica {
    fn new(id: u64, cohort: u64, supervised: bool) -> Replica {
        Replica {
            id,
            cohort,
            supervised,
            state: ReplicaState::Starting,
            addr: None,
            pid: None,
            healthy: false,
            probation: false,
            consec_fail: 0,
            restarts: 0,
            outstanding: HashMap::new(),
            outstanding_total: 0,
            served: 0,
            last_counts: None,
            unhealthy_rate: 0.0,
        }
    }

    fn attached(id: u64, addr: String) -> Replica {
        let mut r = Replica::new(id, 0, false);
        r.state = ReplicaState::Up;
        r.addr = Some(addr);
        r
    }

    pub fn outstanding_for(&self, key: &str) -> usize {
        self.outstanding.get(key).copied().unwrap_or(0)
    }
}

/// Small ring of recent forward latencies; p95 is recomputed every 64
/// inserts (cheap enough to sort 256 samples, rare enough to stay off
/// the hot path) and published through `GatewayShared::p95_us`.
pub(crate) struct LatRing {
    buf: Vec<u64>,
    pos: usize,
    since_recompute: usize,
}

impl LatRing {
    const CAP: usize = 256;
    const RECOMPUTE_EVERY: usize = 64;

    fn new() -> LatRing {
        LatRing {
            buf: Vec::with_capacity(LatRing::CAP),
            pos: 0,
            since_recompute: 0,
        }
    }

    /// Records one latency; returns a fresh p95 when due.
    pub(crate) fn push(&mut self, us: u64) -> Option<u64> {
        if self.buf.len() < LatRing::CAP {
            self.buf.push(us);
        } else {
            self.buf[self.pos] = us;
            self.pos = (self.pos + 1) % LatRing::CAP;
        }
        self.since_recompute += 1;
        if self.since_recompute < LatRing::RECOMPUTE_EVERY {
            return None;
        }
        self.since_recompute = 0;
        let mut sorted = self.buf.clone();
        sorted.sort_unstable();
        let idx = (sorted.len().saturating_sub(1)) * 95 / 100;
        Some(sorted[idx])
    }
}

/// State shared by the router, supervisor slots, health prober, and
/// deploy watcher.
pub struct GatewayShared {
    pub replicas: Mutex<Vec<Replica>>,
    pub stopping: AtomicBool,
    /// Cohort the router prefers; other healthy cohorts are fallback.
    pub active_cohort: AtomicU64,
    pub(crate) next_id: AtomicU64,
    pub(crate) next_cohort: AtomicU64,
    pub retries: AtomicU64,
    pub hedges: AtomicU64,
    pub hedge_wins: AtomicU64,
    pub upstream_errors: AtomicU64,
    pub deploys: AtomicU64,
    pub rollbacks: AtomicU64,
    /// Set when a rollback fired under `fail_on_rollback`; the CLI exits
    /// nonzero on it (the CI rollback smoke's exit-code assertion).
    pub rollback_fatal: AtomicBool,
    pub telemetry: TelemetrySink,
    pub(crate) slots: Mutex<Vec<JoinHandle<()>>>,
    pub(crate) lat: Mutex<LatRing>,
    /// Published p95 forward latency, microseconds (0 = no samples yet).
    pub p95_us: AtomicU64,
}

/// Runs `f` on the replica record with this id (if it still exists).
pub(crate) fn with_replica<T>(
    shared: &GatewayShared,
    id: u64,
    f: impl FnOnce(&mut Replica) -> T,
) -> Option<T> {
    let mut fleet = shared.replicas.lock().unwrap();
    fleet.iter_mut().find(|r| r.id == id).map(f)
}

pub(crate) fn replica_state(shared: &GatewayShared, id: u64) -> Option<ReplicaState> {
    with_replica(shared, id, |r| r.state)
}

/// Tail-hedging policy: when to fire a second forward for a request
/// whose primary has not answered yet.
#[derive(Debug, Clone, Copy)]
pub enum HedgePolicy {
    /// Fixed delay in milliseconds.
    FixedMs(u64),
    /// Delay = the gateway's observed p95 forward latency (20 ms until
    /// enough samples exist; clamped to [1 ms, 500 ms]).
    P95,
}

/// Rolling-deploy policy for `--watch-artifact`.
#[derive(Debug, Clone)]
pub struct DeployPolicy {
    /// `.strumc` path to watch. A changed `version_key` (weights
    /// fingerprint + encoder version) triggers a deploy.
    pub artifact: PathBuf,
    /// Cohort size (replicas per deploy).
    pub replicas: usize,
    /// Watch poll interval.
    pub poll: Duration,
    /// How long the new cohort gets to become fully healthy before the
    /// deploy rolls back.
    pub health_timeout: Duration,
    /// Post-shift window in which a death or shed/reject regression in
    /// the new cohort triggers rollback.
    pub probation: Duration,
    /// Shed+reject rate (per probe interval) above which probation
    /// fails.
    pub regress_threshold: f64,
    /// Latch `rollback_fatal` on any rollback (CI exit-code gate).
    pub fail_on_rollback: bool,
}

impl Default for DeployPolicy {
    fn default() -> DeployPolicy {
        DeployPolicy {
            artifact: PathBuf::new(),
            replicas: 1,
            poll: Duration::from_millis(500),
            health_timeout: Duration::from_secs(30),
            probation: Duration::from_secs(5),
            regress_threshold: 0.2,
            fail_on_rollback: false,
        }
    }
}

/// Everything `Gateway::start` needs.
pub struct GatewayOptions {
    /// Supervised replica count (0 with a non-empty `attach` is valid).
    pub replicas: usize,
    /// How to launch supervised replicas (required when `replicas > 0`).
    pub spec: Option<ReplicaSpec>,
    /// Externally managed replica addresses to route to as cohort 0.
    pub attach: Vec<String>,
    /// Arm supervised slot `index` with a fault-plan spec via the
    /// child's `STRUM_FAULT_PLAN` environment.
    pub fault_replica: Option<(usize, String)>,
    pub probe_interval: Duration,
    /// Consecutive probe failures before a replica is unroutable.
    pub fail_threshold: u32,
    /// One bounded retry-on-another-replica for retryable outcomes.
    pub retry: bool,
    pub hedge: Option<HedgePolicy>,
    /// Per-forward read timeout (also bounds hedging waits).
    pub forward_timeout: Duration,
    pub restart_backoff_base: Duration,
    pub restart_backoff_cap: Duration,
    pub watch: Option<DeployPolicy>,
    pub telemetry: TelemetrySink,
}

impl Default for GatewayOptions {
    fn default() -> GatewayOptions {
        GatewayOptions {
            replicas: 0,
            spec: None,
            attach: Vec::new(),
            fault_replica: None,
            probe_interval: Duration::from_millis(250),
            fail_threshold: 2,
            retry: true,
            hedge: None,
            forward_timeout: Duration::from_secs(10),
            restart_backoff_base: Duration::from_millis(100),
            restart_backoff_cap: Duration::from_secs(5),
            watch: None,
            telemetry: TelemetrySink::disabled(),
        }
    }
}

/// Point-in-time copy of one replica row.
#[derive(Debug, Clone)]
pub struct ReplicaView {
    pub id: u64,
    pub cohort: u64,
    pub state: &'static str,
    pub addr: Option<String>,
    pub pid: Option<u32>,
    pub healthy: bool,
    pub restarts: u64,
    pub consec_fail: u32,
    pub outstanding: usize,
    pub served: u64,
    pub unhealthy_rate: f64,
}

/// Typed snapshot of the whole gateway fleet (the gateway-level analogue
/// of the engine's `MetricsSnapshot`).
#[derive(Debug, Clone)]
pub struct FleetView {
    pub replicas: Vec<ReplicaView>,
    pub active_cohort: u64,
    pub retries: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    pub upstream_errors: u64,
    pub deploys: u64,
    pub rollbacks: u64,
}

impl FleetView {
    pub fn completed(&self) -> u64 {
        self.replicas.iter().map(|r| r.served).sum()
    }

    pub fn healthy(&self) -> usize {
        self.replicas.iter().filter(|r| r.healthy).count()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "replicas",
                Json::Arr(
                    self.replicas
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::Num(r.id as f64)),
                                ("cohort", Json::Num(r.cohort as f64)),
                                ("state", Json::str(r.state)),
                                (
                                    "addr",
                                    match &r.addr {
                                        Some(a) => Json::str(a.as_str()),
                                        None => Json::Null,
                                    },
                                ),
                                (
                                    "pid",
                                    match r.pid {
                                        Some(p) => Json::Num(p as f64),
                                        None => Json::Null,
                                    },
                                ),
                                ("healthy", Json::Bool(r.healthy)),
                                ("restarts", Json::Num(r.restarts as f64)),
                                ("outstanding", Json::Num(r.outstanding as f64)),
                                ("served", Json::Num(r.served as f64)),
                                ("unhealthy_rate", Json::Num(r.unhealthy_rate)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("active_cohort", Json::Num(self.active_cohort as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("hedges", Json::Num(self.hedges as f64)),
            ("hedge_wins", Json::Num(self.hedge_wins as f64)),
            ("upstream_errors", Json::Num(self.upstream_errors as f64)),
            ("deploys", Json::Num(self.deploys as f64)),
            ("rollbacks", Json::Num(self.rollbacks as f64)),
        ])
    }

    /// Human summary for the CLI exit report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.replicas {
            out.push_str(&format!(
                "replica id={} cohort={} state={} healthy={} restarts={} served={}{}\n",
                r.id,
                r.cohort,
                r.state,
                r.healthy,
                r.restarts,
                r.served,
                match &r.addr {
                    Some(a) => format!(" addr={}", a),
                    None => String::new(),
                }
            ));
        }
        out.push_str(&format!(
            "gateway: completed={} retries={} hedges={} hedge_wins={} upstream_errors={} \
             deploys={} rollbacks={}",
            self.completed(),
            self.retries,
            self.hedges,
            self.hedge_wins,
            self.upstream_errors,
            self.deploys,
            self.rollbacks
        ));
        out
    }
}

pub(crate) fn fleet_view(shared: &GatewayShared) -> FleetView {
    let replicas = shared
        .replicas
        .lock()
        .unwrap()
        .iter()
        .map(|r| ReplicaView {
            id: r.id,
            cohort: r.cohort,
            state: r.state.name(),
            addr: r.addr.clone(),
            pid: r.pid,
            healthy: r.healthy,
            restarts: r.restarts,
            consec_fail: r.consec_fail,
            outstanding: r.outstanding_total,
            served: r.served,
            unhealthy_rate: r.unhealthy_rate,
        })
        .collect();
    FleetView {
        replicas,
        active_cohort: shared.active_cohort.load(Ordering::Relaxed),
        retries: shared.retries.load(Ordering::Relaxed),
        hedges: shared.hedges.load(Ordering::Relaxed),
        hedge_wins: shared.hedge_wins.load(Ordering::Relaxed),
        upstream_errors: shared.upstream_errors.load(Ordering::Relaxed),
        deploys: shared.deploys.load(Ordering::Relaxed),
        rollbacks: shared.rollbacks.load(Ordering::Relaxed),
    }
}

/// The running gateway: supervisor slots + health prober + optional
/// deploy watcher, and the [`GatewayHandler`] to mount on a
/// [`WireServer`](crate::server::WireServer).
pub struct Gateway {
    shared: Arc<GatewayShared>,
    handler: Arc<GatewayHandler>,
    health: Option<JoinHandle<()>>,
    watcher: Option<JoinHandle<()>>,
}

impl Gateway {
    pub fn start(opts: GatewayOptions) -> crate::Result<Gateway> {
        anyhow::ensure!(
            opts.replicas > 0 || !opts.attach.is_empty(),
            "gateway needs supervised replicas or attached addresses"
        );
        anyhow::ensure!(
            opts.replicas == 0 || opts.spec.is_some(),
            "supervised replicas need a ReplicaSpec"
        );
        if opts.watch.is_some() {
            anyhow::ensure!(
                opts.spec.is_some(),
                "--watch-artifact requires supervised replicas (a spec to respawn from)"
            );
        }
        let shared = Arc::new(GatewayShared {
            replicas: Mutex::new(Vec::new()),
            stopping: AtomicBool::new(false),
            active_cohort: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            next_cohort: AtomicU64::new(1),
            retries: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
            upstream_errors: AtomicU64::new(0),
            deploys: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            rollback_fatal: AtomicBool::new(false),
            telemetry: opts.telemetry.clone(),
            slots: Mutex::new(Vec::new()),
            lat: Mutex::new(LatRing::new()),
            p95_us: AtomicU64::new(0),
        });

        // Fleet records first, then threads: a slot thread must find
        // its record the moment it starts.
        let mut supervised_ids = Vec::new();
        {
            let mut fleet = shared.replicas.lock().unwrap();
            for addr in &opts.attach {
                let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
                fleet.push(Replica::attached(id, addr.clone()));
            }
            for _ in 0..opts.replicas {
                let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
                fleet.push(Replica::new(id, 0, true));
                supervised_ids.push(id);
            }
        }
        if let Some(spec) = &opts.spec {
            for (i, id) in supervised_ids.iter().enumerate() {
                let mut s = spec.clone();
                if let Some((idx, plan)) = &opts.fault_replica {
                    if *idx == i {
                        s.env.push(("STRUM_FAULT_PLAN".to_string(), plan.clone()));
                    }
                }
                let h = supervisor::spawn_slot(
                    shared.clone(),
                    *id,
                    s,
                    opts.restart_backoff_base,
                    opts.restart_backoff_cap,
                );
                shared.slots.lock().unwrap().push(h);
            }
        }
        let health = health::spawn_prober(shared.clone(), opts.probe_interval, opts.fail_threshold);
        let watcher = match (&opts.watch, &opts.spec) {
            (Some(policy), Some(spec)) => Some(deploy::spawn_watcher(
                shared.clone(),
                policy.clone(),
                spec.clone(),
                opts.restart_backoff_base,
                opts.restart_backoff_cap,
            )),
            _ => None,
        };
        let handler = Arc::new(GatewayHandler::new(
            shared.clone(),
            opts.retry,
            opts.hedge,
            opts.forward_timeout,
        ));
        Ok(Gateway {
            shared,
            handler,
            health: Some(health),
            watcher,
        })
    }

    /// The wire handler to mount:
    /// `WireServer::bind_handler(addr, gateway.handler(), opts)`.
    pub fn handler(&self) -> Arc<GatewayHandler> {
        self.handler.clone()
    }

    pub fn shared(&self) -> &Arc<GatewayShared> {
        &self.shared
    }

    pub fn snapshot(&self) -> FleetView {
        fleet_view(&self.shared)
    }

    /// True once a rollback fired under `fail_on_rollback`.
    pub fn rollback_fired(&self) -> bool {
        self.shared.rollback_fatal.load(Ordering::Acquire)
    }

    /// Blocks until at least `n` replicas are healthy (true) or the
    /// timeout passes (false).
    pub fn wait_healthy(&self, n: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            let healthy = self
                .shared
                .replicas
                .lock()
                .unwrap()
                .iter()
                .filter(|r| r.healthy)
                .count();
            if healthy >= n {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// Stops every thread and kills every supervised child. Idempotent.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.shared.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(h) = self.health.take() {
            let _ = h.join();
        }
        if let Some(h) = self.watcher.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut slots = self.shared.slots.lock().unwrap();
            slots.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lat_ring_publishes_p95_periodically() {
        let mut ring = LatRing::new();
        let mut published = None;
        for i in 0..64u64 {
            published = ring.push(i * 10).or(published);
        }
        // 64 samples 0..630: p95 index = 63*95/100 = 59 → 590.
        assert_eq!(published, Some(590));
        // Not republished until another 64 inserts.
        assert_eq!(ring.push(1), None);
    }

    #[test]
    fn fleet_view_rolls_up_counters() {
        let shared = GatewayShared {
            replicas: Mutex::new(vec![
                {
                    let mut r = Replica::attached(0, "127.0.0.1:1".into());
                    r.healthy = true;
                    r.served = 3;
                    r
                },
                Replica::new(1, 0, true),
            ]),
            stopping: AtomicBool::new(false),
            active_cohort: AtomicU64::new(0),
            next_id: AtomicU64::new(2),
            next_cohort: AtomicU64::new(1),
            retries: AtomicU64::new(2),
            hedges: AtomicU64::new(1),
            hedge_wins: AtomicU64::new(1),
            upstream_errors: AtomicU64::new(0),
            deploys: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            rollback_fatal: AtomicBool::new(false),
            telemetry: TelemetrySink::disabled(),
            slots: Mutex::new(Vec::new()),
            lat: Mutex::new(LatRing::new()),
            p95_us: AtomicU64::new(0),
        };
        let view = fleet_view(&shared);
        assert_eq!(view.replicas.len(), 2);
        assert_eq!(view.completed(), 3);
        assert_eq!(view.healthy(), 1);
        assert_eq!(view.retries, 2);
        let json = view.to_json().to_string();
        assert!(json.contains("\"state\":\"up\""));
        assert!(json.contains("\"state\":\"starting\""));
    }
}
