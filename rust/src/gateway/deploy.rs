//! Rolling deploys with auto-rollback.
//!
//! The watcher polls a `.strumc` artifact path for a changed
//! `version_key` (weights fingerprint + encoder version from the
//! header — mtime is not identity, and a same-bytes rewrite is not a
//! deploy). On a new version it:
//!
//! 1. spawns a fresh cohort of supervised replicas whose serve command
//!    loads the artifact (`--artifact PATH`),
//! 2. gates on the whole cohort becoming healthy within
//!    `health_timeout` — a corrupt artifact fails *here*, because its
//!    replicas die at `CompiledNet::load` before printing an address,
//! 3. shifts traffic by swapping `active_cohort` (the router prefers
//!    the active cohort; the old one instantly becomes fallback),
//! 4. holds a probation window: any new-cohort death, restart, or
//!    shed/reject rate above `regress_threshold` restores the old
//!    cohort and rolls back,
//! 5. on success, marks the old cohort's supervised replicas Draining
//!    (their slot threads kill them once in-flight work reaches zero).
//!
//! A rolled-back version is remembered and never redeployed until the
//! artifact changes again — otherwise the watcher would hot-loop on a
//! bad push. Under `fail_on_rollback` a rollback also latches
//! `rollback_fatal`, which the CLI turns into a nonzero exit (the CI
//! rollback smoke asserts on exactly this).

use super::{supervisor, DeployPolicy, GatewayShared, Replica, ReplicaSpec, ReplicaState};
use crate::artifact;
use crate::telemetry::Event;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Poll cadence while waiting on cohort health / probation.
const WATCH_POLL: Duration = Duration::from_millis(50);

pub(crate) fn spawn_watcher(
    shared: Arc<GatewayShared>,
    policy: DeployPolicy,
    spec: ReplicaSpec,
    backoff_base: Duration,
    backoff_cap: Duration,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("gw-deploy".into())
        .spawn(move || watcher_loop(&shared, &policy, &spec, backoff_base, backoff_cap))
        .expect("spawn gateway deploy watcher")
}

fn watcher_loop(
    shared: &Arc<GatewayShared>,
    policy: &DeployPolicy,
    spec: &ReplicaSpec,
    backoff_base: Duration,
    backoff_cap: Duration,
) {
    // The boot fleet's version (if the artifact is readable now) is the
    // baseline: redeploying what is already serving is a no-op.
    let mut current: Option<String> = artifact::read_identity(&policy.artifact)
        .ok()
        .map(|h| h.version_key());
    let mut rejected: Option<String> = None;
    while !shared.stopping.load(Ordering::Acquire) {
        sleep_interruptible(shared, policy.poll);
        if shared.stopping.load(Ordering::Acquire) {
            return;
        }
        let Ok(header) = artifact::read_identity(&policy.artifact) else {
            // Unreadable mid-write (or corrupt): keep serving what we
            // have and look again next poll.
            continue;
        };
        let version = header.version_key();
        if Some(&version) == current.as_ref() || Some(&version) == rejected.as_ref() {
            continue;
        }
        match run_deploy(shared, policy, spec, &version, backoff_base, backoff_cap) {
            DeployOutcome::Completed => {
                current = Some(version);
                rejected = None;
            }
            DeployOutcome::RolledBack => rejected = Some(version),
            DeployOutcome::Stopping => return,
        }
    }
}

enum DeployOutcome {
    Completed,
    RolledBack,
    Stopping,
}

fn run_deploy(
    shared: &Arc<GatewayShared>,
    policy: &DeployPolicy,
    spec: &ReplicaSpec,
    version: &str,
    backoff_base: Duration,
    backoff_cap: Duration,
) -> DeployOutcome {
    shared.deploys.fetch_add(1, Ordering::Relaxed);
    let cohort = shared.next_cohort.fetch_add(1, Ordering::Relaxed);
    shared.telemetry.emit(Event::DeployStarted {
        cohort,
        version: version.to_string(),
    });

    // The new cohort serves from the artifact; the spec's own args stay
    // (variants registered from weights remain available during and
    // after the deploy).
    let mut cohort_spec = spec.clone();
    cohort_spec.args.push("--artifact".to_string());
    cohort_spec
        .args
        .push(policy.artifact.to_string_lossy().into_owned());

    let mut cohort_ids = Vec::with_capacity(policy.replicas);
    {
        let mut fleet = shared.replicas.lock().unwrap();
        for _ in 0..policy.replicas.max(1) {
            let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
            fleet.push(Replica::new(id, cohort, true));
            cohort_ids.push(id);
        }
    }
    for &id in &cohort_ids {
        let h = supervisor::spawn_slot(
            shared.clone(),
            id,
            cohort_spec.clone(),
            backoff_base,
            backoff_cap,
        );
        shared.slots.lock().unwrap().push(h);
    }

    // Health gate: every cohort replica Up + healthy before any traffic
    // shifts. A cohort that dies on startup (corrupt artifact) restarts
    // against this deadline and never passes.
    let deadline = Instant::now() + policy.health_timeout;
    loop {
        if shared.stopping.load(Ordering::Acquire) {
            return DeployOutcome::Stopping;
        }
        let healthy = count_healthy(shared, &cohort_ids);
        if healthy == cohort_ids.len() {
            break;
        }
        if Instant::now() >= deadline {
            rollback(
                shared,
                cohort,
                version,
                "cohort never became healthy",
                policy.fail_on_rollback,
            );
            return DeployOutcome::RolledBack;
        }
        std::thread::sleep(WATCH_POLL);
    }

    // Shift: the router prefers the new cohort from here on. The old
    // cohort keeps serving as fallback through probation, so a rollback
    // is a pointer swap, not a cold start.
    let old_cohort = shared.active_cohort.swap(cohort, Ordering::SeqCst);

    // Probation: watch the new cohort for deaths, restarts, and
    // shed/reject regressions before committing.
    let restarts_at_shift = restart_total(shared, &cohort_ids);
    let probation_end = Instant::now() + policy.probation;
    while Instant::now() < probation_end {
        if shared.stopping.load(Ordering::Acquire) {
            return DeployOutcome::Stopping;
        }
        if let Some(reason) = regression(shared, &cohort_ids, restarts_at_shift, policy) {
            shared.active_cohort.store(old_cohort, Ordering::SeqCst);
            rollback(shared, cohort, version, &reason, policy.fail_on_rollback);
            return DeployOutcome::RolledBack;
        }
        std::thread::sleep(WATCH_POLL);
    }

    // Commit: drain every supervised replica outside the new cohort.
    {
        let mut fleet = shared.replicas.lock().unwrap();
        for r in fleet.iter_mut() {
            if r.cohort != cohort && r.supervised && r.state != ReplicaState::Retired {
                r.state = ReplicaState::Draining;
                r.healthy = false;
            }
        }
    }
    shared.telemetry.emit(Event::DeployCompleted {
        cohort,
        version: version.to_string(),
    });
    DeployOutcome::Completed
}

fn count_healthy(shared: &GatewayShared, ids: &[u64]) -> usize {
    let fleet = shared.replicas.lock().unwrap();
    fleet
        .iter()
        .filter(|r| ids.contains(&r.id) && r.healthy && r.state == ReplicaState::Up)
        .count()
}

fn restart_total(shared: &GatewayShared, ids: &[u64]) -> u64 {
    let fleet = shared.replicas.lock().unwrap();
    fleet
        .iter()
        .filter(|r| ids.contains(&r.id))
        .map(|r| r.restarts)
        .sum()
}

/// First probation violation in the cohort, if any.
fn regression(
    shared: &GatewayShared,
    ids: &[u64],
    restarts_at_shift: u64,
    policy: &DeployPolicy,
) -> Option<String> {
    let fleet = shared.replicas.lock().unwrap();
    let mut restarts = 0u64;
    for r in fleet.iter().filter(|r| ids.contains(&r.id)) {
        if r.state == ReplicaState::Dead {
            return Some(format!("replica {} died during probation", r.id));
        }
        if r.unhealthy_rate > policy.regress_threshold {
            return Some(format!(
                "replica {} shed/reject rate {:.3} over threshold {:.3}",
                r.id, r.unhealthy_rate, policy.regress_threshold
            ));
        }
        restarts += r.restarts;
    }
    if restarts > restarts_at_shift {
        return Some("replica restarted during probation".to_string());
    }
    None
}

/// Drains the failed cohort, emits `deploy_rolled_back`, and (under
/// `fail_on_rollback`) latches the fatal flag the CLI exits on.
fn rollback(shared: &GatewayShared, cohort: u64, version: &str, reason: &str, fatal: bool) {
    shared.rollbacks.fetch_add(1, Ordering::Relaxed);
    {
        let mut fleet = shared.replicas.lock().unwrap();
        for r in fleet.iter_mut() {
            if r.cohort == cohort && r.state != ReplicaState::Retired {
                r.state = ReplicaState::Draining;
                r.healthy = false;
            }
        }
    }
    shared.telemetry.emit(Event::DeployRolledBack {
        cohort,
        version: version.to_string(),
        reason: reason.to_string(),
    });
    if fatal {
        shared.rollback_fatal.store(true, Ordering::Release);
    }
}

fn sleep_interruptible(shared: &GatewayShared, total: Duration) {
    let mut left = total;
    while !left.is_zero() {
        if shared.stopping.load(Ordering::Acquire) {
            return;
        }
        let step = WATCH_POLL.min(left);
        std::thread::sleep(step);
        left -= step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn policy() -> DeployPolicy {
        DeployPolicy {
            artifact: std::path::PathBuf::from("/nonexistent.strumc"),
            replicas: 2,
            ..DeployPolicy::default()
        }
    }

    fn shared_with(replicas: Vec<Replica>) -> GatewayShared {
        use crate::telemetry::TelemetrySink;
        use std::sync::atomic::{AtomicBool, AtomicU64};
        use std::sync::Mutex;
        GatewayShared {
            replicas: Mutex::new(replicas),
            stopping: AtomicBool::new(false),
            active_cohort: AtomicU64::new(0),
            next_id: AtomicU64::new(100),
            next_cohort: AtomicU64::new(1),
            retries: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
            upstream_errors: AtomicU64::new(0),
            deploys: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            rollback_fatal: AtomicBool::new(false),
            telemetry: TelemetrySink::disabled(),
            slots: Mutex::new(Vec::new()),
            lat: Mutex::new(super::super::LatRing::new()),
            p95_us: AtomicU64::new(0),
        }
    }

    fn cohort_replica(id: u64, cohort: u64) -> Replica {
        let mut r = Replica::new(id, cohort, true);
        r.state = ReplicaState::Up;
        r.healthy = true;
        r
    }

    #[test]
    fn regression_flags_death_rate_and_restarts() {
        let mut dead = cohort_replica(1, 1);
        dead.state = ReplicaState::Dead;
        let shared = shared_with(vec![cohort_replica(0, 1), dead]);
        let p = policy();
        let reason = regression(&shared, &[0, 1], 0, &p).expect("death is a regression");
        assert!(reason.contains("died"), "{}", reason);

        let mut shedding = cohort_replica(2, 1);
        shedding.unhealthy_rate = 0.5;
        let shared = shared_with(vec![shedding]);
        let reason = regression(&shared, &[2], 0, &p).expect("rate is a regression");
        assert!(reason.contains("shed/reject"), "{}", reason);

        let mut restarted = cohort_replica(3, 1);
        restarted.restarts = 2;
        let shared = shared_with(vec![restarted]);
        let reason = regression(&shared, &[3], 1, &p).expect("restart is a regression");
        assert!(reason.contains("restarted"), "{}", reason);

        let shared = shared_with(vec![cohort_replica(4, 1)]);
        assert!(regression(&shared, &[4], 0, &p).is_none());
    }

    #[test]
    fn rollback_drains_cohort_and_latches_fatal() {
        let shared = shared_with(vec![cohort_replica(0, 0), cohort_replica(1, 1)]);
        rollback(&shared, 1, "net/fp:00/enc:1", "probe failed", true);
        assert_eq!(shared.rollbacks.load(Ordering::Relaxed), 1);
        assert!(shared.rollback_fatal.load(Ordering::Acquire));
        let fleet = shared.replicas.lock().unwrap();
        let old = fleet.iter().find(|r| r.id == 0).unwrap();
        let bad = fleet.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(old.state, ReplicaState::Up, "other cohorts untouched");
        assert_eq!(bad.state, ReplicaState::Draining);
        assert!(!bad.healthy);
    }

    #[test]
    fn rng_smoke_for_jittered_polls() {
        // Determinism guard for the watcher's only nondeterministic
        // dependency (shared with the supervisor's backoff).
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        assert_eq!(a.f64().to_bits(), b.f64().to_bits());
    }
}
